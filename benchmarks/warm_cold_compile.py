"""Warm vs cold compile benchmark for the persistent variant cache.

Compiles every PolyBench kernel (np style) twice: cold (empty cache dir,
full parse → SCoP → dependence → schedule → codegen) and warm (a fresh
``VariantCache`` over the same dir, simulating a process restart — the
dispatcher is rebuilt from stored source). Reports per-kernel and total
times plus the aggregate speedup, and verifies via telemetry that every
warm compile actually skipped codegen.

Run:  PYTHONPATH=src python benchmarks/warm_cold_compile.py
"""

import argparse
import shutil
import tempfile
import time

from benchmarks.polybench_kernels import KERNELS
from repro.core.compiler import compile_kernel
from repro.profiler import VariantCache


def bench(repeat: int = 3):
    cache_dir = tempfile.mkdtemp(prefix="automphc-bench-cache-")
    rows = []
    try:
        for name in sorted(KERNELS):
            fn = KERNELS[name]["np"]

            cold_cache = VariantCache(cache_dir)
            t0 = time.perf_counter()
            compile_kernel(fn, cache=cold_cache)
            cold_s = time.perf_counter() - t0
            assert cold_cache.stats.puts == 1, name

            warm_best = float("inf")
            skipped = 0
            for _ in range(repeat):
                warm_cache = VariantCache(cache_dir)  # fresh = restart
                t0 = time.perf_counter()
                compile_kernel(fn, cache=warm_cache)
                warm_best = min(warm_best, time.perf_counter() - t0)
                skipped += warm_cache.stats.codegen_skipped
            assert skipped == repeat, \
                f"{name}: warm compile did not skip codegen"
            rows.append((name, cold_s, warm_best))
    finally:
        shutil.rmtree(cache_dir, ignore_errors=True)

    print(f"{'kernel':<16} {'cold (ms)':>10} {'warm (ms)':>10} "
          f"{'speedup':>8}")
    print("-" * 48)
    tot_cold = tot_warm = 0.0
    for name, cold_s, warm_s in rows:
        tot_cold += cold_s
        tot_warm += warm_s
        print(f"{name:<16} {cold_s*1e3:>10.2f} {warm_s*1e3:>10.2f} "
              f"{cold_s/warm_s:>7.1f}x")
    print("-" * 48)
    print(f"{'TOTAL':<16} {tot_cold*1e3:>10.2f} {tot_warm*1e3:>10.2f} "
          f"{tot_cold/tot_warm:>7.1f}x")
    print(f"\nall {len(rows)} warm compiles skipped codegen "
          f"(verified by cache telemetry)")
    return rows


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--repeat", type=int, default=3,
                    help="warm-compile repetitions (best-of)")
    args = ap.parse_args()
    bench(repeat=args.repeat)
