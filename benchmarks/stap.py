"""STAP radar benchmark — reproduces the paper's §5.3 methodology
(Figs. 9–10) at container scale.

Pipeline per data cube (paper Fig. 7): beamforming (steer-vector ×
channels matmul) → Doppler FFT → match-filter multiply. Variants:

  python_numpy   — original sequential NumPy implementation;
  automphc       — the compiler's auto-parallelized version: the cube loop
                   is detected as pfor, tiled, and distributed as raylite
                   tasks (the Ray deployment of §4.3);
  projection     — multi-node throughput projected from the measured
                   single-worker per-cube time and the measured raylite
                   scheduling overhead, for the paper's node counts.
                   (This container has one CPU core: real multi-node
                   scaling cannot be measured, so the cluster dimension is
                   SIMULATED and labeled as such — see EXPERIMENTS.md.)

Reported metric: cubes/sec (the paper's real-time requirement is 33.3
cubes/sec at full problem size; we also report our scaled-size numbers
against a proportionally scaled requirement).
"""

from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

# scaled-down cube (paper: pulses=100, channels=1000, samples=30000 —
# 24 GB/cube complex128; here ~4 MB/cube so the suite runs on one core)
CHANNELS = 64
SAMPLES = 4096
FFT_SIZE = 8192
N_CUBES = 24

# full-size scaling factor for the real-time-requirement comparison
PAPER_CUBE_FLOPS = (100 * 1000 * 30000 * 8          # beamform
                    + 100 * 5 * 30000 * 15          # fft (nlogn-ish)
                    + 100 * 30000 * 6)
OUR_CUBE_FLOPS = (CHANNELS * SAMPLES * 8
                  + 5 * FFT_SIZE * 13 + FFT_SIZE * 6)


def stap_kernel(dataCubes: "ndarray[c128,3]", steerVector: "ndarray[c128,1]",
                matchFilter: "ndarray[c128,2]", outY: "ndarray[c128,2]",
                numCubes: int, fftSize: int):
    for c in range(0, numCubes):
        bf = np.dot(steerVector, dataCubes[c, 0:steerVector.shape[0], :])
        X = np.fft.fft(bf, fftSize)
        outY[c, 0:fftSize] = X * matchFilter[c, 0:fftSize]


def stap_ref(dataCubes, steerVector, matchFilter, outY, numCubes,
             fftSize):
    for c in range(numCubes):
        bf = steerVector @ dataCubes[c]
        X = np.fft.fft(bf, fftSize)
        outY[c] = X * matchFilter[c]


def make_data(n_cubes=N_CUBES, seed=5):
    rng = np.random.default_rng(seed)
    cubes = (rng.normal(size=(n_cubes, CHANNELS, SAMPLES))
             + 1j * rng.normal(size=(n_cubes, CHANNELS, SAMPLES)))
    sv = rng.normal(size=CHANNELS) + 1j * rng.normal(size=CHANNELS)
    mf = (rng.normal(size=(n_cubes, FFT_SIZE))
          + 1j * rng.normal(size=(n_cubes, FFT_SIZE)))
    out = np.zeros((n_cubes, FFT_SIZE), complex)
    return cubes, sv, mf, out


def run(csv: bool = True) -> List[Dict]:
    from repro.core.compiler import compile_kernel
    from repro.runtime import TaskRuntime

    cubes, sv, mf, out = make_data()
    rows = []

    # -- sequential numpy baseline ---------------------------------------
    out_ref = out.copy()
    t0 = time.perf_counter()
    stap_ref(cubes, sv, mf, out_ref, N_CUBES, FFT_SIZE)
    t_seq = time.perf_counter() - t0
    seq_tput = N_CUBES / t_seq
    rows.append({"variant": "python_numpy", "workers": 1,
                 "cubes_per_s": seq_tput, "measured": True})

    # -- AutoMPHC + raylite -------------------------------------------------
    for workers in (1, 2, 4):
        rt = TaskRuntime(workers=workers, speculation=False)
        ck = compile_kernel(stap_kernel, runtime=rt, workers=workers)
        ck.pfor_config.distribute_threshold = 0  # force distribution
        out_a = out.copy()
        ck.call_variant("np", cubes, sv, mf, out_a, N_CUBES, FFT_SIZE)
        t0 = time.perf_counter()
        out_a = out.copy()
        ck.call_variant("np", cubes, sv, mf, out_a, N_CUBES, FFT_SIZE)
        t_am = time.perf_counter() - t0
        assert np.allclose(out_a, out_ref), "automphc STAP mismatch"
        rows.append({"variant": "automphc_raylite", "workers": workers,
                     "cubes_per_s": N_CUBES / t_am, "measured": True,
                     "stats": rt.stats()})
        rt.shutdown()

    # -- projected multi-node scaling (SIMULATED — 1 physical core) -------
    t_cube = 1.0 / max(r["cubes_per_s"] for r in rows
                       if r["measured"])
    t_sched = 0.0008  # measured raylite submit+get overhead per task
    for nodes in (1, 2, 4, 8, 16, 24):
        workers = nodes * 6  # paper: 6 GPUs/node on Summit
        per_node = N_CUBES / max(1, workers)
        t_total = per_node * t_cube + t_sched * N_CUBES / workers \
            + 0.002 * nodes  # inter-node result gather
        rows.append({"variant": "projected_multinode", "workers": workers,
                     "nodes": nodes,
                     "cubes_per_s": N_CUBES / t_total,
                     "measured": False})

    if csv:
        for r in rows:
            tag = "" if r["measured"] else " (projected)"
            print(f"stap.{r['variant']},workers={r['workers']},"
                  f"{r['cubes_per_s']:.2f}_cubes_per_s{tag}", flush=True)
        scale = PAPER_CUBE_FLOPS / OUR_CUBE_FLOPS
        print(f"stap.scale_note,paper_cube/our_cube_flops={scale:.0f}x,"
              f"realtime_req_scaled={33.3 / 1:.1f}_cubes_per_s_at_full_size")
    return rows


def _phase_delta(before: Dict[str, float],
                 after: Dict[str, float]) -> Dict[str, float]:
    """Per-phase seconds attributable to one timed call (the cluster's
    phase counters are cumulative)."""
    return {k: after.get(k, 0.0) - before.get(k, 0.0) for k in after}


def _trace_diagnosis(delta: Dict[str, float], wall_s: float,
                     workers: int) -> str:
    """One-line, trace-derived explanation of where a cluster round's
    wall time went — the 'why is this row slow' statement."""
    round_s = delta.get("round_s", 0.0) or wall_s
    head = {k[:-2]: v for k, v in delta.items()
            if k in ("plan_s", "split_s", "dispatch_s", "gather_s",
                     "merge_s")}
    parts = dict(head)
    if "compute_s" in delta:
        # worker compute is summed across workers: normalize to the
        # head's wall by dividing by the worker count
        parts["compute"] = delta["compute_s"] / max(1, workers)
    name, secs = max(parts.items(), key=lambda kv: kv[1])
    pct = 100.0 * secs / round_s if round_s > 0 else 0.0
    where = "on head" if name in head else f"across {workers} workers"
    return (f"{name} {where} = {pct:.0f}% of round wall "
            f"({secs * 1e3:.1f}ms of {round_s * 1e3:.1f}ms)")


def run_distrib(smoke: bool = False, out_path: str = "BENCH_distrib.json",
                trace_path: str = "TRACE_distrib.json") -> List[Dict]:
    """Adaptive STAP (examples/stap.py) on the multi-process cluster
    runtime: sequential vs 1-process vs N-process, measured — no
    simulated dimension. Writes ``BENCH_distrib.json`` and (for the
    widest cluster run, which is traced) the Perfetto timeline
    ``TRACE_distrib.json`` — feed it to ``python -m
    repro.obs.summarize`` for the per-phase breakdown."""
    import json
    import os
    import sys

    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    from examples.stap import (ALPHA, ITERS, LOADING, make_stap_data,
                               stap_adaptive, stap_seq)
    from repro import obs
    from repro.core.compiler import compile_kernel
    from repro.distrib import ClusterRuntime

    if smoke:
        gates, k, dof, iters = 16, 16, 16, 30
    else:
        gates, k, dof, iters = 96, 64, 64, ITERS
    snap, train, steer, out = make_stap_data(gates, k, dof)

    reps = 1 if smoke else 3   # best-of-N: the container is noisy

    rows: List[Dict] = []
    out_ref = out.copy()
    t_seq = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        stap_seq(snap, train, steer, out_ref, gates, k, dof, iters,
                 ALPHA, LOADING)
        t_seq = min(t_seq, time.perf_counter() - t0)
    rows.append({"variant": "sequential_numpy", "workers": 0,
                 "wall_s": round(t_seq, 5),
                 "gates_per_s": round(gates / t_seq, 2),
                 "speedup_vs_seq": 1.0, "measured": True})

    fleet = (1, 2) if smoke else (1, 2, 4)
    for workers in fleet:
        # every cluster run is traced (compute/idle need worker spans);
        # the widest run gets a fresh recorder and exports the Perfetto
        # timeline at shutdown, so the artifact is one clean fleet run
        last = workers == fleet[-1]
        if last:
            obs.enable()
            obs.recorder().clear()
        rt = ClusterRuntime(workers=workers,
                            trace=trace_path if last else True)
        try:
            ck = compile_kernel(stap_adaptive, runtime=rt,
                                workers=workers)
            ck.pfor_config.distribute_threshold = 0
            out_a = out.copy()
            ck.call_variant("np", snap, train, steer, out_a, gates, k,
                            dof, iters, ALPHA, LOADING)  # warm workers
            t_n = float("inf")
            phases: Dict[str, float] = {}
            for _ in range(reps):
                out_a = out.copy()
                ph0 = rt.phase_breakdown()
                t0 = time.perf_counter()
                ck.call_variant("np", snap, train, steer, out_a, gates,
                                k, dof, iters, ALPHA, LOADING)
                t_rep = time.perf_counter() - t0
                if t_rep < t_n:
                    t_n = t_rep
                    phases = _phase_delta(ph0, rt.phase_breakdown())
            err = float(abs(out_a - out_ref).max())
            assert err < 1e-8, f"distributed STAP mismatch: {err:.2e}"
            st = rt.stats()
            # data-movement contract: sliceable args actually sliced,
            # and the repeated calls above hit the persistent blob cache
            # (the warm call is the one miss) without re-shipping
            # unchanged cells
            assert st["sliced_args"] > 0, st
            assert st["blob_hits"] > 0, st
            assert st["cells_skipped"] > 0, st
            rows.append({
                "variant": "cluster", "workers": workers,
                "wall_s": round(t_n, 5),
                "gates_per_s": round(gates / t_n, 2),
                "speedup_vs_seq": round(t_seq / t_n, 3),
                "max_abs_err": err, "measured": True,
                "chunks": st["chunks_dispatched"],
                "bytes_shipped": st["bytes_shipped"],
                "bytes_saved_sliced": st["bytes_saved_sliced"],
                "sliced_args": st["sliced_args"],
                "blob_hits": st["blob_hits"],
                "blob_misses": st["blob_misses"],
                "cells_shipped": st["cells_shipped"],
                "cells_skipped": st["cells_skipped"],
                "profiles_gflops": [p.gflops for p in rt.profiles()],
                # trace-plane phase breakdown for the best rep
                "ship_s": round(phases.get("ship_s", 0.0), 5),
                "gather_s": round(phases.get("gather_s", 0.0), 5),
                "compute_s": round(phases.get("compute_s", 0.0), 5),
                "idle_s": round(phases.get("idle_s", 0.0), 5),
                "phases": {k: round(v, 5) for k, v in phases.items()},
                "diagnosis": _trace_diagnosis(phases, t_n, workers),
            })
        finally:
            rt.shutdown()

    doc = {"workload": "stap_adaptive",
           "shape": {"gates": gates, "k_train": k, "dof": dof,
                     "iters": iters},
           "smoke": smoke, "rows": rows}
    with open(out_path, "w") as f:
        json.dump(doc, f, indent=2)
    for r in rows:
        extra = ""
        if r["variant"] == "cluster":
            extra = (f",shipped={r['bytes_shipped']}B"
                     f",saved_sliced={r['bytes_saved_sliced']}B"
                     f",blob_hits={r['blob_hits']}")
        print(f"stap_distrib.{r['variant']},workers={r['workers']},"
              f"{r['gates_per_s']}_gates_per_s,"
              f"x{r['speedup_vs_seq']}{extra}", flush=True)
        if r.get("diagnosis"):
            print(f"stap_distrib.diagnosis,workers={r['workers']},"
                  f"{r['diagnosis']}", flush=True)
    print(f"stap_distrib.written,{out_path}")
    print(f"stap_distrib.trace_written,{trace_path}")
    return rows


def run_hetero(smoke: bool = False, out_path: str = "BENCH_distrib.json"
               ) -> List[Dict]:
    """Heterogeneous fleet: 1 CPU worker + 1 simulated-GPU worker (jax
    CPU posing via the ``has_gpu`` profile override) running the *same*
    compiled pfor — per-worker backend selection (np vs jnp twin
    bodies), chunks sized by chosen-backend throughput, one gathered
    result. Appends measured ``cluster_hetero`` rows to
    ``BENCH_distrib.json`` (regular ``--distrib`` rows are preserved).

    The simulated GPU runs jnp *eagerly on the CPU*, so the hetero rows
    measure routing + gather overhead, not accelerator speedup — they
    are labeled ``simulated_gpu: true``."""
    import json
    import os
    import sys

    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    from examples.stap import (ALPHA, LOADING, make_stap_data,
                               stap_adaptive, stap_seq)
    from repro import obs
    from repro.core.compiler import compile_kernel
    from repro.distrib import ClusterRuntime

    if smoke:
        # large enough that per-round compute dominates dispatch/IPC
        # overhead — the smoke CI asserts compare fleet variants'
        # throughput, which is pure noise at tiny shapes
        gates, k, dof, iters = 32, 32, 32, 80
    else:
        gates, k, dof, iters = 48, 32, 32, 120
    snap, train, steer, out = make_stap_data(gates, k, dof)
    reps = 2 if smoke else 3

    out_ref = out.copy()
    t_seq = float("inf")
    for _ in range(max(1, reps)):
        t0 = time.perf_counter()
        stap_seq(snap, train, steer, out_ref, gates, k, dof, iters,
                 ALPHA, LOADING)
        t_seq = min(t_seq, time.perf_counter() - t0)

    rows: List[Dict] = []

    def fleet_row(variant: str, workers: int, sim_gpus,
                  np_only: bool = False, trace: bool = False) -> Dict:
        """One serving-loop measurement on a fresh fleet: warm call to
        ship blobs + compile the jitted twins, then best-of-reps."""
        rt = ClusterRuntime(workers=workers, sim_gpu_workers=sim_gpus,
                            np_only=np_only, trace=trace)
        try:
            comp = obs.metrics.scope("compile.stap_adaptive")
            c0 = sum(comp.snapshot().values())
            ck = compile_kernel(stap_adaptive, runtime=rt,
                                workers=workers)
            compile_s = sum(comp.snapshot().values()) - c0
            ck.pfor_config.distribute_threshold = 0
            out_a = out.copy()
            ck.call_variant("np", snap, train, steer, out_a, gates, k,
                            dof, iters, ALPHA, LOADING)   # warm
            t_h = float("inf")
            phases: Dict[str, float] = {}
            for _ in range(reps):
                out_a = out.copy()
                ph0 = rt.phase_breakdown()
                t0 = time.perf_counter()
                ck.call_variant("np", snap, train, steer, out_a, gates,
                                k, dof, iters, ALPHA, LOADING)
                t_rep = time.perf_counter() - t0
                if t_rep < t_h:
                    t_h = t_rep
                    phases = _phase_delta(ph0, rt.phase_breakdown())
            err = float(abs(out_a - out_ref).max())
            assert err < 1e-8, f"{variant} STAP mismatch: {err:.2e}"
            st = rt.stats()
            profs = rt.profiles()
            row = {
                "variant": variant, "workers": workers,
                "simulated_gpu": bool(sim_gpus),
                "np_only": np_only,
                "wall_s": round(t_h, 5),
                "gates_per_s": round(gates / t_h, 2),
                "speedup_vs_seq": round(t_seq / t_h, 3),
                "max_abs_err": err, "measured": True,
                "gpu_chunks": st["gpu_chunks"],
                "cpu_chunks": st["cpu_chunks"],
                "chunks_executed": st["chunks_executed"],
                "unit_backend": st["unit_backend"],
                "blob_hits": st["blob_hits"],
                "blob_misses": st["blob_misses"],
                "bytes_shipped": st["bytes_shipped"],
                # accelerated-path telemetry (ISSUE 9): compiled-twin
                # cache behavior, device residency, row re-ship skips,
                # and gather/compute overlap from pipelined rounds
                "jit_hits": st["jit_hits"],
                "jit_recompiles": st["jit_recompiles"],
                "jit_fallbacks": st["jit_fallbacks"],
                "resident_hits": st["resident_hits"],
                "resident_cells": st["resident_cells"],
                "rows_skipped": st["rows_skipped"],
                "bytes_saved_rows": st["bytes_saved_rows"],
                "pipeline_depth": st["pipeline_depth"],
                "overlap_s": round(phases.get("overlap_s", 0.0), 5),
                "profiles": [{"gflops": p.gflops, "has_gpu": p.has_gpu,
                              "gpu_gflops": p.gpu_gflops,
                              "gpu_kind": p.gpu_kind} for p in profs],
                "compile_s": round(compile_s, 5),
                "ship_s": round(phases.get("ship_s", 0.0), 5),
                "gather_s": round(phases.get("gather_s", 0.0), 5),
                "compute_s": round(phases.get("compute_s", 0.0), 5),
                "idle_s": round(phases.get("idle_s", 0.0), 5),
                "phases": {k_: round(v, 5) for k_, v in phases.items()},
            }
            if trace:
                row["diagnosis"] = _trace_diagnosis(phases, t_h,
                                                    workers)
            return row
        finally:
            rt.shutdown()

    # control arm: the same posed fleet with twin routing suppressed —
    # the bar cluster_hetero must clear to claim the accelerator helps
    rows.append(fleet_row("cluster_np_only", 2, (1,), np_only=True))
    # traced: the hetero row's historically terrible speedup (0.006x
    # pre-fix) needs the span timeline to say *why*, not just how fast
    hetero = fleet_row("cluster_hetero", 2, (1,), trace=True)
    rows.append(hetero)
    # scaling arm: twice the fleet (2 CPU + 2 posed GPU)
    rows.append(fleet_row("cluster_hetero_4w", 4, (1, 3)))

    # the heterogeneity contract: the same pfor *executed* np chunks on
    # the CPU worker and jnp chunks on the GPU-posing worker (confirmed
    # by worker done-messages, not dispatch intent), the persistent
    # blobs survived the serving loop, and the serving loop ran on the
    # compiled twin path (jit cache hits, no eager fallbacks)
    assert hetero["chunks_executed"].get("np", 0) > 0, hetero
    assert hetero["chunks_executed"].get("jnp", 0) > 0, hetero
    assert hetero["gpu_chunks"] > 0 and hetero["cpu_chunks"] > 0, hetero
    assert hetero["blob_hits"] > 0, hetero
    assert hetero["jit_hits"] > 0, hetero

    rows.insert(0, {"variant": "sequential_numpy_hetero_ref",
                    "workers": 0, "wall_s": round(t_seq, 5),
                    "gates_per_s": round(gates / t_seq, 2),
                    "speedup_vs_seq": 1.0, "measured": True})
    try:
        with open(out_path) as f:
            doc = json.load(f)
    except (OSError, ValueError):
        doc = {"workload": "stap_adaptive", "rows": []}
    doc["rows"] = [r for r in doc.get("rows", [])
                   if r.get("variant") not in
                   ("cluster_hetero", "cluster_np_only",
                    "cluster_hetero_4w", "sequential_numpy_hetero_ref")]
    doc["rows"].extend(rows)
    doc["hetero_shape"] = {"gates": gates, "k_train": k, "dof": dof,
                           "iters": iters, "smoke": smoke}
    with open(out_path, "w") as f:
        json.dump(doc, f, indent=2)
    for r in rows:
        extra = ""
        if r["variant"].startswith("cluster_"):
            extra = (f",gpu_chunks={r['gpu_chunks']}"
                     f",cpu_chunks={r['cpu_chunks']}"
                     f",blob_hits={r['blob_hits']}"
                     f",jit_hits={r['jit_hits']}"
                     f",rows_skipped={r['rows_skipped']}")
        print(f"stap_hetero.{r['variant']},workers={r['workers']},"
              f"{r['gates_per_s']}_gates_per_s,"
              f"x{r['speedup_vs_seq']}{extra}", flush=True)
        if r.get("diagnosis"):
            print(f"stap_hetero.diagnosis,x{r['speedup_vs_seq']},"
                  f"{r['diagnosis']}", flush=True)
    print(f"stap_hetero.written,{out_path}")
    return rows


def gemm_rowscale(A: "ndarray[f64,2]", B: "ndarray[f64,2]",
                  C: "ndarray[f64,2]", n: int, k: int, m: int):
    """Matmul-shaped pfor for the pallas routing benchmark: the scaled
    row keeps the dot statement inside a pfor body (a bare single-dot
    loop is absorbed into a top-level raised unit), and the pattern
    matcher fuses the scale into the ``__plk.matmul`` operand."""
    for i in range(0, n):
        r = 2.0 * A[i, 0:k]
        C[i, 0:m] = np.dot(r, B[0:k, 0:m])


def run_pallas(smoke: bool = False,
               out_path: str = "BENCH_distrib.json") -> List[Dict]:
    """Pallas-backend routing benchmark: a matmul-shaped pfor on a
    simulated-GPU fleet must route its chunks to the pallas backend
    (roofline-priced above np/jnp via the fused-kernel speedup) and
    produce results identical to the np-only control arm. Appends a
    measured ``cluster_pallas`` row (plus its control) to
    ``BENCH_distrib.json``.

    On CPU-only hosts the kernels run in interpret mode, so the row
    measures routing + gather overhead, not kernel speedup — labeled
    ``simulated_gpu: true`` like the hetero rows."""
    import json

    from repro.core.compiler import compile_kernel
    from repro.distrib import ClusterRuntime

    if smoke:
        n, k, m, reps = 192, 48, 40, 2
    else:
        n, k, m, reps = 384, 64, 56, 3
    rng = np.random.default_rng(42)
    A = rng.normal(size=(n, k))
    B = rng.normal(size=(k, m))

    ref = np.zeros((n, m))
    t_seq = float("inf")
    for _ in range(max(1, reps)):
        t0 = time.perf_counter()
        gemm_rowscale(A, B, ref, n, k, m)
        t_seq = min(t_seq, time.perf_counter() - t0)

    rows: List[Dict] = []

    def fleet_row(variant: str, sim_gpus, np_only: bool = False) -> Dict:
        rt = ClusterRuntime(workers=2, sim_gpu_workers=sim_gpus,
                            np_only=np_only)
        try:
            ck = compile_kernel(gemm_rowscale, runtime=rt, workers=2)
            ck.pfor_config.distribute_threshold = 0
            C = np.zeros((n, m))
            ck.call_variant("np", A, B, C, n, k, m)      # warm
            t_w = float("inf")
            for _ in range(reps):
                C = np.zeros((n, m))
                t0 = time.perf_counter()
                ck.call_variant("np", A, B, C, n, k, m)
                t_w = min(t_w, time.perf_counter() - t0)
            err = float(abs(C - ref).max())
            assert err < 1e-8, f"{variant} matmul mismatch: {err:.2e}"
            st = rt.stats()
            return {
                "variant": variant, "workers": 2,
                "simulated_gpu": bool(sim_gpus),
                "np_only": np_only,
                "wall_s": round(t_w, 5),
                "rows_per_s": round(n / t_w, 2),
                "speedup_vs_seq": round(t_seq / t_w, 3),
                "max_abs_err": err, "measured": True,
                "chunks_executed": st["chunks_executed"],
                "unit_backend": st["unit_backend"],
                "pallas_chunks": st["pallas_chunks"],
                "pallas_fallbacks": st["pallas_fallbacks"],
                "pallas_calls": st["pallas_calls"],
                "pallas_interpret_calls": st["pallas_interpret_calls"],
                "gpu_chunks": st["gpu_chunks"],
                "cpu_chunks": st["cpu_chunks"],
                "blob_hits": st["blob_hits"],
            }
        finally:
            rt.shutdown()

    rows.append(fleet_row("cluster_pallas_np_only", (0, 1),
                          np_only=True))
    pal = fleet_row("cluster_pallas", (0, 1))
    rows.append(pal)

    # the routing contract: chunks *executed* on the pallas backend
    # (confirmed by worker done-messages), no fallbacks burned, and the
    # np-only control produced the same answer (asserted above vs ref)
    assert pal["chunks_executed"].get("pallas", 0) > 0, pal
    assert pal["pallas_chunks"] > 0, pal
    assert pal["pallas_fallbacks"] == 0, pal
    assert pal["pallas_calls"] > 0, pal

    try:
        with open(out_path) as f:
            doc = json.load(f)
    except (OSError, ValueError):
        doc = {"workload": "stap_adaptive", "rows": []}
    doc["rows"] = [r for r in doc.get("rows", [])
                   if r.get("variant") not in
                   ("cluster_pallas", "cluster_pallas_np_only")]
    doc["rows"].extend(rows)
    doc["pallas_shape"] = {"n": n, "k": k, "m": m, "smoke": smoke}
    with open(out_path, "w") as f:
        json.dump(doc, f, indent=2)
    for r in rows:
        print(f"stap_pallas.{r['variant']},workers={r['workers']},"
              f"{r['rows_per_s']}_rows_per_s,"
              f"x{r['speedup_vs_seq']},"
              f"pallas_chunks={r['pallas_chunks']},"
              f"fallbacks={r['pallas_fallbacks']}", flush=True)
    print(f"stap_pallas.written,{out_path}")
    return rows


def run_chaos(smoke: bool = False,
              out_path: str = "FAULTS_distrib.json") -> Dict:
    """Fault-injection drill: the STAP serving loop over the TCP
    transport with seeded chaos — a worker SIGKILLed mid-loop, a worker
    joining mid-loop, and every head→worker message delayed — must keep
    producing atol-1e-8-correct answers with zero head-side exceptions,
    and the joined worker must visibly take a share of the chunks.

    A second drill collapses the whole fleet with respawn disabled and
    checks the runtime degrades to correct local execution. The fault
    journal + recovery counters are written to ``FAULTS_distrib.json``
    (uploaded beside ``BENCH_distrib.json`` in CI)."""
    import json
    import os
    import sys

    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    from examples.stap import (ALPHA, LOADING, make_stap_data,
                               stap_adaptive, stap_seq)
    from repro.core.compiler import compile_kernel
    from repro.distrib import ChaosPlan, ClusterRuntime

    if smoke:
        gates, k, dof, iters = 16, 16, 16, 30
        calls = 8
    else:
        gates, k, dof, iters = 48, 32, 32, 120
        calls = 12
    snap, train, steer, out = make_stap_data(gates, k, dof)
    out_ref = out.copy()
    stap_seq(snap, train, steer, out_ref, gates, k, dof, iters,
             ALPHA, LOADING)

    plan = ChaosPlan(seed=7, delay_s=0.002)   # every message delayed
    kill_at, join_at = 3, calls // 2
    joined_wid = None
    rt = ClusterRuntime(workers=2, transport="tcp", respawn=True,
                        hb_interval_s=0.2, reconnect_grace_s=1.0,
                        chaos=plan)
    try:
        ck = compile_kernel(stap_adaptive, runtime=rt, workers=2)
        ck.pfor_config.distribute_threshold = 0
        for call in range(calls):
            if call == kill_at:
                print(f"stap_chaos.kill,call={call},"
                      f"wid={rt.kill_worker()}", flush=True)
            if call == join_at:
                joined_wid = rt.add_worker()
                print(f"stap_chaos.join,call={call},wid={joined_wid}",
                      flush=True)
            out_a = out.copy()
            ck.call_variant("np", snap, train, steer, out_a, gates, k,
                            dof, iters, ALPHA, LOADING)
            err = float(abs(out_a - out_ref).max())
            assert err < 1e-8, \
                f"chaos STAP mismatch at call {call}: {err:.2e}"
        st = rt.stats()
        by_worker = dict(st["chunks_executed_by_worker"])
        assert st["worker_deaths"] >= 1, st["faults"]
        assert st["faults"].get("respawns", 0) >= 1, st["faults"]
        assert st["faults"].get("joins", 0) >= 1, st["faults"]
        assert plan.delayed > 0, plan.stats()
        assert joined_wid in by_worker and by_worker[joined_wid] > 0, \
            f"joined worker {joined_wid} got no chunks: {by_worker}"
        serving = {"calls": calls, "kill_at_call": kill_at,
                   "join_at_call": join_at, "joined_wid": joined_wid,
                   "max_abs_err": err,
                   "chunks_executed_by_worker": by_worker,
                   "worker_deaths": st["worker_deaths"],
                   "faults": st["faults"], "chaos": plan.stats()}
        events = list(rt.fault_events)
    finally:
        rt.shutdown()

    # fleet collapse with respawn off: correctness must survive via
    # in-process degradation, not hang or raise
    rt = ClusterRuntime(workers=2, respawn=False)
    try:
        ck = compile_kernel(stap_adaptive, runtime=rt, workers=2)
        ck.pfor_config.distribute_threshold = 0
        while rt.kill_worker() is not None:
            pass
        deadline = time.perf_counter() + 10.0
        while rt.workers_alive() > 0 and time.perf_counter() < deadline:
            time.sleep(0.05)
        out_a = out.copy()
        ck.call_variant("np", snap, train, steer, out_a, gates, k, dof,
                        iters, ALPHA, LOADING)
        err = float(abs(out_a - out_ref).max())
        assert err < 1e-8, f"degraded STAP mismatch: {err:.2e}"
        st = rt.stats()
        degraded = (st["faults"].get("degraded_local_runs", 0)
                    + st["faults"].get("degraded_chunks", 0))
        assert degraded >= 1, st["faults"]
        degrade = {"max_abs_err": err, "faults": st["faults"]}
        events += list(rt.fault_events)
    finally:
        rt.shutdown()

    doc = {"workload": "stap_adaptive_chaos",
           "shape": {"gates": gates, "k_train": k, "dof": dof,
                     "iters": iters}, "smoke": smoke,
           "serving_loop": serving, "degrade_drill": degrade,
           "events": events}
    with open(out_path, "w") as f:
        json.dump(doc, f, indent=2)
    print(f"stap_chaos.serving,calls={calls},max_abs_err={err:.2e},"
          f"deaths={serving['worker_deaths']},"
          f"respawns={serving['faults'].get('respawns', 0)},"
          f"delayed_msgs={serving['chaos']['delayed']}", flush=True)
    print(f"stap_chaos.rebalance,{serving['chunks_executed_by_worker']}",
          flush=True)
    print(f"stap_chaos.degrade,"
          f"local_runs={degrade['faults'].get('degraded_local_runs', 0)},"
          f"degraded_chunks={degrade['faults'].get('degraded_chunks', 0)}",
          flush=True)
    print(f"stap_chaos.written,{out_path}", flush=True)
    return doc


def main():
    import sys

    if "--hetero" in sys.argv:
        run_hetero(smoke="--smoke" in sys.argv)
    elif "--pallas" in sys.argv:
        run_pallas(smoke="--smoke" in sys.argv)
    elif "--chaos" in sys.argv:
        run_chaos(smoke="--smoke" in sys.argv)
    elif "--distrib" in sys.argv:
        run_distrib(smoke="--smoke" in sys.argv)
    else:
        run()


if __name__ == "__main__":
    main()
