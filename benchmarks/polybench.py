"""PolyBench benchmark — reproduces the paper's Table 4 + Fig. 8
methodology on this host:

  variants per kernel:
    list_default   — original Python loops over lists (paper "List Default")
    numpy          — original NumPy version (paper "NumPy" baseline)
    automphc_cpu   — our compiler's optimized-NumPy variant (paper
                     "AutoMPHC opt-CPU")
    automphc_accel — our compiler's JAX variant where feasible (paper
                     "AutoMPHC opt-GPU": the NumPy→CuPy conversion,
                     retargeted at XLA)

Reports seconds and GFLOP/s per variant. List-default timings use a
reduced problem size with measured-time extrapolation (n³ kernels at
paper-scale list sizes take minutes in pure Python; the paper's own Table
4 shows 150-350 s — we scale instead of burning the suite budget) —
marked with '*' in the output.
"""

from __future__ import annotations

import json
import platform
import time
from typing import Dict, List, Optional

import numpy as np

from .fusion_chains import CHAINS
from .polybench_kernels import KERNELS, clone_args, to_lists


def _time(fn, *args, repeat=3, min_time=0.01) -> float:
    best = float("inf")
    for _ in range(repeat):
        t0 = time.perf_counter()
        fn(*args)
        best = min(best, time.perf_counter() - t0)
        if best > 5.0:
            break
    return best


def run(n: int = 256, list_n: int = 48, kernels: List[str] = None,
        csv: bool = True) -> List[Dict]:
    from repro.core.compiler import compile_kernel

    rows = []
    names = kernels or list(KERNELS)
    for name in names:
        k = KERNELS[name]
        rng = np.random.default_rng(11)

        # -- list default (reduced size, scaled) -------------------------
        args_small, _ = k["make_args"](list_n, rng)
        la = to_lists(clone_args(args_small))
        t_list_small = _time(k["list"], *la, repeat=1)
        scale = k["flops"](n) / max(k["flops"](list_n), 1.0)
        t_list = t_list_small * scale

        # -- numpy baseline ----------------------------------------------
        args, _ = k["make_args"](n, rng)
        t_numpy = _time(k["np"], *clone_args(args))

        # -- AutoMPHC variants -------------------------------------------
        ck = compile_kernel(k["np"])
        t_cpu = _time(lambda *a: ck.call_variant("np", *a),
                      *clone_args(args))
        t_accel = None
        if "jnp" in ck.variants:
            ck.call_variant("jnp", *clone_args(args))  # compile warmup
            t_accel = _time(lambda *a: ck.call_variant("jnp", *a),
                            *clone_args(args))

        gf = k["flops"](n) / 1e9
        row = {
            "kernel": name,
            "list_default_s*": t_list,
            "numpy_s": t_numpy,
            "automphc_cpu_s": t_cpu,
            "automphc_accel_s": t_accel,
            "numpy_gflops": gf / t_numpy if t_numpy else None,
            "automphc_cpu_gflops": gf / t_cpu if t_cpu else None,
            "automphc_accel_gflops": (gf / t_accel
                                      if t_accel else None),
            "speedup_cpu_vs_numpy": t_numpy / t_cpu if t_cpu else None,
            "speedup_cpu_vs_list": t_list / t_cpu if t_cpu else None,
        }
        rows.append(row)
        if csv:
            acc = f"{t_accel:.4g}" if t_accel else "n/a"
            print(f"polybench.{name},{t_list:.4g}*,{t_numpy:.4g},"
                  f"{t_cpu:.4g},{acc},"
                  f"x{row['speedup_cpu_vs_numpy']:.2f}_vs_numpy",
                  flush=True)
    return rows


# ---------------------------------------------------------------------------
# Fusion benchmark (BENCH_fusion.json): fused vs unfused, same backend
# ---------------------------------------------------------------------------

# (kernel, style, backend, n): producer–consumer chains isolate the fusion
# patterns at the backend where each pattern pays — contraction of local
# intermediates on the in-place np backend, statement folding on the
# functional jnp backend (where every unfused statement costs a full
# `.at[].set` materialization). PolyBench list styles ride on jnp, where
# the fused form is exactly the hand-written NumPy statement.
FUSION_BENCH = [
    ("smooth", "np", "np", 1200),
    ("scaled_sq", "np", "np", 1200),
    ("doitgen_local", "np", "np", 256),
    ("elem_chain", "np", "jnp", 1000),
    ("vec_chain", "np", "jnp", 1000),
    ("gemm", "list", "jnp", 500),
    ("2mm", "list", "jnp", 400),
    ("3mm", "list", "jnp", 400),
    ("atax", "list", "jnp", 1500),
    ("bicg", "list", "jnp", 1500),
    ("gesummv", "list", "jnp", 1000),
    ("2mm", "list", "np", 400),
    ("atax", "list", "np", 1500),
]


def _registry(name):
    return CHAINS[name] if name in CHAINS else KERNELS[name]


def run_fusion(n: Optional[int] = None, check_n: int = 16, repeat: int = 5,
               out_path: Optional[str] = "BENCH_fusion.json",
               kernels: Optional[List[str]] = None,
               csv: bool = True) -> List[Dict]:
    """Time each kernel with the fusion pass on vs off (same backend,
    identical pipeline otherwise) and write BENCH_fusion.json.

    Numerical agreement between the two variants and the trusted
    reference is asserted at ``check_n`` before anything is timed.
    ``n`` overrides every row's problem size (smoke mode)."""
    from repro.core.compiler import compile_kernel

    rows: List[Dict] = []
    for name, style, backend, row_n in FUSION_BENCH:
        if kernels and name not in kernels:
            continue
        bench_n = n or row_n
        k = _registry(name)
        fn = k[style]
        ck_fused = compile_kernel(fn, fuse=True)
        ck_plain = compile_kernel(fn, fuse=False)
        if backend not in ck_fused.variants or \
                backend not in ck_plain.variants:
            continue  # e.g. jax unavailable

        # correctness gate (small shapes, fresh inputs per variant)
        rng = np.random.default_rng(7)
        args, meta = k["make_args"](check_n, rng)
        ref_args = clone_args(args)
        k["ref"](*ref_args)
        for ck in (ck_fused, ck_plain):
            test_args = clone_args(args)
            ck.call_variant(backend, *test_args)
            for oi in meta["out"]:
                np.testing.assert_allclose(
                    np.asarray(test_args[oi], dtype=float),
                    np.asarray(ref_args[oi], dtype=float),
                    atol=1e-8, rtol=1e-8)

        # timing (ndarray args either way: list-style variants asarray
        # their inputs, a no-op here, so both variants pay the same cost)
        rng = np.random.default_rng(11)
        args, _ = k["make_args"](bench_n, rng)
        a_plain, a_fused = clone_args(args), clone_args(args)
        ck_plain.call_variant(backend, *a_plain)   # warmup / jax setup
        ck_fused.call_variant(backend, *a_fused)
        t_plain = _time(lambda *a: ck_plain.call_variant(backend, *a),
                        *a_plain, repeat=repeat)
        t_fused = _time(lambda *a: ck_fused.call_variant(backend, *a),
                        *a_fused, repeat=repeat)
        gen = ck_fused.variants[backend].generated
        meta_f = gen.meta if gen is not None else None
        row = {
            "kernel": name,
            "style": style,
            "backend": backend,
            "n": bench_n,
            "unfused_s": t_plain,
            "fused_s": t_fused,
            "speedup": t_plain / t_fused if t_fused else None,
            "fused_units": getattr(meta_f, "fused_units", 0),
            "contracted_arrays": list(
                getattr(meta_f, "contracted_arrays", [])),
        }
        rows.append(row)
        if csv:
            print(f"fusion.{name}.{backend},{t_plain:.4g},{t_fused:.4g},"
                  f"x{row['speedup']:.2f},fused={row['fused_units']},"
                  f"contracted={len(row['contracted_arrays'])}",
                  flush=True)
    if out_path:
        doc = {
            "benchmark": "fusion",
            "repeat": repeat,
            "host": platform.node(),
            "improved": sum(1 for r in rows if r["speedup"]
                            and r["speedup"] > 1.05),
            "rows": rows,
        }
        with open(out_path, "w") as f:
            json.dump(doc, f, indent=2)
    return rows


def main():
    import argparse

    ap = argparse.ArgumentParser()
    ap.add_argument("--fusion", action="store_true",
                    help="run the fused-vs-unfused comparison only")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny shapes / single repeat (CI)")
    ap.add_argument("-n", type=int, default=None)
    ap.add_argument("--out", default="BENCH_fusion.json")
    opts = ap.parse_args()
    if opts.fusion:
        n = opts.n or (48 if opts.smoke else None)
        run_fusion(n=n, repeat=1 if opts.smoke else 5, out_path=opts.out)
        return
    print("kernel,list_default_s*,numpy_s,automphc_cpu_s,"
          "automphc_accel_s,speedup")
    run(n=opts.n or 256)


if __name__ == "__main__":
    main()
