"""PolyBench benchmark — reproduces the paper's Table 4 + Fig. 8
methodology on this host:

  variants per kernel:
    list_default   — original Python loops over lists (paper "List Default")
    numpy          — original NumPy version (paper "NumPy" baseline)
    automphc_cpu   — our compiler's optimized-NumPy variant (paper
                     "AutoMPHC opt-CPU")
    automphc_accel — our compiler's JAX variant where feasible (paper
                     "AutoMPHC opt-GPU": the NumPy→CuPy conversion,
                     retargeted at XLA)

Reports seconds and GFLOP/s per variant. List-default timings use a
reduced problem size with measured-time extrapolation (n³ kernels at
paper-scale list sizes take minutes in pure Python; the paper's own Table
4 shows 150-350 s — we scale instead of burning the suite budget) —
marked with '*' in the output.
"""

from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from .polybench_kernels import KERNELS, clone_args, to_lists


def _time(fn, *args, repeat=3, min_time=0.01) -> float:
    best = float("inf")
    for _ in range(repeat):
        t0 = time.perf_counter()
        fn(*args)
        best = min(best, time.perf_counter() - t0)
        if best > 5.0:
            break
    return best


def run(n: int = 256, list_n: int = 48, kernels: List[str] = None,
        csv: bool = True) -> List[Dict]:
    from repro.core.compiler import compile_kernel

    rows = []
    names = kernels or list(KERNELS)
    for name in names:
        k = KERNELS[name]
        rng = np.random.default_rng(11)

        # -- list default (reduced size, scaled) -------------------------
        args_small, _ = k["make_args"](list_n, rng)
        la = to_lists(clone_args(args_small))
        t_list_small = _time(k["list"], *la, repeat=1)
        scale = k["flops"](n) / max(k["flops"](list_n), 1.0)
        t_list = t_list_small * scale

        # -- numpy baseline ----------------------------------------------
        args, _ = k["make_args"](n, rng)
        t_numpy = _time(k["np"], *clone_args(args))

        # -- AutoMPHC variants -------------------------------------------
        ck = compile_kernel(k["np"])
        t_cpu = _time(lambda *a: ck.call_variant("np", *a),
                      *clone_args(args))
        t_accel = None
        if "jnp" in ck.variants:
            ck.call_variant("jnp", *clone_args(args))  # compile warmup
            t_accel = _time(lambda *a: ck.call_variant("jnp", *a),
                            *clone_args(args))

        gf = k["flops"](n) / 1e9
        row = {
            "kernel": name,
            "list_default_s*": t_list,
            "numpy_s": t_numpy,
            "automphc_cpu_s": t_cpu,
            "automphc_accel_s": t_accel,
            "numpy_gflops": gf / t_numpy if t_numpy else None,
            "automphc_cpu_gflops": gf / t_cpu if t_cpu else None,
            "automphc_accel_gflops": (gf / t_accel
                                      if t_accel else None),
            "speedup_cpu_vs_numpy": t_numpy / t_cpu if t_cpu else None,
            "speedup_cpu_vs_list": t_list / t_cpu if t_cpu else None,
        }
        rows.append(row)
        if csv:
            acc = f"{t_accel:.4g}" if t_accel else "n/a"
            print(f"polybench.{name},{t_list:.4g}*,{t_numpy:.4g},"
                  f"{t_cpu:.4g},{acc},"
                  f"x{row['speedup_cpu_vs_numpy']:.2f}_vs_numpy",
                  flush=True)
    return rows


def main():
    print("kernel,list_default_s*,numpy_s,automphc_cpu_s,"
          "automphc_accel_s,speedup")
    run()


if __name__ == "__main__":
    main()
