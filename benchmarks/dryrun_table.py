"""Roofline table from the dry-run sweep artifacts (EXPERIMENTS.md
§Roofline source). Reads artifacts/dryrun/results.json."""

from __future__ import annotations

import json
import os
from typing import Dict, List, Optional

ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun",
                   "results.json")


def load() -> Dict:
    if not os.path.exists(ART):
        return {}
    with open(ART) as f:
        return json.load(f)


def rows(mesh: str = "single") -> List[Dict]:
    out = []
    for key, r in sorted(load().items()):
        if r.get("mesh") != mesh:
            continue
        row = {"arch": r["arch"], "shape": r["shape"],
               "status": r["status"]}
        if r["status"] == "ok":
            rt = r["roofline"]
            row.update({
                "strategy": r.get("strategy"),
                "compute_s": rt["compute_s"],
                "memory_s": rt["memory_s"],
                "collective_s": rt["collective_s"],
                "dominant": rt["dominant"],
                "model_flops": rt["model_flops"],
                "useful_ratio": rt["useful_flops_ratio"],
                "compile_s": r.get("compile_s"),
            })
        elif r["status"] == "skipped":
            row["reason"] = r.get("reason", "")[:60]
        else:
            row["error"] = r.get("error", "")[:60]
        out.append(row)
    return out


def main(csv: bool = True):
    for mesh in ("single", "multi"):
        got = rows(mesh)
        if not got:
            continue
        print(f"# dryrun roofline table — {mesh}-pod mesh")
        for r in got:
            if r["status"] == "ok":
                frac = (min(1.0, r["compute_s"] /
                            max(r["compute_s"], r["memory_s"],
                                r["collective_s"]))
                        if r["compute_s"] else 0.0)
                print(f"dryrun.{r['arch']}.{r['shape']}.{mesh},"
                      f"{r['strategy']},"
                      f"compute={r['compute_s']:.4g}s,"
                      f"memory={r['memory_s']:.4g}s,"
                      f"collective={r['collective_s']:.4g}s,"
                      f"dominant={r['dominant']},"
                      f"roofline_frac={frac:.3f}")
            else:
                print(f"dryrun.{r['arch']}.{r['shape']}.{mesh},"
                      f"{r['status']},"
                      f"{r.get('reason', r.get('error', ''))}")


if __name__ == "__main__":
    main()
