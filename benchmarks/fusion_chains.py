"""Producer–consumer chain kernels for the fusion benchmark.

PolyBench's classics are dominated by their BLAS-3 contractions, so the
fusion pass's memory-traffic savings barely move their clocks. These
kernels isolate the patterns fusion targets — read-modify-write
elementwise chains, kernel-local intermediates, and per-iteration temps —
at sizes where the arrays exceed the last-level cache and every eliminated
store/load pass is wall-clock visible. Same registry schema as
``polybench_kernels.KERNELS`` (minus the list style).
"""

from __future__ import annotations

import numpy as np

# ---------------------------------------------------------------------------
# elem_chain: out = (A*B + A + B) * 0.5, written as an RMW chain
# ---------------------------------------------------------------------------

def elem_chain_np(A: "ndarray[f64,2]", B: "ndarray[f64,2]",
                  out: "ndarray[f64,2]", N: int):
    out[0:N, 0:N] = A[0:N, 0:N] * B[0:N, 0:N]
    out[0:N, 0:N] += A[0:N, 0:N] + B[0:N, 0:N]
    out[0:N, 0:N] *= 0.5


def elem_chain_ref(A, B, out, N):
    out[:] = (A * B + A + B) * 0.5


# ---------------------------------------------------------------------------
# smooth: local intermediate contracted away + trailing RMW scale
# ---------------------------------------------------------------------------

def smooth_np(A: "ndarray[f64,2]", B: "ndarray[f64,2]",
              out: "ndarray[f64,2]", N: int):
    T = A[0:N, 0:N] + B[0:N, 0:N]
    out[0:N, 0:N] = T[0:N, 0:N] * A[0:N, 0:N]
    out[0:N, 0:N] *= 0.25


def smooth_ref(A, B, out, N):
    out[:] = (A + B) * A * 0.25


# ---------------------------------------------------------------------------
# scaled_sq: two chained local intermediates, both contracted
# ---------------------------------------------------------------------------

def scaled_sq_np(A: "ndarray[f64,2]", out: "ndarray[f64,2]", N: int):
    T = A[0:N, 0:N] * A[0:N, 0:N]
    U = T[0:N, 0:N] * 0.5
    out[0:N, 0:N] = U[0:N, 0:N] + A[0:N, 0:N]


def scaled_sq_ref(A, out, N):
    out[:] = A * A * 0.5 + A


# ---------------------------------------------------------------------------
# vec_chain: long-vector RMW chain (BLAS-1 regime, pure memory bound)
# ---------------------------------------------------------------------------

def vec_chain_np(x: "ndarray[f64,1]", y: "ndarray[f64,1]",
                 out: "ndarray[f64,1]", N: int):
    out[0:N] = x[0:N] * y[0:N]
    out[0:N] += x[0:N]
    out[0:N] += y[0:N]
    out[0:N] *= 0.125


def vec_chain_ref(x, y, out, N):
    out[:] = (x * y + x + y) * 0.125


# ---------------------------------------------------------------------------
# doitgen_local: per-iteration local temp contracted into the update
# ---------------------------------------------------------------------------

def doitgen_local_np(A: "ndarray[f64,3]", C4: "ndarray[f64,2]",
                     NR: int, NQ: int, NP: int):
    for r in range(0, NR):
        for q in range(0, NQ):
            w = np.dot(A[r, q, 0:NP], C4[0:NP, 0:NP])
            A[r, q, 0:NP] = w[0:NP]


def doitgen_local_ref(A, C4, NR, NQ, NP):
    for r in range(NR):
        for q in range(NQ):
            A[r, q, :] = A[r, q, :] @ C4


# ---------------------------------------------------------------------------
# Registry (schema-compatible with polybench_kernels.KERNELS)
# ---------------------------------------------------------------------------

def _mk(shape, rng):
    return rng.normal(size=shape)


def _elem_chain_args(n, rng):
    return [_mk((n, n), rng), _mk((n, n), rng), np.zeros((n, n)), n], \
        {"out": [2]}


def _smooth_args(n, rng):
    return [_mk((n, n), rng), _mk((n, n), rng), np.zeros((n, n)), n], \
        {"out": [2]}


def _scaled_sq_args(n, rng):
    return [_mk((n, n), rng), np.zeros((n, n)), n], {"out": [1]}


def _vec_chain_args(n, rng):
    m = n * n  # same byte volume as the 2-D chains
    return [_mk((m,), rng), _mk((m,), rng), np.zeros(m), m], {"out": [2]}


def _doitgen_local_args(n, rng):
    nr, nq, npp = max(2, n // 8), max(2, n // 8), n
    return [_mk((nr, nq, npp), rng), _mk((npp, npp), rng), nr, nq, npp], \
        {"out": [0]}


CHAINS = {
    "elem_chain": {
        "np": elem_chain_np, "ref": elem_chain_ref,
        "make_args": _elem_chain_args, "flops": lambda n: 4.0 * n ** 2,
    },
    "smooth": {
        "np": smooth_np, "ref": smooth_ref,
        "make_args": _smooth_args, "flops": lambda n: 3.0 * n ** 2,
    },
    "scaled_sq": {
        "np": scaled_sq_np, "ref": scaled_sq_ref,
        "make_args": _scaled_sq_args, "flops": lambda n: 3.0 * n ** 2,
    },
    "vec_chain": {
        "np": vec_chain_np, "ref": vec_chain_ref,
        "make_args": _vec_chain_args, "flops": lambda n: 4.0 * n ** 2,
    },
    "doitgen_local": {
        "np": doitgen_local_np, "ref": doitgen_local_ref,
        "make_args": _doitgen_local_args,
        "flops": lambda n: 2.0 * (n // 8) ** 2 * n ** 2,
    },
}
