"""Benchmark driver — one function per paper table/figure.

  polybench   → paper Table 4 + Fig. 8 (15 kernels, 4 variants)
  fusion      → fused vs unfused timings per kernel/backend
                (machine-readable BENCH_fusion.json)
  stap        → paper Figs. 9-10 (throughput + scaling; cluster dimension
                simulated, labeled)
  kernels     → Pallas kernel parity vs jnp oracles (interpret mode)
  dryrun      → roofline table per (arch × shape × mesh) from artifacts

Prints ``name,value,derived`` CSV lines.
"""

from __future__ import annotations

import time


def _section(title):
    print(f"\n### {title}", flush=True)


def bench_kernels():
    import jax.numpy as jnp
    import numpy as np

    from repro.kernels.flash_attention.ops import flash_attention
    from repro.kernels.flash_attention.ref import attention_ref
    from repro.kernels.matmul.ops import matmul
    from repro.kernels.matmul.ref import matmul_ref
    from repro.kernels.mamba_scan.ops import mamba_scan
    from repro.kernels.mamba_scan.ref import mamba_scan_ref

    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(256, 512)), jnp.float32)
    y = jnp.asarray(rng.normal(size=(512, 256)), jnp.float32)
    got = matmul(x, y, force_pallas=True, interpret=True, bm=128, bn=128,
                 bk=256)
    err = float(jnp.abs(got - matmul_ref(x, y)).max())
    print(f"kernels.matmul_interpret,parity_maxerr={err:.2e}")

    q = jnp.asarray(rng.normal(size=(1, 128, 4, 64)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, 128, 2, 64)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, 128, 2, 64)), jnp.float32)
    got = flash_attention(q, k, v, force_pallas=True, interpret=True,
                          bq=64, bk=64)
    err = float(jnp.abs(got - attention_ref(q, k, v)).max())
    print(f"kernels.flash_attention_interpret,parity_maxerr={err:.2e}")

    B, L, I, N = 1, 64, 16, 4
    xs = jnp.asarray(rng.normal(size=(B, L, I)), jnp.float32)
    dt = jnp.asarray(np.abs(rng.normal(size=(B, L, I))) * 0.1,
                     jnp.float32)
    Bm = jnp.asarray(rng.normal(size=(B, L, N)), jnp.float32)
    Cm = jnp.asarray(rng.normal(size=(B, L, N)), jnp.float32)
    a = jnp.asarray(np.log(np.abs(rng.normal(size=(I, N))) + 0.5),
                    jnp.float32)
    d = jnp.asarray(rng.normal(size=(I,)), jnp.float32)
    got = mamba_scan(xs, dt, Bm, Cm, a, d, chunk=16, force_pallas=True,
                     interpret=True)
    err = float(jnp.abs(got - mamba_scan_ref(xs, dt, Bm, Cm, a, d)).max())
    print(f"kernels.mamba_scan_interpret,parity_maxerr={err:.2e}")


def main() -> None:
    t0 = time.perf_counter()
    _section("polybench (paper Table 4 / Fig 8)")
    from . import polybench

    polybench.run(n=192, list_n=32)

    _section("fusion: fused vs unfused (BENCH_fusion.json)")
    polybench.run_fusion()

    _section("stap (paper Figs 9-10)")
    from . import stap

    stap.run()

    _section("stap distributed: cluster runtime (BENCH_distrib.json)")
    stap.run_distrib()

    _section("pallas kernels (interpret-mode parity)")
    bench_kernels()

    _section("dryrun roofline table (EXPERIMENTS.md §Roofline)")
    from . import dryrun_table

    dryrun_table.main()

    print(f"\nbenchmarks.total_s,{time.perf_counter() - t0:.1f}")


if __name__ == "__main__":
    main()
