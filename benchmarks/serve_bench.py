"""Serving-plane benchmark: open-loop Poisson load against the
multi-tenant cluster engines, written to ``BENCH_serve.json``.

Two flagships, per ROADMAP item #3 ("millions of users", measured):

* **stap** — the adaptive STAP kernel (examples/stap.py) compiled by
  the repo's own pipeline and served through
  :class:`repro.serve.ClusterServeEngine` on a real worker fleet.
  The same Poisson schedule runs twice: ``naive`` (coalescing window
  0 — every request is its own pfor round) and ``coalesced``
  (same-signature requests merge into one stacked pfor). The win the
  row pair measures is round amortization: N requests of k gates
  become one N·k-gate pfor — bigger chunks, one ship/dispatch/gather.

* **lm_decode** — token-by-token LM inference:
  :class:`repro.serve.ClusterLMEngine` (params + KV caches resident in
  a worker's object store) versus the single-process seed
  ``ServeEngine``, same prompts. The cluster row must match the
  single-process token streams **exactly** (``exact_match``) and
  reports TTFT / per-output-token / end-to-end percentiles under the
  open-loop load.

    PYTHONPATH=src python -m benchmarks.serve_bench [--smoke] \
        [--stap-only | --lm-only]
"""

from __future__ import annotations

import json
import time
from typing import Dict, List

import numpy as np

OUT_PATH = "BENCH_serve.json"


# ---------------------------------------------------------------------------
# STAP kernel serving: coalesced vs naive under the same Poisson load
# ---------------------------------------------------------------------------

def run_stap(smoke: bool = False) -> List[Dict]:
    import os
    import sys

    sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
    from examples.stap import ALPHA, LOADING, stap_adaptive, stap_seq
    from repro.core.compiler import compile_kernel
    from repro.distrib import ClusterRuntime
    from repro.serve import (AdmissionController, BatchSpec,
                             ClusterServeEngine, TenantQuota, open_loop)

    if smoke:
        gates, k, dof, iters = 8, 12, 12, 40
        requests, workers = 48, 2
    else:
        gates, k, dof, iters = 16, 24, 24, 60
        requests, workers = 96, 2

    rng = np.random.default_rng(7)
    steer = rng.normal(size=dof)
    trains = [rng.normal(size=(gates, k, dof)) for _ in range(requests)]
    snaps = [rng.normal(size=(gates, dof)) for _ in range(requests)]
    expected = []
    for tr, sn in zip(trains, snaps):
        o = np.zeros(gates)
        stap_seq(sn, tr, steer, o, gates, k, dof, iters, ALPHA, LOADING)
        expected.append(o)

    rows: List[Dict] = []
    rt = ClusterRuntime(workers=workers)
    try:
        ck = compile_kernel(stap_adaptive, runtime=rt)
        ck.pfor_config.distribute_threshold = 0   # force the cluster
        batch = BatchSpec(stacked=("snap", "train"), count="numGates",
                          out=("outY",),
                          shared=("steer", "K", "dof", "iters",
                                  "alpha", "loading"))
        # warm calls ship + persist the body blob on the workers, and
        # measure the per-request service time; the open-loop rate is
        # pinned at 3x naive capacity so per-request dispatch is
        # genuinely saturated (an open-loop driver below capacity never
        # queues, and an empty queue has nothing to coalesce). The
        # schedule is cumulative, so even sub-millisecond gaps are
        # honored on average.
        warm = np.zeros(gates)
        t_call = float("inf")
        for _ in range(3):
            t0 = time.perf_counter()
            ck.call_variant("np", snaps[0], trains[0], steer, warm,
                            gates, k, dof, iters, ALPHA, LOADING)
            t_call = min(t_call, time.perf_counter() - t0)
        rate_rps = min(1500.0, max(30.0, 3.0 / t_call))

        for mode, window in (("naive", 0.0), ("coalesced", 0.01)):
            eng = ClusterServeEngine(
                rt, coalesce_window_s=window, max_batch=16,
                admission=AdmissionController(
                    default=TenantQuota(max_inflight=256),
                    max_queue=1024))
            eng.register("stap", ck, batch=batch)
            outs = [np.zeros(gates) for _ in range(requests)]

            def submit(i, tenant):
                return eng.submit(tenant, "stap",
                                  (snaps[i], trains[i], steer, outs[i],
                                   gates, k, dof, iters, ALPHA,
                                   LOADING))

            res = open_loop(submit, requests=requests,
                            rate_rps=rate_rps, seed=11,
                            tenants=("tenant-a", "tenant-b"))
            eng.close()
            err = max(float(np.abs(o - e).max())
                      for o, e in zip(outs, expected))
            tel = eng.telemetry()
            row = {"flagship": "stap", "mode": mode,
                   "workers": workers, "gates_per_request": gates,
                   "coalesce_window_s": window, "measured": True,
                   "service_ms": round(t_call * 1e3, 3),
                   "max_abs_err": err,
                   "coalesced_batches": tel["coalesced_batches"],
                   "coalesced_requests": tel["coalesced_requests"],
                   "fallthrough_dispatches":
                       tel["fallthrough_dispatches"],
                   **res.as_row()}
            rows.append(row)
            print(f"[serve_bench] stap/{mode}: "
                  f"{row['throughput_rps']:.1f} req/s, "
                  f"e2e p95 {row['e2e_ms'].get('p95')}ms, "
                  f"batches={row['coalesced_batches']}, "
                  f"max|err|={err:.1e}")
            assert err < 1e-8, f"stap serving mismatch ({mode}): {err}"
    finally:
        rt.shutdown()
    return rows


# ---------------------------------------------------------------------------
# LM decode flagship: cluster engine vs single-process, exact match
# ---------------------------------------------------------------------------

def run_lm(smoke: bool = False) -> List[Dict]:
    import jax

    from repro.configs import get_smoke_config
    from repro.distrib import ClusterRuntime
    from repro.models import transformer as T
    from repro.serve import ClusterLMEngine, open_loop
    from repro.serve.engine import Request, ServeEngine

    requests = 6 if smoke else 16
    max_tokens = 8 if smoke else 16
    n_slots, max_seq, workers = 2, 64, 1
    rate_rps = 20.0

    cfg = get_smoke_config("stablelm_3b")
    params, _ = T.init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(3)
    prompts = [rng.integers(0, cfg.vocab, int(rng.integers(4, 12)))
               for _ in range(requests)]

    # single-process reference (and its own telemetry row)
    ref_eng = ServeEngine(params, cfg, n_slots=n_slots, max_seq=max_seq)
    t0 = time.perf_counter()
    for i, p in enumerate(prompts):
        ref_eng.add_request(Request(f"req-{i}", p,
                                    max_tokens=max_tokens))
    ref_done = ref_eng.run_until_done()
    ref_wall = time.perf_counter() - t0
    ref = {r.request_id: list(r.generated) for r in ref_done}
    ref_tel = ref_eng.telemetry()
    rows: List[Dict] = [{
        "flagship": "lm_decode", "mode": "single_process",
        "workers": 0, "requests": requests, "measured": True,
        "tokens_generated": ref_tel["tokens_generated"],
        "throughput_tok_s": round(
            ref_tel["tokens_generated"] / ref_wall, 2),
        "ttft_ms": ref_tel["latency"]["ttft_ms"],
        "tpot_ms": ref_tel["latency"]["tpot_ms"],
        "e2e_ms": ref_tel["latency"]["e2e_ms"],
    }]

    rt = ClusterRuntime(workers=workers, start_method="spawn")
    try:
        eng = ClusterLMEngine(rt, params, cfg, n_slots=n_slots,
                              max_seq=max_seq, trim_every=16)
        # warm the worker's jit cache off the measured clock (the
        # warmup slot decodes alongside early requests; slots are
        # row-independent, so measured token streams are unaffected)
        eng.submit("warmup", prompts[0], max_tokens=2,
                   request_id="warm-0").wait(300.0)

        got: Dict[str, List[int]] = {}

        def submit(i, tenant):
            return eng.submit(tenant, prompts[i],
                              max_tokens=max_tokens,
                              request_id=f"req-{i}")

        res = open_loop(submit, requests=requests, rate_rps=rate_rps,
                        seed=5, tenants=("tenant-a", "tenant-b"),
                        wait_timeout_s=300.0)
        for r in eng.finished:
            if r.request_id.startswith("req-"):
                got[r.request_id] = list(r.generated)
        exact = got == ref
        tel = eng.telemetry()
        eng.close()
        row = {"flagship": "lm_decode", "mode": "cluster",
               "workers": workers, "requests": requests,
               "measured": True, "exact_match": exact,
               "tokens_generated": tel["tokens_generated"],
               "throughput_tok_s": round(
                   tel["tokens_generated"] / max(res.duration_s, 1e-9),
                   2),
               "anchors": tel["anchors"],
               "ttft_ms": tel["latency"]["ttft_ms"],
               "tpot_ms": tel["latency"]["tpot_ms"],
               "per_tenant_tokens": tel["tenants"]["tokens"],
               **res.as_row()}
        rows.append(row)
        print(f"[serve_bench] lm/cluster: exact_match={exact}, "
              f"{row['throughput_rps']:.1f} req/s, "
              f"ttft p50 {row['ttft_ms']['p50']:.1f}ms, "
              f"tpot p50 {row['tpot_ms']['p50']:.1f}ms")
        assert exact, ("cluster LM decode diverged from the "
                       "single-process engine")
    finally:
        rt.shutdown()
    return rows


def main() -> None:
    import sys

    smoke = "--smoke" in sys.argv
    rows: List[Dict] = []
    if "--lm-only" not in sys.argv:
        rows += run_stap(smoke=smoke)
    if "--stap-only" not in sys.argv:
        rows += run_lm(smoke=smoke)

    doc: Dict = {"benchmark": "serve", "smoke": smoke, "rows": rows}
    stap = {r["mode"]: r for r in rows if r["flagship"] == "stap"}
    if {"naive", "coalesced"} <= stap.keys():
        n, c = stap["naive"], stap["coalesced"]
        doc["coalesced_vs_naive"] = {
            "throughput_ratio": round(
                c["throughput_rps"] / max(n["throughput_rps"], 1e-9),
                3),
            "p95_ratio": round(
                c["e2e_ms"]["p95"] / max(n["e2e_ms"]["p95"], 1e-9), 3),
        }
        print(f"[serve_bench] coalesced vs naive: "
              f"{doc['coalesced_vs_naive']}")
    with open(OUT_PATH, "w") as f:
        json.dump(doc, f, indent=2)
    print(f"[serve_bench] wrote {OUT_PATH} ({len(rows)} rows)")


if __name__ == "__main__":
    main()
