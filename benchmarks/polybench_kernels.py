"""PolyBench-Python corpus: the paper's 15 single-node kernels (Table 4 /
Fig. 8), each in two styles exactly as the paper evaluates them:

  * ``<name>_list``  — explicit Python loops over list-of-lists (the
    paper's "List Default" version);
  * ``<name>_np``    — NumPy-operator style (the paper's "NumPy" version,
    and the baseline for Fig. 8).

Both styles go through the AutoMPHC compiler unchanged; the SCoP
unification means they raise to the same optimized code. Each entry also
carries ``ref`` — a trusted plain-numpy executor used as the ground-truth
oracle by the tests — plus problem-size presets and FLOP estimates.

All kernels mutate their output arguments in place (PolyBench convention).
"""

from __future__ import annotations

import numpy as np

# ---------------------------------------------------------------------------
# gemm: C = alpha*A@B + beta*C
# ---------------------------------------------------------------------------

def gemm_list(alpha: float, beta: float, C: "list[f64,2]",
              A: "list[f64,2]", B: "list[f64,2]",
              NI: int, NJ: int, NK: int):
    for i in range(0, NI):
        for j in range(0, NJ):
            C[i][j] *= beta
        for k in range(0, NK):
            for j in range(0, NJ):
                C[i][j] += alpha * A[i][k] * B[k][j]


def gemm_np(alpha: float, beta: float, C: "ndarray[f64,2]",
            A: "ndarray[f64,2]", B: "ndarray[f64,2]",
            NI: int, NJ: int, NK: int):
    C[0:NI, 0:NJ] = beta * C[0:NI, 0:NJ] + alpha * np.dot(
        A[0:NI, 0:NK], B[0:NK, 0:NJ])


def gemm_ref(alpha, beta, C, A, B, NI, NJ, NK):
    C *= beta
    C += alpha * (A @ B)


# ---------------------------------------------------------------------------
# 2mm: D = alpha*A@B@C + beta*D
# ---------------------------------------------------------------------------

def k2mm_list(alpha: float, beta: float, tmp: "list[f64,2]",
              A: "list[f64,2]", B: "list[f64,2]", C: "list[f64,2]",
              D: "list[f64,2]", NI: int, NJ: int, NK: int, NL: int):
    for i in range(0, NI):
        for j in range(0, NJ):
            tmp[i][j] = 0.0
            for k in range(0, NK):
                tmp[i][j] += alpha * A[i][k] * B[k][j]
    for i in range(0, NI):
        for j in range(0, NL):
            D[i][j] *= beta
            for k in range(0, NJ):
                D[i][j] += tmp[i][k] * C[k][j]


def k2mm_np(alpha: float, beta: float, tmp: "ndarray[f64,2]",
            A: "ndarray[f64,2]", B: "ndarray[f64,2]", C: "ndarray[f64,2]",
            D: "ndarray[f64,2]", NI: int, NJ: int, NK: int, NL: int):
    tmp[0:NI, 0:NJ] = alpha * np.dot(A[0:NI, 0:NK], B[0:NK, 0:NJ])
    D[0:NI, 0:NL] = beta * D[0:NI, 0:NL] + np.dot(tmp[0:NI, 0:NJ],
                                                  C[0:NJ, 0:NL])


def k2mm_ref(alpha, beta, tmp, A, B, C, D, NI, NJ, NK, NL):
    tmp[:] = alpha * (A @ B)
    D *= beta
    D += tmp @ C


# ---------------------------------------------------------------------------
# 3mm: G = (A@B)@(C@D)
# ---------------------------------------------------------------------------

def k3mm_list(E: "list[f64,2]", A: "list[f64,2]", B: "list[f64,2]",
              F: "list[f64,2]", C: "list[f64,2]", D: "list[f64,2]",
              G: "list[f64,2]", NI: int, NJ: int, NK: int, NL: int,
              NM: int):
    for i in range(0, NI):
        for j in range(0, NJ):
            E[i][j] = 0.0
            for k in range(0, NK):
                E[i][j] += A[i][k] * B[k][j]
    for i in range(0, NJ):
        for j in range(0, NL):
            F[i][j] = 0.0
            for k in range(0, NM):
                F[i][j] += C[i][k] * D[k][j]
    for i in range(0, NI):
        for j in range(0, NL):
            G[i][j] = 0.0
            for k in range(0, NJ):
                G[i][j] += E[i][k] * F[k][j]


def k3mm_np(E: "ndarray[f64,2]", A: "ndarray[f64,2]", B: "ndarray[f64,2]",
            F: "ndarray[f64,2]", C: "ndarray[f64,2]", D: "ndarray[f64,2]",
            G: "ndarray[f64,2]", NI: int, NJ: int, NK: int, NL: int,
            NM: int):
    E[0:NI, 0:NJ] = np.dot(A[0:NI, 0:NK], B[0:NK, 0:NJ])
    F[0:NJ, 0:NL] = np.dot(C[0:NJ, 0:NM], D[0:NM, 0:NL])
    G[0:NI, 0:NL] = np.dot(E[0:NI, 0:NJ], F[0:NJ, 0:NL])


def k3mm_ref(E, A, B, F, C, D, G, NI, NJ, NK, NL, NM):
    E[:] = A @ B
    F[:] = C @ D
    G[:] = E @ F


# ---------------------------------------------------------------------------
# atax: y = A.T @ (A @ x)
# ---------------------------------------------------------------------------

def atax_list(A: "list[f64,2]", x: "list[f64,1]", y: "list[f64,1]",
              tmp: "list[f64,1]", M: int, N: int):
    for i in range(0, N):
        y[i] = 0.0
    for i in range(0, M):
        tmp[i] = 0.0
        for j in range(0, N):
            tmp[i] += A[i][j] * x[j]
        for j in range(0, N):
            y[j] += A[i][j] * tmp[i]


def atax_np(A: "ndarray[f64,2]", x: "ndarray[f64,1]", y: "ndarray[f64,1]",
            tmp: "ndarray[f64,1]", M: int, N: int):
    tmp[0:M] = np.dot(A[0:M, 0:N], x[0:N])
    y[0:N] = np.dot(A[0:M, 0:N].T, tmp[0:M])


def atax_ref(A, x, y, tmp, M, N):
    tmp[:] = A @ x
    y[:] = A.T @ tmp


# ---------------------------------------------------------------------------
# bicg: q = A @ p ; s = A.T @ r
# ---------------------------------------------------------------------------

def bicg_list(A: "list[f64,2]", s: "list[f64,1]", q: "list[f64,1]",
              p: "list[f64,1]", r: "list[f64,1]", M: int, N: int):
    for i in range(0, M):
        s[i] = 0.0
    for i in range(0, N):
        q[i] = 0.0
        for j in range(0, M):
            s[j] += r[i] * A[i][j]
            q[i] += A[i][j] * p[j]


def bicg_np(A: "ndarray[f64,2]", s: "ndarray[f64,1]", q: "ndarray[f64,1]",
            p: "ndarray[f64,1]", r: "ndarray[f64,1]", M: int, N: int):
    s[0:M] = np.dot(A[0:N, 0:M].T, r[0:N])
    q[0:N] = np.dot(A[0:N, 0:M], p[0:M])


def bicg_ref(A, s, q, p, r, M, N):
    s[:] = A.T @ r
    q[:] = A @ p


# ---------------------------------------------------------------------------
# mvt: x1 += A @ y1 ; x2 += A.T @ y2
# ---------------------------------------------------------------------------

def mvt_list(x1: "list[f64,1]", x2: "list[f64,1]", y1: "list[f64,1]",
             y2: "list[f64,1]", A: "list[f64,2]", N: int):
    for i in range(0, N):
        for j in range(0, N):
            x1[i] += A[i][j] * y1[j]
    for i in range(0, N):
        for j in range(0, N):
            x2[i] += A[j][i] * y2[j]


def mvt_np(x1: "ndarray[f64,1]", x2: "ndarray[f64,1]",
           y1: "ndarray[f64,1]", y2: "ndarray[f64,1]",
           A: "ndarray[f64,2]", N: int):
    x1[0:N] = x1[0:N] + np.dot(A[0:N, 0:N], y1[0:N])
    x2[0:N] = x2[0:N] + np.dot(A[0:N, 0:N].T, y2[0:N])


def mvt_ref(x1, x2, y1, y2, A, N):
    x1 += A @ y1
    x2 += A.T @ y2


# ---------------------------------------------------------------------------
# gesummv: y = alpha*A@x + beta*B@x
# ---------------------------------------------------------------------------

def gesummv_list(alpha: float, beta: float, A: "list[f64,2]",
                 B: "list[f64,2]", tmp: "list[f64,1]", x: "list[f64,1]",
                 y: "list[f64,1]", N: int):
    for i in range(0, N):
        tmp[i] = 0.0
        y[i] = 0.0
        for j in range(0, N):
            tmp[i] += A[i][j] * x[j]
            y[i] += B[i][j] * x[j]
        y[i] = alpha * tmp[i] + beta * y[i]


def gesummv_np(alpha: float, beta: float, A: "ndarray[f64,2]",
               B: "ndarray[f64,2]", tmp: "ndarray[f64,1]",
               x: "ndarray[f64,1]", y: "ndarray[f64,1]", N: int):
    tmp[0:N] = np.dot(A[0:N, 0:N], x[0:N])
    y[0:N] = np.dot(B[0:N, 0:N], x[0:N])
    y[0:N] = alpha * tmp[0:N] + beta * y[0:N]


def gesummv_ref(alpha, beta, A, B, tmp, x, y, N):
    tmp[:] = A @ x
    y[:] = alpha * tmp + beta * (B @ x)


# ---------------------------------------------------------------------------
# gemver: rank-2 update + two matvecs
# ---------------------------------------------------------------------------

def gemver_list(alpha: float, beta: float, A: "list[f64,2]",
                u1: "list[f64,1]", v1: "list[f64,1]", u2: "list[f64,1]",
                v2: "list[f64,1]", w: "list[f64,1]", x: "list[f64,1]",
                y: "list[f64,1]", z: "list[f64,1]", N: int):
    for i in range(0, N):
        for j in range(0, N):
            A[i][j] = A[i][j] + u1[i] * v1[j] + u2[i] * v2[j]
    for i in range(0, N):
        for j in range(0, N):
            x[i] += beta * A[j][i] * y[j]
    for i in range(0, N):
        x[i] += z[i]
    for i in range(0, N):
        for j in range(0, N):
            w[i] += alpha * A[i][j] * x[j]


def gemver_np(alpha: float, beta: float, A: "ndarray[f64,2]",
              u1: "ndarray[f64,1]", v1: "ndarray[f64,1]",
              u2: "ndarray[f64,1]", v2: "ndarray[f64,1]",
              w: "ndarray[f64,1]", x: "ndarray[f64,1]",
              y: "ndarray[f64,1]", z: "ndarray[f64,1]", N: int):
    A[0:N, 0:N] = A[0:N, 0:N] + np.outer(u1[0:N], v1[0:N]) \
        + np.outer(u2[0:N], v2[0:N])
    x[0:N] = x[0:N] + beta * np.dot(A[0:N, 0:N].T, y[0:N]) + z[0:N]
    w[0:N] = w[0:N] + alpha * np.dot(A[0:N, 0:N], x[0:N])


def gemver_ref(alpha, beta, A, u1, v1, u2, v2, w, x, y, z, N):
    A += np.outer(u1, v1) + np.outer(u2, v2)
    x += beta * (A.T @ y) + z
    w += alpha * (A @ x)


# ---------------------------------------------------------------------------
# syrk: C = alpha*A@A.T + beta*C (lower triangle)
# ---------------------------------------------------------------------------

def syrk_list(alpha: float, beta: float, C: "list[f64,2]",
              A: "list[f64,2]", N: int, M: int):
    for i in range(0, N):
        for j in range(0, i + 1):
            C[i][j] *= beta
        for k in range(0, M):
            for j in range(0, i + 1):
                C[i][j] += alpha * A[i][k] * A[j][k]


def syrk_np(alpha: float, beta: float, C: "ndarray[f64,2]",
            A: "ndarray[f64,2]", N: int, M: int):
    for i in range(0, N):
        C[i, 0:i + 1] = beta * C[i, 0:i + 1] \
            + alpha * np.dot(A[0:i + 1, 0:M], A[i, 0:M])


def syrk_ref(alpha, beta, C, A, N, M):
    full = alpha * (A @ A.T)
    tri = np.tril_indices(N)
    C[tri] = beta * C[tri] + full[tri]


# ---------------------------------------------------------------------------
# syr2k: C = alpha*(A@B.T + B@A.T) + beta*C (lower triangle)
# ---------------------------------------------------------------------------

def syr2k_list(alpha: float, beta: float, C: "list[f64,2]",
               A: "list[f64,2]", B: "list[f64,2]", N: int, M: int):
    for i in range(0, N):
        for j in range(0, i + 1):
            C[i][j] *= beta
        for k in range(0, M):
            for j in range(0, i + 1):
                C[i][j] += A[j][k] * alpha * B[i][k] \
                    + B[j][k] * alpha * A[i][k]


def syr2k_np(alpha: float, beta: float, C: "ndarray[f64,2]",
             A: "ndarray[f64,2]", B: "ndarray[f64,2]", N: int, M: int):
    for i in range(0, N):
        C[i, 0:i + 1] = beta * C[i, 0:i + 1] \
            + alpha * np.dot(A[0:i + 1, 0:M], B[i, 0:M]) \
            + alpha * np.dot(B[0:i + 1, 0:M], A[i, 0:M])


def syr2k_ref(alpha, beta, C, A, B, N, M):
    full = alpha * (A @ B.T + B @ A.T)
    tri = np.tril_indices(N)
    C[tri] = beta * C[tri] + full[tri]


# ---------------------------------------------------------------------------
# trmm: B = alpha * A^T_lower @ B (in place)
# ---------------------------------------------------------------------------

def trmm_list(alpha: float, B: "list[f64,2]", A: "list[f64,2]",
              M: int, N: int):
    for i in range(0, M):
        for j in range(0, N):
            for k in range(i + 1, M):
                B[i][j] += A[k][i] * B[k][j]
            B[i][j] *= alpha


def trmm_np(alpha: float, B: "ndarray[f64,2]", A: "ndarray[f64,2]",
            M: int, N: int):
    for i in range(0, M):
        B[i, 0:N] = alpha * (B[i, 0:N]
                             + np.dot(A[i + 1:M, i], B[i + 1:M, 0:N]))


def trmm_ref(alpha, B, A, M, N):
    for i in range(M):
        B[i, :] += A[i + 1:, i] @ B[i + 1:, :]
        B[i, :] *= alpha


# ---------------------------------------------------------------------------
# symm: C = alpha*A_sym@B + beta*C (A symmetric, lower stored)
# ---------------------------------------------------------------------------

def symm_list(alpha: float, beta: float, C: "list[f64,2]",
              A: "list[f64,2]", B: "list[f64,2]", M: int, N: int):
    for i in range(0, M):
        for j in range(0, N):
            temp2 = 0.0
            for k in range(0, i):
                C[k][j] += alpha * B[i][j] * A[i][k]
                temp2 += B[k][j] * A[i][k]
            C[i][j] = beta * C[i][j] + alpha * B[i][j] * A[i][i] \
                + alpha * temp2


def symm_np(alpha: float, beta: float, C: "ndarray[f64,2]",
            A: "ndarray[f64,2]", B: "ndarray[f64,2]", M: int, N: int):
    for i in range(0, M):
        C[0:i, 0:N] = C[0:i, 0:N] + alpha * np.outer(A[i, 0:i], B[i, 0:N])
        C[i, 0:N] = beta * C[i, 0:N] + alpha * B[i, 0:N] * A[i, i] \
            + alpha * np.dot(A[i, 0:i], B[0:i, 0:N])


def symm_ref(alpha, beta, C, A, B, M, N):
    for i in range(M):
        C[:i, :] += alpha * np.outer(A[i, :i], B[i, :])
        C[i, :] = beta * C[i, :] + alpha * B[i, :] * A[i, i] \
            + alpha * (A[i, :i] @ B[:i, :])


# ---------------------------------------------------------------------------
# doitgen: A[r,q,:] = A[r,q,:] @ C4
# ---------------------------------------------------------------------------

def doitgen_list(A: "list[f64,3]", C4: "list[f64,2]", w: "list[f64,1]",
                 NR: int, NQ: int, NP: int):
    for r in range(0, NR):
        for q in range(0, NQ):
            for p in range(0, NP):
                w[p] = 0.0
                for s in range(0, NP):
                    w[p] += A[r][q][s] * C4[s][p]
            for p in range(0, NP):
                A[r][q][p] = w[p]


def doitgen_np(A: "ndarray[f64,3]", C4: "ndarray[f64,2]",
               w: "ndarray[f64,1]", NR: int, NQ: int, NP: int):
    for r in range(0, NR):
        for q in range(0, NQ):
            w[0:NP] = np.dot(A[r, q, 0:NP], C4[0:NP, 0:NP])
            A[r, q, 0:NP] = w[0:NP]


def doitgen_ref(A, C4, w, NR, NQ, NP):
    for r in range(NR):
        for q in range(NQ):
            A[r, q, :] = A[r, q, :] @ C4


# ---------------------------------------------------------------------------
# correlation
# ---------------------------------------------------------------------------

def correlation_list(float_n: float, data: "list[f64,2]",
                     corr: "list[f64,2]", mean: "list[f64,1]",
                     stddev: "list[f64,1]", M: int, N: int):
    for j in range(0, M):
        mean[j] = 0.0
        for i in range(0, N):
            mean[j] += data[i][j]
        mean[j] = mean[j] / float_n
    for j in range(0, M):
        stddev[j] = 0.0
        for i in range(0, N):
            stddev[j] += (data[i][j] - mean[j]) * (data[i][j] - mean[j])
        stddev[j] = np.sqrt(stddev[j] / float_n)
        stddev[j] = np.maximum(stddev[j], 0.1)
    for i in range(0, N):
        for j in range(0, M):
            data[i][j] = (data[i][j] - mean[j]) \
                / (np.sqrt(float_n) * stddev[j])
    for i in range(0, M):
        corr[i][i] = 1.0
    for i in range(0, M - 1):
        for j in range(i + 1, M):
            corr[i][j] = 0.0
            for k in range(0, N):
                corr[i][j] += data[k][i] * data[k][j]
            corr[j][i] = corr[i][j]


def correlation_np(float_n: float, data: "ndarray[f64,2]",
                   corr: "ndarray[f64,2]", mean: "ndarray[f64,1]",
                   stddev: "ndarray[f64,1]", M: int, N: int):
    mean[0:M] = data[0:N, 0:M].sum(axis=0) / float_n
    stddev[0:M] = np.sqrt(
        ((data[0:N, 0:M] - mean[0:M])
         * (data[0:N, 0:M] - mean[0:M])).sum(axis=0) / float_n)
    stddev[0:M] = np.maximum(stddev[0:M], 0.1)
    data[0:N, 0:M] = (data[0:N, 0:M] - mean[0:M]) \
        / (np.sqrt(float_n) * stddev[0:M])
    for i in range(0, M):
        corr[i][i] = 1.0
    for i in range(0, M - 1):
        corr[i, i + 1:M] = (data[0:N, i] * data[0:N, i + 1:M].T).sum(axis=1)
        corr[i + 1:M, i] = corr[i, i + 1:M]


def correlation_ref(float_n, data, corr, mean, stddev, M, N):
    mean[:] = data.sum(axis=0) / float_n
    stddev[:] = np.sqrt(((data - mean) ** 2).sum(axis=0) / float_n)
    stddev[:] = np.maximum(stddev, 0.1)
    data -= mean
    data /= np.sqrt(float_n) * stddev
    corr[:] = data.T @ data
    np.fill_diagonal(corr, 1.0)


# ---------------------------------------------------------------------------
# covariance
# ---------------------------------------------------------------------------

def covariance_list(float_n: float, data: "list[f64,2]",
                    cov: "list[f64,2]", mean: "list[f64,1]",
                    M: int, N: int):
    for j in range(0, M):
        mean[j] = 0.0
        for i in range(0, N):
            mean[j] += data[i][j]
        mean[j] = mean[j] / float_n
    for i in range(0, N):
        for j in range(0, M):
            data[i][j] -= mean[j]
    for i in range(0, M):
        for j in range(i, M):
            cov[i][j] = 0.0
            for k in range(0, N):
                cov[i][j] += data[k][i] * data[k][j]
            cov[i][j] = cov[i][j] / (float_n - 1.0)
            cov[j][i] = cov[i][j]


def covariance_np(float_n: float, data: "ndarray[f64,2]",
                  cov: "ndarray[f64,2]", mean: "ndarray[f64,1]",
                  M: int, N: int):
    mean[0:M] = data[0:N, 0:M].sum(axis=0) / float_n
    data[0:N, 0:M] = data[0:N, 0:M] - mean[0:M]
    for i in range(0, M):
        cov[i, i:M] = (data[0:N, i] * data[0:N, i:M].T).sum(axis=1) \
            / (float_n - 1.0)
        cov[i:M, i] = cov[i, i:M]


def covariance_ref(float_n, data, cov, mean, M, N):
    mean[:] = data.sum(axis=0) / float_n
    data -= mean
    cov[:] = (data.T @ data) / (float_n - 1.0)


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

def _mk(shape, rng):
    return rng.normal(size=shape)


KERNELS = {}


def register(name, list_fn, np_fn, ref_fn, make_args, flops):
    KERNELS[name] = {
        "list": list_fn, "np": np_fn, "ref": ref_fn,
        "make_args": make_args, "flops": flops,
    }


def _gemm_args(n, rng):
    NI = NJ = NK = n
    return [1.5, 1.2, _mk((NI, NJ), rng), _mk((NI, NK), rng),
            _mk((NK, NJ), rng), NI, NJ, NK], {"out": [2]}


register("gemm", gemm_list, gemm_np, gemm_ref, _gemm_args,
         lambda n: 2.0 * n ** 3)


def _2mm_args(n, rng):
    NI = NJ = NK = NL = n
    return [1.5, 1.2, np.zeros((NI, NJ)), _mk((NI, NK), rng),
            _mk((NK, NJ), rng), _mk((NJ, NL), rng), _mk((NI, NL), rng),
            NI, NJ, NK, NL], {"out": [2, 6]}


register("2mm", k2mm_list, k2mm_np, k2mm_ref, _2mm_args,
         lambda n: 4.0 * n ** 3)


def _3mm_args(n, rng):
    NI = NJ = NK = NL = NM = n
    return [np.zeros((NI, NJ)), _mk((NI, NK), rng), _mk((NK, NJ), rng),
            np.zeros((NJ, NL)), _mk((NJ, NM), rng), _mk((NM, NL), rng),
            np.zeros((NI, NL)), NI, NJ, NK, NL, NM], {"out": [0, 3, 6]}


register("3mm", k3mm_list, k3mm_np, k3mm_ref, _3mm_args,
         lambda n: 6.0 * n ** 3)


def _atax_args(n, rng):
    M = N = n
    return [_mk((M, N), rng), _mk((N,), rng), np.zeros(N), np.zeros(M),
            M, N], {"out": [2, 3]}


register("atax", atax_list, atax_np, atax_ref, _atax_args,
         lambda n: 4.0 * n ** 2)


def _bicg_args(n, rng):
    M = N = n
    return [_mk((N, M), rng), np.zeros(M), np.zeros(N), _mk((M,), rng),
            _mk((N,), rng), M, N], {"out": [1, 2]}


register("bicg", bicg_list, bicg_np, bicg_ref, _bicg_args,
         lambda n: 4.0 * n ** 2)


def _mvt_args(n, rng):
    N = n
    return [_mk((N,), rng), _mk((N,), rng), _mk((N,), rng),
            _mk((N,), rng), _mk((N, N), rng), N], {"out": [0, 1]}


register("mvt", mvt_list, mvt_np, mvt_ref, _mvt_args,
         lambda n: 4.0 * n ** 2)


def _gesummv_args(n, rng):
    N = n
    return [1.5, 1.2, _mk((N, N), rng), _mk((N, N), rng), np.zeros(N),
            _mk((N,), rng), np.zeros(N), N], {"out": [4, 6]}


register("gesummv", gesummv_list, gesummv_np, gesummv_ref, _gesummv_args,
         lambda n: 4.0 * n ** 2)


def _gemver_args(n, rng):
    N = n
    return [1.5, 1.2, _mk((N, N), rng), _mk((N,), rng), _mk((N,), rng),
            _mk((N,), rng), _mk((N,), rng), np.zeros(N), np.zeros(N),
            _mk((N,), rng), _mk((N,), rng), N], {"out": [2, 7, 8]}


register("gemver", gemver_list, gemver_np, gemver_ref, _gemver_args,
         lambda n: 10.0 * n ** 2)


def _syrk_args(n, rng):
    N = M = n
    return [1.5, 1.2, _mk((N, N), rng), _mk((N, M), rng), N, M], \
        {"out": [2]}


register("syrk", syrk_list, syrk_np, syrk_ref, _syrk_args,
         lambda n: 1.0 * n ** 3)


def _syr2k_args(n, rng):
    N = M = n
    return [1.5, 1.2, _mk((N, N), rng), _mk((N, M), rng),
            _mk((N, M), rng), N, M], {"out": [2]}


register("syr2k", syr2k_list, syr2k_np, syr2k_ref, _syr2k_args,
         lambda n: 2.0 * n ** 3)


def _trmm_args(n, rng):
    M = N = n
    return [1.5, _mk((M, N), rng), _mk((M, M), rng), M, N], {"out": [1]}


register("trmm", trmm_list, trmm_np, trmm_ref, _trmm_args,
         lambda n: 1.0 * n ** 3)


def _symm_args(n, rng):
    M = N = n
    return [1.5, 1.2, _mk((M, N), rng), _mk((M, M), rng),
            _mk((M, N), rng), M, N], {"out": [2]}


register("symm", symm_list, symm_np, symm_ref, _symm_args,
         lambda n: 2.0 * n ** 3)


def _doitgen_args(n, rng):
    NR, NQ, NP = max(2, n // 8), max(2, n // 8), n
    return [_mk((NR, NQ, NP), rng), _mk((NP, NP), rng), np.zeros(NP),
            NR, NQ, NP], {"out": [0]}


register("doitgen", doitgen_list, doitgen_np, doitgen_ref, _doitgen_args,
         lambda n: 2.0 * (n // 8) ** 2 * n ** 2)


def _correlation_args(n, rng):
    M = N = n
    return [float(N), _mk((N, M), rng), np.zeros((M, M)), np.zeros(M),
            np.zeros(M), M, N], {"out": [1, 2, 3, 4]}


register("correlation", correlation_list, correlation_np, correlation_ref,
         _correlation_args, lambda n: 2.0 * n ** 3)


def _covariance_args(n, rng):
    M = N = n
    return [float(N), _mk((N, M), rng), np.zeros((M, M)), np.zeros(M),
            M, N], {"out": [1, 2, 3]}


register("covariance", covariance_list, covariance_np, covariance_ref,
         _covariance_args, lambda n: 1.0 * n ** 3)


def clone_args(args):
    """Deep-copy argument list (arrays copied; scalars shared)."""
    out = []
    for a in args:
        if isinstance(a, np.ndarray):
            out.append(a.copy())
        elif isinstance(a, list):
            out.append([row.copy() if isinstance(row, list) else row
                        for row in a])
        else:
            out.append(a)
    return out


def to_lists(args):
    """Convert ndarray args to nested lists (the paper's List versions)."""
    return [a.tolist() if isinstance(a, np.ndarray) else a for a in args]
