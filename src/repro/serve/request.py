"""The serving request record, shared by the single-process
:class:`repro.serve.engine.ServeEngine` and the cluster engines.

Lives in its own jax-free module so the cluster serving plane (and
worker processes resolving shipped functions) can import it without
paying the jax import that ``engine.py`` needs for its jitted steps.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import List, Optional

import numpy as np

__all__ = ["Request"]


@dataclass
class Request:
    request_id: str
    prompt: np.ndarray                  # (S,) int32
    max_tokens: int = 16
    eos_id: Optional[int] = None
    generated: List[int] = field(default_factory=list)
    slot: Optional[int] = None
    submitted_s: float = field(default_factory=time.perf_counter)
    first_token_s: Optional[float] = None
    finished_s: Optional[float] = None
