"""Open-loop Poisson load generator for the serving plane.

Closed-loop drivers (issue → wait → issue) hide saturation: the
arrival rate collapses to whatever the server sustains and tail
latency looks flat. The open-loop generator submits on a fixed
Poisson schedule regardless of completions — the standard
serving-benchmark discipline — so queueing delay and admission
rejections show up in the percentiles instead of being absorbed by
the driver.

The generator is engine-agnostic: it drives any ``submit(i, tenant)``
callable that returns a ticket exposing ``wait(timeout)`` plus
``submitted_s`` / ``finished_s`` stamps (duck-typed against
:class:`repro.serve.cluster_engine.ServeTicket`), and treats
:class:`repro.serve.admission.AdmissionError` as a counted rejection,
not a failure.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from .admission import AdmissionError

__all__ = ["LoadResult", "open_loop"]


def _pct(xs: List[float], q: float) -> Optional[float]:
    if not xs:
        return None
    return float(np.percentile(np.asarray(xs), q))


@dataclass
class LoadResult:
    offered: int = 0                  # requests the schedule issued
    completed: int = 0
    failed: int = 0                   # errored after admission
    rejected: int = 0                 # explicit admission rejections
    duration_s: float = 0.0
    offered_rps: float = 0.0
    throughput_rps: float = 0.0
    e2e_ms: Dict[str, float] = field(default_factory=dict)
    queue_ms: Dict[str, float] = field(default_factory=dict)
    per_tenant: Dict[str, Dict[str, int]] = field(default_factory=dict)
    reject_reasons: Dict[str, int] = field(default_factory=dict)

    def as_row(self) -> Dict[str, object]:
        return {
            "offered": self.offered, "completed": self.completed,
            "failed": self.failed, "rejected": self.rejected,
            "duration_s": round(self.duration_s, 6),
            "offered_rps": round(self.offered_rps, 3),
            "throughput_rps": round(self.throughput_rps, 3),
            "e2e_ms": self.e2e_ms, "queue_ms": self.queue_ms,
            "per_tenant": self.per_tenant,
            "reject_reasons": self.reject_reasons,
        }


def open_loop(submit: Callable[[int, str], object], *, requests: int,
              rate_rps: float, tenants: Sequence[str] = ("tenant-a",),
              seed: int = 0, wait_timeout_s: float = 120.0) -> LoadResult:
    """Drive ``submit`` with Poisson arrivals at ``rate_rps``.

    Inter-arrival gaps are exponential (pre-drawn from ``seed`` so a
    coalesced and a naive run see the *same* schedule); tenants are
    assigned round-robin. Submission never blocks on a previous
    request; after the schedule drains, every accepted ticket is
    awaited and the percentiles are computed from its stamps."""
    if rate_rps <= 0:
        raise ValueError("rate_rps must be > 0 for an open-loop run")
    rng = np.random.default_rng(seed)
    gaps = rng.exponential(1.0 / rate_rps, size=requests)
    res = LoadResult(offered=requests)
    tickets: List[tuple] = []   # (tenant, ticket)

    t_start = time.perf_counter()
    due = t_start
    for i in range(requests):
        due += float(gaps[i])
        delay = due - time.perf_counter()
        if delay > 0:
            time.sleep(delay)
        tenant = tenants[i % len(tenants)]
        per = res.per_tenant.setdefault(
            tenant, {"requests": 0, "completed": 0, "rejected": 0})
        per["requests"] += 1
        try:
            tickets.append((tenant, submit(i, tenant)))
        except AdmissionError as e:
            res.rejected += 1
            per["rejected"] += 1
            res.reject_reasons[e.reason] = \
                res.reject_reasons.get(e.reason, 0) + 1

    e2e, queue = [], []
    for tenant, tk in tickets:
        per = res.per_tenant[tenant]
        try:
            tk.wait(wait_timeout_s)
        except Exception:
            res.failed += 1
            continue
        res.completed += 1
        per["completed"] += 1
        if tk.finished_s is not None:
            e2e.append((tk.finished_s - tk.submitted_s) * 1e3)
        if getattr(tk, "started_s", None) is not None:
            queue.append((tk.started_s - tk.submitted_s) * 1e3)
    res.duration_s = time.perf_counter() - t_start
    res.offered_rps = requests / res.duration_s if res.duration_s else 0.0
    res.throughput_rps = (res.completed / res.duration_s
                          if res.duration_s else 0.0)
    for name, xs in (("e2e_ms", e2e), ("queue_ms", queue)):
        if xs:
            getattr(res, name).update(
                {"p50": round(_pct(xs, 50), 3),
                 "p95": round(_pct(xs, 95), 3),
                 "p99": round(_pct(xs, 99), 3),
                 "mean": round(float(np.mean(xs)), 3)})
    return res
