"""Multi-tenant serving plane over the cluster runtime.

Two engines share one front-door discipline (admission → bounded queue
→ dispatch → per-tenant accounting in the ``serve#N`` metrics scope):

* :class:`ClusterServeEngine` — serves *compiled kernels*. Concurrent
  callers submit ``(tenant, kernel, args)``; a *coalescer* merges
  same-kernel, same-signature requests that arrive within a short
  window into one stacked call — the batch axis is the kernel's pfor
  axis, so N requests of ``k`` rows each become one ``N·k``-row pfor:
  bigger chunks, one ship/dispatch/gather round amortized across
  callers. Results are split back per request by row offsets. When
  coalescing is illegal (shape/shared-arg mismatch, no
  :class:`BatchSpec`) or the window closes empty, the request falls
  through to plain per-request dispatch — never wrong, just unbatched.

* :class:`ClusterLMEngine` — the LM inference flagship: the seed
  :class:`repro.serve.engine.ServeEngine` continuous-batching decode
  loop, with params + KV caches living in a *worker's* object store
  (``repro.serve.remote_lm``) instead of the head process. Each tick
  ships one small token vector each way; the state chain is lineage-
  tracked, so a worker SIGKILL mid-decode replays from the last anchor
  and every accepted request still gets the exact tokens it would have
  gotten — bitwise equal to the single-process engine.

Queue depth is exported for :class:`repro.runtime.elastic.ElasticController`
(``depth_fn=engine.queue_depth``), closing the loop: load → queue →
fleet size.
"""

from __future__ import annotations

import inspect
import threading
import time
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro import obs

from . import remote_lm
from .admission import AdmissionController, AdmissionError
from .engine import Request
from .kvcache import SlotMap

__all__ = ["BatchSpec", "ServeTicket", "ClusterServeEngine",
           "ClusterLMEngine", "LMTicket"]


@dataclass(frozen=True)
class BatchSpec:
    """How a kernel's signature stacks across coalesced requests.

    ``stacked`` args concatenate along axis 0 (the pfor axis);
    ``count`` names the scalar that equals their leading dim;
    ``out`` are the written outputs (a subset of ``stacked``) split
    back per request; ``shared`` args must match across requests for a
    merge to be legal (they ride once, from the first request)."""

    stacked: Tuple[str, ...]
    count: str
    out: Tuple[str, ...]
    shared: Tuple[str, ...] = ()


class ServeTicket:
    """Handle returned by :meth:`ClusterServeEngine.submit`."""

    def __init__(self, tenant: str, kernel: str, args: Tuple[Any, ...]):
        self.tenant = tenant
        self.kernel = kernel
        self.args = args
        self.submitted_s = time.perf_counter()
        self.started_s: Optional[float] = None
        self.finished_s: Optional[float] = None
        self.result: Any = None
        self.error: Optional[BaseException] = None
        self.coalesced = False
        self.batch_size = 1
        self._key: Optional[tuple] = None
        self._event = threading.Event()

    def done(self) -> bool:
        return self._event.is_set()

    def wait(self, timeout: Optional[float] = 60.0):
        if not self._event.wait(timeout):
            raise TimeoutError(
                f"serve ticket ({self.kernel}, tenant {self.tenant}) "
                f"not fulfilled after {timeout}s")
        if self.error is not None:
            raise self.error
        return self.result


class _KernelRec:
    __slots__ = ("name", "fn", "batch", "params", "remote")

    def __init__(self, name: str, fn: Callable,
                 batch: Optional[BatchSpec], remote: bool):
        self.name = name
        self.fn = fn
        self.batch = batch
        self.remote = remote
        if hasattr(fn, "params"):          # CompiledKernel
            self.params = [n for n, _ in fn.params]
        else:
            self.params = list(inspect.signature(fn).parameters)
        if batch is not None:
            known = set(self.params)
            for p in (*batch.stacked, batch.count, *batch.out,
                      *batch.shared):
                if p not in known:
                    raise ValueError(
                        f"BatchSpec names unknown param {p!r} of "
                        f"kernel {name!r} (params: {self.params})")
            if remote:
                raise ValueError(
                    "remote kernels use the return-value convention; "
                    "BatchSpec's written-output splitting needs the "
                    "caller's arrays in-process")


def _fingerprint(v: Any):
    """Equality token for a shared arg (content, not identity)."""
    if isinstance(v, np.ndarray):
        return ("nd", v.shape, str(v.dtype), hash(v.tobytes()))
    return ("v", v)


class ClusterServeEngine:
    """Multi-tenant kernel front-end: admission → coalescing window →
    one stacked dispatch (or per-request fall-through).

    ``rt`` (a :class:`repro.distrib.cluster.ClusterRuntime`) is
    optional: compiled kernels carry their own runtime binding via
    ``pfor_config``, and plain callables run in-process unless
    registered ``remote=True`` (then they ship via ``rt.submit`` /
    ``rt.submit_batch``). ``coalesce_window_s=0`` disables merging —
    the naive baseline the benchmark compares against."""

    requests = obs.MetricAttr("requests")
    rejections = obs.MetricAttr("rejections")
    coalesced_batches = obs.MetricAttr("coalesced_batches")
    coalesced_requests = obs.MetricAttr("coalesced_requests")
    fallthrough_dispatches = obs.MetricAttr("fallthrough_dispatches")

    def __init__(self, rt=None, *,
                 admission: Optional[AdmissionController] = None,
                 coalesce_window_s: float = 0.004, max_batch: int = 16,
                 variant: str = "np"):
        self.rt = rt
        self.admission = admission or AdmissionController()
        self.coalesce_window_s = float(coalesce_window_s)
        self.max_batch = int(max_batch)
        self.variant = variant
        self._kernels: Dict[str, _KernelRec] = {}
        self._queue: List[ServeTicket] = []
        self._cond = threading.Condition()
        self._stop = False
        self._thread: Optional[threading.Thread] = None
        self._mscope = obs.metrics.unique_scope("serve")
        self._h_e2e = self._mscope.histogram("e2e_ms")
        self._h_queue = self._mscope.histogram("queue_ms")
        self._t_requests = self._mscope.dictmetric("tenant_requests")
        self._t_rejections = self._mscope.dictmetric("tenant_rejections")
        self._t_tokens = self._mscope.dictmetric("tenant_tokens")
        self.requests = 0
        self.rejections = 0
        self.coalesced_batches = 0
        self.coalesced_requests = 0
        self.fallthrough_dispatches = 0

    # -- registration -------------------------------------------------------
    def register(self, name: str, fn: Callable, *,
                 batch: Optional[BatchSpec] = None,
                 remote: bool = False) -> None:
        if remote and self.rt is None:
            raise ValueError(f"kernel {name!r}: remote=True needs rt")
        self._kernels[name] = _KernelRec(name, fn, batch, remote)

    # -- submission ---------------------------------------------------------
    def _coalesce_key(self, rec: _KernelRec,
                      args: Tuple[Any, ...]) -> Optional[tuple]:
        """Signature under which requests may merge; ``None`` marks the
        request per-request-only (no BatchSpec, or stacking illegal)."""
        b = rec.batch
        if b is None or self.coalesce_window_s <= 0:
            return None
        idx = {p: i for i, p in enumerate(rec.params)}
        try:
            count = int(args[idx[b.count]])
        except (TypeError, ValueError):
            return None
        parts: List[tuple] = [("k", rec.name)]
        for p in (*b.stacked, *b.out):
            a = args[idx[p]]
            if not isinstance(a, np.ndarray) or a.ndim < 1 \
                    or a.shape[0] != count:
                return None     # not row-stackable → fall through
            parts.append(("s", p, a.shape[1:], str(a.dtype)))
        for p in b.shared:
            parts.append(("h", p, _fingerprint(args[idx[p]])))
        return tuple(parts)

    def submit(self, tenant: str, kernel: str,
               args: Sequence[Any]) -> ServeTicket:
        """Admit + enqueue one request; raises
        :class:`~repro.serve.admission.AdmissionError` on rejection."""
        rec = self._kernels[kernel]
        try:
            self.admission.admit(tenant)
        except AdmissionError:
            self.rejections += 1
            self._t_rejections[tenant] = \
                self._t_rejections.get(tenant, 0) + 1
            raise
        tk = ServeTicket(tenant, kernel, tuple(args))
        tk._key = self._coalesce_key(rec, tk.args)
        with self._cond:
            self._queue.append(tk)
            self._cond.notify_all()
        self._ensure_dispatcher()
        return tk

    def queue_depth(self) -> int:
        """Requests admitted but not yet dispatched (what the elastic
        controller scales the fleet on)."""
        with self._cond:
            return len(self._queue)

    # -- dispatch loop ------------------------------------------------------
    def _ensure_dispatcher(self) -> None:
        if self._thread is None or not self._thread.is_alive():
            with self._cond:
                if self._thread is None or not self._thread.is_alive():
                    self._stop = False
                    self._thread = threading.Thread(
                        target=self._dispatch_loop, daemon=True,
                        name="serve-dispatch")
                    self._thread.start()

    def _dispatch_loop(self) -> None:
        while True:
            with self._cond:
                while not self._queue and not self._stop:
                    self._cond.wait(0.05)
                if self._stop and not self._queue:
                    return
                head = self._queue.pop(0)
                self.admission.dequeued()
            group = [head]
            if head._key is not None:
                self._fill_window(head._key, group)
            self._execute(group)

    def _fill_window(self, key: tuple, group: List[ServeTicket]) -> None:
        """Collect same-key requests until the window closes or the
        batch fills; the window is measured from the head pop, so a
        backlogged queue coalesces without adding idle latency."""
        deadline = time.perf_counter() + self.coalesce_window_s
        while len(group) < self.max_batch:
            with self._cond:
                hit = next((i for i, t in enumerate(self._queue)
                            if t._key == key), None)
                if hit is not None:
                    group.append(self._queue.pop(hit))
                    self.admission.dequeued()
                    continue
                remaining = deadline - time.perf_counter()
                if remaining <= 0:
                    return
                self._cond.wait(remaining)

    # -- execution ----------------------------------------------------------
    def _call(self, rec: _KernelRec, args: Tuple[Any, ...]) -> Any:
        fn = rec.fn
        if hasattr(fn, "call_variant"):
            return fn.call_variant(self.variant, *args)
        return fn(*args)

    def _written(self, rec: _KernelRec, args: Tuple[Any, ...]):
        idx = {p: i for i, p in enumerate(rec.params)}
        outs = tuple(args[idx[p]] for p in rec.batch.out)
        return outs[0] if len(outs) == 1 else outs

    def _execute(self, group: List[ServeTicket]) -> None:
        rec = self._kernels[group[0].kernel]
        now = time.perf_counter()
        for tk in group:
            tk.started_s = now
        try:
            if len(group) > 1:
                self._run_coalesced(rec, group)
            else:
                self._run_single(rec, group[0])
        except BaseException as e:                # noqa: BLE001
            for tk in group:
                tk.error = e
        finally:
            done = time.perf_counter()
            for tk in group:
                tk.finished_s = done
                self.admission.release(tk.tenant)
                self.requests += 1
                self._t_requests[tk.tenant] = \
                    self._t_requests.get(tk.tenant, 0) + 1
                self._h_e2e.observe((done - tk.submitted_s) * 1e3)
                self._h_queue.observe(
                    (tk.started_s - tk.submitted_s) * 1e3)
                tk._event.set()

    def _run_single(self, rec: _KernelRec, tk: ServeTicket) -> None:
        self.fallthrough_dispatches += 1
        if rec.remote:
            ref = self.rt.submit(rec.fn, *tk.args)
            try:
                tk.result = self.rt.get(ref)
            finally:
                self.rt.release(ref)
            return
        ret = self._call(rec, tk.args)
        tk.result = (self._written(rec, tk.args)
                     if rec.batch is not None else ret)

    def _run_coalesced(self, rec: _KernelRec,
                       group: List[ServeTicket]) -> None:
        if rec.remote:      # plain callables batch via submit_batch
            refs = self.rt.submit_batch(rec.fn,
                                        [tk.args for tk in group])
            try:
                for tk, ref in zip(group, refs):
                    tk.result = self.rt.get(ref)
            finally:
                for ref in refs:
                    self.rt.release(ref)
            self._mark_coalesced(group)
            return
        b = rec.batch
        idx = {p: i for i, p in enumerate(rec.params)}
        counts = [int(tk.args[idx[b.count]]) for tk in group]
        offsets = np.concatenate(([0], np.cumsum(counts)))
        total = int(offsets[-1])
        stacked: Dict[str, np.ndarray] = {}
        merged: List[Any] = []
        for p in rec.params:
            if p in b.stacked or p in b.out:
                arr = np.concatenate(
                    [np.asarray(tk.args[idx[p]]) for tk in group],
                    axis=0)
                stacked[p] = arr
                merged.append(arr)
            elif p == b.count:
                merged.append(total)
            else:
                merged.append(group[0].args[idx[p]])
        self._call(rec, tuple(merged))
        for p in b.out:
            big = stacked[p]
            for k, tk in enumerate(group):
                lo, hi = int(offsets[k]), int(offsets[k + 1])
                np.copyto(tk.args[idx[p]], big[lo:hi])
        for tk in group:
            tk.result = self._written(rec, tk.args)
        self._mark_coalesced(group)

    def _mark_coalesced(self, group: List[ServeTicket]) -> None:
        self.coalesced_batches += 1
        self.coalesced_requests += len(group)
        for tk in group:
            tk.coalesced = True
            tk.batch_size = len(group)

    # -- lifecycle / telemetry ----------------------------------------------
    def close(self, timeout: float = 10.0) -> None:
        """Drain the queue, then stop the dispatcher."""
        with self._cond:
            self._stop = True
            self._cond.notify_all()
        if self._thread is not None:
            self._thread.join(timeout)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    @staticmethod
    def _latency(h) -> Dict[str, Any]:
        return {"count": h.count, "mean": round(h.mean, 6),
                "p50": h.percentile(50), "p95": h.percentile(95),
                "p99": h.percentile(99)}

    def telemetry(self) -> Dict[str, Any]:
        return {
            "requests": self.requests,
            "rejections": self.rejections,
            "queued": self.queue_depth(),
            "coalesced_batches": self.coalesced_batches,
            "coalesced_requests": self.coalesced_requests,
            "fallthrough_dispatches": self.fallthrough_dispatches,
            "e2e_ms": self._latency(self._h_e2e),
            "queue_ms": self._latency(self._h_queue),
            "tenants": {
                "requests": dict(self._t_requests),
                "rejections": dict(self._t_rejections),
                "tokens": dict(self._t_tokens),
            },
            "admission": self.admission.telemetry(),
        }


class LMTicket:
    """Per-request handle for :class:`ClusterLMEngine` — duck-typed
    against :class:`ServeTicket` so one load generator drives both."""

    def __init__(self, tenant: str, req: Request):
        self.tenant = tenant
        self.request = req
        self.submitted_s = req.submitted_s
        self.started_s: Optional[float] = None
        self.finished_s: Optional[float] = None
        self.error: Optional[BaseException] = None
        self._event = threading.Event()

    def done(self) -> bool:
        return self._event.is_set()

    def wait(self, timeout: Optional[float] = 60.0) -> List[int]:
        if not self._event.wait(timeout):
            raise TimeoutError(
                f"LM request {self.request.request_id} not finished "
                f"after {timeout}s")
        if self.error is not None:
            raise self.error
        return self.request.generated


class ClusterLMEngine:
    """The seed continuous-batching LM decode loop, state-on-a-worker.

    Params + KV caches boot once into a worker's object store
    (:func:`repro.serve.remote_lm.lm_boot`); each tick ships one small
    token vector each way. The state chain is lineage-tracked: every
    ``trim_every`` ticks the engine pulls the state to the head,
    re-anchors it as a fresh lineage root, and releases the old chain —
    head memory stays flat while worker loss anywhere in the window
    replays transitively from the last anchor. Token streams are
    bitwise-identical to :class:`repro.serve.engine.ServeEngine` on the
    same prompts (same ops, same order, explicit model dtypes).

    The cluster must use ``start_method="spawn"``: the head has a live
    jax runtime and forking it is unsafe.
    """

    ticks = obs.MetricAttr("ticks")
    prefills = obs.MetricAttr("prefills")
    tokens_generated = obs.MetricAttr("tokens_generated")
    anchors = obs.MetricAttr("anchors")

    def __init__(self, rt, params, cfg, *, n_slots: int = 4,
                 max_seq: int = 256, trim_every: int = 32,
                 admission: Optional[AdmissionController] = None,
                 op_timeout_s: float = 180.0):
        if getattr(rt, "start_method", "spawn") == "fork":
            raise ValueError(
                "ClusterLMEngine needs a spawn-started fleet: the head "
                "holds a live jax runtime and forked workers would "
                "inherit its state (pass start_method='spawn')")
        self.rt = rt
        self.cfg = cfg
        self.max_seq = max_seq
        self.trim_every = int(trim_every)
        self.op_timeout_s = op_timeout_s
        self.admission = admission
        self.slots = SlotMap(n_slots)
        self.queue: List[Request] = []
        self.active: Dict[int, Request] = {}
        self.finished: List[Request] = []
        self._mscope = obs.metrics.unique_scope("serve")
        self._h_ttft = self._mscope.histogram("ttft_ms")
        self._h_tpot = self._mscope.histogram("tpot_ms")
        self._h_e2e = self._mscope.histogram("e2e_ms")
        self._t_requests = self._mscope.dictmetric("tenant_requests")
        self._t_rejections = self._mscope.dictmetric("tenant_rejections")
        self._t_tokens = self._mscope.dictmetric("tenant_tokens")
        self.ticks = 0
        self.prefills = 0
        self.tokens_generated = 0
        self.anchors = 0
        self._tenant_of: Dict[str, str] = {}
        self._tickets: Dict[str, LMTicket] = {}
        self._lock = threading.Lock()
        self._pump: Optional[threading.Thread] = None
        self._pump_stop = threading.Event()
        self._state = rt.submit(remote_lm.lm_boot,
                                remote_lm.tree_np(params), cfg,
                                n_slots, max_seq)
        self._chain: List[Any] = []

    # -- state chain --------------------------------------------------------
    def _roll(self, fn, *args) -> np.ndarray:
        """Advance the worker-resident state by one op and fetch its
        small output. The superseded state ref stays alive (lineage for
        replay) until the next anchor trims the chain."""
        new_ref = self.rt.submit(fn, self._state, *args)
        self._chain.append(self._state)
        self._state = new_ref
        out_ref = self.rt.submit(remote_lm.lm_out, new_ref)
        try:
            return self.rt.get(out_ref, timeout=self.op_timeout_s)
        finally:
            self.rt.release(out_ref)

    def _maybe_trim(self) -> None:
        if len(self._chain) < self.trim_every:
            return
        value = self.rt.get(self._state, timeout=self.op_timeout_s)
        new_root = self.rt.submit(remote_lm.lm_anchor, value)
        old = self._chain + [self._state]
        self._state = new_root
        self._chain = []
        self.anchors += 1
        for ref in old:
            self.rt.release(ref)

    # -- ServeEngine-compatible API -----------------------------------------
    def add_request(self, req: Request, tenant: str = "default") -> None:
        if self.admission is not None:
            try:
                self.admission.admit(tenant)
            except AdmissionError:
                self._t_rejections[tenant] = \
                    self._t_rejections.get(tenant, 0) + 1
                raise
        self._tenant_of[req.request_id] = tenant
        self._t_requests[tenant] = self._t_requests.get(tenant, 0) + 1
        with self._lock:
            self.queue.append(req)

    def _admit(self) -> None:
        while True:
            with self._lock:
                if not self.queue:
                    return
                slot = self.slots.allocate(self.queue[0].request_id)
                if slot is None:
                    return
                req = self.queue.pop(0)
            req.slot = slot
            out = self._roll(remote_lm.lm_prefill,
                             np.asarray(req.prompt, np.int32), slot)
            req.generated.append(int(out[0]))
            self.prefills += 1
            self._count_token(req)
            req.first_token_s = time.perf_counter()
            tk = self._tickets.get(req.request_id)
            if tk is not None:
                tk.started_s = req.first_token_s
            self.slots.lengths[slot] = len(req.prompt) + 1
            self.active[slot] = req
            if self.admission is not None:
                self.admission.dequeued()

    def _count_token(self, req: Request) -> None:
        self.tokens_generated += 1
        tenant = self._tenant_of.get(req.request_id, "default")
        self._t_tokens[tenant] = self._t_tokens.get(tenant, 0) + 1

    def step(self) -> int:
        """One engine tick: admit + one batched decode on the worker-
        resident caches. Same semantics as ``ServeEngine.step``."""
        self._admit()
        self.ticks += 1
        if not self.active:
            return 0
        n_slots = self.slots.n_slots
        tokens = np.zeros((n_slots, 1), np.int32)
        for slot, req in self.active.items():
            tokens[slot, 0] = req.generated[-1]
        next_tokens = self._roll(remote_lm.lm_decode, tokens)
        done_slots = []
        for slot, req in self.active.items():
            tok = int(next_tokens[slot])
            req.generated.append(tok)
            self._count_token(req)
            self.slots.advance(slot)
            if (len(req.generated) >= req.max_tokens
                    or (req.eos_id is not None and tok == req.eos_id)
                    or self.slots.lengths[slot] >= self.max_seq - 1):
                req.finished_s = time.perf_counter()
                done_slots.append(slot)
        for slot in done_slots:
            self._finish(self.active.pop(slot))
            self.slots.free(slot)
        self._maybe_trim()
        return len(self.active)

    def _finish(self, req: Request) -> None:
        self.finished.append(req)
        n_gen = max(1, len(req.generated) - 1)
        self._h_ttft.observe((req.first_token_s - req.submitted_s) * 1e3)
        self._h_e2e.observe((req.finished_s - req.submitted_s) * 1e3)
        self._h_tpot.observe(
            (req.finished_s - req.first_token_s) * 1e3 / n_gen)
        tenant = self._tenant_of.get(req.request_id, "default")
        if self.admission is not None:
            self.admission.release(tenant)
        tk = self._tickets.pop(req.request_id, None)
        if tk is not None:
            tk.finished_s = req.finished_s
            tk._event.set()

    def run_until_done(self, max_ticks: int = 10_000) -> List[Request]:
        for _ in range(max_ticks):
            with self._lock:
                idle = not self.queue and not self.active
            if idle:
                break
            self.step()
        return self.finished

    # -- ticketed (threaded) API --------------------------------------------
    def submit(self, tenant: str, prompt: np.ndarray, *,
               max_tokens: int = 16, eos_id: Optional[int] = None,
               request_id: Optional[str] = None) -> LMTicket:
        """Concurrent front door: enqueue one request and return a
        ticket; a background pump thread drives :meth:`step` while work
        remains. Raises :class:`AdmissionError` when rejected."""
        rid = request_id or f"req-{len(self._tenant_of)}"
        req = Request(rid, np.asarray(prompt, np.int32),
                      max_tokens=max_tokens, eos_id=eos_id)
        tk = LMTicket(tenant, req)
        self._tickets[rid] = tk
        try:
            self.add_request(req, tenant)
        except AdmissionError:
            self._tickets.pop(rid, None)
            raise
        self._ensure_pump()
        return tk

    def queue_depth(self) -> int:
        with self._lock:
            return len(self.queue)

    def _ensure_pump(self) -> None:
        if self._pump is None or not self._pump.is_alive():
            self._pump_stop.clear()
            self._pump = threading.Thread(target=self._pump_loop,
                                          daemon=True, name="serve-lm")
            self._pump.start()

    def _pump_loop(self) -> None:
        idle_ticks = 0
        while not self._pump_stop.is_set():
            with self._lock:
                busy = bool(self.queue) or bool(self.active)
            if busy or self.active:
                self.step()
                idle_ticks = 0
            else:
                idle_ticks += 1
                if idle_ticks > 200:    # ~1 s of quiet: park the pump
                    return
                time.sleep(0.005)

    def close(self) -> None:
        self._pump_stop.set()
        if self._pump is not None:
            self._pump.join(5.0)
        for ref in self._chain + [self._state]:
            try:
                self.rt.release(ref)
            except Exception:       # noqa: BLE001 — fleet may be gone
                pass
        self._chain = []

    def telemetry(self) -> Dict[str, Any]:
        out = {
            "ticks": self.ticks,
            "prefills": self.prefills,
            "tokens_generated": self.tokens_generated,
            "anchors": self.anchors,
            "queued": self.queue_depth(),
            "active": len(self.active),
            "finished": len(self.finished),
            "slot_utilization": self.slots.utilization(),
            "latency": {
                "ttft_ms": ClusterServeEngine._latency(self._h_ttft),
                "tpot_ms": ClusterServeEngine._latency(self._h_tpot),
                "e2e_ms": ClusterServeEngine._latency(self._h_e2e),
            },
            "tenants": {
                "requests": dict(self._t_requests),
                "rejections": dict(self._t_rejections),
                "tokens": dict(self._t_tokens),
            },
        }
        if self.admission is not None:
            out["admission"] = self.admission.telemetry()
        return out
