"""Admission control for the multi-tenant serving plane.

Every request entering :class:`repro.serve.cluster_engine.ClusterServeEngine`
passes through one :class:`AdmissionController` before it may occupy
queue space: a global bounded queue (backpressure toward the load
balancer, not unbounded memory growth on the head) plus per-tenant
quotas — a max-in-flight cap and a token-bucket rate budget. Rejection
is **explicit** (an :class:`AdmissionError` carrying a machine-readable
reason) and **counted** per tenant, so a saturated fleet degrades into
measured 429s instead of latency collapse.

The controller is pure bookkeeping — no threads, no cluster handle —
and takes an injectable monotonic ``clock`` so quota math unit-tests
without sleeping.
"""

from __future__ import annotations

import math
import threading
import time
from dataclasses import dataclass
from typing import Callable, Dict, Optional

__all__ = ["TenantQuota", "AdmissionError", "AdmissionController"]

# rejection reasons (stable strings: they key telemetry dicts)
REASON_QUEUE_FULL = "queue_full"
REASON_INFLIGHT = "quota_inflight"
REASON_RATE = "rate"


@dataclass(frozen=True)
class TenantQuota:
    """Per-tenant admission budget.

    ``max_inflight`` bounds requests admitted but not yet finished;
    ``rate_per_s`` is a token-bucket refill rate (``inf`` = unmetered)
    with ``burst`` tokens of headroom (defaults to ``rate_per_s`` so a
    one-second burst is always admissible, min 1)."""

    max_inflight: int = 8
    rate_per_s: float = math.inf
    burst: Optional[float] = None

    def burst_tokens(self) -> float:
        if self.burst is not None:
            return max(1.0, float(self.burst))
        if math.isinf(self.rate_per_s):
            return math.inf
        return max(1.0, float(self.rate_per_s))


class AdmissionError(RuntimeError):
    """Explicit rejection: ``reason`` is one of ``queue_full`` /
    ``quota_inflight`` / ``rate``."""

    def __init__(self, tenant: str, reason: str, detail: str = ""):
        self.tenant = tenant
        self.reason = reason
        msg = f"request rejected for tenant {tenant!r}: {reason}"
        if detail:
            msg += f" ({detail})"
        super().__init__(msg)


class _Bucket:
    __slots__ = ("tokens", "stamp")

    def __init__(self, tokens: float, stamp: float):
        self.tokens = tokens
        self.stamp = stamp


class AdmissionController:
    """Bounded queue + per-tenant quotas with explicit, counted
    rejection.

    ``admit(tenant)`` either raises :class:`AdmissionError` or records
    one in-flight request; the engine must pair every successful admit
    with exactly one ``release(tenant)`` when the request finishes
    (success or failure). ``queued`` is tracked here too so the global
    bound covers admitted-but-not-yet-dispatched requests.
    """

    def __init__(self, quotas: Optional[Dict[str, TenantQuota]] = None,
                 *, default: TenantQuota = TenantQuota(),
                 max_queue: int = 64,
                 clock: Callable[[], float] = time.monotonic):
        self.quotas = dict(quotas or {})
        self.default = default
        self.max_queue = max_queue
        self.clock = clock
        self._lock = threading.Lock()
        self._inflight: Dict[str, int] = {}
        self._buckets: Dict[str, _Bucket] = {}
        self.queued = 0
        # telemetry: {tenant: count} / {tenant: {reason: count}}
        self.admitted: Dict[str, int] = {}
        self.rejected: Dict[str, Dict[str, int]] = {}

    def quota_for(self, tenant: str) -> TenantQuota:
        return self.quotas.get(tenant, self.default)

    def _reject(self, tenant: str, reason: str, detail: str = ""):
        by = self.rejected.setdefault(tenant, {})
        by[reason] = by.get(reason, 0) + 1
        raise AdmissionError(tenant, reason, detail)

    def admit(self, tenant: str) -> None:
        """Admit one request for ``tenant`` or raise
        :class:`AdmissionError`. On success the request counts as both
        queued and in-flight until :meth:`release`."""
        q = self.quota_for(tenant)
        with self._lock:
            if self.queued >= self.max_queue:
                self._reject(tenant, REASON_QUEUE_FULL,
                             f"{self.queued}/{self.max_queue} queued")
            inflight = self._inflight.get(tenant, 0)
            if inflight >= q.max_inflight:
                self._reject(tenant, REASON_INFLIGHT,
                             f"{inflight}/{q.max_inflight} in flight")
            if not math.isinf(q.rate_per_s):
                now = self.clock()
                b = self._buckets.get(tenant)
                if b is None:
                    b = _Bucket(q.burst_tokens(), now)
                    self._buckets[tenant] = b
                b.tokens = min(q.burst_tokens(),
                               b.tokens + (now - b.stamp) * q.rate_per_s)
                b.stamp = now
                if b.tokens < 1.0:
                    self._reject(tenant, REASON_RATE,
                                 f"{q.rate_per_s}/s budget exhausted")
                b.tokens -= 1.0
            self._inflight[tenant] = inflight + 1
            self.queued += 1
            self.admitted[tenant] = self.admitted.get(tenant, 0) + 1

    def dequeued(self) -> None:
        """A request left the queue for execution (still in-flight)."""
        with self._lock:
            self.queued = max(0, self.queued - 1)

    def release(self, tenant: str) -> None:
        """A request finished (fulfilled or failed after admission)."""
        with self._lock:
            n = self._inflight.get(tenant, 0)
            if n > 0:
                self._inflight[tenant] = n - 1

    def inflight(self, tenant: Optional[str] = None) -> int:
        with self._lock:
            if tenant is not None:
                return self._inflight.get(tenant, 0)
            return sum(self._inflight.values())

    def telemetry(self) -> Dict[str, object]:
        with self._lock:
            return {
                "queued": self.queued,
                "max_queue": self.max_queue,
                "inflight": dict(self._inflight),
                "admitted": dict(self.admitted),
                "rejected": {t: dict(r) for t, r in self.rejected.items()},
            }
