"""Batched serving engine with continuous batching.

Requests are admitted into free cache slots (prefill), then all active
slots advance together through one jit'd batched decode step per tick —
new requests join between ticks without recompilation (static shapes).
Greedy sampling; per-request max_tokens / eos termination.
"""

from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs

from repro.models import transformer as T
from repro.models.common import ArchConfig

from .kvcache import SlotMap
from .request import Request

__all__ = ["Request", "ServeEngine"]


class ServeEngine:
    """Continuous-batching engine.

    ``kernel_registry`` (a :class:`repro.profiler.specializer.Specializer`
    or a plain ``{name: CompiledKernel}`` dict) and ``variant_cache``
    (:class:`repro.profiler.cache.VariantCache`) are optional attachments;
    when present, :meth:`telemetry` folds their dispatch/cache counters
    into the engine's serving stats so one endpoint answers "what is the
    compiler doing under this traffic".

    Serving counters are registry-backed (``serve#N`` scope of
    ``obs.metrics``) via MetricAttr descriptors — attribute semantics
    unchanged, values readable alongside cluster/kernel metrics."""

    ticks = obs.MetricAttr("ticks")
    prefills = obs.MetricAttr("prefills")
    tokens_generated = obs.MetricAttr("tokens_generated")

    def __init__(self, params, cfg: ArchConfig, *, n_slots: int = 4,
                 max_seq: int = 256, kernel_registry=None,
                 variant_cache=None):
        self.params = params
        self.cfg = cfg
        self.max_seq = max_seq
        self.slots = SlotMap(n_slots)
        self.caches = T.init_caches(cfg, n_slots, max_seq)
        self.queue: List[Request] = []
        self.active: Dict[int, Request] = {}
        self.finished: List[Request] = []
        self.kernel_registry = kernel_registry
        self.variant_cache = variant_cache
        self._tenant_of: Dict[str, str] = {}
        self._mscope = obs.metrics.unique_scope("serve")
        self.ticks = 0
        self.prefills = 0
        self.tokens_generated = 0

        def _prefill(params, tokens):
            return T.prefill(params, {"tokens": tokens}, cfg, max_seq)

        def _decode(params, tokens, caches):
            return T.decode_step(params, tokens, caches, cfg)

        self._prefill = jax.jit(_prefill)
        self._decode = jax.jit(_decode)
        self._insert = jax.jit(self._insert_impl)

    @staticmethod
    def _insert_impl(caches, one, slot):
        """Write a batch-1 cache into batched caches at `slot`."""
        def ins(big, small):
            return jax.lax.dynamic_update_slice_in_dim(
                big, small.astype(big.dtype), slot, axis=1)

        return jax.tree.map(ins, caches, one)

    # latency histograms + per-tenant counters resolve through the
    # instance scope lazily (same contract as MetricAttr: an instance
    # built via __new__ in tests still gets a working telemetry surface)
    @property
    def _h_ttft(self):
        return obs.MetricAttr._scope_of(self).histogram("ttft_ms")

    @property
    def _h_tpot(self):
        return obs.MetricAttr._scope_of(self).histogram("tpot_ms")

    @property
    def _h_e2e(self):
        return obs.MetricAttr._scope_of(self).histogram("e2e_ms")

    @property
    def _t_requests(self):
        return obs.MetricAttr._scope_of(self).dictmetric("tenant_requests")

    @property
    def _t_tokens(self):
        return obs.MetricAttr._scope_of(self).dictmetric("tenant_tokens")

    # -- API ----------------------------------------------------------------
    def add_request(self, req: Request, tenant: str = "default") -> None:
        self._tenant_of[req.request_id] = tenant
        self._t_requests[tenant] = self._t_requests.get(tenant, 0) + 1
        self.queue.append(req)

    def _admit(self) -> None:
        while self.queue:
            slot = self.slots.allocate(self.queue[0].request_id)
            if slot is None:
                return
            req = self.queue.pop(0)
            req.slot = slot
            tokens = jnp.asarray(req.prompt, jnp.int32)[None]
            one_cache, logits = self._prefill(self.params, tokens)
            tok = int(jnp.argmax(logits[0]))
            req.generated.append(tok)
            self.prefills += 1
            self._count_token(req)
            req.first_token_s = time.perf_counter()
            self.caches = self._insert(self.caches, one_cache,
                                       jnp.int32(slot))
            self.slots.lengths[slot] = len(req.prompt) + 1
            self.active[slot] = req

    def step(self) -> int:
        """One engine tick: admit + one batched decode. Returns number of
        active requests after the tick."""
        self._admit()
        self.ticks += 1
        if not self.active:
            return 0
        n_slots = self.slots.n_slots
        tokens = np.zeros((n_slots, 1), np.int32)
        for slot, req in self.active.items():
            tokens[slot, 0] = req.generated[-1]
        logits, self.caches = self._decode(self.params,
                                           jnp.asarray(tokens),
                                           self.caches)
        next_tokens = np.asarray(jnp.argmax(logits, axis=-1))
        done_slots = []
        for slot, req in self.active.items():
            tok = int(next_tokens[slot])
            req.generated.append(tok)
            self._count_token(req)
            self.slots.advance(slot)
            if (len(req.generated) >= req.max_tokens
                    or (req.eos_id is not None and tok == req.eos_id)
                    or self.slots.lengths[slot] >= self.max_seq - 1):
                req.finished_s = time.perf_counter()
                done_slots.append(slot)
        for slot in done_slots:
            req = self.active.pop(slot)
            self._observe_finish(req)
            self.finished.append(req)
            self.slots.free(slot)
        return len(self.active)

    def _count_token(self, req: Request) -> None:
        self.tokens_generated += 1
        tenant = self._tenant_of.get(req.request_id, "default")
        self._t_tokens[tenant] = self._t_tokens.get(tenant, 0) + 1

    def _observe_finish(self, req: Request) -> None:
        """Land the request's latency stamps in the ``serve#N``
        histograms (TTFT / per-output-token / end-to-end)."""
        n_gen = max(1, len(req.generated) - 1)
        self._h_ttft.observe((req.first_token_s - req.submitted_s) * 1e3)
        self._h_e2e.observe((req.finished_s - req.submitted_s) * 1e3)
        self._h_tpot.observe(
            (req.finished_s - req.first_token_s) * 1e3 / n_gen)

    def run_until_done(self, max_ticks: int = 10_000) -> List[Request]:
        for _ in range(max_ticks):
            if not self.queue and not self.active:
                break
            self.step()
        return self.finished

    # -- telemetry ----------------------------------------------------------
    def telemetry(self) -> Dict[str, object]:
        """Serving + compiler-dispatch + variant-cache counters."""
        out: Dict[str, object] = {
            "ticks": self.ticks,
            "prefills": self.prefills,
            "tokens_generated": self.tokens_generated,
            "queued": len(self.queue),
            "active": len(self.active),
            "finished": len(self.finished),
            "slot_utilization": self.slots.utilization(),
            "latency": {
                name: {"count": h.count, "mean": round(h.mean, 6),
                       "p50": h.percentile(50), "p95": h.percentile(95),
                       "p99": h.percentile(99)}
                for name, h in (("ttft_ms", self._h_ttft),
                                ("tpot_ms", self._h_tpot),
                                ("e2e_ms", self._h_e2e))},
            "tenants": {"requests": dict(self._t_requests),
                        "tokens": dict(self._t_tokens)},
        }
        reg = self.kernel_registry
        if reg is not None:
            if hasattr(reg, "telemetry"):        # Specializer
                out["kernels"] = reg.telemetry()
            else:                                # plain dict of kernels
                out["kernels"] = {
                    name: ck.stats() for name, ck in reg.items()
                    if hasattr(ck, "stats")}
        if self.variant_cache is not None:
            out["variant_cache"] = self.variant_cache.telemetry()
        return out
