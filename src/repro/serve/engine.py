"""Batched serving engine with continuous batching.

Requests are admitted into free cache slots (prefill), then all active
slots advance together through one jit'd batched decode step per tick —
new requests join between ticks without recompilation (static shapes).
Greedy sampling; per-request max_tokens / eos termination.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.models import transformer as T
from repro.models.common import ArchConfig

from .kvcache import SlotMap


@dataclass
class Request:
    request_id: str
    prompt: np.ndarray                  # (S,) int32
    max_tokens: int = 16
    eos_id: Optional[int] = None
    generated: List[int] = field(default_factory=list)
    slot: Optional[int] = None
    submitted_s: float = field(default_factory=time.perf_counter)
    first_token_s: Optional[float] = None
    finished_s: Optional[float] = None


class ServeEngine:
    """Continuous-batching engine.

    ``kernel_registry`` (a :class:`repro.profiler.specializer.Specializer`
    or a plain ``{name: CompiledKernel}`` dict) and ``variant_cache``
    (:class:`repro.profiler.cache.VariantCache`) are optional attachments;
    when present, :meth:`telemetry` folds their dispatch/cache counters
    into the engine's serving stats so one endpoint answers "what is the
    compiler doing under this traffic".

    Serving counters are registry-backed (``serve#N`` scope of
    ``obs.metrics``) via MetricAttr descriptors — attribute semantics
    unchanged, values readable alongside cluster/kernel metrics."""

    ticks = obs.MetricAttr("ticks")
    prefills = obs.MetricAttr("prefills")
    tokens_generated = obs.MetricAttr("tokens_generated")

    def __init__(self, params, cfg: ArchConfig, *, n_slots: int = 4,
                 max_seq: int = 256, kernel_registry=None,
                 variant_cache=None):
        self.params = params
        self.cfg = cfg
        self.max_seq = max_seq
        self.slots = SlotMap(n_slots)
        self.caches = T.init_caches(cfg, n_slots, max_seq)
        self.queue: List[Request] = []
        self.active: Dict[int, Request] = {}
        self.finished: List[Request] = []
        self.kernel_registry = kernel_registry
        self.variant_cache = variant_cache
        self._mscope = obs.metrics.unique_scope("serve")
        self.ticks = 0
        self.prefills = 0
        self.tokens_generated = 0

        def _prefill(params, tokens):
            return T.prefill(params, {"tokens": tokens}, cfg, max_seq)

        def _decode(params, tokens, caches):
            return T.decode_step(params, tokens, caches, cfg)

        self._prefill = jax.jit(_prefill)
        self._decode = jax.jit(_decode)
        self._insert = jax.jit(self._insert_impl)

    @staticmethod
    def _insert_impl(caches, one, slot):
        """Write a batch-1 cache into batched caches at `slot`."""
        def ins(big, small):
            return jax.lax.dynamic_update_slice_in_dim(
                big, small.astype(big.dtype), slot, axis=1)

        return jax.tree.map(ins, caches, one)

    # -- API ----------------------------------------------------------------
    def add_request(self, req: Request) -> None:
        self.queue.append(req)

    def _admit(self) -> None:
        while self.queue:
            slot = self.slots.allocate(self.queue[0].request_id)
            if slot is None:
                return
            req = self.queue.pop(0)
            req.slot = slot
            tokens = jnp.asarray(req.prompt, jnp.int32)[None]
            one_cache, logits = self._prefill(self.params, tokens)
            tok = int(jnp.argmax(logits[0]))
            req.generated.append(tok)
            self.prefills += 1
            self.tokens_generated += 1
            req.first_token_s = time.perf_counter()
            self.caches = self._insert(self.caches, one_cache,
                                       jnp.int32(slot))
            self.slots.lengths[slot] = len(req.prompt) + 1
            self.active[slot] = req

    def step(self) -> int:
        """One engine tick: admit + one batched decode. Returns number of
        active requests after the tick."""
        self._admit()
        self.ticks += 1
        if not self.active:
            return 0
        n_slots = self.slots.n_slots
        tokens = np.zeros((n_slots, 1), np.int32)
        for slot, req in self.active.items():
            tokens[slot, 0] = req.generated[-1]
        logits, self.caches = self._decode(self.params,
                                           jnp.asarray(tokens),
                                           self.caches)
        next_tokens = np.asarray(jnp.argmax(logits, axis=-1))
        done_slots = []
        for slot, req in self.active.items():
            tok = int(next_tokens[slot])
            req.generated.append(tok)
            self.tokens_generated += 1
            self.slots.advance(slot)
            if (len(req.generated) >= req.max_tokens
                    or (req.eos_id is not None and tok == req.eos_id)
                    or self.slots.lengths[slot] >= self.max_seq - 1):
                req.finished_s = time.perf_counter()
                done_slots.append(slot)
        for slot in done_slots:
            self.finished.append(self.active.pop(slot))
            self.slots.free(slot)
        return len(self.active)

    def run_until_done(self, max_ticks: int = 10_000) -> List[Request]:
        for _ in range(max_ticks):
            if not self.queue and not self.active:
                break
            self.step()
        return self.finished

    # -- telemetry ----------------------------------------------------------
    def telemetry(self) -> Dict[str, object]:
        """Serving + compiler-dispatch + variant-cache counters."""
        out: Dict[str, object] = {
            "ticks": self.ticks,
            "prefills": self.prefills,
            "tokens_generated": self.tokens_generated,
            "queued": len(self.queue),
            "active": len(self.active),
            "finished": len(self.finished),
            "slot_utilization": self.slots.utilization(),
        }
        reg = self.kernel_registry
        if reg is not None:
            if hasattr(reg, "telemetry"):        # Specializer
                out["kernels"] = reg.telemetry()
            else:                                # plain dict of kernels
                out["kernels"] = {
                    name: ck.stats() for name, ck in reg.items()
                    if hasattr(ck, "stats")}
        if self.variant_cache is not None:
            out["variant_cache"] = self.variant_cache.telemetry()
        return out
