"""Serving layer: single-process continuous batching
(:mod:`repro.serve.engine`) and the multi-tenant cluster serving plane
(:mod:`repro.serve.cluster_engine`).

Import note: :class:`ServeEngine` pulls in jax, so it is *not*
re-exported here — the admission/loadgen/coalescing machinery stays
importable on jax-free processes (cluster workers resolving shipped
functions by reference must import this package cheaply).
"""

from .admission import AdmissionController, AdmissionError, TenantQuota
from .cluster_engine import (BatchSpec, ClusterLMEngine,
                             ClusterServeEngine, LMTicket, ServeTicket)
from .loadgen import LoadResult, open_loop

__all__ = [
    "AdmissionController", "AdmissionError", "TenantQuota",
    "BatchSpec", "ClusterServeEngine", "ClusterLMEngine",
    "ServeTicket", "LMTicket", "LoadResult", "open_loop",
]
