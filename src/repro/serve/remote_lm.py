"""Worker-side task functions for cluster LM serving.

The cluster ships task functions with :func:`repro.distrib.serial.dumps_fn`,
which pickles a function's non-module globals **by value**. A bare
module-level dict referenced from a shipped function would therefore
arrive as a private copy per task — a jit cache that never hits. The
rule this module is built around: shipped entry points
(``lm_boot``/``lm_prefill``/``lm_decode``/``lm_out``/``lm_anchor``)
reference only module-level *functions* (pickle serializes those by
reference, so the worker imports this module and resolves the real
objects). All mutable state — the per-config jit cache ``_JITS`` —
lives behind those by-reference functions and persists across tasks
inside each worker process.

State travels as a :class:`Resident`: a wrapper whose ``nbytes``
reports at least 64 KiB so the worker's result-residency rule
(``repro.distrib.worker.INLINE_MAX``) keeps the params+KV state in the
worker's object store instead of inlining it back to the head every
tick. Only ``lm_out``'s token vector — a few bytes — rides the wire
per decode step. Pickling (lineage anchors, head fetches for
re-anchoring) converts jax leaves to numpy so a Resident crosses
processes without a live jax runtime on the sending side's devices.

The decode math is a transplant of :class:`repro.serve.engine.ServeEngine`
(same prefill → argmax → insert → batched decode ordering, same
explicit-dtype model code), which is what makes the cluster engine's
token streams **bitwise-identical** to the single-process engine.
"""

from __future__ import annotations

import numpy as np

__all__ = ["Resident", "lm_boot", "lm_prefill", "lm_decode", "lm_out",
           "lm_anchor", "tree_np"]

# worker residency floor: anything reporting more bytes than
# repro.distrib.worker.INLINE_MAX stays in the worker object store
_RESIDENT_FLOOR = 1 << 16

_JITS: dict = {}   # (cfg.name, dtype, max_seq) → (prefill, decode, insert)


def tree_np(tree):
    """Recursively convert array leaves (jax or numpy) to numpy."""
    if isinstance(tree, dict):
        return {k: tree_np(v) for k, v in tree.items()}
    if isinstance(tree, (list, tuple)):
        return type(tree)(tree_np(v) for v in tree)
    if hasattr(tree, "shape") and hasattr(tree, "dtype"):
        return np.asarray(tree)
    return tree


def _tree_nbytes(tree) -> int:
    if isinstance(tree, dict):
        return sum(_tree_nbytes(v) for v in tree.values())
    if isinstance(tree, (list, tuple)):
        return sum(_tree_nbytes(v) for v in tree)
    return int(getattr(tree, "nbytes", 0) or 0)


class Resident:
    """Worker-resident serving state (+ the small per-step output that
    :func:`lm_out` extracts for the head)."""

    def __init__(self, value, out=None):
        self.value = value
        self.out = out
        self.nbytes = max(_tree_nbytes(value), _RESIDENT_FLOOR)

    def __getstate__(self):
        return {"value": tree_np(self.value), "out": tree_np(self.out),
                "nbytes": self.nbytes}

    def __setstate__(self, state):
        self.__dict__.update(state)


def _jits_for(cfg, max_seq: int):
    """Per-(config, max_seq) jitted prefill/decode/insert, cached for
    the life of the worker process — call 2 of a serving loop hits a
    compiled executable."""
    key = (cfg.name, str(getattr(cfg, "dtype", "")), int(max_seq))
    entry = _JITS.get(key)
    if entry is None:
        import jax
        from repro.models import transformer as T

        def _prefill(params, tokens):
            return T.prefill(params, {"tokens": tokens}, cfg, max_seq)

        def _decode(params, tokens, caches):
            return T.decode_step(params, tokens, caches, cfg)

        def _insert(caches, one, slot):
            def ins(big, small):
                return jax.lax.dynamic_update_slice_in_dim(
                    big, small.astype(big.dtype), slot, axis=1)
            return jax.tree.map(ins, caches, one)

        entry = (jax.jit(_prefill), jax.jit(_decode), jax.jit(_insert))
        _JITS[key] = entry
    return entry


def _boot_impl(params, cfg, n_slots: int, max_seq: int) -> Resident:
    from repro.models import transformer as T
    caches = T.init_caches(cfg, n_slots, max_seq)
    state = {"params": params, "caches": caches, "cfg": cfg,
             "n_slots": int(n_slots), "max_seq": int(max_seq)}
    return Resident(state, out=np.zeros(0, np.int32))


def _prefill_impl(res: Resident, prompt, slot: int) -> Resident:
    import jax.numpy as jnp
    st = res.value
    jit_prefill, _, jit_insert = _jits_for(st["cfg"], st["max_seq"])
    tokens = jnp.asarray(prompt, jnp.int32)[None]
    one_cache, logits = jit_prefill(st["params"], tokens)
    tok = int(jnp.argmax(logits[0]))
    caches = jit_insert(st["caches"], one_cache, jnp.int32(slot))
    new = dict(st)
    new["caches"] = caches
    return Resident(new, out=np.asarray([tok], np.int32))


def _decode_impl(res: Resident, tokens) -> Resident:
    import jax.numpy as jnp
    st = res.value
    _, jit_decode, _ = _jits_for(st["cfg"], st["max_seq"])
    logits, caches = jit_decode(st["params"], jnp.asarray(tokens),
                                st["caches"])
    next_tokens = np.asarray(jnp.argmax(logits, axis=-1), np.int32)
    new = dict(st)
    new["caches"] = caches
    return Resident(new, out=next_tokens)


# -- shipped entry points (reference only module-level functions) -----------

def lm_boot(params, cfg, n_slots, max_seq):
    """Materialize fresh serving state (params + empty KV caches)."""
    return _boot_impl(params, cfg, n_slots, max_seq)


def lm_prefill(state, prompt, slot):
    """Prefill one prompt into ``slot``; out = its first greedy token."""
    return _prefill_impl(state, prompt, slot)


def lm_decode(state, tokens):
    """One batched decode tick; out = next token per slot."""
    return _decode_impl(state, tokens)


def lm_out(state):
    """Extract the small per-step output (inlined back to the head)."""
    return np.asarray(state.out)


def lm_anchor(state):
    """Re-root lineage: the head attaches the full state value to this
    task's spec, so replay after a worker loss restarts here instead of
    walking the whole decode history."""
    return state
