"""Serving KV-cache management: fixed-slot batched cache with per-slot
occupancy — the static-shape (XLA-friendly) sibling of paged attention.

The engine keeps a cache of shape (slots, …, max_seq, …) per layer; a slot
map tracks which request occupies which slot and its current length.
Freeing is O(1) (occupancy bit), insertion finds the first free slot —
continuous batching without dynamic shapes.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np


@dataclass
class SlotMap:
    n_slots: int
    occupied: np.ndarray = None          # bool (slots,)
    lengths: np.ndarray = None           # int32 (slots,)
    request_ids: List[Optional[str]] = None

    def __post_init__(self):
        self.occupied = np.zeros(self.n_slots, bool)
        self.lengths = np.zeros(self.n_slots, np.int32)
        self.request_ids = [None] * self.n_slots

    def allocate(self, request_id: str, length: int = 0) -> Optional[int]:
        free = np.flatnonzero(~self.occupied)
        if free.size == 0:
            return None
        slot = int(free[0])
        self.occupied[slot] = True
        self.lengths[slot] = length
        self.request_ids[slot] = request_id
        return slot

    def free(self, slot: int) -> None:
        self.occupied[slot] = False
        self.lengths[slot] = 0
        self.request_ids[slot] = None

    def advance(self, slot: int, by: int = 1) -> None:
        self.lengths[slot] += by

    @property
    def active_slots(self) -> np.ndarray:
        return np.flatnonzero(self.occupied)

    def utilization(self) -> float:
        return float(self.occupied.mean())
