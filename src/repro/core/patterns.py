"""Kernel-shape pattern matching over scheduled pfor units.

The pallas backend (``core/backends.py``) does not lower arbitrary unit
bodies: it recognizes three fixed shapes — matmul-, attention- and
scan-shaped pfor bodies — and rewrites each onto the corresponding seed
Pallas kernel behind :mod:`repro.kernels.api` (bound as ``__plk`` in the
twin's namespace). Matching is deliberately conservative: any structure
outside the template (extra statements, augmented writes, non-unit
strides, affine indices that are not plain loop variables, bounds that
depend on the pfor variable or on codegen-internal shape symbols) means
*no match* and the unit simply keeps its np/jnp twins.

A match produces the twin's body lines in chunk form: the pfor variable
``g`` becomes the block slice ``__lo:__hi`` and every reduction /
free dimension becomes its hull-bound slice, so one ``__plk`` call
covers the whole chunk. Writes go through the captured numpy arrays
(:class:`repro.distrib.serial.ChunkSlice` re-bases slice keys on the
leading axis, so global ``[__lo:__hi]`` coordinates stay correct on
workers that only hold their chunk's rows).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, List, Optional, Tuple

from .isl_lite import Affine, LoopDim
from .schedule import PforUnit, RaisedUnit, SeqLoopUnit
from .scop import VAccess, VBin, VConst, VParam, VReduce, VUnary


class _NoMatch(Exception):
    pass


@dataclass
class KernelMatch:
    """One recognized unit body, ready to emit as a pallas twin."""

    kind: str                 # 'matmul' | 'attention' | 'scan'
    body_lines: List[str]     # twin body, chunk form (uses __lo/__hi)
    arrays: Tuple[str, ...] = ()


# ---------------------------------------------------------------------------
# small affine predicates
# ---------------------------------------------------------------------------

def _is_var(a, var: str) -> bool:
    return (isinstance(a, Affine) and a.const == 0
            and a.coeffs == ((var, 1),))


def _pure_var(a) -> Optional[str]:
    if isinstance(a, Affine) and a.const == 0 and len(a.coeffs) == 1 \
            and a.coeffs[0][1] == 1:
        return a.coeffs[0][0]
    return None


def _bound_ok(a: Affine, g: str) -> bool:
    """A bound we may re-emit inside the twin: free of the pfor var and
    of compiler-internal symbols (deferred shape syms like ``p__d0`` are
    only defined inside the np body's scope)."""
    for v, _c in a.coeffs:
        if v == g or v.startswith("_") or "__" in v:
            return False
    return True


def _sl(d: LoopDim, g: str) -> str:
    """Render a loop dim as a python slice, or refuse."""
    if d.step != 1 or not _bound_ok(d.lower, g) or not _bound_ok(d.upper, g):
        raise _NoMatch
    from .codegen import affine_py
    return f"{affine_py(d.lower)}:{affine_py(d.upper)}"


def _dims_eq(a: LoopDim, b: LoopDim) -> bool:
    return a.lower == b.lower and a.upper == b.upper and a.step == b.step


# ---------------------------------------------------------------------------
# elementwise expression rendering
# ---------------------------------------------------------------------------

_EW_BIN = ("+", "-", "*", "/", "**")
_EW_UNARY = ("np.exp", "np.sqrt", "np.abs", "np.tanh", "np.log",
             "np.log1p", "np.sin", "np.cos", "-")


def _render(e, acc: Callable[[VAccess], str]) -> str:
    """Render an elementwise VExpr with ``acc`` deciding how each array
    access becomes a block slice. Anything outside the elementwise
    grammar (nested reductions, exotic ops) refuses the match."""
    if isinstance(e, VConst):
        return repr(e.value)
    if isinstance(e, VParam):
        return e.name
    if isinstance(e, VAccess):
        return acc(e)
    if isinstance(e, VBin) and e.op in _EW_BIN:
        return f"({_render(e.left, acc)} {e.op} {_render(e.right, acc)})"
    if isinstance(e, VUnary) and e.fn in _EW_UNARY:
        if e.fn == "-":
            return f"(-{_render(e.operand, acc)})"
        return f"xp.{e.fn[3:]}({_render(e.operand, acc)})"
    raise _NoMatch


def _accesses(e) -> List[VAccess]:
    """All VAccess leaves of an elementwise expr (VReduce refuses)."""
    if isinstance(e, VAccess):
        return [e]
    if isinstance(e, (VConst, VParam)):
        return []
    if isinstance(e, VBin):
        return _accesses(e.left) + _accesses(e.right)
    if isinstance(e, VUnary):
        return _accesses(e.operand)
    raise _NoMatch


def _idx_vars(e) -> set:
    out = set()
    for a in _accesses(e):
        for aff in a.idx:
            for v, _c in aff.coeffs:
                out.add(v)
    return out


def _mul_factors(e) -> List:
    """Flatten a multiplication tree into its factors."""
    if isinstance(e, VBin) and e.op == "*":
        return _mul_factors(e.left) + _mul_factors(e.right)
    return [e]


# ---------------------------------------------------------------------------
# matmul:   C[g, j] = sum_k  row(g, k) * mat(k, j)
# ---------------------------------------------------------------------------

def _match_matmul(u: PforUnit) -> Optional[KernelMatch]:
    if len(u.body) != 1 or not isinstance(u.body[0], RaisedUnit):
        return None
    s = u.body[0].stmt
    g = u.dim.var
    if s.aug is not None or s.write_full or len(s.write_idx) != 2:
        return None
    if not _is_var(s.write_idx[0], g):
        return None
    j = _pure_var(s.write_idx[1])
    if j is None or j == g:
        return None
    if len(s.domain.dims) != 1 or s.domain.dims[0].var != j:
        return None
    jdim = s.domain.dims[0]
    rhs = s.rhs
    if not (isinstance(rhs, VReduce) and rhs.op == "sum"
            and len(rhs.dims) == 1):
        return None
    kdim = rhs.dims[0]
    k = kdim.var
    if k in (g, j):
        return None
    try:
        js = _sl(jdim, g)
        ks = _sl(kdim, g)

        row_factors, mat_factors = [], []
        for f in _mul_factors(rhs.child):
            vs = _idx_vars(f)
            if not vs <= {g, j, k}:
                raise _NoMatch
            if j in vs:
                if g in vs:
                    raise _NoMatch       # mixed factor: not a matmul
                mat_factors.append(f)
            else:
                row_factors.append(f)
        if not mat_factors or not row_factors:
            raise _NoMatch

        def row_acc(a: VAccess) -> str:
            if a.array == s.write_array:
                raise _NoMatch
            pat = tuple(_pure_var(x) for x in a.idx)
            if pat == (g, k):
                return f"{a.array}[__lo:__hi, {ks}]"
            if pat == (k,):
                return f"{a.array}[{ks}]"
            if pat == (g,):
                return f"{a.array}[__lo:__hi, None]"
            raise _NoMatch

        def mat_acc(a: VAccess) -> str:
            if a.array == s.write_array:
                raise _NoMatch
            pat = tuple(_pure_var(x) for x in a.idx)
            if pat == (k, j):
                return f"{a.array}[{ks}, {js}]"
            if pat == (j,):
                return f"{a.array}[{js}]"
            if pat == (k,):
                return f"{a.array}[{ks}, None]"
            raise _NoMatch

        # the kernel needs genuinely 2-D operands: at least one (g, k)
        # access on the row side and one (k, j) access on the mat side
        if not any(tuple(_pure_var(x) for x in a.idx) == (g, k)
                   for f in row_factors for a in _accesses(f)):
            raise _NoMatch
        if not any(tuple(_pure_var(x) for x in a.idx) == (k, j)
                   for f in mat_factors for a in _accesses(f)):
            raise _NoMatch

        row = " * ".join(_render(f, row_acc) for f in row_factors)
        mat = " * ".join(_render(f, mat_acc) for f in mat_factors)
    except _NoMatch:
        return None
    arrays = tuple(sorted({a.array for a in _accesses(rhs.child)}))
    line = (f"{s.write_array}[__lo:__hi, {js}] = "
            f"__plk.matmul({row}, {mat})")
    return KernelMatch("matmul", [line], arrays)


# ---------------------------------------------------------------------------
# attention:  p[t] = exp(sum_d K[t,d]*Q[g,d])
#             O[g,j] = (sum_t p[t]*V[t,j]) / sum_t p[t]
# ---------------------------------------------------------------------------

def _match_attention(u: PforUnit) -> Optional[KernelMatch]:
    if len(u.body) != 2:
        return None
    if not all(isinstance(b, RaisedUnit) for b in u.body):
        return None
    ps, os_ = u.body[0].stmt, u.body[1].stmt
    g = u.dim.var

    # -- scores statement: p[t] = exp(sum_d K[t,d] * Q[g,d]) ---------------
    if ps.aug is not None or len(ps.write_idx) != 1:
        return None
    if len(ps.domain.dims) != 1:
        return None
    tdim = ps.domain.dims[0]
    t = tdim.var
    if not _is_var(ps.write_idx[0], t):
        return None
    p_name = ps.write_array
    rhs = ps.rhs
    if not (isinstance(rhs, VUnary) and rhs.fn == "np.exp"):
        return None
    red = rhs.operand
    if not (isinstance(red, VReduce) and red.op == "sum"
            and len(red.dims) == 1):
        return None
    ddim = red.dims[0]
    d = ddim.var
    prod = red.child
    if not (isinstance(prod, VBin) and prod.op == "*"
            and isinstance(prod.left, VAccess)
            and isinstance(prod.right, VAccess)):
        return None
    k_acc = q_acc = None
    for a in (prod.left, prod.right):
        pat = tuple(_pure_var(x) for x in a.idx)
        if pat == (t, d):
            k_acc = a
        elif pat == (g, d):
            q_acc = a
    if k_acc is None or q_acc is None:
        return None

    # -- combine statement: O[g,j] = sum_t p[t]*V[t,j] / sum_x p[x] --------
    if os_.aug is not None or os_.write_full or len(os_.write_idx) != 2:
        return None
    if not _is_var(os_.write_idx[0], g):
        return None
    j = _pure_var(os_.write_idx[1])
    if j is None or len(os_.domain.dims) != 1 or os_.domain.dims[0].var != j:
        return None
    jdim = os_.domain.dims[0]
    div = os_.rhs
    if not (isinstance(div, VBin) and div.op == "/"):
        return None
    num, den = div.left, div.right
    if not (isinstance(num, VReduce) and num.op == "sum"
            and len(num.dims) == 1 and _dims_eq(num.dims[0], tdim)):
        return None
    t2 = num.dims[0].var
    np_ = num.child
    if not (isinstance(np_, VBin) and np_.op == "*"
            and isinstance(np_.left, VAccess)
            and isinstance(np_.right, VAccess)):
        return None
    v_acc = None
    p_ok = False
    for a in (np_.left, np_.right):
        pat = tuple(_pure_var(x) for x in a.idx)
        if a.array == p_name and pat == (t2,):
            p_ok = True
        elif pat == (t2, j):
            v_acc = a
    if not p_ok or v_acc is None:
        return None
    if not (isinstance(den, VReduce) and den.op == "sum"
            and len(den.dims) == 1 and isinstance(den.child, VAccess)
            and den.child.array == p_name
            and _is_var(den.child.idx[0], den.dims[0].var)
            and len(den.child.idx) == 1):
        return None
    xdim = den.dims[0]
    # the denominator may be bounded by t's extent or by p's recorded
    # shape symbol (``p__d0``) — both mean "all of p"
    if not (xdim.lower == tdim.lower
            and (xdim.upper == tdim.upper
                 or xdim.upper == Affine(((f"{p_name}__d0", 1),), 0))):
        return None

    # no aliasing: p is a local temp, and the output must not be one of
    # the inputs; flash needs q/k/v to share the head dimension
    if p_name in (q_acc.array, k_acc.array, v_acc.array, os_.write_array):
        return None
    if os_.write_array in (q_acc.array, k_acc.array, v_acc.array):
        return None
    if not (_dims_eq(ddim, jdim)):
        return None
    try:
        ts = _sl(tdim, g)
        ds = _sl(ddim, g)
        js = _sl(jdim, g)
    except _NoMatch:
        return None
    line = (f"{os_.write_array}[__lo:__hi, {js}] = __plk.attention_rows("
            f"{q_acc.array}[__lo:__hi, {ds}], "
            f"{k_acc.array}[{ts}, {ds}], "
            f"{v_acc.array}[{ts}, {js}])")
    return KernelMatch("attention", [line],
                       (q_acc.array, k_acc.array, v_acc.array))


# ---------------------------------------------------------------------------
# scan:  h = 0.0; for t: h = c*h + X[g,t]; Y[g,t] = h
# ---------------------------------------------------------------------------

def _scan_coeff(e, h: str):
    """``c*h`` (either order) → render c, else None."""
    if not (isinstance(e, VBin) and e.op == "*"):
        return None
    for c, other in ((e.left, e.right), (e.right, e.left)):
        if isinstance(other, VParam) and other.name == h:
            if isinstance(c, VConst):
                # statically out of the stable range: never match, the
                # lowering (log of the decay) would be infeasible anyway
                try:
                    if not (0.0 < float(c.value) < 1.0):
                        return None
                except (TypeError, ValueError):
                    return None
                return repr(c.value)
            if isinstance(c, VParam) and c.name != h:
                return c.name
    return None


def _match_scan(u: PforUnit) -> Optional[KernelMatch]:
    if len(u.body) != 2:
        return None
    init_u, loop_u = u.body
    if not (isinstance(init_u, RaisedUnit) and isinstance(loop_u,
                                                          SeqLoopUnit)):
        return None
    g = u.dim.var
    init = init_u.stmt
    if not (init.write_full and init.aug is None and not init.write_idx
            and not init.domain.dims and isinstance(init.rhs, VConst)):
        return None
    try:
        if float(init.rhs.value) != 0.0:
            return None
    except (TypeError, ValueError):
        return None
    h = init.write_array
    tdim = loop_u.dim
    t = tdim.var
    if len(loop_u.body) != 2:
        return None
    if not all(isinstance(b, RaisedUnit) for b in loop_u.body):
        return None
    rec, out = loop_u.body[0].stmt, loop_u.body[1].stmt

    # h = c*h + X[g,t]   (either order of the sum)
    if not (rec.write_array == h and rec.write_full and rec.aug is None
            and not rec.domain.dims):
        return None
    if not (isinstance(rec.rhs, VBin) and rec.rhs.op == "+"):
        return None
    coeff = x_acc = None
    for a, b in ((rec.rhs.left, rec.rhs.right),
                 (rec.rhs.right, rec.rhs.left)):
        c = _scan_coeff(a, h)
        if (c is not None and isinstance(b, VAccess)
                and tuple(_pure_var(x) for x in b.idx) == (g, t)):
            coeff, x_acc = c, b
            break
    if coeff is None:
        return None

    # Y[g,t] = h
    if not (out.aug is None and not out.write_full
            and len(out.write_idx) == 2 and not out.domain.dims
            and _is_var(out.write_idx[0], g)
            and _is_var(out.write_idx[1], t)
            and isinstance(out.rhs, VParam) and out.rhs.name == h):
        return None
    if out.write_array in (x_acc.array, h):
        return None
    try:
        ts = _sl(tdim, g)
    except _NoMatch:
        return None
    line = (f"{out.write_array}[__lo:__hi, {ts}] = __plk.scan_rows("
            f"{x_acc.array}[__lo:__hi, {ts}], {coeff})")
    return KernelMatch("scan", [line], (x_acc.array,))


# ---------------------------------------------------------------------------

_MATCHERS = (_match_matmul, _match_attention, _match_scan)


def match_pfor_unit(u: PforUnit) -> Optional[KernelMatch]:
    """Recognize a pfor unit body as one of the pallas-lowerable kernel
    shapes, or None. Only exact template structure matches; every check
    is conservative (a false negative costs performance, a false
    positive would be a miscompile)."""
    if not isinstance(u, PforUnit) or u.dim.step != 1:
        return None
    for m in _MATCHERS:
        try:
            km = m(u)
        except _NoMatch:      # defensive: matchers normally catch this
            km = None
        if km is not None:
            return km
    return None
