"""Pluggable backend registry: one object per code-variant target.

Until this module existed, the np/jnp twin pair was hand-woven through
codegen (twin emission), cost (a hard-coded ``jnp`` branch), serial
(backend tags), and the cluster (bodies dict / ``TaskSpec.alt``). A
:class:`Backend` now owns everything that made those layers
backend-aware:

  * its **module binding** — the namespace symbol the generated twin
    computes through (``__jxp`` → ``jax.numpy``, ``__plk`` → the
    pallas lowering surface) and the importable module behind it (which
    is also how the twin ships to workers: a module global rides the
    serializer's existing module-by-name marker);
  * its **dtype map** — how annotation dtypes land on the device;
  * its **pfor-body codegen idiom** — an ``emit_twin`` hook the emitter
    calls per accelerator-feasible pfor unit (returning None when the
    unit does not fit this backend's shape);
  * its **compile hook** — the exec-namespace bindings a generated
    variant needs (``accel.pfor_jit`` is the jnp backend's hook);
  * its **cost profile** — the gflops/membw/launch-overhead terms
    :func:`repro.core.cost.pick_chunk_backend` prices a (unit, backend,
    worker) cell with;
  * its **serialization tag** — the token the variant-cache key and the
    cluster's per-chunk blob tagging derive from.

``codegen.emit_pfor`` iterates :func:`twin_backends` instead of
hard-coding a pair; the cluster's degradation chain
(:func:`degradation_chain`) and the compiler's cache tag
(:func:`cache_token`) are registry-derived. Adding an accelerator —
the ``pallas`` backend below, or CuPy/Triton later — is one
:func:`register` call, not a cross-layer sweep.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

__all__ = [
    "Backend", "BackendUnavailable", "register", "unregister", "get",
    "is_registered", "names", "twin_backends", "twin_names",
    "degradation_chain", "cache_token",
]


class BackendUnavailable(RuntimeError):
    """A registered backend's runtime dependency is missing."""


# Default device dtype map (PolyBench float64 semantics preserved on
# accelerators via x64; integer index math stays 64-bit).
_NP_DTYPES = {"f32": "float32", "f64": "float64",
              "i32": "int32", "i64": "int64"}


@dataclass
class Backend:
    """One retargetable code-variant target (slope/Loo.py-style)."""

    name: str
    # namespace symbol the twin body computes through, and the module
    # imported behind it ("" for np: the base variant's own ``xp``)
    xp_binding: str = ""
    module: str = ""
    # serialization/cache token component; bumping it invalidates cached
    # variants generated with an older codegen idiom for this backend
    codegen_version: int = 1
    # placement preference for chunks routed to this backend in a
    # heterogeneous round ('' | 'cpu' | 'gpu')
    device_pref: str = "cpu"
    # routing preference order: ties and zero-flop estimates resolve to
    # the highest-priority feasible candidate; degradation walks down
    priority: int = 0
    # whether codegen emits a per-unit pfor twin body for this backend
    twin: bool = False
    dtype_map: Dict[str, str] = field(default_factory=lambda: dict(_NP_DTYPES))
    # (emitter, unit, body_name, idx, pending_syms) -> twin fn name | None
    emit_twin: Optional[Callable[..., Optional[str]]] = None
    # (emit_meta) -> exec-namespace bindings for variants whose meta
    # records twin units of this backend
    namespace: Optional[Callable[[Any], Dict[str, Any]]] = None
    # (flops, nbytes, profile) -> estimated seconds for one chunk
    chunk_seconds: Optional[Callable[[float, float, Any], float]] = None
    # (profile) -> chunk-sizing throughput weight
    effective_gflops: Optional[Callable[[Any], float]] = None
    # (profile) -> can this worker run the twin at all
    feasible: Optional[Callable[[Any], bool]] = None

    @property
    def attr(self) -> str:
        """Attribute name the np body carries this twin under."""
        return f"__{self.name}__"

    @property
    def tag(self) -> str:
        """Serialization/cache token component."""
        return f"{self.name}{self.codegen_version}"


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, Backend] = {}


def register(backend: Backend) -> Backend:
    """Register (or replace) a backend. Registration order is the twin
    emission order; pricing/degradation order comes from ``priority``."""
    if backend.name == "np" and backend.twin:
        raise ValueError("the np base backend cannot be a twin")
    _REGISTRY[backend.name] = backend
    return backend


def unregister(name: str) -> Optional[Backend]:
    """Remove a backend (test isolation for toy registrations). The np
    base backend cannot be removed."""
    if name == "np":
        raise ValueError("cannot unregister the np base backend")
    return _REGISTRY.pop(name, None)


def get(name: str) -> Backend:
    return _REGISTRY[name]


def is_registered(name: str) -> bool:
    return name in _REGISTRY


def names() -> List[str]:
    return list(_REGISTRY)


def twin_backends() -> List[Backend]:
    """Twin-emitting backends in registration (= emission) order."""
    return [b for b in _REGISTRY.values() if b.twin]


def twin_names() -> List[str]:
    return [b.name for b in _REGISTRY.values() if b.twin]


def degradation_chain(name: str) -> List[str]:
    """Backends a failing chunk of ``name`` degrades through, ordered by
    descending priority and always ending at ``np`` — the
    ``TaskSpec.alt`` chain (pallas → jnp → np)."""
    start = _REGISTRY.get(name)
    pri = start.priority if start is not None else 0
    lower = sorted((b for b in _REGISTRY.values()
                    if b.twin and b.priority < pri and b.name != name),
                   key=lambda b: -b.priority)
    chain = [b.name for b in lower]
    if "np" not in chain and name != "np":
        chain.append("np")
    return chain


def cache_token(accel_ok: bool) -> str:
    """Registry-derived variant-cache token: sorted backend names, each
    with its codegen version. Twin backends are earned only when the
    accelerator runtime is actually importable (``accel_ok``), so a
    jax-less host files twin-less variants under the np-only token and
    recompiles with twins once jax appears. Distinct by construction
    from the pre-registry "np+jnpu" / "np+jnp" literals, so old cache
    entries miss into a recompile instead of serving stale code."""
    active = [b for b in _REGISTRY.values() if accel_ok or not b.twin]
    return "+".join(b.tag for b in sorted(active, key=lambda b: b.name))


# ---------------------------------------------------------------------------
# Cost-profile terms (imported by repro.core.cost; kept here so a
# backend's pricing rides its registration)
# ---------------------------------------------------------------------------

# Per-chunk accelerator launch overhead for the jnp twin (host→device
# staging + XLA dispatch); conservative so tiny chunks stay on np.
GPU_CHUNK_OVERHEAD_S = 5e-3

# Host↔device staging bandwidth fallback when the profile carries no
# measured number (PCIe-gen3-ish, GB/s).
GPU_XFER_GBS = 12.0

# Fused-kernel advantage of the pallas backend over the generic jnp op
# stream: tiled MXU-style compute and operands touched once instead of
# per-op re-materialization. Both the compute and transfer roofline
# terms improve by this factor, so a matched unit routes to pallas only
# where its arithmetic-intensity win is real — on a real device the
# (smaller) kernel-launch overhead still prices tiny chunks back to
# np/jnp.
PALLAS_FUSION_SPEEDUP = 1.6

# Per-chunk pallas kernel launch overhead on a real device (a compiled
# pallas_call dispatch is cheaper than a full XLA op-stream round).
PALLAS_CHUNK_OVERHEAD_S = 2e-3


def _np_chunk_seconds(flops: float, nbytes: float, profile) -> float:
    rate = max(1e-3, getattr(profile, "gflops", 1.0))
    membw = max(1e-3, getattr(profile, "membw_gbs", 1.0))
    return max(flops / (rate * 1e9), nbytes / (membw * 1e9))


def _gpu_xfer_overhead(profile) -> tuple:
    """(xfer_gbs, real_device) staging terms shared by the accelerator
    backends. A *simulated* GPU (jax-CPU posing for laptops/CI) prices
    like an integrated accelerator — no staging overhead, memory
    bandwidth as the transfer term; real devices use the bandwidth the
    device probe measured, falling back to the PCIe-ish constant."""
    if getattr(profile, "gpu_kind", "") == "sim":
        return max(1e-3, getattr(profile, "membw_gbs", 1.0)), False
    h2d = getattr(profile, "h2d_gbs", 0.0) or 0.0
    d2h = getattr(profile, "d2h_gbs", 0.0) or 0.0
    measured = (min(b for b in (h2d, d2h) if b > 0)
                if (h2d > 0 or d2h > 0) else 0.0)
    return (measured if measured > 0 else GPU_XFER_GBS), True


def _jnp_chunk_seconds(flops: float, nbytes: float, profile) -> float:
    rate = max(1e-3, getattr(profile, "gpu_gflops", 0.0))
    xfer_gbs, real = _gpu_xfer_overhead(profile)
    overhead = GPU_CHUNK_OVERHEAD_S if real else 0.0
    return max(flops / (rate * 1e9),
               nbytes / (xfer_gbs * 1e9)) + overhead


def _pallas_chunk_seconds(flops: float, nbytes: float, profile) -> float:
    rate = max(1e-3, getattr(profile, "gpu_gflops", 0.0)) \
        * PALLAS_FUSION_SPEEDUP
    xfer_gbs, real = _gpu_xfer_overhead(profile)
    xfer_gbs *= PALLAS_FUSION_SPEEDUP
    overhead = PALLAS_CHUNK_OVERHEAD_S if real else 0.0
    return max(flops / (rate * 1e9),
               nbytes / (xfer_gbs * 1e9)) + overhead


def _accel_feasible(profile) -> bool:
    return (getattr(profile, "has_gpu", False)
            and getattr(profile, "gpu_gflops", 0.0) > 0)


def _gpu_effective_gflops(profile) -> float:
    return max(1e-3, getattr(profile, "gpu_gflops", 0.0))


def _np_effective_gflops(profile) -> float:
    return max(1e-3, getattr(profile, "gflops", 1.0))


# ---------------------------------------------------------------------------
# Built-in backends
# ---------------------------------------------------------------------------

def _jnp_emit_twin(emitter, u, body_name: str, idx: int,
                   pending_syms) -> Optional[str]:
    return emitter._try_emit_jnp_twin(u, body_name, idx, pending_syms)


def _jnp_namespace(meta) -> Dict[str, Any]:
    """Exec bindings for variants with jnp twin units: jax.numpy under
    ``__jxp``, plus the ``__pfor_jit`` compile hook (vmap/jit/residency,
    :func:`repro.distrib.accel.pfor_jit`) for units that also carry the
    jit-iteration fast path."""
    try:
        import jax

        jax.config.update("jax_enable_x64", True)
        import jax.numpy as jnp
    except Exception as exc:
        raise BackendUnavailable(
            f"hybrid np variant references jax, which is unavailable: "
            f"{exc}")
    ns: Dict[str, Any] = {"__jxp": jnp}
    if getattr(meta, "pfor_jit_units", None):
        from repro.distrib.accel import pfor_jit

        ns["__jax"] = jax
        ns["__pfor_jit"] = pfor_jit
    return ns


def _pallas_emit_twin(emitter, u, body_name: str, idx: int,
                      pending_syms) -> Optional[str]:
    from .patterns import match_pfor_unit

    m = match_pfor_unit(u)
    if m is None:
        return None
    name = f"{body_name}__pallas"
    emitter.w(f"def {name}(__lo, __hi):")
    emitter.depth += 1
    for line in m.body_lines:
        emitter.w(line)
    emitter.depth -= 1
    return name


def _pallas_namespace(meta) -> Dict[str, Any]:
    try:
        import repro.kernels.api as _plk
    except Exception as exc:
        raise BackendUnavailable(
            f"pallas twin references repro.kernels.api, which failed "
            f"to import: {exc}")
    return {"__plk": _plk}


register(Backend(
    name="np",
    codegen_version=1,
    device_pref="cpu",
    priority=10,
    twin=False,
    chunk_seconds=_np_chunk_seconds,
    effective_gflops=_np_effective_gflops,
    feasible=lambda profile: True,
))

register(Backend(
    name="jnp",
    xp_binding="__jxp",
    module="jax.numpy",
    codegen_version=1,
    device_pref="gpu",
    priority=20,
    twin=True,
    emit_twin=_jnp_emit_twin,
    namespace=_jnp_namespace,
    chunk_seconds=_jnp_chunk_seconds,
    effective_gflops=_gpu_effective_gflops,
    feasible=_accel_feasible,
))

register(Backend(
    name="pallas",
    xp_binding="__plk",
    module="repro.kernels.api",
    codegen_version=1,
    device_pref="gpu",
    priority=30,
    twin=True,
    emit_twin=_pallas_emit_twin,
    namespace=_pallas_namespace,
    chunk_seconds=_pallas_chunk_seconds,
    effective_gflops=lambda p: _gpu_effective_gflops(p)
    * PALLAS_FUSION_SPEEDUP,
    feasible=_accel_feasible,
))
