"""Type system for the AutoMPHC front-end.

The paper's compiler is driven by type *hints* on kernel parameters; from
those it statically infers the types of locals and expressions using type
rules from the library knowledge base (§2.1). Hints are not trusted — the
multi-versioner (core/multiversion.py) guards specialized code with runtime
legality checks derived from these same TypeInfo objects.
"""

from __future__ import annotations

import re
from dataclasses import dataclass
from typing import Optional

import numpy as np


class TypeError_(Exception):
    """Type-inference failure (kernel leaves the supported subset)."""


_DTYPE_ALIASES = {
    "float": "float64",
    "f64": "float64",
    "double": "float64",
    "f32": "float32",
    "single": "float32",
    "bf16": "bfloat16",
    "int": "int64",
    "i64": "int64",
    "i32": "int32",
    "bool": "bool",
    "c64": "complex64",
    "c128": "complex128",
    "complex": "complex128",
}


def canon_dtype(name: str) -> str:
    name = _DTYPE_ALIASES.get(name, name)
    if name not in {
        "float64", "float32", "bfloat16", "float16",
        "int64", "int32", "int16", "int8", "uint8",
        "bool", "complex64", "complex128",
    }:
        raise TypeError_(f"unsupported dtype {name!r}")
    return name


@dataclass(frozen=True)
class TypeInfo:
    """kind: 'scalar' | 'array' | 'list' | 'none' | 'unknown'.

    ``rank`` is the array rank (0 for scalar). Lists-of-lists — the paper's
    PolyBench "List version" — carry the *element* dtype plus nesting depth
    so the compiler can treat them as arrays (with a list→ndarray conversion
    inserted at the kernel boundary, exactly as §4.2 describes).
    """

    kind: str
    dtype: Optional[str] = None
    rank: int = 0

    # -- constructors --------------------------------------------------
    @staticmethod
    def scalar(dtype: str) -> "TypeInfo":
        return TypeInfo("scalar", canon_dtype(dtype), 0)

    @staticmethod
    def array(dtype: str, rank: int) -> "TypeInfo":
        return TypeInfo("array", canon_dtype(dtype), rank)

    @staticmethod
    def list_of(dtype: str, depth: int) -> "TypeInfo":
        return TypeInfo("list", canon_dtype(dtype), depth)

    @staticmethod
    def none() -> "TypeInfo":
        return TypeInfo("none")

    @staticmethod
    def unknown() -> "TypeInfo":
        return TypeInfo("unknown")

    # -- queries --------------------------------------------------------
    @property
    def is_array_like(self) -> bool:
        return self.kind in ("array", "list")

    @property
    def is_numeric_scalar(self) -> bool:
        return self.kind == "scalar"

    def as_array(self) -> "TypeInfo":
        """List-of-list viewed as an array of the same rank."""
        if self.kind == "list":
            return TypeInfo("array", self.dtype, self.rank)
        return self

    def np_dtype(self):
        import numpy as _np
        if self.dtype == "bfloat16":  # numpy has no native bf16
            import ml_dtypes  # type: ignore

            return _np.dtype(ml_dtypes.bfloat16)
        return _np.dtype(self.dtype)


# ---------------------------------------------------------------------------
# Annotation parsing
# ---------------------------------------------------------------------------

_NDARRAY_RE = re.compile(r"ndarray\[\s*(\w+)\s*,\s*(\d+)\s*\]")
_LIST_RE = re.compile(r"list\[\s*(\w+)\s*,\s*(\d+)\s*\]")


def parse_annotation(ann) -> TypeInfo:
    """Parse a Python type hint into TypeInfo.

    Accepted forms (paper-style hints):
      float, int, bool, complex          → scalar
      'ndarray' / numpy.ndarray          → array of unknown dtype/rank
                                            (legality guard will check)
      'ndarray[f64,2]'                   → array float64 rank 2
      'list[f64,2]'                      → list-of-list, element float64
      list                               → list, unknown element
    """
    if ann is None:
        return TypeInfo.unknown()
    if ann in (float,):
        return TypeInfo.scalar("float64")
    if ann in (int,):
        return TypeInfo.scalar("int64")
    if ann in (bool,):
        return TypeInfo.scalar("bool")
    if ann in (complex,):
        return TypeInfo.scalar("complex128")
    if ann is list:
        return TypeInfo("list", None, 0)
    if isinstance(ann, str):
        s = ann.strip()
        # `from __future__ import annotations` stringifies the source
        # expression, wrapping already-quoted hints in a second layer
        if len(s) >= 2 and s[0] == s[-1] and s[0] in "'\"":
            s = s[1:-1].strip()
        m = _NDARRAY_RE.fullmatch(s)
        if m:
            return TypeInfo.array(m.group(1), int(m.group(2)))
        m = _LIST_RE.fullmatch(s)
        if m:
            return TypeInfo.list_of(m.group(1), int(m.group(2)))
        if s in ("ndarray", "np.ndarray", "numpy.ndarray"):
            return TypeInfo("array", None, 0)
        if s in ("float", "f64"):
            return TypeInfo.scalar("float64")
        if s in ("float32", "f32"):
            return TypeInfo.scalar("float32")
        if s in ("int", "i64"):
            return TypeInfo.scalar("int64")
        if s in ("int32", "i32"):
            return TypeInfo.scalar("int32")
        if s in ("complex", "c128"):
            return TypeInfo.scalar("complex128")
        if s in ("complex64", "c64"):
            return TypeInfo.scalar("complex64")
        if s == "bool":
            return TypeInfo.scalar("bool")
        if s == "None":
            return TypeInfo.none()
        return TypeInfo.unknown()
    try:  # numpy.ndarray class object
        if ann is np.ndarray:
            return TypeInfo("array", None, 0)
    except Exception:  # pragma: no cover
        pass
    return TypeInfo.unknown()


# ---------------------------------------------------------------------------
# Promotion / inference rules
# ---------------------------------------------------------------------------

_PROMOTE_ORDER = [
    "bool", "int8", "uint8", "int16", "int32", "int64",
    "bfloat16", "float16", "float32", "float64",
    "complex64", "complex128",
]


def promote_dtype(a: Optional[str], b: Optional[str]) -> Optional[str]:
    if a is None:
        return b
    if b is None:
        return a
    ia, ib = _PROMOTE_ORDER.index(a), _PROMOTE_ORDER.index(b)
    hi = _PROMOTE_ORDER[max(ia, ib)]
    # int ⊕ float → float64 like numpy default; complex absorbs.
    if {a, b} <= set(_PROMOTE_ORDER[:6]) or hi in _PROMOTE_ORDER[6:]:
        return hi
    return hi


def broadcast(a: TypeInfo, b: TypeInfo) -> TypeInfo:
    """Elementwise-op result type (numpy broadcasting on ranks)."""
    a, b = a.as_array(), b.as_array()
    dtype = promote_dtype(a.dtype, b.dtype)
    rank = max(a.rank, b.rank)
    if rank == 0:
        return TypeInfo.scalar(dtype or "float64")
    return TypeInfo.array(dtype or "float64", rank)


def nested_list_shape(value) -> "tuple":
    """Shape of a nested list-of-lists judged by its first elements:
    ``[[1,2],[3,4],[5,6]]`` → ``(3, 2)``. The single implementation used
    by runtime legality checks, dispatch signatures, and the tracer."""
    dims = []
    x = value
    while isinstance(x, list):
        dims.append(len(x))
        x = x[0] if x else None
    return tuple(dims)


def runtime_typeinfo(value) -> TypeInfo:
    """TypeInfo of an actual runtime value (used by legality checks)."""
    import numpy as _np

    if isinstance(value, (bool, _np.bool_)):
        return TypeInfo.scalar("bool")
    if isinstance(value, (int, _np.integer)):
        return TypeInfo.scalar("int64")
    if isinstance(value, (float, _np.floating)):
        return TypeInfo.scalar("float64")
    if isinstance(value, (complex, _np.complexfloating)):
        return TypeInfo.scalar("complex128")
    if isinstance(value, _np.ndarray):
        return TypeInfo.array(str(value.dtype), value.ndim)
    try:
        import jax

        if isinstance(value, jax.Array):
            return TypeInfo.array(str(value.dtype), value.ndim)
    except Exception:  # pragma: no cover
        pass
    if isinstance(value, list):
        # NB: depth counts non-empty levels only — an empty list stays
        # rank-0/unknown so legality falls back conservatively. This is
        # intentionally NOT nested_list_shape (which sizes every level).
        depth, elem = 0, value
        while isinstance(elem, list) and elem:
            depth += 1
            elem = elem[0]
        et = runtime_typeinfo(elem) if not isinstance(elem, list) else TypeInfo.unknown()
        return TypeInfo("list", et.dtype, depth)
    return TypeInfo.unknown()


def matches(hint: TypeInfo, actual: TypeInfo) -> bool:
    """Legality predicate: does a runtime value satisfy the hint?

    This is the check compiled into the multi-version dispatcher: the
    specialized variant runs only when annotated/inferred types AND ranks
    match reality (paper §4.1)."""
    if hint.kind == "unknown":
        return True
    if hint.kind != actual.kind and not (
        hint.kind == "array" and actual.kind == "array"
    ):
        if hint.kind == "list" and actual.kind == "list":
            pass
        else:
            return False
    if hint.dtype is not None and actual.dtype is not None:
        if hint.dtype != actual.dtype:
            return False
    if hint.kind in ("array", "list") and hint.rank and actual.rank:
        if hint.rank != actual.rank:
            return False
    return True
