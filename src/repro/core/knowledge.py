"""Library knowledge base (paper §4.2, Table 2).

Each entry describes one library function the compiler understands:
  * a TYPE RULE  — result dtype/rank from argument types (used by inference,
    §2.1: "library knowledge base, which specifies type rules");
  * a DATAFLOW SEMANTIC — how the op maps output index space to input index
    space, expressed as a small tag language interpreted by core/scop.py
    when expanding implicit loops into the SCoP;
  * a COST RULE — FLOPs and bytes touched as a function of shapes (drives
    the profitability decision trees and the LM planner's roofline terms).

The same registry carries the large-model ops (dot_general, attention, MoE
dispatch, scans) so the sharding planner shares one source of truth with the
kernel compiler — Table 2 scaled up.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from .types import TypeInfo, broadcast, promote_dtype


@dataclass
class OpEntry:
    name: str
    # ('elementwise',) / ('transpose',) / ('reduce', 'sum') /
    # ('contract', 'dot') / ('fft',) / ('alloc',) / ('opaque',) ...
    semantic: Tuple[str, ...]
    type_rule: Callable[..., TypeInfo]
    # flops per output element (given contraction length k where relevant)
    flops: Callable[..., float] = lambda **kw: 0.0
    notes: str = ""


REGISTRY: Dict[str, OpEntry] = {}


def register(entry: OpEntry) -> None:
    REGISTRY[entry.name] = entry


def lookup(name: str) -> Optional[OpEntry]:
    return REGISTRY.get(name)


# ---------------------------------------------------------------------------
# Type-rule helpers
# ---------------------------------------------------------------------------

def _arr(dtype, rank):
    if rank == 0:
        return TypeInfo.scalar(dtype or "float64")
    return TypeInfo.array(dtype or "float64", rank)


def _t_elementwise(*args: TypeInfo, **kw) -> TypeInfo:
    out = args[0].as_array()
    for a in args[1:]:
        out = broadcast(out, a)
    return out


def _t_same(*args: TypeInfo, **kw) -> TypeInfo:
    return args[0].as_array()


def _t_float_unary(*args: TypeInfo, **kw) -> TypeInfo:
    a = args[0].as_array()
    dt = a.dtype
    if dt in (None, "int64", "int32", "bool"):
        dt = "float64"
    return _arr(dt, a.rank)


def _t_transpose(*args: TypeInfo, **kw) -> TypeInfo:
    return args[0].as_array()


def _t_dot(a: TypeInfo, b: TypeInfo, **kw) -> TypeInfo:
    a, b = a.as_array(), b.as_array()
    dt = promote_dtype(a.dtype, b.dtype)
    if a.rank == 1 and b.rank == 1:
        return _arr(dt, 0)
    if a.rank == 2 and b.rank == 1:
        return _arr(dt, 1)
    if a.rank == 1 and b.rank == 2:
        return _arr(dt, 1)
    return _arr(dt, max(a.rank, b.rank))


def _t_reduce(a: TypeInfo, *rest, axis=None, **kw) -> TypeInfo:
    a = a.as_array()
    dt = a.dtype
    if a.rank == 0:
        return _arr(dt, 0)
    if axis is None:
        return _arr(dt, 0)
    return _arr(dt, max(0, a.rank - 1))


def _t_mean(a: TypeInfo, *rest, axis=None, **kw) -> TypeInfo:
    out = _t_reduce(a, axis=axis)
    dt = out.dtype
    if dt in (None, "int64", "int32", "bool"):
        dt = "float64"
    return _arr(dt, out.rank)


def _t_alloc(*args, dtype=None, rank=1, **kw) -> TypeInfo:
    return _arr(dtype or "float64", rank)


def _t_fft(a: TypeInfo, *rest, **kw) -> TypeInfo:
    a = a.as_array()
    dt = "complex128" if a.dtype in (None, "float64", "complex128") else "complex64"
    return _arr(dt, a.rank)


def _t_scalar_float(*args, **kw) -> TypeInfo:
    return TypeInfo.scalar("float64")


# ---------------------------------------------------------------------------
# Elementwise ops (implicit loops over the broadcast output domain)
# ---------------------------------------------------------------------------

for _name in [
    "np.sqrt", "np.abs", "np.exp", "np.log", "np.sin", "np.cos",
    "np.conj", "np.real", "np.imag", "np.square", "np.reciprocal",
]:
    register(OpEntry(_name, ("elementwise", "unary"), _t_float_unary,
                     flops=lambda **kw: 1.0))

register(OpEntry("np.maximum", ("elementwise",), _t_elementwise,
                 flops=lambda **kw: 1.0))
register(OpEntry("np.minimum", ("elementwise",), _t_elementwise,
                 flops=lambda **kw: 1.0))
register(OpEntry("np.power", ("elementwise",), _t_elementwise,
                 flops=lambda **kw: 10.0))

# ---------------------------------------------------------------------------
# Structural ops
# ---------------------------------------------------------------------------

register(OpEntry("method.T", ("transpose",), _t_transpose,
                 notes="R[i0,i1] := A[i1,i0]"))
register(OpEntry("np.transpose", ("transpose",), _t_transpose))
register(OpEntry("np.squeeze", ("squeeze",),
                 lambda a, **kw: _arr(a.as_array().dtype,
                                      max(0, a.as_array().rank - 1))))
register(OpEntry("np.reshape", ("opaque",), _t_same))
register(OpEntry("np.triu", ("mask", "triu"), _t_same))
register(OpEntry("np.tril", ("mask", "tril"), _t_same))

# ---------------------------------------------------------------------------
# Reductions (Table 2: sum_1D, sum_2D_axis1, mean, …)
# ---------------------------------------------------------------------------

register(OpEntry("method.sum", ("reduce", "sum"), _t_reduce,
                 flops=lambda k=1.0, **kw: float(k),
                 notes="R[i0] := sum_k A[i0,k]  (axis form per Table 2)"))
register(OpEntry("np.sum", ("reduce", "sum"), _t_reduce,
                 flops=lambda k=1.0, **kw: float(k)))
register(OpEntry("method.mean", ("reduce", "mean"), _t_mean,
                 flops=lambda k=1.0, **kw: float(k) + 1))
register(OpEntry("np.mean", ("reduce", "mean"), _t_mean,
                 flops=lambda k=1.0, **kw: float(k) + 1))
register(OpEntry("np.max", ("reduce", "max"), _t_reduce,
                 flops=lambda k=1.0, **kw: float(k)))
register(OpEntry("method.max", ("reduce", "max"), _t_reduce,
                 flops=lambda k=1.0, **kw: float(k)))

# ---------------------------------------------------------------------------
# Contractions (Table 2: dot_{2D,2D} := sum(mult(A1[i0,:], A2[:,i1])))
# ---------------------------------------------------------------------------

register(OpEntry("np.dot", ("contract", "dot"), _t_dot,
                 flops=lambda k=1.0, **kw: 2.0 * float(k),
                 notes="R[i0,i1] := sum_1D(mult_1D,1D(A1[i0,:], A2[:,i1]))"))
register(OpEntry("np.matmul", ("contract", "dot"), _t_dot,
                 flops=lambda k=1.0, **kw: 2.0 * float(k)))
register(OpEntry("np.outer", ("contract", "outer"),
                 lambda a, b, **kw: _arr(promote_dtype(a.as_array().dtype,
                                                       b.as_array().dtype), 2),
                 flops=lambda **kw: 1.0))
register(OpEntry("np.einsum", ("opaque",), lambda *a, **kw: TypeInfo.unknown()))

# ---------------------------------------------------------------------------
# Spectral (STAP): fft along an axis — 1-D domains per Table 2 last row
# ---------------------------------------------------------------------------

register(OpEntry("np.fft.fft", ("fft",), _t_fft,
                 flops=lambda k=1.0, **kw: 5.0 * float(k) * math.log2(max(2.0, float(k))),
                 notes="R[i0,:] := fft_1D(A1[i0,:]) for axis=1"))
register(OpEntry("np.fft.ifft", ("fft",), _t_fft,
                 flops=lambda k=1.0, **kw: 5.0 * float(k) * math.log2(max(2.0, float(k)))))

# ---------------------------------------------------------------------------
# Allocation
# ---------------------------------------------------------------------------

register(OpEntry("np.zeros", ("alloc", "zeros"), _t_alloc))
register(OpEntry("np.empty", ("alloc", "empty"), _t_alloc))
register(OpEntry("np.ones", ("alloc", "ones"), _t_alloc))
register(OpEntry("np.diag_indices", ("opaque",), lambda *a, **kw: TypeInfo.unknown()))
register(OpEntry("np.tril_indices", ("opaque",), lambda *a, **kw: TypeInfo.unknown()))
register(OpEntry("np.triu_indices", ("opaque",), lambda *a, **kw: TypeInfo.unknown()))

# ---------------------------------------------------------------------------
# Scalar / misc
# ---------------------------------------------------------------------------

register(OpEntry("len", ("meta",), lambda *a, **kw: TypeInfo.scalar("int64")))
register(OpEntry("range", ("meta",), lambda *a, **kw: TypeInfo.unknown()))
register(OpEntry("float", ("meta",), _t_scalar_float))
register(OpEntry("int", ("meta",), lambda *a, **kw: TypeInfo.scalar("int64")))
register(OpEntry("abs", ("elementwise", "unary"), _t_same,
                 flops=lambda **kw: 1.0))
register(OpEntry("min", ("meta",), lambda *a, **kw: a[0] if a else TypeInfo.unknown()))
register(OpEntry("max", ("meta",), lambda *a, **kw: a[0] if a else TypeInfo.unknown()))


# ===========================================================================
# Large-model op entries — Table 2 scaled to the LM pool. Used only by the
# planner/cost model (core/cost.py, core/planner.py); the kernel front-end
# never sees these names.
# ===========================================================================

@dataclass
class LMOp:
    name: str
    flops: Callable[..., float]
    bytes_: Callable[..., float]
    # which logical axes may be sharded without changing semantics
    shardable: Tuple[str, ...] = ()
    # collective implied when the named axis is sharded: axis -> kind
    collectives: Dict[str, str] = field(default_factory=dict)


LM_REGISTRY: Dict[str, LMOp] = {}


def register_lm(op: LMOp) -> None:
    LM_REGISTRY[op.name] = op


def _bytes_linear(m, k, n, dtype_bytes=2, **kw):
    return dtype_bytes * (m * k + k * n + m * n)


register_lm(LMOp(
    "matmul",
    flops=lambda m, k, n, **kw: 2.0 * m * k * n,
    bytes_=_bytes_linear,
    shardable=("m", "k", "n"),
    collectives={"k": "psum"},
))

register_lm(LMOp(
    "attention",
    # 2*b*h*s*s*d (QK^T) + 2*b*h*s*s*d (PV)
    flops=lambda b, h, s_q, s_kv, d, **kw: 4.0 * b * h * s_q * s_kv * d,
    bytes_=lambda b, h, s_q, s_kv, d, kv_h=None, dtype_bytes=2, **kw:
        dtype_bytes * (b * h * s_q * d + 2 * b * (kv_h or h) * s_kv * d
                       + b * h * s_q * d),
    shardable=("b", "h"),
    collectives={},
))

register_lm(LMOp(
    "moe_dispatch",
    # all-to-all of token activations to experts and back
    flops=lambda tokens, d, topk, **kw: 0.0,
    bytes_=lambda tokens, d, topk, dtype_bytes=2, **kw:
        2.0 * dtype_bytes * tokens * topk * d,
    shardable=("experts", "tokens"),
    collectives={"experts": "all_to_all"},
))

register_lm(LMOp(
    "ssm_scan",
    # Selective scan: ~9 flops per (b, s, heads*state) element
    flops=lambda b, s, dim, state, **kw: 9.0 * b * s * dim * state,
    bytes_=lambda b, s, dim, state, dtype_bytes=2, **kw:
        dtype_bytes * b * s * dim * (2 + state),
    shardable=("b", "dim"),
    collectives={},
))

register_lm(LMOp(
    "vocab_xent",
    flops=lambda tokens, d, vocab, **kw: 2.0 * tokens * d * vocab,
    bytes_=lambda tokens, d, vocab, dtype_bytes=2, **kw:
        dtype_bytes * (tokens * d + d * vocab + tokens * vocab),
    shardable=("vocab", "tokens"),
    collectives={"vocab": "psum"},
))
