"""Auto-sharding planner: the paper's inter-node parallelization applied
to LM training/serving steps.

The compiler's `pfor (output=…, input=…, transfer=…)` clause (paper §4.3)
reappears here as a *sharding plan*: for every parameter/activation leaf
(annotated with logical axes by the model zoo), the planner

  1. enumerates candidate strategies (DP / FSDP / FSDP×TP),
  2. filters by LEGALITY — divisibility of each logical axis by its mesh
     axes and per-chip HBM fit (the paper's type/rank runtime checks become
     static shape checks; §4.1 decision-tree top level),
  3. scores by PROFITABILITY — a three-term roofline estimate from the
     knowledge base (compute / memory / collective; §4.1 lower level),

and emits NamedShardings for pjit. Per-leaf fallbacks implement the
paper's multi-versioning: an indivisible axis falls back to the next legal
mapping (e.g. gemma2's 8 heads < tp=16 → shard head_dim or fold the model
axis into the embed axis) instead of failing the arch.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.common import ArchConfig

from .cost import TPU_V5E, ChipSpec, RooflineTerms


# ---------------------------------------------------------------------------
# Strategy definitions
# ---------------------------------------------------------------------------

@dataclass
class Strategy:
    """Maps logical axes to mesh-axis preference lists."""

    name: str
    # logical axis → ordered candidate mesh-axis tuples (first legal wins;
    # None = replicate)
    rules: Dict[str, List[Optional[Tuple[str, ...]]]]
    batch_axes: Tuple[str, ...]          # data-parallel mesh axes


def make_strategies(mesh: Mesh) -> List[Strategy]:
    axes = mesh.axis_names
    dp: Tuple[str, ...] = tuple(a for a in axes if a in ("pod", "data"))
    all_axes: Tuple[str, ...] = tuple(axes)
    tp = ("model",) if "model" in axes else ()
    fsdp = dp
    tp_l: List[Optional[Tuple[str, ...]]] = [tp, None] if tp else [None]

    strategies = [
        Strategy(
            name="fsdp_tp",
            rules={
                "vocab": tp_l,
                # GQA: kv_heads takes the model axis when divisible;
                # otherwise head_dim (must mirror cache_sharding priority
                # or GSPMD hits involuntary rematerialization)
                "heads": tp_l,
                "kv_heads": tp_l,
                "head_dim": tp_l,
                "mlp": tp_l,
                "experts": tp_l,
                "inner": tp_l,
                "ssm": [None],
                "embed": [fsdp, None],
                "layers": [None],
            },
            batch_axes=dp,
        ),
        Strategy(
            name="fsdp",
            # ZeRO-3 style: every parameter fully sharded over the whole
            # mesh on its largest legal dim; activations batch-sharded
            # over the whole mesh too.
            rules={
                "vocab": [all_axes, fsdp, None],
                "heads": [None],
                "kv_heads": [None],
                "head_dim": [None],
                "mlp": [all_axes, fsdp, None],
                "experts": [all_axes, fsdp, None],
                "inner": [all_axes, fsdp, None],
                "ssm": [None],
                "embed": [all_axes, fsdp, None],
                "layers": [None],
            },
            batch_axes=all_axes,
        ),
        Strategy(
            name="dp",
            rules={k: [None] for k in
                   ("vocab", "heads", "kv_heads", "head_dim", "mlp",
                    "experts", "inner", "ssm", "embed", "layers")},
            batch_axes=all_axes,
        ),
    ]
    return strategies


def _mesh_size(mesh: Mesh, axes: Optional[Tuple[str, ...]]) -> int:
    if not axes:
        return 1
    out = 1
    for a in axes:
        out *= mesh.shape[a]
    return out


# ---------------------------------------------------------------------------
# Per-leaf spec resolution (legality with fallback)
# ---------------------------------------------------------------------------

def resolve_leaf_spec(shape: Tuple[int, ...], logical: Tuple[str, ...],
                      strategy: Strategy, mesh: Mesh) -> P:
    """Choose mesh axes per dim: first legal candidate, each mesh axis used
    at most once per leaf."""
    used: set = set()
    parts: List[Optional[Tuple[str, ...]]] = []
    for dim, axis_name in zip(shape, logical):
        choice: Optional[Tuple[str, ...]] = None
        for cand in strategy.rules.get(axis_name, [None]):
            if cand is None:
                choice = None
                break
            if any(a in used for a in cand):
                continue
            if dim % _mesh_size(mesh, cand) == 0:
                choice = cand
                break
        if choice:
            used.update(choice)
            parts.append(choice if len(choice) > 1 else choice[0])
        else:
            parts.append(None)
    # big 2-D+ leaves with an unused model axis: fold model into the embed
    # dim when divisible (gemma2 fallback — row-parallel attention)
    if ("model" in mesh.axis_names and "model" not in used
            and strategy.name == "fsdp_tp"):
        nbytes = math.prod(shape)
        if nbytes >= 1 << 20:
            for i, (dim, axis_name) in enumerate(zip(shape, logical)):
                if axis_name != "embed":
                    continue
                prev = parts[i]
                prev_t = (prev,) if isinstance(prev, str) else \
                    (tuple(prev) if prev else ())
                cand = prev_t + ("model",)
                if dim % _mesh_size(mesh, cand) == 0:
                    parts[i] = cand if len(cand) > 1 else cand[0]
                    used.add("model")
                    break
    return P(*parts)


def plan_params(specs, shapes, strategy: Strategy, mesh: Mesh):
    """specs: pytree of logical-axis tuples; shapes: matching pytree of
    ShapeDtypeStruct. Returns pytree of NamedSharding."""
    def is_axes(x):
        return isinstance(x, tuple) and all(isinstance(e, str) for e in x)

    def mk(logical, shp):
        spec = resolve_leaf_spec(tuple(shp.shape), logical, strategy, mesh)
        return NamedSharding(mesh, spec)

    return jax.tree.map(mk, specs, shapes, is_leaf=is_axes)


# ---------------------------------------------------------------------------
# Batch / cache shardings
# ---------------------------------------------------------------------------

def batch_sharding(mesh: Mesh, strategy: Strategy, batch: int,
                   extra_dims: int = 1) -> NamedSharding:
    """(B, …): shard B over the dp axes that divide it."""
    dp = tuple(a for a in strategy.batch_axes
               if batch % _mesh_size(mesh, strategy.batch_axes) == 0
               or True)
    # choose the largest dp prefix that divides batch
    chosen: Tuple[str, ...] = ()
    for i in range(len(strategy.batch_axes), 0, -1):
        cand = strategy.batch_axes[:i]
        if batch % _mesh_size(mesh, cand) == 0:
            chosen = cand
            break
    spec = [chosen if len(chosen) > 1 else
            (chosen[0] if chosen else None)] + [None] * extra_dims
    return NamedSharding(mesh, P(*spec))


def cache_sharding(mesh: Mesh, strategy: Strategy, cfg: ArchConfig,
                   batch: int, leaf_shape: Tuple[int, ...]) -> NamedSharding:
    """Decode caches: (n_periods, B, S, KVH, HD) KV tensors, (n_periods, B)
    indices, (n_periods, B, …) ssm states. Shard B over dp when divisible,
    the trailing feature dim over model when divisible."""
    ndim = len(leaf_shape)
    parts: List[Any] = [None] * ndim
    # batch dim is axis 1 when present
    if ndim >= 2 and leaf_shape[1] == batch:
        chosen: Tuple[str, ...] = ()
        for i in range(len(strategy.batch_axes), 0, -1):
            cand = strategy.batch_axes[:i]
            if batch % _mesh_size(mesh, cand) == 0:
                chosen = cand
                break
        if chosen:
            parts[1] = chosen if len(chosen) > 1 else chosen[0]
    if ndim >= 4 and "model" in mesh.axis_names \
            and strategy.name == "fsdp_tp":
        # try kv_heads (axis -2) then head_dim (axis -1)
        m = mesh.shape["model"]
        if leaf_shape[-2] % m == 0 and leaf_shape[-2] > 1:
            parts[-2] = "model"
        elif leaf_shape[-1] % m == 0:
            parts[-1] = "model"
    return NamedSharding(mesh, P(*parts))


# ---------------------------------------------------------------------------
# Analytic roofline (profitability scoring)
# ---------------------------------------------------------------------------

@dataclass
class PlanEstimate:
    strategy: str
    hbm_bytes_per_chip: float
    compute_s: float
    memory_s: float
    collective_s: float
    legal: bool
    note: str = ""
    microbatch: int = 1          # planner-adapted grad-accumulation steps

    @property
    def step_s(self) -> float:
        return max(self.compute_s, self.memory_s) + self.collective_s


def effective_dp(mesh: Mesh, batch_axes: Tuple[str, ...],
                 rows: int) -> int:
    """Largest prefix of batch_axes whose size divides ``rows`` — the DP
    extent GSPMD can actually use. Anything less than the full product
    leaves trailing axes REPLICATING compute (the silent 16× waste the
    estimate must see)."""
    for i in range(len(batch_axes), 0, -1):
        size = _mesh_size(mesh, batch_axes[:i])
        if rows % size == 0:
            return size
    return 1


def adapt_microbatch(cfg: ArchConfig, batch: int, mesh: Mesh,
                     batch_axes: Tuple[str, ...]) -> Tuple[int, int]:
    """Choose (microbatch, effective_dp): maximize DP utilization first
    (a replicated model axis is a 16× compute waste), then accumulation
    depth (memory relief). The paper's legality-branch resolution: adjust
    the variant instead of failing."""
    best = (1, effective_dp(mesh, batch_axes, batch))
    for mb in range(1, max(1, cfg.microbatch) + 1):
        if batch % mb:
            continue
        eff = effective_dp(mesh, batch_axes, batch // mb)
        if (eff, mb) > (best[1], best[0]):
            best = (mb, eff)
    return best


def estimate_plan(cfg: ArchConfig, strategy: Strategy, mesh: Mesh,
                  seq: int, batch: int, kind: str,
                  chip: ChipSpec = TPU_V5E) -> PlanEstimate:
    chips = mesh.size
    tp = mesh.shape.get("model", 1) if strategy.name == "fsdp_tp" else 1
    if kind == "train":
        mb, dp = adapt_microbatch(cfg, batch, mesh, strategy.batch_axes)
    else:
        mb = 1
        dp = effective_dp(mesh, strategy.batch_axes, batch)
    # chips not covered by dp×tp replicate compute — chargeable waste
    replication = chips / max(1, dp * tp)
    n_params = cfg.param_count()
    n_active = cfg.active_param_count()
    p_bytes = 2.0 * n_params
    if strategy.name == "dp":
        shard = 1
    elif strategy.name == "fsdp":
        shard = chips  # params fully sharded regardless of batch extent
    else:
        shard = _mesh_size(mesh, strategy.batch_axes) * tp
    param_per_chip = p_bytes / shard

    tokens = batch * seq if kind != "decode" else batch
    flops = (6.0 if kind == "train" else 2.0) * n_active * tokens
    compute_s = flops * replication / (chips * chip.peak_flops)

    # memory term: params read once per microbatch pass + activations
    act_bytes = 2.0 * tokens / max(1, dp) * cfg.d_model * cfg.layers / \
        max(1, mb)
    if cfg.seq_shard and tp > 1:
        act_bytes /= tp  # sequence-parallel checkpoints
    passes = mb if kind == "train" else 1
    mem_bytes = param_per_chip * passes + act_bytes
    memory_s = mem_bytes / chip.hbm_bw

    # collective term (per chip): FSDP all-gather of params + DP grad
    # reduce-scatter (train) + TP activation psum
    coll = 0.0
    if strategy.name in ("fsdp", "fsdp_tp") and dp > 1:
        coll += param_per_chip * (dp - 1) / dp * passes      # all-gather
        if kind == "train":
            coll += 2.0 * param_per_chip * (dp - 1) / dp     # grad RS+AG
    if tp > 1:
        act = 2.0 * tokens / max(1, dp) * cfg.d_model
        coll += 2.0 * act * cfg.layers * (tp - 1) / tp / max(1, mb)
    collective_s = coll / chip.ici_bw

    # HBM legality (bytes relative to bf16 params: grads f32 = 2×,
    # moments int8 = 1× / f32 = 4×)
    if kind == "train":
        opt_mult = 2.0 + (1.0 if cfg.opt_8bit else 4.0)
        hbm = param_per_chip * (1.0 + opt_mult) + act_bytes * 2
    else:
        kv = 0.0
        if kind in ("prefill", "decode"):
            n_attn = sum(1 for i in range(cfg.period)
                         if cfg.layer_kind(i) == "attn") * cfg.n_periods
            kv = 2.0 * 2.0 * batch * seq * cfg.kv_heads * cfg.head_dim \
                * n_attn
            kv /= max(1, dp if batch % dp == 0 else 1)
            kv /= max(1, tp if (cfg.kv_heads % tp == 0
                                or cfg.head_dim % tp == 0) else 1)
        hbm = param_per_chip + kv + act_bytes * 2
    legal = hbm < chip.hbm_bytes * 0.92
    return PlanEstimate(strategy.name, hbm, compute_s, memory_s,
                        collective_s, legal, microbatch=mb)


# ---------------------------------------------------------------------------
# Top-level plan
# ---------------------------------------------------------------------------

@dataclass
class ShardingPlan:
    strategy: Strategy
    estimate: PlanEstimate
    param_shardings: Any
    mesh: Mesh
    alternatives: List[PlanEstimate] = field(default_factory=list)

    def describe(self) -> str:
        e = self.estimate
        lines = [f"plan: {self.strategy.name}  "
                 f"hbm/chip={e.hbm_bytes_per_chip/2**30:.2f}GiB  "
                 f"compute={e.compute_s*1e3:.2f}ms "
                 f"memory={e.memory_s*1e3:.2f}ms "
                 f"collective={e.collective_s*1e3:.2f}ms"]
        for a in self.alternatives:
            lines.append(f"  alt {a.strategy}: step={a.step_s*1e3:.2f}ms "
                         f"hbm={a.hbm_bytes_per_chip/2**30:.2f}GiB "
                         f"legal={a.legal}")
        return "\n".join(lines)


def plan(cfg: ArchConfig, specs, param_shapes, mesh: Mesh, *, seq: int,
         batch: int, kind: str) -> ShardingPlan:
    """Pick the min-cost legal strategy; emit param NamedShardings."""
    cands = []
    for st in make_strategies(mesh):
        est = estimate_plan(cfg, st, mesh, seq, batch, kind)
        cands.append((st, est))
    legal = [(st, e) for st, e in cands if e.legal]
    pool = legal if legal else cands  # nothing fits: pick least-bad
    if getattr(cfg, "force_strategy", None):
        forced = [(st, e) for st, e in cands
                  if st.name == cfg.force_strategy]
        pool = forced or pool
    st, est = min(pool, key=lambda p: p[1].step_s)
    shardings = plan_params(specs, param_shapes, st, mesh)
    return ShardingPlan(st, est, shardings, mesh,
                        alternatives=[e for _, e in cands])
