"""isl_lite: exact integer affine expressions and iteration domains.

The paper manipulates polyhedral sets via islpy/sympy; neither is available
offline, so this module implements the affine subset AutoMPHC's benchmarks
exercise: affine expressions over loop iterators and symbolic parameters,
rectangular/triangular iteration domains, and the set operations the
dependence tester and scheduler need. All arithmetic is exact (ints +
symbolic coefficients); nothing here touches floating point.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Optional, Sequence, Tuple


class AffineError(Exception):
    """Raised when an expression leaves the affine subset."""


@dataclass(frozen=True)
class Affine:
    """Affine expression sum_i coeff[v_i] * v_i + const.

    Variables are plain strings (loop iterators or structure parameters such
    as ``M``/``N``). Immutable and hashable so it can key dependence caches.
    """

    coeffs: Tuple[Tuple[str, int], ...] = ()
    const: int = 0

    # -- constructors -------------------------------------------------
    @staticmethod
    def constant(c: int) -> "Affine":
        return Affine((), int(c))

    @staticmethod
    def var(name: str, coeff: int = 1) -> "Affine":
        if coeff == 0:
            return Affine((), 0)
        return Affine(((name, int(coeff)),), 0)

    @staticmethod
    def of(x) -> "Affine":
        if isinstance(x, Affine):
            return x
        if isinstance(x, bool):
            raise AffineError("bool is not affine")
        if isinstance(x, int):
            return Affine.constant(x)
        if isinstance(x, str):
            return Affine.var(x)
        raise AffineError(f"cannot coerce {x!r} to Affine")

    # -- helpers ------------------------------------------------------
    def as_dict(self) -> Dict[str, int]:
        return dict(self.coeffs)

    @staticmethod
    def _from_dict(d: Dict[str, int], const: int) -> "Affine":
        items = tuple(sorted((k, v) for k, v in d.items() if v != 0))
        return Affine(items, int(const))

    # -- algebra ------------------------------------------------------
    def __add__(self, other) -> "Affine":
        other = Affine.of(other)
        d = self.as_dict()
        for k, v in other.coeffs:
            d[k] = d.get(k, 0) + v
        return Affine._from_dict(d, self.const + other.const)

    __radd__ = __add__

    def __neg__(self) -> "Affine":
        return Affine._from_dict({k: -v for k, v in self.coeffs}, -self.const)

    def __sub__(self, other) -> "Affine":
        return self + (-Affine.of(other))

    def __rsub__(self, other) -> "Affine":
        return Affine.of(other) + (-self)

    def __mul__(self, other) -> "Affine":
        if isinstance(other, Affine):
            if other.is_constant():
                other = other.const
            elif self.is_constant():
                self, other = other, self.const
            else:
                raise AffineError("product of two non-constant affines")
        if not isinstance(other, int):
            raise AffineError(f"cannot scale Affine by {other!r}")
        return Affine._from_dict(
            {k: v * other for k, v in self.coeffs}, self.const * other
        )

    __rmul__ = __mul__

    # -- queries ------------------------------------------------------
    def is_constant(self) -> bool:
        return not self.coeffs

    def coeff(self, name: str) -> int:
        for k, v in self.coeffs:
            if k == name:
                return v
        return 0

    def vars(self) -> Tuple[str, ...]:
        return tuple(k for k, _ in self.coeffs)

    def drop(self, names: Iterable[str]) -> "Affine":
        names = set(names)
        return Affine._from_dict(
            {k: v for k, v in self.coeffs if k not in names}, self.const
        )

    def substitute(self, env: Dict[str, "Affine"]) -> "Affine":
        out = Affine.constant(self.const)
        for k, v in self.coeffs:
            out = out + (env[k] * v if k in env else Affine.var(k, v))
        return out

    def evaluate(self, env: Dict[str, int]) -> int:
        total = self.const
        for k, v in self.coeffs:
            if k not in env:
                raise AffineError(f"unbound variable {k} in {self}")
            total += v * env[k]
        return total

    def equals(self, other: "Affine") -> bool:
        return (self - other).is_zero()

    def is_zero(self) -> bool:
        return self.is_constant() and self.const == 0

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        parts = []
        for k, v in self.coeffs:
            if v == 1:
                parts.append(k)
            elif v == -1:
                parts.append(f"-{k}")
            else:
                parts.append(f"{v}*{k}")
        if self.const or not parts:
            parts.append(str(self.const))
        out = " + ".join(parts)
        return out.replace("+ -", "- ")


@dataclass(frozen=True)
class LoopDim:
    """One iteration dimension: var in [lower, upper) step `step`.

    Bounds are affine in parameters and *enclosing* iterators (triangular
    domains, e.g. j in [i+1, M), are first-class — the correlation kernel
    needs them).
    """

    var: str
    lower: Affine
    upper: Affine  # exclusive
    step: int = 1

    def extent(self) -> Affine:
        return self.upper - self.lower


@dataclass(frozen=True)
class Domain:
    """Iteration domain as an ordered list of LoopDims (lexicographic)."""

    dims: Tuple[LoopDim, ...] = ()

    def iter_vars(self) -> Tuple[str, ...]:
        return tuple(d.var for d in self.dims)

    def rank(self) -> int:
        return len(self.dims)

    def inner(self, var: str) -> "Domain":
        """Dims strictly inside `var`."""
        names = self.iter_vars()
        i = names.index(var)
        return Domain(self.dims[i + 1 :])

    def with_dim(self, dim: LoopDim) -> "Domain":
        return Domain(self.dims + (dim,))

    def is_rectangular(self) -> bool:
        seen: set = set()
        for d in self.dims:
            for b in (d.lower, d.upper):
                if any(v in seen for v in b.vars()):
                    return False
            seen.add(d.var)
        return True

    def triangular_pairs(self) -> List[Tuple[str, str, int]]:
        """Return (outer, inner, offset) for inner dims bounded below by an
        outer iterator (j >= i + offset). Used by raising to emit triu/tril."""
        out = []
        seen: Dict[str, int] = {}
        for idx, d in enumerate(self.dims):
            for v in d.lower.vars():
                if v in seen:
                    off = d.lower.const if d.lower.coeff(v) == 1 else None
                    if off is not None and len(d.lower.coeffs) == 1:
                        out.append((v, d.var, off))
            seen[d.var] = idx
        return out

    def cardinality(self, env: Dict[str, int]) -> int:
        """Number of points given concrete parameter values (exact for
        rectangular; triangular handled by summation)."""
        total = 0

        def rec(i: int, binding: Dict[str, int]) -> int:
            if i == len(self.dims):
                return 1
            d = self.dims[i]
            lo = d.lower.evaluate({**env, **binding})
            hi = d.upper.evaluate({**env, **binding})
            n = max(0, -(-(hi - lo) // d.step))
            # Fast path: remaining dims do not reference this var.
            refs = any(
                d.var in b.vars()
                for dd in self.dims[i + 1 :]
                for b in (dd.lower, dd.upper)
            )
            if not refs:
                sub = rec(i + 1, binding)
                return n * sub
            count = 0
            v = lo
            while v < hi:
                binding2 = dict(binding)
                binding2[d.var] = v
                count += rec(i + 1, binding2)
                v += d.step
            return count

        total = rec(0, {})
        return total


# ---------------------------------------------------------------------------
# Dependence-solving primitives
# ---------------------------------------------------------------------------

def gcd_test(coeffs: Sequence[int], const: int) -> bool:
    """Return True if sum coeffs[i]*x_i = const MAY have an integer solution
    (classic GCD test). False ⇒ definitely independent."""
    nz = [abs(c) for c in coeffs if c != 0]
    if not nz:
        return const == 0
    g = nz[0]
    for c in nz[1:]:
        g = math.gcd(g, c)
    return const % g == 0


def banerjee_test(
    coeffs: Sequence[int],
    const: int,
    bounds: Sequence[Tuple[Optional[int], Optional[int]]],
) -> bool:
    """Banerjee interval test for sum coeffs[i]*x_i + const = 0 with
    x_i in [lo_i, hi_i] (inclusive; None = unbounded). Returns True if a
    real solution may exist. False ⇒ definitely independent."""
    lo_total, hi_total = const, const
    for c, (lo, hi) in zip(coeffs, bounds):
        if c == 0:
            continue
        cand = []
        for b in (lo, hi):
            if b is None:
                cand.append(None)
            else:
                cand.append(c * b)
        vals = [v for v in cand if v is not None]
        if len(vals) < 2:
            return True  # unbounded direction: cannot disprove
        lo_total += min(vals)
        hi_total += max(vals)
    return lo_total <= 0 <= hi_total


def affine_eq_may_hold(
    lhs: Affine,
    rhs: Affine,
    var_bounds: Dict[str, Tuple[Optional[int], Optional[int]]],
) -> bool:
    """May lhs == rhs hold for integer assignments within var_bounds?
    Parameters absent from var_bounds are treated as unbounded symbols.
    Conservative: True when undecidable."""
    diff = lhs - rhs
    if diff.is_constant():
        return diff.const == 0
    names = list(diff.vars())
    coeffs = [diff.coeff(n) for n in names]
    if not gcd_test(coeffs, diff.const):
        return False
    bounds = [var_bounds.get(n, (None, None)) for n in names]
    return banerjee_test(coeffs, diff.const, bounds)
