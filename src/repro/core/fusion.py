"""Producer–consumer fusion with array contraction (post-scheduling pass).

The scheduler's distribution/absorption policies deliberately split the
kernel into maximal per-statement library calls; every unit then
materializes its full output before the next unit reads it. This pass runs
*after* scheduling and walks the unit lists looking for three patterns the
polyhedral literature calls profitable (Klöckner's loo.py fusion, the
data-centric Python map fusion of Ziogas et al.):

  1. SAME-ARRAY FLOW FUSION — ``W = e1`` (or ``W op= e1``) followed by
     ``W op= e2`` over an identical iteration domain collapses into a
     single statement ``W = combine(e1, e2)``. One full store+load round
     trip over W disappears (the PolyBench List idiom ``C *= beta;
     C += alpha·A@B`` becomes the single fused statement the hand-written
     NumPy version expresses directly).

  2. ARRAY CONTRACTION — a kernel-local intermediate written once and read
     only by later sibling statements is forward-substituted into its use
     sites and its definition deleted, so codegen never allocates the full
     array. Gated by the roofline model: substitution that would duplicate
     an expensive producer (e.g. a contraction feeding several reads) is
     rejected, keeping the single library call — the paper's "maximal
     library call" policy wins whenever compute dominates.

  3. LOOP FUSION — adjacent sequential loops with identical domains merge
     when every cross-loop dependence pins the same iteration
     (``dependence.fusion_legal``), which then exposes (1)/(2) across the
     former loop boundary.

All rewrites preserve the statement-atomic semantics both backends
guarantee (rhs fully evaluated before the store); the loop-fallback
emitter snapshots self-read arrays to keep that contract (codegen.py).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set, Tuple

from . import cost, dependence
from .isl_lite import Affine, Domain, LoopDim
from .schedule import (FFTUnit, OpaqueUnit, PforUnit, RaisedUnit,
                       SeqLoopUnit, Unit)
from .scop import (CanonStmt, VAccess, VBin, VConst, VExpr, VParam, VReduce,
                   VUnary, fresh, substitute_array_reads, substitute_vexpr,
                   vexpr_accesses)


@dataclass
class FusionStats:
    """Telemetry recorded on the Schedule (surfaced via kernel stats)."""

    fused_units: int = 0
    contracted_arrays: List[str] = field(default_factory=list)
    loops_fused: int = 0
    rejected: int = 0
    log: List[str] = field(default_factory=list)


# ---------------------------------------------------------------------------
# Expression / unit helpers
# ---------------------------------------------------------------------------

def _pure_var(a: Affine) -> Optional[str]:
    if a.const != 0 or len(a.coeffs) != 1:
        return None
    (v, c), = a.coeffs
    return v if c == 1 else None


def _freshen_reduce_vars(e: VExpr) -> VExpr:
    """Alpha-rename every VReduce iterator so substituting the producer
    into a consumer cannot capture the consumer's iterators."""
    if isinstance(e, VReduce):
        env: Dict[str, Affine] = {}
        dims = []
        for d in e.dims:
            nv = fresh("fz")
            # triangular bounds may reference earlier sibling iterators:
            # rename them too (with the env accumulated so far)
            lo, hi = d.lower.substitute(env), d.upper.substitute(env)
            env[d.var] = Affine.var(nv)
            dims.append(LoopDim(nv, lo, hi, d.step))
        child = substitute_vexpr(_freshen_reduce_vars(e.child), env)
        return VReduce(e.op, tuple(dims), child)
    if isinstance(e, VBin):
        return VBin(e.op, _freshen_reduce_vars(e.left),
                    _freshen_reduce_vars(e.right))
    if isinstance(e, VUnary):
        return VUnary(e.fn, _freshen_reduce_vars(e.operand))
    return e


def _stmt_read_arrays(s: CanonStmt) -> Set[str]:
    out = {a.array for a in vexpr_accesses(s.rhs)}
    if s.aug is not None:
        out.add(s.write_array)
    return out


def _unit_reads_writes(u: Unit) -> Tuple[Set[str], Set[str]]:
    if isinstance(u, RaisedUnit):
        return _stmt_read_arrays(u.stmt), {u.stmt.write_array}
    if isinstance(u, FFTUnit):
        return {u.stmt.src}, {u.stmt.out}
    if isinstance(u, OpaqueUnit):
        return set(u.item.reads), set(u.item.writes)
    reads: Set[str] = set()
    writes: Set[str] = set()
    for b in u.body:
        r, w = _unit_reads_writes(b)
        reads |= r
        writes |= w
    return reads, writes


def _stmt_affine_vars(s: CanonStmt) -> Set[str]:
    out: Set[str] = set()
    for d in list(s.domain.dims) + list(s.reduce_dims()):
        out.update(d.lower.vars())
        out.update(d.upper.vars())
    for idx in s.write_idx:
        out.update(idx.vars())
    for acc in vexpr_accesses(s.rhs):
        for idx in acc.idx:
            out.update(idx.vars())
    return out


def _unit_affine_vars(u: Unit) -> Set[str]:
    if isinstance(u, RaisedUnit):
        return _stmt_affine_vars(u.stmt)
    if isinstance(u, FFTUnit):
        return set(u.stmt.n.vars()) if u.stmt.n is not None else set()
    if isinstance(u, OpaqueUnit):
        return set()
    out = set(u.dim.lower.vars()) | set(u.dim.upper.vars())
    for b in u.body:
        out |= _unit_affine_vars(b)
    return out


def _subst_stmt_affines(s: CanonStmt, env: Dict[str, Affine]) -> CanonStmt:
    dims = tuple(LoopDim(d.var, d.lower.substitute(env),
                         d.upper.substitute(env), d.step)
                 for d in s.domain.dims)
    return CanonStmt(
        write_array=s.write_array,
        write_idx=tuple(i.substitute(env) for i in s.write_idx),
        domain=Domain(dims), rhs=substitute_vexpr(s.rhs, env), aug=s.aug,
        write_is_temp=s.write_is_temp, write_full=s.write_full,
        label=s.label, dtype=s.dtype)


def _is_const(e: VExpr, value: float) -> bool:
    return isinstance(e, VConst) and isinstance(e.value, (int, float)) \
        and float(e.value) == value


def _combine(op: str, left: VExpr, right: VExpr) -> VExpr:
    """left ∘ right with identity-element folding (0 + x → x, 1·x → x)."""
    if op == "+" and _is_const(left, 0.0):
        return right
    if op == "+" and _is_const(right, 0.0):
        return left
    if op == "*" and _is_const(left, 1.0):
        return right
    if op == "*" and _is_const(right, 1.0):
        return left
    return VBin(op, left, right)


def _stored_value(s: CanonStmt) -> Optional[VExpr]:
    """The full value the statement stores, as an expression over the
    statement's own iterators (aug forms expand their implicit read)."""
    if s.aug is None:
        return s.rhs
    if s.aug in ("+", "*"):
        return _combine(s.aug, VAccess(s.write_array, s.write_idx, s.dtype),
                        s.rhs)
    return None


# ---------------------------------------------------------------------------
# Domain matching (producer write space → consumer write space)
# ---------------------------------------------------------------------------

def _iter_env(p: CanonStmt, c: CanonStmt) -> Optional[Dict[str, Affine]]:
    """Positional iterator renaming that maps p's write onto c's write,
    requiring identical domains (bounds and step) after renaming."""
    if len(p.write_idx) != len(c.write_idx):
        return None
    if p.domain.rank() != c.domain.rank():
        return None
    names: Dict[str, str] = {}
    for ip, ic in zip(p.write_idx, c.write_idx):
        pv, cv = _pure_var(ip), _pure_var(ic)
        if pv is None and cv is None:
            if not ip.equals(ic):
                return None
            continue
        if pv is None or cv is None:
            return None
        if pv in names:
            if names[pv] != cv:
                return None
        else:
            names[pv] = cv
    pd = {d.var: d for d in p.domain.dims}
    cd = {d.var: d for d in c.domain.dims}
    for v, t in names.items():
        if v not in pd and v != t:
            return None  # enclosing bound iterator: must map to itself
    mapped = {}
    for v in pd:
        if v not in names or names[v] not in cd:
            return None
        mapped[v] = names[v]
    if len(set(mapped.values())) != len(cd):
        return None
    env = {k: Affine.var(v) for k, v in names.items()}
    for v, d in pd.items():
        d2 = cd[mapped[v]]
        if d.step != d2.step:
            return None
        if not d.lower.substitute(env).equals(d2.lower):
            return None
        if not d.upper.substitute(env).equals(d2.upper):
            return None
    return env


# ---------------------------------------------------------------------------
# Pattern 1: same-array flow fusion (W = e1 ; W op= e2)
# ---------------------------------------------------------------------------

def _reads_of_w_pinned(c: CanonStmt) -> bool:
    """Every explicit consumer read of its own write array must be at
    exactly the written element — reads at other elements would observe
    the producer's value at a different point in time."""
    for acc in vexpr_accesses(c.rhs):
        if acc.array != c.write_array:
            continue
        if len(acc.idx) != len(c.write_idx):
            return False
        for ia, iw in zip(acc.idx, c.write_idx):
            if not ia.equals(iw):
                return False
    return True


def _count_reads(e: VExpr, array: str) -> int:
    return sum(1 for a in vexpr_accesses(e) if a.array == array)


def _try_flow_fuse(p: CanonStmt, c: CanonStmt,
                   profile: str) -> Optional[CanonStmt]:
    if p.write_array != c.write_array:
        return None
    if p.write_full != c.write_full or p.write_is_temp != c.write_is_temp:
        return None
    if c.aug not in (None, "+", "*"):
        return None
    if profile == "inplace" and c.aug is not None:
        # the np backend executes `W op= e` in place — no temporary, no
        # separate store pass — so folding it into an expression + slice
        # store usually *adds* traffic. Only a plain constant fill
        # (`W = 0; W += e` → `W = e`) still saves a pass there; on the
        # functional profile every statement materializes the full array
        # (`.at[].set` copies), so all legal folds pay.
        if p.aug is not None or not isinstance(p.rhs, (VConst, VParam)):
            return None
    if not _reads_of_w_pinned(c):
        return None
    env = _iter_env(p, c)
    if env is None:
        return None
    value = _stored_value(p)
    if value is None:
        return None
    value = substitute_vexpr(_freshen_reduce_vars(value), env)
    # every consumer read of W — the implicit aug read AND any explicit
    # rhs access — observes the producer's stored value, so all of them
    # become the producer expression (duplication is cost-gated)
    uses = _count_reads(c.rhs, c.write_array)
    if uses:
        pts = cost.domain_points(list(c.domain.dims))
        pflops = cost.expr_flops_per_point(value)
        occurrences = uses + (1 if c.aug is not None else 0)
        if not cost.fusion_profitable(
                pts, pflops, occurrences,
                backend=_profile_backend(profile)):
            return None
        new_c_rhs = substitute_array_reads(c.rhs, c.write_array,
                                           lambda acc: value)
    else:
        new_c_rhs = c.rhs  # aug-less + no reads: dead store elimination
    if c.aug is not None:
        rhs = _combine(c.aug, value, new_c_rhs)
    else:
        rhs = new_c_rhs
    return CanonStmt(
        write_array=c.write_array, write_idx=c.write_idx, domain=c.domain,
        rhs=rhs, aug=None, write_is_temp=c.write_is_temp,
        write_full=c.write_full,
        label=f"fused:{p.label or p.write_array}+{c.label or c.write_array}",
        dtype=c.dtype or p.dtype)


def _flow_fuse_pass(units: List[Unit], stats: FusionStats,
                    profile: str) -> bool:
    for j, cu in enumerate(units):
        if not isinstance(cu, RaisedUnit):
            continue
        c = cu.stmt
        for i in range(j - 1, -1, -1):
            pu = units[i]
            if not isinstance(pu, RaisedUnit):
                break_reads, break_writes = _unit_reads_writes(pu)
                if c.write_array in (break_reads | break_writes):
                    break
                continue
            p = pu.stmt
            if p.write_array != c.write_array:
                # unrelated unit: legal to look past it only if it never
                # touches W and the producer's inputs are not written later
                continue
            fused = _try_flow_fuse(p, c, profile)
            if fused is not None and _between_clear(units, i, j, p):
                units[j] = RaisedUnit(fused)
                del units[i]
                stats.fused_units += 1
                stats.log.append(f"flow-fuse {p.write_array}: "
                                 f"{p.label} + {c.label}")
                return True
            break  # nearest same-array producer decides; don't skip it
    return False


def _between_clear(units: List[Unit], i: int, j: int,
                   p: CanonStmt) -> bool:
    """Units strictly between producer i and consumer j must not touch the
    fused array nor overwrite anything the producer reads (its evaluation
    moves to position j)."""
    w = p.write_array
    preads = _stmt_read_arrays(p)
    for k in range(i + 1, j):
        reads, writes = _unit_reads_writes(units[k])
        if w in reads or w in writes:
            return False
        if writes & preads:
            return False
    return True


# ---------------------------------------------------------------------------
# Pattern 2: array contraction (dead local temps)
# ---------------------------------------------------------------------------

def _walk_units(units: List[Unit]):
    for u in units:
        yield u
        if isinstance(u, (SeqLoopUnit, PforUnit)):
            yield from _walk_units(u.body)


def _uses_in(e: VExpr, array: str, in_reduce: bool = False):
    """Yield (access, in_reduce) for every read of ``array`` in e."""
    if isinstance(e, VAccess):
        if e.array == array:
            yield e, in_reduce
    elif isinstance(e, VBin):
        yield from _uses_in(e.left, array, in_reduce)
        yield from _uses_in(e.right, array, in_reduce)
    elif isinstance(e, VUnary):
        yield from _uses_in(e.operand, array, in_reduce)
    elif isinstance(e, VReduce):
        yield from _uses_in(e.child, array, True)


def _has_reduce(e: VExpr) -> bool:
    if isinstance(e, VReduce):
        return True
    if isinstance(e, VBin):
        return _has_reduce(e.left) or _has_reduce(e.right)
    if isinstance(e, VUnary):
        return _has_reduce(e.operand)
    return False


def _profile_backend(profile: str) -> str:
    """The cost-model backend a fusion profile arbitrates for (the
    per-backend ``alloc_cost`` term prices the eliminated temp)."""
    return "np" if profile == "inplace" else "jnp"


def _try_contract(units: List[Unit], root: List[Unit],
                  params: frozenset, stats: FusionStats,
                  profile: str) -> bool:
    for i, pu in enumerate(units):
        if not isinstance(pu, RaisedUnit):
            continue
        p = pu.stmt
        t = p.write_array
        if t in params or p.aug is not None:
            continue
        if not (p.write_full or p.write_is_temp):
            continue
        if any(_pure_var(idx) is None for idx in p.write_idx):
            continue
        writers = [u for u in _walk_units(root)
                   if isinstance(u, RaisedUnit) and u.stmt.write_array == t]
        if len(writers) != 1 or writers[0] is not pu:
            continue
        readers = []
        blocked = False
        for u in _walk_units(root):
            if isinstance(u, RaisedUnit):
                # aug re-writers of t need no clause here: any second
                # writer already failed the single-writer check above
                if any(True for _ in _uses_in(u.stmt.rhs, t)):
                    readers.append(u)
            elif isinstance(u, (FFTUnit, OpaqueUnit)):
                r, w = _unit_reads_writes(u)
                if t in r or t in w:
                    blocked = True
        if blocked or not readers:
            continue
        # every reader must be a later sibling at this level (a reader
        # nested one loop deeper would re-evaluate the producer per
        # iteration — never contract into a deeper nest)
        try:
            positions = [units.index(r) for r in readers]
        except ValueError:
            continue
        if any(pos <= i for pos in positions):
            continue
        # no unit may reference the temp's shape symbols except readers
        syms = {f"{t}__d{d}" for d in range(len(p.write_idx))}
        outside = False
        for u in _walk_units(root):
            if u is pu or u in readers:
                continue
            if isinstance(u, (SeqLoopUnit, PforUnit)):
                dvars = set(u.dim.lower.vars()) | set(u.dim.upper.vars())
                if dvars & syms:
                    outside = True
            elif _unit_affine_vars(u) & syms:
                outside = True
        if outside:
            continue
        if _contract_into(units, i, pu, readers, stats, profile):
            return True
    return False


def _contract_into(units: List[Unit], i: int, pu: RaisedUnit,
                   readers: List[RaisedUnit], stats: FusionStats,
                   profile: str) -> bool:
    p = pu.stmt
    t = p.write_array
    p_has_reduce = _has_reduce(p.rhs)
    uses = 0
    for r in readers:
        for acc, in_red in _uses_in(r.stmt.rhs, t):
            uses += 1
            if len(acc.idx) != len(p.write_idx):
                return False
            if in_red and p_has_reduce:
                # nested contraction would break einsum raising — keep
                # the producer as its own library call
                stats.rejected += 1
                return False
    pts = cost.domain_points(list(p.domain.dims))
    pflops = cost.expr_flops_per_point(p.rhs)
    if not cost.fusion_profitable(pts, pflops, uses,
                                  backend=_profile_backend(profile)):
        stats.rejected += 1
        return False
    # interference: between the producer and each reader no sibling may
    # overwrite anything the producer reads (readers themselves are
    # statement-atomic, so their own writes are safe)
    preads = _stmt_read_arrays(p)
    last = max(units.index(r) for r in readers)
    for k in range(i + 1, last + 1):
        u = units[k]
        reads, writes = _unit_reads_writes(u)
        if u in readers:
            if writes & preads and units.index(u) != last:
                return False
            continue
        if writes & preads:
            return False
    # substitute: T[f0..fk] → producer rhs with o_k := f_k, and the
    # temp's shape symbols → producer domain extents
    pvars = [_pure_var(idx) for idx in p.write_idx]
    dim_by_var = {d.var: d for d in p.domain.dims}
    sym_env = {}
    for d, v in enumerate(pvars):
        if v in dim_by_var:
            sym_env[f"{t}__d{d}"] = dim_by_var[v].extent()

    def builder(acc: VAccess) -> VExpr:
        value = _freshen_reduce_vars(p.rhs)
        env = {v: acc.idx[k] for k, v in enumerate(pvars)}
        return substitute_vexpr(value, env)

    for r in readers:
        pos = units.index(r)
        s = r.stmt
        new_rhs = substitute_array_reads(s.rhs, t, builder)
        ns = CanonStmt(
            write_array=s.write_array, write_idx=s.write_idx,
            domain=s.domain, rhs=new_rhs, aug=s.aug,
            write_is_temp=s.write_is_temp, write_full=s.write_full,
            label=s.label, dtype=s.dtype)
        units[pos] = RaisedUnit(_subst_stmt_affines(ns, sym_env))
    del units[i]
    stats.fused_units += 1
    stats.contracted_arrays.append(t)
    stats.log.append(f"contract {t} into {len(readers)} consumer(s)")
    return True


# ---------------------------------------------------------------------------
# Pattern 3: adjacent sequential-loop fusion
# ---------------------------------------------------------------------------

def _try_loop_fuse(u1: SeqLoopUnit, u2: SeqLoopUnit,
                   stats: FusionStats) -> Optional[SeqLoopUnit]:
    d1, d2 = u1.dim, u2.dim
    if d1.step != d2.step:
        return None
    if not (d1.lower.equals(d2.lower) and d1.upper.equals(d2.upper)):
        return None
    if not all(isinstance(b, RaisedUnit) for b in u1.body + u2.body):
        return None
    body2 = [b.stmt for b in u2.body]
    if d1.var != d2.var:
        used = set()
        for s in body2:
            used |= _stmt_affine_vars(s)
        if d1.var in used:
            return None  # renaming would capture
        env = {d2.var: Affine.var(d1.var)}
        body2 = [_subst_stmt_affines(s, env) for s in body2]
    body1 = [b.stmt for b in u1.body]
    if not dependence.fusion_legal(body1, body2, [d1.var]):
        stats.rejected += 1
        return None
    return SeqLoopUnit(d1, [RaisedUnit(s) for s in body1 + body2])


def _loop_fuse_pass(units: List[Unit], stats: FusionStats) -> bool:
    for i in range(len(units) - 1):
        u1, u2 = units[i], units[i + 1]
        if isinstance(u1, SeqLoopUnit) and isinstance(u2, SeqLoopUnit):
            fused = _try_loop_fuse(u1, u2, stats)
            if fused is not None:
                units[i] = fused
                del units[i + 1]
                stats.loops_fused += 1
                stats.log.append(f"loop-fuse {u1.dim.var}")
                return True
    return False


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------

def _fuse_level(units: List[Unit], root: List[Unit], params: frozenset,
                stats: FusionStats, profile: str) -> None:
    for u in units:
        if isinstance(u, (SeqLoopUnit, PforUnit)):
            _fuse_level(u.body, root, params, stats, profile)
    changed = True
    while changed:
        changed = (_loop_fuse_pass(units, stats)
                   or _flow_fuse_pass(units, stats, profile)
                   or _try_contract(units, root, params, stats,
                                    profile))
        if changed:
            # merged loop bodies expose new intra-body opportunities
            for u in units:
                if isinstance(u, (SeqLoopUnit, PforUnit)):
                    _fuse_level(u.body, root, params, stats, profile)


def fuse(sched, profile: str = "functional") -> FusionStats:
    """Run the fusion pass in place on a Schedule.

    ``profile`` names the backend's memory behaviour for the cost gate:
    ``"functional"`` (jnp — every statement materializes its full output,
    all legal fusions save traffic) or ``"inplace"`` (np — aug statements
    already run in place, so only contraction, pure forward substitution,
    and constant-fill folding pay). Returns the stats that are also
    recorded on ``sched.fusion``."""
    assert profile in ("functional", "inplace")
    params = frozenset(n for n, _ in sched.program.fn.params)
    stats = FusionStats()
    _fuse_level(sched.units, sched.units, params, stats, profile)
    sched.fusion = stats
    return stats
