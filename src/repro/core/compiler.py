"""Public compiler API: ``automphc.optimize`` — the whole paper in one call.

    from repro.core.compiler import optimize

    @optimize                       # or optimize(distribute=True, ...)
    def kernel(data: 'ndarray[f64,2]', corr: 'ndarray[f64,2]', M: int, N: int):
        ...

Pipeline (paper Fig. 4): Front-end (parse + type inference) → SCoP
extraction (explicit+implicit loop unification) → dependence analysis →
scheduling (absorption / distribution / pfor) → operator raising → code
generation (np + jnp variants) → multi-version dispatcher.

Hints can be hand-written (above) or harvested by the dynamic profiler
(paper §1: "supplied by the programmer or obtained by dynamic profiler
tools"):

    @optimize(profile=True, warmup=8)   # no hints needed
    def kernel(data, corr, M, N): ...

    ck = optimize.from_trace(traced_fn)          # explicit trace → kernel

With ``cache=VariantCache(dir)`` (or a path string) compiled variants
persist on disk keyed by (source hash, type signature, backend); a warm
process rebuilds the dispatcher from stored source and skips
parse → SCoP → schedule → codegen entirely.
"""

from __future__ import annotations

import functools
import inspect
import time
from typing import Callable, Dict, Optional, Union

import numpy as np

from repro import obs
from repro.profiler.cache import (CacheEntry, VariantCache, source_hash)
from repro.profiler.hints import (synthesize_hint_tiers, synthesize_hints,
                                  type_signature)
from repro.profiler.tracer import FunctionTrace, Tracer

from . import backends, codegen, cost, parser, schedule as schedule_mod, scop
from .multiversion import CompiledKernel, Variant
from .pfor import PforConfig


def _stage(scope, kernel: str, name: str, t0: float, t1: float,
           tracing: bool) -> None:
    """File one compile-pipeline stage: duration into the kernel's
    ``compile.<name>`` metrics scope, and (when tracing) a span on the
    head timeline."""
    scope.add_time(name + "_s", t1 - t0)
    if tracing:
        obs.recorder().record(name, "compile", t0, t1,
                              args={"kernel": kernel})


def _exec_variant(gen: codegen.GeneratedVariant, xp, extra: Dict) -> Callable:
    ns: Dict = {"xp": xp}
    ns.update(extra)
    exec(compile(gen.source, f"<automphc:{gen.fn_name}>", "exec"), ns)
    return ns[gen.fn_name]


def _resolved_type_sig(fn: Callable,
                       hints: Optional[Dict[str, str]]) -> str:
    """Canonical per-param type signature (cache key component). Merges
    source annotations with override hints and delegates the encoding to
    :func:`repro.profiler.hints.type_signature`. Uses only
    ``inspect.signature`` — deliberately cheap so the warm path never
    touches the AST."""
    try:
        names = [p for p in inspect.signature(fn).parameters
                 if p != "self"]
    except (TypeError, ValueError):
        names = []
    anns = dict(getattr(fn, "__annotations__", {}) or {})
    if hints:
        anns.update(hints)
    return type_signature(anns, names)


def _jnp_module():
    """jax.numpy with x64 enabled, or None when jax is unavailable.

    Numeric kernels carry float64 semantics (PolyBench); the LM stack
    requests bf16/f32 explicitly so enabling x64 globally is safe."""
    try:
        import jax

        jax.config.update("jax_enable_x64", True)
        import jax.numpy as jnp
    except Exception:
        return None
    return jnp


def _make_np_variant(gen_np: codegen.GeneratedVariant,
                     pfor_cfg: PforConfig) -> Variant:
    extra = {"__pfor_run": pfor_cfg.make_runner()}
    # hybrid variant: pfor bodies carry per-backend twins — each
    # recorded backend contributes its exec-namespace bindings (__jxp,
    # __pfor_jit, __plk, …) via its registry hook. Entries predating
    # the registry recorded jnp twins only (pfor_jnp_units).
    twin_units = dict(getattr(gen_np.meta, "pfor_twin_units", None) or {})
    if not twin_units and getattr(gen_np.meta, "pfor_jnp_units", None):
        twin_units = {"jnp": list(gen_np.meta.pfor_jnp_units)}
    for bname in twin_units:
        try:
            bk = backends.get(bname)
        except KeyError:
            raise codegen.EmitError(
                f"variant references unregistered backend {bname!r}")
        if bk.namespace is not None:
            extra.update(bk.namespace(gen_np.meta))
    np_fn = _exec_variant(gen_np, np, extra)
    return Variant("np", np_fn, gen_np)


def _make_jnp_variant(gen_jnp: codegen.GeneratedVariant) -> Optional[Variant]:
    jnp = _jnp_module()
    if jnp is None:
        return None
    jnp_fn = _exec_variant(gen_jnp, jnp, {})
    return Variant("jnp", jnp_fn, gen_jnp)


def compile_kernel(
    fn: Callable,
    *,
    distribute: bool = True,
    fuse: bool = True,
    runtime=None,
    tile: Optional[int] = None,
    workers: int = 4,
    accel_threshold: float = cost.ACCEL_FLOP_THRESHOLD,
    enable_jax: bool = True,
    hints: Optional[Dict[str, str]] = None,
    cache: Optional[Union[VariantCache, str]] = None,
    trace=None,
) -> CompiledKernel:
    if isinstance(cache, str):
        cache = VariantCache(cache)
    # trace=True (or REPRO_TRACE=1) records compile-pipeline spans; the
    # per-stage duration counters below are always on
    if trace:
        obs.enable()
    tracing = obs.enabled() if trace is None else bool(trace)
    kname = getattr(fn, "__name__", "kernel")
    cscope = obs.metrics.scope(f"compile.{kname}")

    pfor_cfg = PforConfig(runtime=runtime, tile=tile, workers=workers)
    pfor_cfg.distribute_threshold = cost.DISTRIBUTE_FLOP_THRESHOLD

    # backend tag carries every option that changes the *generated code*
    # (schedule shape included); runtime knobs (tile/workers/thresholds)
    # live in PforConfig / dispatch state rebuilt fresh on every load.
    # The token is registry-derived (sorted backend tags, each carrying
    # its codegen version): registering a backend or bumping a
    # backend's codegen_version re-keys the cache, so entries generated
    # with an older twin set miss into a recompile instead of serving
    # stale code. Twin tags are earned only when jax is *actually*
    # importable: a twin-less compile on a jax-less host files under
    # the np-only token, so installing jax later recompiles with twins
    # instead of serving the twin-less entry forever. The probe costs a
    # one-time jax import per process (already paid by any non-pfor
    # kernel's whole-jnp variant).
    jax_ok = enable_jax and _jnp_module() is not None
    backend_tag = backends.cache_token(jax_ok) \
        + ("" if enable_jax else ":nojax") \
        + (":dist" if distribute else ":nodist") \
        + (":fuse" if fuse else ":nofuse")
    src_h = type_sig = None
    if cache is not None:
        src_h = source_hash(fn)
        type_sig = _resolved_type_sig(fn, hints)
        entry = cache.get(src_h, type_sig, backend_tag)
        if entry is not None:
            tr0 = time.perf_counter()
            ck = _rebuild_from_entry(fn, entry, pfor_cfg, accel_threshold)
            if ck is not None:
                _stage(cscope, kname, "rebuild", tr0,
                       time.perf_counter(), tracing)
                cache.stats.codegen_skipped += 1
                return ck

    t0 = time.perf_counter()
    tir_fn = parser.parse_function(fn, hint_overrides=hints)
    t_parse = time.perf_counter()
    program = scop.extract(tir_fn)
    t_scop = time.perf_counter()
    # Each backend gets the fusion profile that matches its memory
    # behaviour: np mutates in place (contract temps, keep aug statements
    # distributed as library calls); jnp materializes every statement
    # (fuse everything legal so .at[].set copies disappear).
    sched = schedule_mod.schedule(program, distribute=distribute, fuse=fuse,
                                  fusion_profile="inplace")
    t_sched = time.perf_counter()
    _stage(cscope, kname, "parse", t0, t_parse, tracing)
    _stage(cscope, kname, "scop", t_parse, t_scop, tracing)
    _stage(cscope, kname, "schedule", t_scop, t_sched, tracing)
    # fusion + dependence ran inside schedule(); it leaves stamped
    # sub-stage intervals behind rather than importing obs itself
    for nm, s0, s1 in getattr(sched, "stage_spans", ()):
        _stage(cscope, kname, nm, s0, s1, tracing)
    # the cluster runtime diffs only schedule-written arrays when
    # gathering pfor chunk results from worker processes
    pfor_cfg.written = tuple(sched.written)
    pfor_cfg.sliceable = _sliceable_union(sched)

    variants: Dict[str, Variant] = {
        "original": Variant("original", fn),
    }

    # Optimized NumPy variant (always attempted; falls back statement-wise).
    # With pfor units and jax available it is a *hybrid*: seq units stay
    # np, every accelerator-feasible pfor body gets a jnp twin the
    # cluster routes GPU-capable workers to (per-unit backend variants —
    # no longer all-or-nothing like the paper's CuPy conversion). Twins
    # are generated eagerly (not on first cluster dispatch) so the
    # cached entry is self-contained and a runtime can be bound to the
    # compiled kernel later — the cost is one extra codegen pass here.
    hybrid = jax_ok and sched.has_pfor
    t_cg0 = time.perf_counter()
    gen_np = codegen.generate(sched, "np", pfor_jnp=hybrid)
    variants["np"] = _make_np_variant(gen_np, pfor_cfg)
    _stage(cscope, kname, "codegen", t_cg0, time.perf_counter(),
           tracing)

    # Whole-kernel accelerator variant (pfor-free kernels only)
    if enable_jax and not sched.has_opaque and not sched.has_pfor:
        t_cg0 = time.perf_counter()
        try:
            # with fusion off both profiles schedule identically
            sched_fn = sched if not fuse else schedule_mod.schedule(
                program, distribute=distribute, fuse=fuse,
                fusion_profile="functional")
            gen_jnp = codegen.generate(sched_fn, "jnp")
            v = _make_jnp_variant(gen_jnp)
            if v is not None:
                variants["jnp"] = v
        except codegen.EmitError:
            pass
        _stage(cscope, kname, "codegen", t_cg0, time.perf_counter(),
               tracing)
    compile_s = time.perf_counter() - t0

    ck = CompiledKernel(fn, tir_fn.params, sched, variants,
                        pfor_config=pfor_cfg,
                        accel_threshold=accel_threshold)

    if cache is not None:
        generated = {name: v.generated for name, v in variants.items()
                     if v.generated is not None}
        t_cs0 = time.perf_counter()
        try:
            cache.put(CacheEntry(
                fn_name=ck.__name__, src_hash=src_h, type_sig=type_sig,
                backend=backend_tag, params=list(tir_fn.params),
                sched=sched, generated=generated, compile_s=compile_s))
        except Exception:
            pass  # cache write failure must never break compilation
        _stage(cscope, kname, "cache_store", t_cs0,
               time.perf_counter(), tracing)
    return ck


def _sliceable_union(sched) -> tuple:
    """Union of per-unit chunk-sliceable arrays (telemetry + fallback for
    generated bodies predating the ``__sliceable__`` attribute)."""
    names = {n
             for u in schedule_mod._flatten(sched.units)
             if isinstance(u, schedule_mod.PforUnit)
             for n in getattr(u, "sliceable", ())}
    return tuple(sorted(names))


def _rebuild_from_entry(fn: Callable, entry: CacheEntry,
                        pfor_cfg: PforConfig,
                        accel_threshold: float) -> Optional[CompiledKernel]:
    """Warm start: dispatcher from stored source, no front-end work."""
    try:
        pfor_cfg.written = tuple(getattr(entry.sched, "written", ()) or ())
        pfor_cfg.sliceable = _sliceable_union(entry.sched)
        variants: Dict[str, Variant] = {
            "original": Variant("original", fn),
        }
        for name, gen in entry.generated.items():
            if name == "np":
                variants["np"] = _make_np_variant(gen, pfor_cfg)
            elif name == "jnp":
                v = _make_jnp_variant(gen)
                if v is not None:
                    variants[name] = v
        ck = CompiledKernel(fn, entry.params, entry.sched, variants,
                            pfor_config=pfor_cfg,
                            accel_threshold=accel_threshold)
        ck.from_cache = True
        return ck
    except Exception:
        # a stale/incompatible entry degrades to a cold compile
        return None


# ---------------------------------------------------------------------------
# Profile-guided entry points (the dynamic-profiler half of §4.1)
# ---------------------------------------------------------------------------

class ProfiledFunction:
    """Wrapper returned by ``optimize(profile=True)``.

    Phase 1 (first ``warmup`` calls): run the original function under the
    tracer, recording call signatures. Phase 2: synthesize a
    legality-ordered hint set from the trace, compile through the normal
    pipeline, and dispatch every later call through the multi-version
    decision tree (original function stays the fallback)."""

    def __init__(self, fn: Callable, *, warmup: int = 8,
                 tracer: Optional[Tracer] = None,
                 specializer=None, calibrate: bool = True, **compile_kw):
        self.fn = fn
        self.warmup = max(1, warmup)
        self.tracer = tracer or Tracer()
        self.traced = self.tracer.wrap(fn)
        self.specializer = specializer
        # calibrate the accelerator FLOP threshold from traced latencies
        # unless the caller pinned an explicit threshold
        self.calibrate = calibrate and "accel_threshold" not in compile_kw
        self.compile_kw = compile_kw
        self.compiled: Optional[CompiledKernel] = None
        self.tiers = None
        functools.update_wrapper(self, fn)

    @property
    def trace(self) -> FunctionTrace:
        return self.traced.__trace__

    def __call__(self, *args, **kwargs):
        if self.compiled is not None:
            return self.compiled(*args, **kwargs)
        out = self.traced(*args, **kwargs)
        if self.trace.calls >= self.warmup:
            try:
                self.compile()
            except Exception:
                # stay on the traced original; retry next call is
                # pointless with the same trace, so disable by doubling
                self.warmup *= 2
        return out

    def compile(self) -> CompiledKernel:
        """Fold the trace into hints and build the dispatcher now."""
        if self.compiled is None:
            self.tiers = synthesize_hint_tiers(self.trace)
            # all tiers share hint strings; one compile serves them all
            hints = self.tiers[-1].hints
            self.compiled = compile_kernel(self.fn, hints=hints,
                                           **self.compile_kw)
            if self.calibrate:
                thr = self.calibrated_threshold()
                if thr is not None:
                    self.compiled.accel_threshold = thr
            if self.specializer is not None:
                self.specializer.register(self.compiled)
        return self.compiled

    def calibrated_threshold(self) -> Optional[float]:
        """Per-machine accelerator threshold from the warmup trace.

        The tracer timed the *original* function per signature; the
        compiled schedule converts each signature's shapes/int params into
        a FLOP estimate, and the roofline calibrator turns the measured
        FLOP rate into the break-even point against the fixed dispatch
        overhead. Returns None (→ keep the static default) when the trace
        carries no usable sample."""
        if self.compiled is None:
            return None
        samples = []
        for rec in self.trace.signatures:
            env: Dict[str, int] = {}
            for o in rec.args:
                if o.kind in ("array", "list") and o.shape:
                    for d, s in enumerate(o.shape):
                        env[f"{o.name}__d{d}"] = int(s)
                elif o.ivalue is not None:
                    env[o.name] = o.ivalue
            try:
                flops = cost.schedule_flops(self.compiled.sched, env)
            except Exception:
                continue
            if rec.mean_s > 0 and flops > 0:
                samples.append((flops, rec.mean_s))
        if not samples:
            return None
        return cost.calibrate_accel_threshold(samples)

    def stats(self) -> Dict[str, object]:
        out: Dict[str, object] = {
            "traced_calls": self.trace.calls,
            "distinct_signatures": len(self.trace.records),
            "compiled": self.compiled is not None,
        }
        if self.compiled is not None:
            out["dispatch"] = self.compiled.stats()
        return out


def optimize(fn: Optional[Callable] = None, *, profile: bool = False,
             warmup: int = 8, tracer: Optional[Tracer] = None,
             specializer=None, calibrate: bool = True, **kw):
    """Decorator form of :func:`compile_kernel`.

    ``profile=True`` defers compilation behind a tracing phase so the
    kernel needs no hand-written hints (and, with ``calibrate=True``,
    tunes the accelerator profitability threshold from the measured
    warmup latencies)."""
    def build(f):
        if profile:
            return ProfiledFunction(f, warmup=warmup, tracer=tracer,
                                    specializer=specializer,
                                    calibrate=calibrate, **kw)
        return compile_kernel(f, **kw)

    if fn is not None and callable(fn):
        return build(fn)
    return build


def from_trace(fn: Callable, trace: Optional[FunctionTrace] = None,
               **kw) -> CompiledKernel:
    """Compile using hints synthesized from an existing trace.

    ``fn`` may be a tracer-wrapped function (its trace is used
    automatically) or the bare function plus an explicit ``trace``."""
    if trace is None:
        trace = getattr(fn, "__trace__", None)
        if trace is None:
            raise ValueError(
                "from_trace needs a tracer-wrapped function or an "
                "explicit trace= argument")
    target = getattr(fn, "__wrapped_fn__", fn)
    hints = synthesize_hints(trace)
    return compile_kernel(target, hints=hints, **kw)


optimize.from_trace = from_trace  # type: ignore[attr-defined]
