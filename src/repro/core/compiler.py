"""Public compiler API: ``automphc.optimize`` — the whole paper in one call.

    from repro.core.compiler import optimize

    @optimize                       # or optimize(distribute=True, ...)
    def kernel(data: 'ndarray[f64,2]', corr: 'ndarray[f64,2]', M: int, N: int):
        ...

Pipeline (paper Fig. 4): Front-end (parse + type inference) → SCoP
extraction (explicit+implicit loop unification) → dependence analysis →
scheduling (absorption / distribution / pfor) → operator raising → code
generation (np + jnp variants) → multi-version dispatcher.
"""

from __future__ import annotations

import functools
from typing import Callable, Dict, Optional

import numpy as np

from . import codegen, cost, parser, schedule as schedule_mod, scop
from .multiversion import CompiledKernel, Variant
from .pfor import PforConfig


def _exec_variant(gen: codegen.GeneratedVariant, xp, extra: Dict) -> Callable:
    ns: Dict = {"xp": xp}
    ns.update(extra)
    exec(compile(gen.source, f"<automphc:{gen.fn_name}>", "exec"), ns)
    return ns[gen.fn_name]


def compile_kernel(
    fn: Callable,
    *,
    distribute: bool = True,
    runtime=None,
    tile: Optional[int] = None,
    workers: int = 4,
    accel_threshold: float = cost.ACCEL_FLOP_THRESHOLD,
    enable_jax: bool = True,
) -> CompiledKernel:
    tir_fn = parser.parse_function(fn)
    program = scop.extract(tir_fn)
    sched = schedule_mod.schedule(program, distribute=distribute)

    pfor_cfg = PforConfig(runtime=runtime, tile=tile, workers=workers)
    pfor_cfg.distribute_threshold = cost.DISTRIBUTE_FLOP_THRESHOLD

    variants: Dict[str, Variant] = {
        "original": Variant("original", fn),
    }

    # Optimized NumPy variant (always attempted; falls back statement-wise)
    gen_np = codegen.generate(sched, "np")
    np_fn = _exec_variant(gen_np, np,
                          {"__pfor_run": pfor_cfg.make_runner()})
    variants["np"] = Variant("np", np_fn, gen_np)

    # Accelerator variant — all-or-nothing, like the paper's CuPy conversion
    if enable_jax and not sched.has_opaque and not sched.has_pfor:
        try:
            gen_jnp = codegen.generate(sched, "jnp")
            import jax

            # Numeric kernels carry float64 semantics (PolyBench); the LM
            # stack requests bf16/f32 explicitly so this is safe globally.
            jax.config.update("jax_enable_x64", True)
            import jax.numpy as jnp

            jnp_fn = _exec_variant(gen_jnp, jnp, {})
            variants["jnp"] = Variant("jnp", jnp_fn, gen_jnp)
        except codegen.EmitError:
            pass

    return CompiledKernel(fn, tir_fn.params, sched, variants,
                          pfor_config=pfor_cfg,
                          accel_threshold=accel_threshold)


def optimize(fn: Optional[Callable] = None, **kw):
    """Decorator form of :func:`compile_kernel`."""
    if fn is not None and callable(fn):
        return compile_kernel(fn, **kw)

    def deco(f):
        return compile_kernel(f, **kw)

    return deco
