"""Typed IR (TIR) — the compiler's AST.

Mirrors the paper's use of the Python Typed AST package as baseline IR
(§4.4): a small expression/statement language covering the affine+NumPy
subset that AutoMPHC optimizes, with a TypeInfo slot on every expression
filled in by inference (core/parser.py).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

from .types import TypeInfo


# ---------------------------------------------------------------------------
# Expressions
# ---------------------------------------------------------------------------

@dataclass
class Expr:
    ty: TypeInfo = field(default_factory=TypeInfo.unknown, kw_only=True)


@dataclass
class Const(Expr):
    value: Any = None


@dataclass
class Name(Expr):
    id: str = ""


@dataclass
class BinOp(Expr):
    op: str = ""  # '+', '-', '*', '/', '//', '%', '**', '@'
    left: Expr = None
    right: Expr = None


@dataclass
class UnaryOp(Expr):
    op: str = ""  # '-', 'not'
    operand: Expr = None


@dataclass
class Compare(Expr):
    op: str = ""  # '<', '<=', '>', '>=', '==', '!='
    left: Expr = None
    right: Expr = None


@dataclass
class IndexExpr(Expr):
    """A single subscript component: point index."""

    value: Expr = None


@dataclass
class SliceExpr(Expr):
    """lo:hi:step — any may be None."""

    lo: Optional[Expr] = None
    hi: Optional[Expr] = None
    step: Optional[Expr] = None


@dataclass
class Subscript(Expr):
    base: Expr = None
    # mixed tuple of IndexExpr / SliceExpr, one per subscripted dim
    indices: Tuple[Expr, ...] = ()


@dataclass
class Call(Expr):
    """Library or method call, canonicalized to a flat name.

    ``fn`` examples: 'np.dot', 'np.sqrt', 'method.sum', 'method.T',
    'np.fft.fft', 'range', 'len', 'np.zeros'.  For method calls the
    receiver is args[0].
    """

    fn: str = ""
    args: Tuple[Expr, ...] = ()
    kwargs: Dict[str, Expr] = field(default_factory=dict)


# ---------------------------------------------------------------------------
# Statements
# ---------------------------------------------------------------------------

@dataclass
class Stmt:
    pass


@dataclass
class Assign(Stmt):
    target: Expr = None  # Name or Subscript
    value: Expr = None
    aug: Optional[str] = None  # '+' for +=, etc.; None for plain =


@dataclass
class For(Stmt):
    var: str = ""
    lo: Expr = None
    hi: Expr = None
    step: Expr = None  # Const(1) default
    body: List[Stmt] = field(default_factory=list)
    # annotations added by the scheduler:
    parallel: bool = False        # provably dependence-free across iterations
    distributed: bool = False     # chosen for inter-node pfor distribution
    tile: Optional[int] = None


@dataclass
class If(Stmt):
    cond: Expr = None
    body: List[Stmt] = field(default_factory=list)
    orelse: List[Stmt] = field(default_factory=list)


@dataclass
class Return(Stmt):
    value: Optional[Expr] = None


@dataclass
class ExprStmt(Stmt):
    value: Expr = None


@dataclass
class Opaque(Stmt):
    """Black-box statement (paper §4.2): unanalyzable code carried through
    with conservative read/write sets so the rest of the kernel still
    optimizes. ``src`` is the original source text re-emitted verbatim."""

    src: str = ""
    reads: Tuple[str, ...] = ()
    writes: Tuple[str, ...] = ()


@dataclass
class Function:
    name: str = ""
    params: List[Tuple[str, TypeInfo]] = field(default_factory=list)
    body: List[Stmt] = field(default_factory=list)
    ret: TypeInfo = field(default_factory=TypeInfo.unknown)
    # free symbols treated as structure parameters (sizes like M, N)
    sym_params: List[str] = field(default_factory=list)


# ---------------------------------------------------------------------------
# Walkers
# ---------------------------------------------------------------------------

def walk_exprs(e: Expr):
    """Yield e and all sub-expressions."""
    if e is None:
        return
    yield e
    if isinstance(e, BinOp):
        yield from walk_exprs(e.left)
        yield from walk_exprs(e.right)
    elif isinstance(e, UnaryOp):
        yield from walk_exprs(e.operand)
    elif isinstance(e, Compare):
        yield from walk_exprs(e.left)
        yield from walk_exprs(e.right)
    elif isinstance(e, Subscript):
        yield from walk_exprs(e.base)
        for i in e.indices:
            yield from walk_exprs(i)
    elif isinstance(e, IndexExpr):
        yield from walk_exprs(e.value)
    elif isinstance(e, SliceExpr):
        for s in (e.lo, e.hi, e.step):
            if s is not None:
                yield from walk_exprs(s)
    elif isinstance(e, Call):
        for a in e.args:
            yield from walk_exprs(a)
        for a in e.kwargs.values():
            yield from walk_exprs(a)


def walk_stmts(stmts: List[Stmt]):
    for s in stmts:
        yield s
        if isinstance(s, For):
            yield from walk_stmts(s.body)
        elif isinstance(s, If):
            yield from walk_stmts(s.body)
            yield from walk_stmts(s.orelse)


def expr_names(e: Expr) -> List[str]:
    return [x.id for x in walk_exprs(e) if isinstance(x, Name)]


def stmt_reads_writes(s: Stmt) -> Tuple[set, set]:
    """Conservative variable-level read/write sets for one statement."""
    reads, writes = set(), set()
    if isinstance(s, Assign):
        if isinstance(s.target, Name):
            writes.add(s.target.id)
        elif isinstance(s.target, Subscript):
            base = s.target.base
            while isinstance(base, Subscript):
                base = base.base
            if isinstance(base, Name):
                writes.add(base.id)
            for i in s.target.indices:
                reads.update(expr_names(i))
        reads.update(expr_names(s.value))
        if s.aug is not None and isinstance(s.target, Subscript):
            base = s.target.base
            while isinstance(base, Subscript):
                base = base.base
            if isinstance(base, Name):
                reads.add(base.id)
    elif isinstance(s, For):
        reads.update(expr_names(s.lo))
        reads.update(expr_names(s.hi))
        if s.step is not None:
            reads.update(expr_names(s.step))
        for b in s.body:
            r, w = stmt_reads_writes(b)
            reads |= r
            writes |= w
        reads.discard(s.var)
    elif isinstance(s, If):
        reads.update(expr_names(s.cond))
        for b in list(s.body) + list(s.orelse):
            r, w = stmt_reads_writes(b)
            reads |= r
            writes |= w
    elif isinstance(s, Return):
        if s.value is not None:
            reads.update(expr_names(s.value))
    elif isinstance(s, ExprStmt):
        reads.update(expr_names(s.value))
    elif isinstance(s, Opaque):
        reads.update(s.reads)
        writes.update(s.writes)
    return reads, writes
