"""Front-end: Python source → Typed IR.

Parses type-hinted kernel functions (paper §3: "kernel functions with type
annotations are first translated by the Front-end to an AST representation")
and runs type inference over the TIR using knowledge-base type rules.

Anything outside the analyzable subset degrades to a tir.Opaque black-box
statement with conservative read/write sets (paper §4.2) — the kernel still
compiles; only that statement is excluded from polyhedral optimization.
"""

from __future__ import annotations

import ast
import inspect
import textwrap
from typing import Callable, Dict, List, Optional, Tuple

from . import knowledge
from . import tir
from .types import TypeInfo, broadcast, parse_annotation, promote_dtype

_BINOPS = {
    ast.Add: "+", ast.Sub: "-", ast.Mult: "*", ast.Div: "/",
    ast.FloorDiv: "//", ast.Mod: "%", ast.Pow: "**", ast.MatMult: "@",
}
_CMPOPS = {
    ast.Lt: "<", ast.LtE: "<=", ast.Gt: ">", ast.GtE: ">=",
    ast.Eq: "==", ast.NotEq: "!=",
}


class ParseError(Exception):
    pass


def _call_name(node: ast.Call) -> Optional[Tuple[str, Optional[ast.expr]]]:
    """Flatten a call target into (registry name, receiver-or-None):
    ('np.fft.fft', None), ('method.sum', <receiver expr>), ('range', None)…
    """
    f = node.func
    parts: List[str] = []
    probe = f
    while isinstance(probe, ast.Attribute):
        parts.append(probe.attr)
        probe = probe.value
    if isinstance(probe, ast.Name):
        dotted = ".".join(reversed(parts + [probe.id]))
        if dotted.startswith("numpy."):
            dotted = "np." + dotted[len("numpy."):]
        if dotted.startswith("np.") or dotted in ("range", "len", "min",
                                                  "max", "abs", "float",
                                                  "int"):
            return dotted, None
    # receiver.method(...) — receiver may be any expression
    if isinstance(f, ast.Attribute):
        return "method." + f.attr, f.value
    if isinstance(f, ast.Name):
        return f.id, None
    return None


class _FnParser(ast.NodeVisitor):
    def __init__(self, src: str, global_syms: Dict[str, object]):
        self.src_lines = src.splitlines()
        self.globals = global_syms
        self.sym_params: List[str] = []

    # -- expressions ---------------------------------------------------
    def expr(self, node: ast.expr) -> tir.Expr:
        if isinstance(node, ast.Constant):
            return tir.Const(value=node.value)
        if isinstance(node, ast.Name):
            return tir.Name(id=node.id)
        if isinstance(node, ast.UnaryOp):
            if isinstance(node.op, ast.USub):
                return tir.UnaryOp(op="-", operand=self.expr(node.operand))
            raise ParseError("unsupported unary op")
        if isinstance(node, ast.BinOp):
            op = _BINOPS.get(type(node.op))
            if op is None:
                raise ParseError("unsupported binop")
            return tir.BinOp(op=op, left=self.expr(node.left),
                             right=self.expr(node.right))
        if isinstance(node, ast.Compare):
            if len(node.ops) != 1:
                raise ParseError("chained compare")
            return tir.Compare(op=_CMPOPS[type(node.ops[0])],
                               left=self.expr(node.left),
                               right=self.expr(node.comparators[0]))
        if isinstance(node, ast.Subscript):
            return self._subscript(node)
        if isinstance(node, ast.Attribute):
            # arr.T / arr.shape handled as pseudo-calls
            if node.attr == "T":
                return tir.Call(fn="method.T", args=(self.expr(node.value),))
            if node.attr == "shape":
                return tir.Call(fn="method.shape",
                                args=(self.expr(node.value),))
            raise ParseError(f"unsupported attribute .{node.attr}")
        if isinstance(node, ast.Call):
            got = _call_name(node)
            if got is None:
                raise ParseError("unanalyzable call")
            name, recv = got
            args: List[tir.Expr] = []
            if recv is not None:
                args.append(self.expr(recv))
            args.extend(self.expr(a) for a in node.args)
            kwargs = {}
            for kw in node.keywords:
                if kw.arg is None:
                    raise ParseError("**kwargs unsupported")
                kwargs[kw.arg] = self.expr(kw.value)
            return tir.Call(fn=name, args=tuple(args), kwargs=kwargs)
        if isinstance(node, ast.Tuple):
            raise ParseError("tuple expression")
        raise ParseError(f"unsupported expr {ast.dump(node)[:60]}")

    def _subscript(self, node: ast.Subscript) -> tir.Subscript:
        base = self.expr(node.value)
        sl = node.slice
        elems = list(sl.elts) if isinstance(sl, ast.Tuple) else [sl]
        indices: List[tir.Expr] = []
        for e in elems:
            if isinstance(e, ast.Slice):
                indices.append(tir.SliceExpr(
                    lo=self.expr(e.lower) if e.lower else None,
                    hi=self.expr(e.upper) if e.upper else None,
                    step=self.expr(e.step) if e.step else None))
            else:
                indices.append(tir.IndexExpr(value=self.expr(e)))
        # a[i][j] → flatten into one Subscript with two indices
        if isinstance(base, tir.Subscript):
            return tir.Subscript(base=base.base,
                                 indices=base.indices + tuple(indices))
        return tir.Subscript(base=base, indices=tuple(indices))

    # -- statements -----------------------------------------------------
    def stmts(self, body: List[ast.stmt]) -> List[tir.Stmt]:
        out: List[tir.Stmt] = []
        for node in body:
            out.extend(self.stmt(node))
        return out

    def _opaque(self, node: ast.stmt) -> tir.Opaque:
        seg = ast.get_source_segment("\n".join(self.src_lines), node)
        reads, writes = set(), set()
        for n in ast.walk(node):
            if isinstance(n, ast.Name):
                if isinstance(n.ctx, ast.Store):
                    writes.add(n.id)
                else:
                    reads.add(n.id)
        return tir.Opaque(src=seg or "", reads=tuple(sorted(reads)),
                          writes=tuple(sorted(writes)))

    def stmt(self, node: ast.stmt) -> List[tir.Stmt]:
        try:
            return self._stmt(node)
        except ParseError:
            return [self._opaque(node)]

    def _stmt(self, node: ast.stmt) -> List[tir.Stmt]:
        if isinstance(node, ast.Expr):
            if isinstance(node.value, ast.Constant):
                return []  # docstring
            return [tir.ExprStmt(value=self.expr(node.value))]
        if isinstance(node, ast.Assign):
            if len(node.targets) != 1:
                raise ParseError("multi-target assign")
            tgt = node.targets[0]
            if isinstance(tgt, ast.Tuple):
                raise ParseError("tuple unpack")
            return [tir.Assign(target=self.expr(tgt),
                               value=self.expr(node.value))]
        if isinstance(node, ast.AugAssign):
            op = _BINOPS.get(type(node.op))
            if op is None:
                raise ParseError("unsupported augop")
            return [tir.Assign(target=self.expr(node.target),
                               value=self.expr(node.value), aug=op)]
        if isinstance(node, ast.AnnAssign) and node.value is not None:
            return [tir.Assign(target=self.expr(node.target),
                               value=self.expr(node.value))]
        if isinstance(node, ast.For):
            if not isinstance(node.target, ast.Name) or node.orelse:
                raise ParseError("non-name loop var")
            it = node.iter
            if not (isinstance(it, ast.Call)
                    and _call_name(it) == ("range", None)):
                raise ParseError("non-range for")
            rargs = [self.expr(a) for a in it.args]
            if len(rargs) == 1:
                lo, hi, step = tir.Const(value=0), rargs[0], tir.Const(value=1)
            elif len(rargs) == 2:
                lo, hi, step = rargs[0], rargs[1], tir.Const(value=1)
            else:
                lo, hi, step = rargs
            return [tir.For(var=node.target.id, lo=lo, hi=hi, step=step,
                            body=self.stmts(node.body))]
        if isinstance(node, ast.If):
            return [tir.If(cond=self.expr(node.test),
                           body=self.stmts(node.body),
                           orelse=self.stmts(node.orelse))]
        if isinstance(node, ast.Return):
            return [tir.Return(value=self.expr(node.value)
                               if node.value else None)]
        if isinstance(node, (ast.Pass,)):
            return []
        raise ParseError(f"unsupported stmt {type(node).__name__}")


# ---------------------------------------------------------------------------
# Type inference
# ---------------------------------------------------------------------------

class TypeInference:
    """Forward dataflow over the TIR, knowledge-base type rules for calls."""

    def __init__(self, fn: tir.Function):
        self.fn = fn
        self.env: Dict[str, TypeInfo] = {n: t for n, t in fn.params}

    def run(self) -> None:
        self._block(self.fn.body)

    def _block(self, body: List[tir.Stmt]) -> None:
        for s in body:
            if isinstance(s, tir.Assign):
                self._expr(s.value)
                if isinstance(s.target, tir.Name):
                    prev = self.env.get(s.target.id)
                    new = s.value.ty
                    if prev is not None and prev.kind == "array" and \
                            new.kind == "array":
                        new = TypeInfo.array(
                            promote_dtype(prev.dtype, new.dtype) or "float64",
                            prev.rank or new.rank)
                    self.env[s.target.id] = new
                    s.target.ty = new
                elif isinstance(s.target, tir.Subscript):
                    self._expr(s.target)
            elif isinstance(s, tir.For):
                self.env[s.var] = TypeInfo.scalar("int64")
                for e in (s.lo, s.hi, s.step):
                    if e is not None:
                        self._expr(e)
                self._block(s.body)
            elif isinstance(s, tir.If):
                self._expr(s.cond)
                self._block(s.body)
                self._block(s.orelse)
            elif isinstance(s, tir.Return) and s.value is not None:
                self._expr(s.value)
                self.fn.ret = s.value.ty
            elif isinstance(s, tir.ExprStmt):
                self._expr(s.value)
            elif isinstance(s, tir.Opaque):
                for w in s.writes:  # black-box poisons its writes
                    self.env[w] = TypeInfo.unknown()

    def _expr(self, e: tir.Expr) -> TypeInfo:
        t = self._expr_inner(e)
        e.ty = t
        return t

    def _expr_inner(self, e: tir.Expr) -> TypeInfo:
        if isinstance(e, tir.Const):
            if isinstance(e.value, bool):
                return TypeInfo.scalar("bool")
            if isinstance(e.value, int):
                return TypeInfo.scalar("int64")
            if isinstance(e.value, float):
                return TypeInfo.scalar("float64")
            if isinstance(e.value, complex):
                return TypeInfo.scalar("complex128")
            return TypeInfo.unknown()
        if isinstance(e, tir.Name):
            return self.env.get(e.id, TypeInfo.unknown())
        if isinstance(e, tir.UnaryOp):
            return self._expr(e.operand)
        if isinstance(e, tir.BinOp):
            lt, rt = self._expr(e.left), self._expr(e.right)
            if e.op == "@":
                entry = knowledge.lookup("np.matmul")
                return entry.type_rule(lt, rt)
            if e.op == "/":
                out = broadcast(lt, rt)
                dt = out.dtype
                if dt in ("int64", "int32", "bool", None):
                    dt = "float64"
                return (TypeInfo.scalar(dt) if out.rank == 0
                        else TypeInfo.array(dt, out.rank))
            return broadcast(lt, rt)
        if isinstance(e, tir.Compare):
            self._expr(e.left)
            self._expr(e.right)
            return TypeInfo.scalar("bool")
        if isinstance(e, tir.Subscript):
            bt = self._expr(e.base).as_array()
            for i in e.indices:
                self._expr(i)
            if bt.kind != "array":
                return TypeInfo.unknown()
            dropped = sum(1 for i in e.indices
                          if isinstance(i, tir.IndexExpr))
            rank = max(0, (bt.rank or len(e.indices)) - dropped)
            return (TypeInfo.scalar(bt.dtype or "float64") if rank == 0
                    else TypeInfo.array(bt.dtype or "float64", rank))
        if isinstance(e, (tir.IndexExpr,)):
            return self._expr(e.value)
        if isinstance(e, tir.SliceExpr):
            for s in (e.lo, e.hi, e.step):
                if s is not None:
                    self._expr(s)
            return TypeInfo.unknown()
        if isinstance(e, tir.Call):
            arg_ts = [self._expr(a) for a in e.args]
            kw_ts = {k: self._expr(v) for k, v in e.kwargs.items()}
            if e.fn == "method.shape":
                return TypeInfo.unknown()
            entry = knowledge.lookup(e.fn)
            if entry is None:
                return TypeInfo.unknown()
            kw: Dict[str, object] = {}
            if "axis" in e.kwargs and isinstance(e.kwargs["axis"], tir.Const):
                kw["axis"] = e.kwargs["axis"].value
            if entry.semantic[0] == "alloc":
                rank = 1
                if e.args and isinstance(e.args[0], tir.Call):
                    pass
                if e.args:
                    a0 = e.args[0]
                    if isinstance(a0, tir.Const):
                        rank = 1
                # np.zeros((m, n)) parsed as Call with Tuple → Opaque; our
                # corpus uses np.zeros_like-free explicit shapes via helper
                shape_arg = e.kwargs.get("shape")
                return entry.type_rule(dtype="float64", rank=rank)
            try:
                return entry.type_rule(*arg_ts, **kw)
            except Exception:
                return TypeInfo.unknown()
        return TypeInfo.unknown()


# ---------------------------------------------------------------------------
# Public API
# ---------------------------------------------------------------------------

def parse_function(fn: Callable,
                   hint_overrides: Optional[Dict[str, str]] = None
                   ) -> tir.Function:
    """Parse a live Python function (with type hints) into typed TIR.

    ``hint_overrides`` maps parameter names to hint strings and takes
    precedence over source annotations — this is how profiler-synthesized
    hints (paper §1: hints "obtained by dynamic profiler tools") enter the
    same front-end as hand-written ones.
    """
    src = textwrap.dedent(inspect.getsource(fn))
    tree = ast.parse(src)
    fdef = None
    for node in tree.body:
        if isinstance(node, ast.FunctionDef):
            fdef = node
            break
    if fdef is None:
        raise ParseError("no function def found")
    try:
        hints = dict(getattr(fn, "__annotations__", {}) or {})
    except Exception:  # pragma: no cover
        hints = {}
    if hint_overrides:
        hints.update(hint_overrides)
    params: List[Tuple[str, TypeInfo]] = []
    for a in fdef.args.args:
        if a.arg == "self":
            continue
        ann = hints.get(a.arg)
        if ann is None and a.annotation is not None:
            if isinstance(a.annotation, ast.Constant):
                ann = a.annotation.value
            elif isinstance(a.annotation, ast.Name):
                ann = a.annotation.id
        params.append((a.arg, parse_annotation(ann)))
    p = _FnParser(src, getattr(fn, "__globals__", {}))
    body = p.stmts(fdef.body)
    out = tir.Function(name=fdef.name, params=params, body=body,
                       ret=parse_annotation(hints.get("return")))
    # structure parameters: int-typed params + any free names
    bound = {n for n, _ in params}
    for s in tir.walk_stmts(out.body):
        if isinstance(s, tir.For):
            bound.add(s.var)
        if isinstance(s, tir.Assign) and isinstance(s.target, tir.Name):
            bound.add(s.target.id)
    free: List[str] = []
    for s in tir.walk_stmts(out.body):
        r, _ = tir.stmt_reads_writes(s)
        for n in r:
            if n not in bound and n not in free and n not in ("np", "numpy"):
                free.append(n)
    out.sym_params = sorted(
        set(free) | {n for n, t in params if t.is_numeric_scalar
                     and t.dtype in ("int64", "int32")})
    TypeInference(out).run()
    return out
