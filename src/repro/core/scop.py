"""SCoP extraction: unify explicit Python loops and implicit NumPy loops.

This is the paper's central §4.2 mechanism. Every analyzable statement is
canonicalized to

    W[f(outs)]  (op)=  Σ_{reduce dims}  e( A_m[g_m(outs, reds)] )

where ``outs`` are the *output* iterators (explicit loop variables plus one
fresh iterator per slice dimension of the write target) and ``reds`` are
reduction iterators (from explicit accumulation loops *or* implicit
contractions like ``np.dot``/``.sum``). Explicit-loop kernels (PolyBench
"List" versions) and NumPy-operator kernels canonicalize to the *same*
form — which is exactly how AutoMPHC optimizes both styles identically.

Ops the knowledge base cannot express element-wise (``np.fft.fft``) are
*materialization points*: their operand is flushed to a temporary statement
and the op becomes a standalone statement (paper Fig 7: statement T).
Anything else unanalyzable becomes an Opaque region (black-box statement
with approximated read/write sets).
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple, Union

from . import knowledge, tir
from .isl_lite import Affine, AffineError, Domain, LoopDim
from .types import TypeInfo


class NonAffine(Exception):
    pass


_fresh_counter = itertools.count()


def fresh(prefix: str) -> str:
    return f"_{prefix}{next(_fresh_counter)}"


# ---------------------------------------------------------------------------
# Scalar-expression trees over array accesses
# ---------------------------------------------------------------------------

@dataclass
class VExpr:
    pass


@dataclass
class VAccess(VExpr):
    array: str
    idx: Tuple[Affine, ...]
    dtype: Optional[str] = None


@dataclass
class VConst(VExpr):
    value: object


@dataclass
class VParam(VExpr):
    """A scalar variable (kernel parameter or loop-invariant local)."""

    name: str


@dataclass
class VBin(VExpr):
    op: str
    left: VExpr
    right: VExpr


@dataclass
class VUnary(VExpr):
    fn: str  # 'np.sqrt', '-', …
    operand: VExpr


@dataclass
class VReduce(VExpr):
    op: str  # 'sum' (mean is rewritten to sum/extent)
    dims: Tuple[LoopDim, ...]
    child: VExpr


def vexpr_arrays(e: VExpr) -> List[str]:
    if isinstance(e, VAccess):
        return [e.array]
    if isinstance(e, VBin):
        return vexpr_arrays(e.left) + vexpr_arrays(e.right)
    if isinstance(e, VUnary):
        return vexpr_arrays(e.operand)
    if isinstance(e, VReduce):
        return vexpr_arrays(e.child)
    return []


def vexpr_accesses(e: VExpr) -> List[VAccess]:
    if isinstance(e, VAccess):
        return [e]
    if isinstance(e, VBin):
        return vexpr_accesses(e.left) + vexpr_accesses(e.right)
    if isinstance(e, VUnary):
        return vexpr_accesses(e.operand)
    if isinstance(e, VReduce):
        return vexpr_accesses(e.child)
    return []


def substitute_array_reads(e: VExpr, array: str, builder) -> VExpr:
    """Replace every read of ``array`` with ``builder(access)`` (shared by
    the fusion pass and the loop-fallback emitter)."""
    if isinstance(e, VAccess):
        return builder(e) if e.array == array else e
    if isinstance(e, VBin):
        return VBin(e.op, substitute_array_reads(e.left, array, builder),
                    substitute_array_reads(e.right, array, builder))
    if isinstance(e, VUnary):
        return VUnary(e.fn, substitute_array_reads(e.operand, array,
                                                   builder))
    if isinstance(e, VReduce):
        return VReduce(e.op, e.dims,
                       substitute_array_reads(e.child, array, builder))
    return e


def substitute_vexpr(e: VExpr, env: Dict[str, Affine]) -> VExpr:
    if isinstance(e, VAccess):
        return VAccess(e.array, tuple(a.substitute(env) for a in e.idx),
                       e.dtype)
    if isinstance(e, VBin):
        return VBin(e.op, substitute_vexpr(e.left, env),
                    substitute_vexpr(e.right, env))
    if isinstance(e, VUnary):
        return VUnary(e.fn, substitute_vexpr(e.operand, env))
    if isinstance(e, VReduce):
        dims = tuple(LoopDim(d.var, d.lower.substitute(env),
                             d.upper.substitute(env), d.step)
                     for d in e.dims)
        return VReduce(e.op, dims, substitute_vexpr(e.child, env))
    return e


# ---------------------------------------------------------------------------
# Views: tensor-valued expressions with named axes
# ---------------------------------------------------------------------------

@dataclass
class View:
    """expr: scalar VExpr in terms of ``axes`` iterators (plus any reduce
    iterators bound inside VReduce nodes). ``dims[v]`` gives each axis
    iterator's LoopDim."""

    expr: VExpr
    axes: Tuple[str, ...]
    dims: Dict[str, LoopDim]
    dtype: Optional[str] = None

    @property
    def rank(self) -> int:
        return len(self.axes)


# ---------------------------------------------------------------------------
# Canonical statements / program structure
# ---------------------------------------------------------------------------

@dataclass
class CanonStmt:
    """W[f(outs)] (op)= rhs.  ``domain`` holds only the out iterators."""

    write_array: str
    write_idx: Tuple[Affine, ...]
    domain: Domain
    rhs: VExpr
    aug: Optional[str] = None  # '+' / '*' / None
    write_is_temp: bool = False     # target is a compiler temp (fresh array)
    write_full: bool = False        # target is a whole variable (x = expr)
    label: str = ""
    dtype: Optional[str] = None

    def reduce_dims(self) -> Tuple[LoopDim, ...]:
        out: List[LoopDim] = []

        def rec(e: VExpr):
            if isinstance(e, VReduce):
                out.extend(e.dims)
                rec(e.child)
            elif isinstance(e, VBin):
                rec(e.left)
                rec(e.right)
            elif isinstance(e, VUnary):
                rec(e.operand)

        rec(self.rhs)
        return tuple(out)


@dataclass
class OpaqueItem:
    stmts: List[tir.Stmt]
    reads: Tuple[str, ...] = ()
    writes: Tuple[str, ...] = ()


@dataclass
class LoopItem:
    dim: LoopDim
    body: List["Item"]
    parallel: Optional[bool] = None  # filled by dependence analysis


Item = Union[CanonStmt, OpaqueItem, LoopItem]


@dataclass
class ScopProgram:
    fn: tir.Function
    items: List[Item]
    params: List[str]
    # arrays allocated by the kernel itself (np.zeros/np.empty temps)
    temps: List[str] = field(default_factory=list)


# ---------------------------------------------------------------------------
# Extraction
# ---------------------------------------------------------------------------

class Extractor:
    def __init__(self, fn: tir.Function):
        self.fn = fn
        self.types: Dict[str, TypeInfo] = {n: t for n, t in fn.params}
        self.scalars: set = {
            n for n, t in fn.params if t.is_numeric_scalar or t.kind == "unknown"
        }
        self.arrays: set = {n for n, t in fn.params if t.is_array_like}
        self.temps: List[str] = []
        self.pre: List[Item] = []  # materialized statements pending emit

    # ---- affine conversion -------------------------------------------
    def affine(self, e: tir.Expr, iters: Dict[str, LoopDim]) -> Affine:
        if isinstance(e, tir.Const):
            if isinstance(e.value, int) and not isinstance(e.value, bool):
                return Affine.constant(e.value)
            raise NonAffine(f"non-int const {e.value!r}")
        if isinstance(e, tir.Name):
            return Affine.var(e.id)
        if isinstance(e, tir.UnaryOp) and e.op == "-":
            return -self.affine(e.operand, iters)
        if isinstance(e, tir.BinOp):
            l = self.affine(e.left, iters)
            r = self.affine(e.right, iters)
            if e.op == "+":
                return l + r
            if e.op == "-":
                return l - r
            if e.op == "*":
                return l * r
            raise NonAffine(f"op {e.op}")
        if isinstance(e, tir.Call) and e.fn == "len" and len(e.args) == 1 \
                and isinstance(e.args[0], tir.Name):
            return Affine.var(f"{e.args[0].id}__d0")
        if isinstance(e, tir.Subscript) and isinstance(e.base, tir.Call) \
                and e.base.fn == "method.shape" \
                and isinstance(e.base.args[0], tir.Name) \
                and len(e.indices) == 1 \
                and isinstance(e.indices[0], tir.IndexExpr) \
                and isinstance(e.indices[0].value, tir.Const):
            return Affine.var(
                f"{e.base.args[0].id}__d{e.indices[0].value.value}")
        raise NonAffine(type(e).__name__)

    # ---- views ----------------------------------------------------------
    def view(self, e: tir.Expr, iters: Dict[str, LoopDim]) -> View:
        if isinstance(e, tir.Const):
            return View(VConst(e.value), (), {})
        if isinstance(e, tir.Name):
            t = self.types.get(e.id, e.ty)
            if e.id in iters:
                raise NonAffine("loop var used as value")  # e.g. x = i*2
            if t.is_array_like and (t.rank or 0) > 0:
                # whole-array reference: one fresh iterator per dim
                axes, dims, idx = [], {}, []
                for d in range(t.rank):
                    v = fresh("x")
                    dim = LoopDim(v, Affine.constant(0),
                                  Affine.var(f"{e.id}__d{d}"))
                    axes.append(v)
                    dims[v] = dim
                    idx.append(Affine.var(v))
                return View(VAccess(e.id, tuple(idx), t.dtype),
                            tuple(axes), dims, t.dtype)
            return View(VParam(e.id), (), {}, t.dtype)
        if isinstance(e, tir.UnaryOp) and e.op == "-":
            v = self.view(e.operand, iters)
            return View(VUnary("-", v.expr), v.axes, v.dims, v.dtype)
        if isinstance(e, tir.Subscript):
            return self.subscript_view(e, iters)
        if isinstance(e, tir.BinOp):
            return self.binop_view(e, iters)
        if isinstance(e, tir.Call):
            return self.call_view(e, iters)
        raise NonAffine(type(e).__name__)

    def subscript_view(self, e: tir.Subscript,
                       iters: Dict[str, LoopDim]) -> View:
        if not isinstance(e.base, tir.Name):
            # subscript of a computed view: materialize then index
            base_view = self.view(e.base, iters)
            tmp = self.materialize(base_view)
            return self.subscript_view(
                tir.Subscript(base=tir.Name(id=tmp, ty=e.base.ty),
                              indices=e.indices, ty=e.ty), iters)
        name = e.base.id
        t = self.types.get(name, e.base.ty).as_array()
        rank = t.rank or len(e.indices)
        axes: List[str] = []
        dims: Dict[str, LoopDim] = {}
        idx: List[Affine] = []
        for d in range(rank):
            if d < len(e.indices):
                comp = e.indices[d]
            else:
                comp = tir.SliceExpr()  # trailing dims fully sliced
            if isinstance(comp, tir.IndexExpr):
                idx.append(self.affine(comp.value, iters))
            elif isinstance(comp, tir.SliceExpr):
                if comp.step is not None and not (
                        isinstance(comp.step, tir.Const)
                        and comp.step.value in (1, None)):
                    raise NonAffine("strided slice")
                lo = (self.affine(comp.lo, iters) if comp.lo is not None
                      else Affine.constant(0))
                hi = (self.affine(comp.hi, iters) if comp.hi is not None
                      else Affine.var(f"{name}__d{d}"))
                v = fresh("s")
                dim = LoopDim(v, lo, hi)
                axes.append(v)
                dims[v] = dim
                idx.append(Affine.var(v))
            else:
                raise NonAffine("bad subscript component")
        return View(VAccess(name, tuple(idx), t.dtype), tuple(axes), dims,
                    t.dtype)

    # ---- broadcasting unification --------------------------------------
    def unify(self, a: View, b: View) -> Tuple[View, View, Tuple[str, ...],
                                               Dict[str, LoopDim]]:
        """Align axes of two views by numpy trailing-dim broadcasting and
        substitute b's iterators with a's. Returns adjusted (a, b, axes,
        dims) for the result."""
        if a.rank < b.rank:
            b2, a2, axes, dims = self.unify(b, a)
            return a2, b2, axes, dims
        # a.rank >= b.rank: align b's axes to the trailing axes of a
        env: Dict[str, Affine] = {}
        for ai, bi in zip(a.axes[a.rank - b.rank:], b.axes):
            env[bi] = Affine.var(ai)
        b_expr = substitute_vexpr(b.expr, env)
        axes = a.axes
        dims = dict(a.dims)
        return a, View(b_expr, axes[a.rank - b.rank:],
                       {ax: dims[ax] for ax in axes[a.rank - b.rank:]},
                       b.dtype), axes, dims

    def binop_view(self, e: tir.BinOp, iters: Dict[str, LoopDim]) -> View:
        if e.op == "@":
            return self.dot_view(self.view(e.left, iters),
                                 self.view(e.right, iters))
        l = self.view(e.left, iters)
        r = self.view(e.right, iters)
        l2, r2, axes, dims = self.unify(l, r)
        return View(VBin(e.op, l2.expr, r2.expr), axes, dims,
                    l.dtype or r.dtype)

    def dot_view(self, a: View, b: View) -> View:
        """np.dot / @ semantics from the knowledge base (Table 2)."""
        if a.rank == 0 or b.rank == 0:
            raise NonAffine("dot with scalar")
        if a.rank == 1 and b.rank == 1:
            k_a, k_b = a.axes[0], b.axes[0]
            env = {k_b: Affine.var(k_a)}
            child = VBin("*", a.expr, substitute_vexpr(b.expr, env))
            red = a.dims[k_a]
            return View(VReduce("sum", (red,), child), (), {},
                        a.dtype or b.dtype)
        if a.rank == 2 and b.rank == 1:
            k_a, k_b = a.axes[1], b.axes[0]
            env = {k_b: Affine.var(k_a)}
            child = VBin("*", a.expr, substitute_vexpr(b.expr, env))
            red = a.dims[k_a]
            ax0 = a.axes[0]
            return View(VReduce("sum", (red,), child), (ax0,),
                        {ax0: a.dims[ax0]}, a.dtype or b.dtype)
        if a.rank == 1 and b.rank == 2:
            k_a, k_b = a.axes[0], b.axes[0]
            env = {k_a: Affine.var(k_b)}
            child = VBin("*", substitute_vexpr(a.expr, env), b.expr)
            red = b.dims[k_b]
            ax1 = b.axes[1]
            return View(VReduce("sum", (red,), child), (ax1,),
                        {ax1: b.dims[ax1]}, a.dtype or b.dtype)
        if a.rank == 2 and b.rank == 2:
            k_a, k_b = a.axes[1], b.axes[0]
            env = {k_b: Affine.var(k_a)}
            child = VBin("*", a.expr, substitute_vexpr(b.expr, env))
            red = a.dims[k_a]
            ax0, ax1 = a.axes[0], b.axes[1]
            return View(VReduce("sum", (red,), child), (ax0, ax1),
                        {ax0: a.dims[ax0], ax1: b.dims[ax1]},
                        a.dtype or b.dtype)
        raise NonAffine(f"dot rank {a.rank}x{b.rank}")

    def call_view(self, e: tir.Call, iters: Dict[str, LoopDim]) -> View:
        entry = knowledge.lookup(e.fn)
        if entry is None:
            raise NonAffine(f"unknown call {e.fn}")
        sem = entry.semantic[0]
        if sem == "elementwise":
            args = [self.view(a, iters) for a in e.args]
            if len(args) == 1:
                v = args[0]
                return View(VUnary(e.fn, v.expr), v.axes, v.dims, v.dtype)
            a, b = args[0], args[1]
            a2, b2, axes, dims = self.unify(a, b)
            return View(VBin(e.fn, a2.expr, b2.expr), axes, dims, a.dtype)
        if sem == "transpose":
            v = self.view(e.args[0], iters)
            if v.rank != 2:
                if v.rank <= 1:
                    return v
                raise NonAffine("transpose rank>2")
            axes = (v.axes[1], v.axes[0])
            return View(v.expr, axes, v.dims, v.dtype)
        if sem == "squeeze":
            v = self.view(e.args[0], iters)
            keep, dims = [], {}
            for ax in v.axes:
                d = v.dims[ax]
                ext = d.upper - d.lower
                if ext.is_constant() and ext.const == 1:
                    # fix the axis at its lower bound
                    v = View(substitute_vexpr(v.expr, {ax: d.lower}),
                             v.axes, v.dims, v.dtype)
                    continue
                keep.append(ax)
                dims[ax] = d
            return View(v.expr, tuple(keep), dims, v.dtype)
        if sem == "reduce":
            v = self.view(e.args[0], iters)
            axis = None
            if "axis" in e.kwargs:
                if not isinstance(e.kwargs["axis"], tir.Const):
                    raise NonAffine("dynamic axis")
                axis = e.kwargs["axis"].value
            kind = entry.semantic[1]
            if kind not in ("sum", "mean"):
                raise NonAffine(f"reduce kind {kind}")
            if axis is None:
                red_axes = list(v.axes)
            else:
                if axis < 0:
                    axis += v.rank
                red_axes = [v.axes[axis]]
            keep = tuple(ax for ax in v.axes if ax not in red_axes)
            red_dims = tuple(v.dims[ax] for ax in red_axes)
            expr: VExpr = VReduce("sum", red_dims, v.expr)
            if kind == "mean":
                denom: VExpr = None
                for d in red_dims:
                    ext = d.upper - d.lower
                    term = affine_to_vexpr(ext)
                    denom = term if denom is None else VBin("*", denom, term)
                expr = VBin("/", expr, denom)
            return View(expr, keep, {ax: v.dims[ax] for ax in keep},
                        v.dtype)
        if sem == "contract":
            if entry.semantic[1] == "dot":
                return self.dot_view(self.view(e.args[0], iters),
                                     self.view(e.args[1], iters))
            if entry.semantic[1] == "outer":
                a = self.view(e.args[0], iters)
                b = self.view(e.args[1], iters)
                if a.rank != 1 or b.rank != 1:
                    raise NonAffine("outer rank")
                axes = (a.axes[0], b.axes[0])
                dims = {a.axes[0]: a.dims[a.axes[0]],
                        b.axes[0]: b.dims[b.axes[0]]}
                return View(VBin("*", a.expr, b.expr), axes, dims, a.dtype)
        if sem == "fft":
            # materialization point: flush operand, emit standalone fft stmt
            v = self.view(e.args[0], iters)
            src = self.materialize(v)
            out = fresh("fft")
            self.temps.append(out)
            n_expr = None
            if len(e.args) >= 2:
                n_expr = self.affine(e.args[1], iters)
            axis = v.rank - 1  # numpy default: last axis
            if "axis" in e.kwargs and isinstance(e.kwargs["axis"], tir.Const):
                axis = e.kwargs["axis"].value
            if "n" in e.kwargs:
                n_expr = self.affine(e.kwargs["n"], iters)
            self.pre.append(FFTStmt(out=out, src=src, fn=e.fn, axis=axis,
                                    n=n_expr, src_rank=v.rank))
            dt = "complex128"
            t = TypeInfo.array(dt, v.rank)
            self.types[out] = t
            # output dims: same as src except fft axis extent may change
            axes, dims, idx = [], {}, []
            for d in range(v.rank):
                nv = fresh("x")
                if d == (axis if axis >= 0 else v.rank + axis) and \
                        n_expr is not None:
                    dim = LoopDim(nv, Affine.constant(0), n_expr)
                else:
                    src_dim = v.dims[v.axes[d]]
                    dim = LoopDim(nv, Affine.constant(0),
                                  src_dim.upper - src_dim.lower)
                axes.append(nv)
                dims[nv] = dim
                idx.append(Affine.var(nv))
            return View(VAccess(out, tuple(idx), dt), tuple(axes), dims, dt)
        raise NonAffine(f"semantic {sem}")

    # ---- materialization -------------------------------------------------
    def materialize(self, v: View) -> str:
        """Flush a view into a fresh temp array; returns its name."""
        # Fast path: the view is a whole-array identity access — no copy.
        if isinstance(v.expr, VAccess) and len(v.expr.idx) == len(v.axes):
            ok = True
            for ax, idx in zip(v.axes, v.expr.idx):
                d = v.dims[ax]
                if not (idx.equals(Affine.var(ax))
                        and d.lower.is_zero()
                        and d.upper.equals(
                            Affine.var(f"{v.expr.array}__d"
                                       f"{list(v.axes).index(ax)}"))):
                    ok = False
                    break
            if ok:
                return v.expr.array
        tmp = fresh("t")
        self.temps.append(tmp)
        # rebase axes to zero-based fresh iterators for a clean rectangular
        # temp: temp[o0, o1, …] = expr with oX = axis - lower
        env: Dict[str, Affine] = {}
        out_dims: List[LoopDim] = []
        idx: List[Affine] = []
        for ax in v.axes:
            d = v.dims[ax]
            o = fresh("o")
            env[ax] = Affine.var(o) + d.lower
            out_dims.append(LoopDim(o, Affine.constant(0),
                                    d.upper - d.lower))
            idx.append(Affine.var(o))
        stmt = CanonStmt(
            write_array=tmp,
            write_idx=tuple(idx),
            domain=Domain(tuple(out_dims)),
            rhs=substitute_vexpr(v.expr, env),
            aug=None, write_is_temp=True, dtype=v.dtype,
            label=f"materialize:{tmp}")
        self.pre.append(stmt)
        self.types[tmp] = TypeInfo.array(v.dtype or "float64", v.rank)
        return tmp

    # ---- statements -------------------------------------------------------
    def canon_assign(self, s: tir.Assign,
                     iters: Dict[str, LoopDim]) -> List[Item]:
        self.pre = []
        try:
            if isinstance(s.target, tir.Name):
                rhs = self.view(s.value, iters)
                if s.aug is not None and rhs.rank > 0:
                    raise NonAffine("aug on array-valued name")
                if s.aug is not None:
                    # scalar accumulator (symm's temp2 pattern): rank-0
                    # write with aug; absorption may turn it into a
                    # reduction
                    stmt = CanonStmt(
                        write_array=s.target.id, write_idx=(),
                        domain=Domain(()), rhs=rhs.expr, aug=s.aug,
                        write_full=True, dtype=rhs.dtype,
                        label=f"accum:{s.target.id}")
                    return self.pre + [stmt]
                # whole-variable assignment: x = <view>
                env: Dict[str, Affine] = {}
                out_dims, idx = [], []
                for ax in rhs.axes:
                    d = rhs.dims[ax]
                    o = fresh("o")
                    env[ax] = Affine.var(o) + d.lower
                    out_dims.append(LoopDim(o, Affine.constant(0),
                                            d.upper - d.lower))
                    idx.append(Affine.var(o))
                stmt = CanonStmt(
                    write_array=s.target.id, write_idx=tuple(idx),
                    domain=Domain(tuple(out_dims)),
                    rhs=substitute_vexpr(rhs.expr, env),
                    aug=None, write_full=True, dtype=rhs.dtype,
                    label=f"assign:{s.target.id}")
                self.types[s.target.id] = TypeInfo.array(
                    rhs.dtype or "float64", rhs.rank) if rhs.rank else \
                    TypeInfo.scalar(rhs.dtype or "float64")
                if rhs.rank:
                    self.arrays.add(s.target.id)
                return self.pre + [stmt]
            if not isinstance(s.target, tir.Subscript):
                raise NonAffine("target kind")
            tgt = self.subscript_view(s.target, iters)
            if not isinstance(tgt.expr, VAccess):
                raise NonAffine("target not a plain access")
            rhs = self.view(s.value, iters)
            if rhs.rank > tgt.rank:
                raise NonAffine("rhs rank exceeds target")
            # unify rhs axes with trailing target axes
            env = {}
            for t_ax, r_ax in zip(tgt.axes[tgt.rank - rhs.rank:], rhs.axes):
                env[r_ax] = Affine.var(t_ax)
            rhs_expr = substitute_vexpr(rhs.expr, env)
            # out iterators: ONLY the target slice axes. Enclosing explicit
            # loop vars stay bound by their loops; absorption
            # (schedule._absorb_loop) prepends them to the domain when the
            # loop is folded into this statement.
            out_dims = [tgt.dims[ax] for ax in tgt.axes]
            aug = s.aug
            stmt = CanonStmt(
                write_array=tgt.expr.array, write_idx=tgt.expr.idx,
                domain=Domain(tuple(out_dims)), rhs=rhs_expr, aug=aug,
                dtype=tgt.dtype,
                label=f"update:{tgt.expr.array}")
            return self.pre + [stmt]
        finally:
            self.pre = []

    def extract(self) -> ScopProgram:
        items = self.block(self.fn.body, {})
        return ScopProgram(self.fn, items, list(self.fn.sym_params),
                           self.temps)

    def block(self, stmts: List[tir.Stmt],
              iters: Dict[str, LoopDim]) -> List[Item]:
        out: List[Item] = []
        for s in stmts:
            if isinstance(s, tir.Assign):
                try:
                    pre_backup = list(self.pre)
                    got = self.canon_assign(s, iters)
                    out.extend(got)
                except (NonAffine, AffineError, Exception) as exc:
                    if not isinstance(exc, (NonAffine, AffineError)):
                        # genuinely unexpected — still degrade gracefully
                        pass
                    out.append(self.opaque([s]))
            elif isinstance(s, tir.For):
                try:
                    lo = self.affine(s.lo, iters)
                    hi = self.affine(s.hi, iters)
                    step = 1
                    if s.step is not None:
                        if isinstance(s.step, tir.Const) and \
                                isinstance(s.step.value, int):
                            step = s.step.value
                        else:
                            raise NonAffine("dynamic step")
                    dim = LoopDim(s.var, lo, hi, step)
                    inner = dict(iters)
                    inner[s.var] = dim
                    body = self.block(s.body, inner)
                    out.append(LoopItem(dim, body))
                except (NonAffine, AffineError):
                    out.append(self.opaque([s]))
            elif isinstance(s, (tir.Return,)):
                out.append(self.opaque([s]))
            elif isinstance(s, tir.Opaque):
                out.append(OpaqueItem([s], s.reads, s.writes))
            else:
                out.append(self.opaque([s]))
        return out

    def opaque(self, stmts: List[tir.Stmt]) -> OpaqueItem:
        reads, writes = set(), set()
        for s in stmts:
            r, w = tir.stmt_reads_writes(s)
            reads |= r
            writes |= w
        return OpaqueItem(stmts, tuple(sorted(reads)), tuple(sorted(writes)))


@dataclass
class FFTStmt:
    """Standalone spectral op (materialization point)."""

    out: str
    src: str
    fn: str
    axis: int
    n: Optional[Affine]
    src_rank: int
    label: str = "fft"


def affine_to_vexpr(a: Affine) -> VExpr:
    e: VExpr = VConst(a.const) if a.const or not a.coeffs else None
    for k, c in a.coeffs:
        term: VExpr = VParam(k) if c == 1 else VBin("*", VConst(c), VParam(k))
        e = term if e is None else VBin("+", e, term)
    return e or VConst(0)


def extract(fn: tir.Function) -> ScopProgram:
    return Extractor(fn).extract()
