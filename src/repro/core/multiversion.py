"""Program multi-versioning (paper §4.1).

Builds the runtime decision tree around the generated variants:

    legality (types/ranks match the hints?)          — correctness
      └─ profitability (enough FLOPs for the accelerator variant?)
           ├─ yes → jnp variant  (the NumPy→CuPy analogue)
           ├─ no  → optimized NumPy variant
      └─ mismatch → original user function (always correct)

"All the conditions are organized as decision trees, where legality
conditions are located at higher levels while profitability conditions are
at lower levels."
"""

from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Callable, Deque, Dict, List, Optional, Tuple

import numpy as np

from repro import obs
from repro.obs.metrics import Counter

from . import cost
from .codegen import GeneratedVariant
from .schedule import Schedule
from .types import (TypeInfo, matches, nested_list_shape,
                    runtime_typeinfo)


@dataclass
class Variant:
    name: str                    # 'jnp' | 'np' | 'original'
    fn: Callable
    generated: Optional[GeneratedVariant] = None

    def __post_init__(self):
        # per-variant call/latency cells: standalone Variants keep
        # private counters; once a CompiledKernel adopts the variant,
        # bind_metrics swaps in registry-backed ones under the kernel's
        # scope — same attribute API either way
        self._calls = Counter()
        self._total = Counter()

    def bind_metrics(self, scope) -> None:
        c, t = scope.counter(f"{self.name}.calls"), \
            scope.counter(f"{self.name}.total_s")
        c.set(self._calls.value)
        t.set(self._total.value)
        self._calls, self._total = c, t

    @property
    def calls(self) -> int:
        return self._calls.value

    @calls.setter
    def calls(self, v) -> None:
        self._calls.set(v)

    @property
    def total_s(self) -> float:
        return self._total.value

    @total_s.setter
    def total_s(self, v) -> None:
        self._total.set(v)


@dataclass
class DispatchRecord:
    variant: str
    legality_ok: bool
    flops: float
    profitable: bool


class CompiledKernel:
    """Callable decision tree over specialized variants.

    Dispatch counters live in the unified ``obs.metrics`` registry
    under a per-instance ``kernel.<name>#N`` scope (the MetricAttr
    descriptors and Variant metric cells keep every attribute
    read/write site unchanged)."""

    # stop recording novel signatures past this point (pathologically
    # dynamic shapes must not grow memory without bound)
    MAX_TRACKED_SIGS = 4096

    spec_hits = obs.MetricAttr("spec_hits")
    bucket_hits = obs.MetricAttr("bucket_hits")

    def __init__(self, original: Callable, params: List[Tuple[str, TypeInfo]],
                 sched: Schedule, variants: Dict[str, Variant],
                 pfor_config=None,
                 accel_threshold: float = cost.ACCEL_FLOP_THRESHOLD):
        self.original = original
        self.params = params
        self.sched = sched
        self.variants = variants
        self.pfor_config = pfor_config
        self.accel_threshold = accel_threshold
        self.__name__ = getattr(original, "__name__", "kernel")
        self.__doc__ = getattr(original, "__doc__", None)
        self._mscope = obs.metrics.unique_scope(
            f"kernel.{self.__name__}")
        for v in variants.values():
            v.bind_metrics(self._mscope.sub("variants"))
        # ring buffer: long-running serving processes dispatch millions
        # of times; keep only the recent window
        self.history: Deque[DispatchRecord] = deque(maxlen=10_000)
        self._flop_cache: Dict[Tuple, float] = {}
        # dispatch stats watched by the profiler's specializer: per exact
        # call-signature counts + the decision the full tree made for it
        self.shape_counts: Dict[Tuple, int] = {}
        self.last_decisions: Dict[Tuple, Tuple[str, float, bool]] = {}
        self.specializations: Dict[Tuple, Any] = {}
        self.spec_hits = 0
        # per-signature latency EMAs: tree-dispatched calls vs pinned
        # calls — the specializer's demotion sweep compares them to spot
        # regressions (a pin whose decision went stale)
        self.tree_latency: Dict[Tuple, float] = {}
        # bucket tier: pinned decisions also guard the enclosing
        # power-of-two shape bucket, so mild shape drift (batch 60 ↔ 64)
        # keeps the fast path instead of falling back to the full tree
        self.bucket_specs: Dict[Tuple, Any] = {}
        self.bucket_hits = 0
        self.from_cache: bool = False   # built from the persistent cache?

    # -- helpers --------------------------------------------------------
    def _bind(self, args, kwargs) -> Dict[str, Any]:
        names = [n for n, _ in self.params]
        bound = dict(zip(names, args))
        bound.update(kwargs)
        return bound

    def _legality(self, bound: Dict[str, Any]) -> bool:
        for name, hint in self.params:
            if name not in bound:
                return False
            if not matches(hint, runtime_typeinfo(bound[name])):
                return False
        return True

    def _size_env(self, bound: Dict[str, Any]) -> Dict[str, int]:
        env: Dict[str, int] = {}
        for name, val in bound.items():
            if isinstance(val, (int, np.integer)) and not isinstance(
                    val, bool):
                env[name] = int(val)
            arr = val
            if isinstance(arr, list):
                for d, s in enumerate(nested_list_shape(arr)):
                    env[f"{name}__d{d}"] = s
            elif hasattr(arr, "shape"):
                for d, s in enumerate(arr.shape):
                    env[f"{name}__d{d}"] = int(s)
        return env

    def estimate_flops(self, bound: Dict[str, Any]) -> float:
        key = tuple(sorted(self._size_env(bound).items()))
        if key not in self._flop_cache:
            self._flop_cache[key] = cost.schedule_flops(
                self.sched, dict(key))
        return self._flop_cache[key]

    @staticmethod
    def _bucket_sig(sig: Tuple) -> Tuple:
        """Widen an exact signature to its power-of-two shape bucket.

        Kind/dtype/rank survive verbatim (they decide legality — two
        signatures in the same bucket are legality-identical); only the
        extents are widened, so a pinned decision stays valid for every
        signature the bucket admits, with the FLOP estimate off by at most
        2× per dimension."""
        parts = []
        for part in sig:
            name, dtype, extra = part
            if isinstance(extra, tuple):
                parts.append((name, dtype,
                              tuple(cost.pow2_bucket(int(s))
                                    for s in extra)))
            elif dtype == "int" and isinstance(extra, int):
                parts.append((name, dtype, cost.pow2_bucket(extra)))
            else:
                parts.append(part)
        return tuple(parts)

    def _sig(self, bound: Dict[str, Any]) -> Tuple:
        """Exact call signature: (name, dtype, shape) per array param,
        integer values for int scalars (they drive the cost model)."""
        parts = []
        for name, _ in self.params:
            v = bound.get(name)
            if isinstance(v, np.ndarray):
                parts.append((name, str(v.dtype), v.shape))
            elif isinstance(v, (int, np.integer)) and not isinstance(
                    v, bool):
                parts.append((name, "int", int(v)))
            elif isinstance(v, list):
                parts.append((name, "list", nested_list_shape(v)))
            elif hasattr(v, "shape") and hasattr(v, "dtype"):
                parts.append((name, str(v.dtype), tuple(v.shape)))
            else:
                parts.append((name, type(v).__name__, None))
        return tuple(parts)

    # -- the decision tree ------------------------------------------------
    def select(self, bound: Dict[str, Any]) -> Tuple[Variant,
                                                     DispatchRecord]:
        legal = self._legality(bound)
        if not legal:
            rec = DispatchRecord("original", False, 0.0, False)
            return self.variants["original"], rec
        flops = self.estimate_flops(bound)
        profitable = cost.accel_profitable(flops, self.accel_threshold)
        if profitable and "jnp" in self.variants:
            rec = DispatchRecord("jnp", True, flops, True)
            return self.variants["jnp"], rec
        if "np" in self.variants:
            rec = DispatchRecord("np", True, flops, profitable)
            return self.variants["np"], rec
        rec = DispatchRecord("original", True, flops, profitable)
        return self.variants["original"], rec

    def __call__(self, *args, **kwargs):
        bound = self._bind(args, kwargs)
        sig = self._sig(bound)
        bucket_hit = False
        spec = self.specializations.get(sig)
        if spec is None:
            # bucket tier: same dtype/rank, shape drifted within the
            # enclosing pow2 bucket → replay the pinned decision anyway.
            # Deliberately NOT recorded in last_decisions: a pin may only
            # ever replay a decision the full tree made for that exact
            # signature, and the borrowed one (FLOPs off by ≤2× per dim)
            # must stay transient, not get promoted by the specializer.
            spec = self.bucket_specs.get(self._bucket_sig(sig))
            if spec is not None:
                self.bucket_hits += 1
                bucket_hit = True
        if spec is not None:
            # hot path pinned by the specializer: replay the decision the
            # full tree made for this exact signature (legality included)
            variant = self.variants[spec.variant_name]
            rec = DispatchRecord(spec.variant_name, spec.legality_ok,
                                 spec.flops, True)
            spec.hits += 1
            self.spec_hits += 1
        else:
            variant, rec = self.select(bound)
            n = self.shape_counts.get(sig)
            if n is not None:
                self.shape_counts[sig] = n + 1
            elif len(self.shape_counts) < self.MAX_TRACKED_SIGS:
                self.shape_counts[sig] = 1
            if sig in self.shape_counts:
                self.last_decisions[sig] = (variant.name, rec.flops,
                                            rec.legality_ok)
        self.history.append(rec)
        if self.pfor_config is not None:
            self.pfor_config.estimated_flops = rec.flops
        t0 = time.perf_counter()
        out = self._invoke(variant, bound)
        dt = time.perf_counter() - t0
        variant.calls += 1
        variant.total_s += dt
        if spec is not None:
            # bucket-tier calls run a *different* shape (up to 2x per
            # dim) — folding their latency into the pin's EMA would fake
            # a regression against the exact-shape tree baseline
            if not bucket_hit:
                ema = getattr(spec, "latency_ema", None)
                spec.latency_ema = (dt if ema is None
                                    else 0.8 * ema + 0.2 * dt)
        elif sig in self.shape_counts:
            ema = self.tree_latency.get(sig)
            self.tree_latency[sig] = (dt if ema is None
                                      else 0.8 * ema + 0.2 * dt)
        return out

    # -- specialization hooks (repro.profiler.specializer) ---------------
    def install_specialization(self, spec) -> None:
        """Hot-swap a pinned decision into the tree. The original
        function remains the fallback for every non-matching signature.
        The same decision also guards the enclosing pow2 shape bucket."""
        self.specializations[spec.sig] = spec
        self.bucket_specs[self._bucket_sig(spec.sig)] = spec

    def drop_specialization(self, sig: Tuple) -> None:
        spec = self.specializations.pop(sig, None)
        if spec is not None:
            bkey = self._bucket_sig(sig)
            if self.bucket_specs.get(bkey) is spec:
                self.bucket_specs.pop(bkey, None)

    def stats(self) -> Dict[str, Any]:
        """Dispatch/cache telemetry (consumed by serve.engine)."""
        fusion = getattr(self.sched, "fusion", None)
        return {
            "calls": sum(v.calls for v in self.variants.values()),
            "variants": {
                name: {"calls": v.calls,
                       "total_s": round(v.total_s, 6)}
                for name, v in self.variants.items()},
            "distinct_signatures": len(self.shape_counts),
            "specializations": len(self.specializations),
            "spec_hits": self.spec_hits,
            "bucket_specs": len(self.bucket_specs),
            "bucket_hits": self.bucket_hits,
            "fused_units": getattr(fusion, "fused_units", 0),
            "contracted_arrays": len(
                getattr(fusion, "contracted_arrays", ()) or ()),
            "pfor_jnp_units": len(self.pfor_jnp_units()),
            "pfor_jit_units": len(self.pfor_jit_units()),
            "pfor_twin_units": {name: len(units) for name, units
                                in self.pfor_twin_units().items()},
            "from_cache": self.from_cache,
        }

    def pfor_jnp_units(self) -> List[int]:
        """pfor unit indices whose np body carries a jnp twin — the
        per-unit backend variants the heterogeneous cluster routes
        between (empty for pfor-free or np-only kernels)."""
        v = self.variants.get("np")
        if v is None or v.generated is None:
            return []
        return list(getattr(v.generated.meta, "pfor_jnp_units", ()) or ())

    def pfor_jit_units(self) -> List[int]:
        """Subset of :meth:`pfor_jnp_units` whose twin also carries a
        vmappable per-iteration function wired through ``__pfor_jit``
        (the compiled accelerator path)."""
        v = self.variants.get("np")
        if v is None or v.generated is None:
            return []
        return list(getattr(v.generated.meta, "pfor_jit_units", ()) or ())

    def pfor_twin_units(self) -> Dict[str, List[int]]:
        """Backend name → pfor unit indices carrying that backend's twin
        (registry-driven superset of :meth:`pfor_jnp_units`). Entries
        generated before the registry recorded jnp twins only; they
        project through unchanged."""
        v = self.variants.get("np")
        if v is None or v.generated is None:
            return {}
        twins = getattr(v.generated.meta, "pfor_twin_units", None)
        if twins:
            return {name: list(units) for name, units in twins.items()}
        jnp_units = self.pfor_jnp_units()
        return {"jnp": jnp_units} if jnp_units else {}

    def call_variant(self, name: str, *args, **kwargs):
        """Force a specific variant (benchmark harness hook)."""
        bound = self._bind(args, kwargs)
        if self.pfor_config is not None:
            self.pfor_config.estimated_flops = self.estimate_flops(bound)
        return self._invoke(self.variants[name], bound)

    def _invoke(self, variant: Variant, bound: Dict[str, Any]):
        names = [n for n, _ in self.params]
        args = [bound[n] for n in names]
        if variant.name == "original":
            return variant.fn(*args)
        result = variant.fn(*args)
        gen = variant.generated
        if gen is not None and gen.returns_written and result is not None:
            outs = result if isinstance(result, tuple) else (result,)
            for name, val in zip(gen.written, outs):
                orig = bound[name]
                self._copy_back(orig, val)
            return None
        return result

    @staticmethod
    def _copy_back(orig, val):
        arr = np.asarray(val)
        if isinstance(orig, np.ndarray):
            np.copyto(orig, arr.astype(orig.dtype, copy=False))
        elif isinstance(orig, list):
            data = arr.tolist()
            orig[:] = data
        # scalars: caller keeps its own copy; nothing to write back

    # -- introspection ------------------------------------------------------
    def source(self, backend: str = "np") -> str:
        v = self.variants.get(backend)
        if v is None or v.generated is None:
            raise KeyError(f"no generated source for backend {backend!r}")
        return v.generated.source

    def explain(self) -> str:
        lines = [f"CompiledKernel({self.__name__})"]
        lines.append("  decision tree:")
        lines.append("    legality: type/rank hints "
                     f"{[(n, t.kind, t.dtype, t.rank) for n, t in self.params]}")
        lines.append(f"    profitability: flops >= {self.accel_threshold:g}"
                     " → accelerator variant")
        fusion = getattr(self.sched, "fusion", None)
        if fusion is not None and (fusion.fused_units
                                   or fusion.contracted_arrays):
            lines.append(
                f"  fusion: {fusion.fused_units} fused unit(s), "
                f"contracted {list(fusion.contracted_arrays)}")
        twin_units = self.pfor_twin_units()
        for bname, units in twin_units.items():
            lines.append(
                f"  hetero: pfor unit(s) {units} carry {bname} twin "
                "bodies — the cluster prices the backends per worker "
                "profile and routes chunks by device_pref")
        for name, v in self.variants.items():
            ops = (v.generated.meta.raised_ops if v.generated else [])
            lines.append(f"  variant {name}: calls={v.calls} "
                         f"time={v.total_s:.4f}s raised={ops}")
        return "\n".join(lines)
