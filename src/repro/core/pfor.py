"""pfor execution: the generated `__pfor_run` hook.

Generated kernels call ``__pfor_run(body, lo, hi, tile)`` where ``body(lo,
hi)`` executes a contiguous chunk of dependence-free iterations, writing
disjoint regions of the output arrays in place.

Backends (a profitability decision, §4.3):
  * sequential      — one call; chosen for small iteration counts;
  * raylite DAG     — chunks submitted as tasks to the runtime/ package
    (the Ray analogue): futures, lineage fault tolerance, straggler
    duplicates all apply.

The SPMD (shard_map) mapping of regular pfor loops lives in the LM planner
(core/planner.py) — numeric kernels distribute via the DAG, matching the
paper's Ray deployment.
"""

from __future__ import annotations

import math
from typing import Callable, Optional


class PforConfig:
    """Mutable knob block bound into each compiled kernel."""

    def __init__(self, runtime=None, tile: Optional[int] = None,
                 workers: int = 4, force_sequential: bool = False):
        self.runtime = runtime          # runtime.tasks.TaskRuntime or None
        self.tile = tile
        self.workers = workers
        self.force_sequential = force_sequential
        # filled per call by the dispatcher (profitability input):
        self.estimated_flops = 0.0
        self.distribute_threshold = 1e7

    def make_runner(self) -> Callable:
        def __pfor_run(body, lo, hi, tile):
            n = max(0, hi - lo)
            if n == 0:
                return
            tile_ = tile or self.tile
            if tile_ is None:
                tile_ = max(1, math.ceil(n / max(1, self.workers)))
            seq = (
                self.force_sequential
                or self.runtime is None
                or n <= 1
                or self.estimated_flops < self.distribute_threshold
            )
            if seq:
                body(lo, hi)
                return
            futures = []
            t = lo
            while t < hi:
                up = min(t + tile_, hi)
                futures.append(self.runtime.submit(body, t, up))
                t = up
            for f in futures:
                self.runtime.get(f)

        return __pfor_run
