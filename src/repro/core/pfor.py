"""pfor execution: the generated `__pfor_run` hook.

Generated kernels call ``__pfor_run(body, lo, hi, tile)`` where ``body(lo,
hi)`` executes a contiguous chunk of dependence-free iterations, writing
disjoint regions of the output arrays in place.

Backends (a profitability decision, §4.3):
  * sequential      — one call; chosen for small iteration counts;
  * raylite DAG     — chunks submitted as tasks to the runtime/ package
    (the Ray analogue): futures, lineage fault tolerance, straggler
    duplicates all apply;
  * cluster shards  — when the bound runtime is a
    :class:`repro.distrib.ClusterRuntime` (it exposes ``pfor_shards``),
    chunks cross OS-process boundaries: the body closure ships to worker
    processes, chunk sizes follow measured device capability, and
    disjoint-region writes gather back on the head. The local-vs-
    distributed call is made per kernel from the fleet's device profiles
    (:func:`repro.core.cost.cluster_distribute_profitable`).

The SPMD (shard_map) mapping of regular pfor loops lives in the LM planner
(core/planner.py) — numeric kernels distribute via the DAG or the cluster
runtime, matching the paper's Ray deployment.
"""

from __future__ import annotations

import inspect
import math
from typing import Callable, Optional, Tuple


class PforConfig:
    """Mutable knob block bound into each compiled kernel."""

    def __init__(self, runtime=None, tile: Optional[int] = None,
                 workers: int = 4, force_sequential: bool = False):
        self.runtime = runtime          # TaskRuntime | ClusterRuntime | None
        self.tile = tile
        self.workers = workers
        self.force_sequential = force_sequential
        # filled per call by the dispatcher (profitability input):
        self.estimated_flops = 0.0
        self.distribute_threshold = 1e7
        # arrays the schedule writes (set by the compiler) — lets the
        # cluster runtime diff only real outputs when gathering chunks
        self.written: Tuple[str, ...] = ()
        # arrays provably indexed only by the pfor var on their leading
        # axis (union over the kernel's pfor units, set by the compiler).
        # Fallback only: freshly generated bodies carry their own exact
        # per-unit ``__sliceable__`` attribute, which always wins; this
        # covers variants cached before the attribute existed (their
        # schedules predate the analysis too, so it stays empty — safe).
        self.sliceable: Tuple[str, ...] = ()
        # memoized signature probes for the bound runtime (legacy duck-
        # typed runtimes may predate the broadcast/sliced protocol):
        # (runtime object, decide accepts sliced_bytes, shards accepts
        # sliceable, shards accepts est_flops) — re-probed only when the
        # runtime is swapped. The memo holds the probed object itself,
        # never a raw id(): address reuse after a swap must not
        # resurrect a stale verdict.
        self._proto_probe: Tuple[object, bool, bool, bool] = (
            None, True, True, True)

    def _runtime_proto(self, shards) -> Tuple[bool, bool, bool]:
        """(decide takes sliced_bytes, pfor_shards takes sliceable,
        pfor_shards takes est_flops) for the current runtime, probed
        once per binding — not per call."""
        if self._proto_probe[0] is not self.runtime:
            def accepts(fn, kw):
                if fn is None:
                    return True
                try:
                    return kw in inspect.signature(fn).parameters
                except (TypeError, ValueError):
                    return True
            decide = getattr(self.runtime, "distribute_profitable", None)
            self._proto_probe = (self.runtime,
                                 accepts(decide, "sliced_bytes"),
                                 accepts(shards, "sliceable"),
                                 accepts(shards, "est_flops"))
        return self._proto_probe[1:]

    def make_runner(self) -> Callable:
        def __pfor_run(body, lo, hi, tile):
            n = max(0, hi - lo)
            if n == 0:
                return
            tile_ = tile or self.tile
            if tile_ is None:
                tile_ = max(1, math.ceil(n / max(1, self.workers)))
            if self.force_sequential or self.runtime is None or n <= 1:
                body(lo, hi)
                return
            shards = getattr(self.runtime, "pfor_shards", None)
            if shards is not None:
                # a cluster runtime instance exists, so repro.distrib is
                # already imported — the shared sizing rule is free here
                from repro.distrib.serial import payload_split_nbytes

                sliceable = getattr(body, "__sliceable__", None)
                if sliceable is None:
                    sliceable = self.sliceable
                # legacy duck-typed runtimes may predate the broadcast/
                # sliced protocol: signature-probe once per runtime
                # binding rather than catching TypeError per call (which
                # would also swallow genuine errors inside the model)
                split_ok, shards_sliceable, shards_flops = \
                    self._runtime_proto(shards)
                sliceable = tuple(sliceable) if shards_sliceable else ()
                # cluster tier: ask the device-profile cost model unless
                # the caller forced distribution (threshold <= 0)
                distribute = self.distribute_threshold <= 0
                if not distribute:
                    decide = getattr(self.runtime,
                                     "distribute_profitable", None)
                    if decide is not None:
                        bcast, sliced = payload_split_nbytes(
                            body, sliceable)
                        if split_ok:
                            distribute = decide(
                                self.estimated_flops, bcast,
                                max(1, math.ceil(n / tile_)),
                                sliced_bytes=sliced)
                        else:
                            distribute = decide(
                                self.estimated_flops, bcast + sliced,
                                max(1, math.ceil(n / tile_)))
                    else:
                        distribute = (self.estimated_flops
                                      >= self.distribute_threshold)
                if distribute:
                    kw = {"written": self.written}
                    if shards_sliceable:
                        kw["sliceable"] = sliceable
                    if shards_flops:
                        # the dispatcher's kernel-level FLOP estimate:
                        # the sharder prices per-(unit, backend, worker)
                        # cells from it when the body carries a jnp twin
                        kw["est_flops"] = self.estimated_flops
                    shards(body, lo, hi, tile or self.tile, **kw)
                else:
                    body(lo, hi)
                return
            if self.estimated_flops < self.distribute_threshold:
                body(lo, hi)
                return
            futures = []
            t = lo
            while t < hi:
                up = min(t + tile_, hi)
                futures.append(self.runtime.submit(body, t, up))
                t = up
            for f in futures:
                self.runtime.get(f)

        return __pfor_run
