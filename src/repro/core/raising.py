"""Operator raising: map canonical statements onto library calls.

This is the SCoP-to-IR generation stage of the paper (§4.2): "the library
knowledge base [is used] to select the efficient combination of available
library functions for each statement whenever possible. The maximal
matching strategy is currently employed."

Given a CanonStmt, we produce:
  * a WritePlan — how to store into the (possibly triangular/diagonal)
    write region: plain slice, masked slice, diagonal scatter, or whole
    variable;
  * an expression plan — the RHS as a tree whose contraction subtrees are
    EinsumSpecs (with a np.dot peephole reproducing the paper's Fig. 6c
    output) and whose remaining nodes are elementwise ops over hull-aligned
    slices.

Raising never fails the kernel: statements it cannot plan fall back to the
loop emitter in core/codegen.py (correct, just slower) — mirroring the
paper's guarantee that optimization is best-effort and correctness comes
from multi-versioning.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

from .isl_lite import Affine, Domain, LoopDim
from .scop import (CanonStmt, VAccess, VBin, VConst, VExpr, VParam, VReduce,
                   VUnary)


class RaiseError(Exception):
    pass


# ---------------------------------------------------------------------------
# Hulls: rectangularize triangular iterator bounds
# ---------------------------------------------------------------------------

@dataclass
class Hull:
    """Rectangular over-approximation of every iterator's range, plus the
    mask conditions that recover the exact (triangular) domain."""

    lo: Dict[str, Affine]
    hi: Dict[str, Affine]
    # (dep_var, outer_var, op, offset): dep_var <op> outer_var + offset
    conds: List[Tuple[str, str, str, int]] = field(default_factory=list)


def compute_hull(dims: List[LoopDim]) -> Hull:
    lo: Dict[str, Affine] = {}
    hi: Dict[str, Affine] = {}
    conds: List[Tuple[str, str, str, int]] = []
    seen: Dict[str, LoopDim] = {}
    for d in dims:
        lo_b, hi_b = d.lower, d.upper
        for bound, is_lower in ((lo_b, True), (hi_b, False)):
            iter_vars = [v for v in bound.vars() if v in seen]
            if not iter_vars:
                continue
            if len(iter_vars) > 1:
                raise RaiseError("bound depends on multiple iterators")
            ov = iter_vars[0]
            c = bound.coeff(ov)
            if c != 1:
                raise RaiseError("non-unit iterator coefficient in bound")
            rest = bound.drop([ov])
            if not rest.is_constant():
                raise RaiseError("mixed symbolic+iterator bound")
            off = rest.const
            if is_lower:
                # v >= ov + off; min over ov ∈ [lo, hi) is lo + off
                conds.append((d.var, ov, ">=", off))
                lo_b = lo[ov] + off
            else:
                # v < ov + off; max v = (hi-1) + off - 1 → exclusive hull
                # bound hi + off - 1
                conds.append((d.var, ov, "<", off))
                hi_b = hi[ov] + off - 1
        # bounds may also reference *later* unseen iterators: reject
        for bound in (lo_b, hi_b):
            bad = [v for v in bound.vars() if v in {dd.var for dd in dims}]
            if bad:
                raise RaiseError("unresolved iterator in hull bound")
        lo[d.var] = lo_b
        hi[d.var] = hi_b
        seen[d.var] = d
    return Hull(lo, hi, conds)


# ---------------------------------------------------------------------------
# RHS normalization
# ---------------------------------------------------------------------------

def normalize(e: VExpr) -> VExpr:
    """Distribute reductions over '+'/'-' and hoist reduce-invariant scalar
    factors out of reductions (Σ_k c·x = c·Σ_k x)."""
    if isinstance(e, VBin):
        l, r = normalize(e.left), normalize(e.right)
        return VBin(e.op, l, r)
    if isinstance(e, VUnary):
        return VUnary(e.fn, normalize(e.operand))
    if isinstance(e, VReduce):
        child = normalize(e.child)
        if isinstance(child, VBin) and child.op in ("+", "-"):
            return VBin(child.op,
                        normalize(VReduce(e.op, e.dims, child.left)),
                        normalize(VReduce(e.op, e.dims, child.right)))
        if isinstance(child, VReduce):
            return normalize(VReduce(e.op, e.dims + child.dims, child.child))
        # hoist factors free of the reduce iterators
        red_vars = {d.var for d in e.dims}
        if isinstance(child, VBin) and child.op == "*":
            factors = _flatten_product(child)
            inside, outside = [], []
            for f in factors:
                if _uses_vars(f, red_vars):
                    inside.append(f)
                else:
                    outside.append(f)
            if outside and inside:
                body = _product(inside)
                out = VReduce(e.op, e.dims, body)
                return _product(outside + [out])
        if isinstance(child, VBin) and child.op == "/":
            if not _uses_vars(child.right, red_vars):
                return VBin("/", normalize(VReduce(e.op, e.dims,
                                                   child.left)),
                            child.right)
        return VReduce(e.op, e.dims, child)
    return e


def _flatten_product(e: VExpr) -> List[VExpr]:
    if isinstance(e, VBin) and e.op == "*":
        return _flatten_product(e.left) + _flatten_product(e.right)
    return [e]


def _product(fs: List[VExpr]) -> VExpr:
    out = fs[0]
    for f in fs[1:]:
        out = VBin("*", out, f)
    return out


def _uses_vars(e: VExpr, names: set) -> bool:
    if isinstance(e, VAccess):
        return any(v in names for idx in e.idx for v in idx.vars())
    if isinstance(e, VBin):
        return _uses_vars(e.left, names) or _uses_vars(e.right, names)
    if isinstance(e, VUnary):
        return _uses_vars(e.operand, names)
    if isinstance(e, VReduce):
        return _uses_vars(e.child, names)
    return False


# ---------------------------------------------------------------------------
# Einsum planning for contraction subtrees
# ---------------------------------------------------------------------------

_LETTERS = "abcdefghijklmnopqrstuvwxyz"


@dataclass
class EinsumOperand:
    access: VAccess
    letters: str


@dataclass
class MaskOperand:
    """np.tri-derived boolean factor recovering a triangular reduce bound."""

    row_var: str
    col_var: str
    op: str  # '>=' or '<'
    offset: int
    letters: str


@dataclass
class EinsumSpec:
    operands: List[EinsumOperand]
    masks: List[MaskOperand]
    out_letters: str
    out_vars: Tuple[str, ...]
    reduce_dims: Tuple[LoopDim, ...]
    spec: str  # full einsum subscripts string

    def is_dot2(self) -> bool:
        """Peephole: exactly two operands, one shared reduction letter,
        rank ≤ 2 each → can be emitted as np.dot (paper Fig. 6c)."""
        if self.masks or len(self.operands) != 2:
            return False
        if len(self.reduce_dims) != 1:
            return False
        return all(1 <= len(op.letters) <= 2 for op in self.operands)


def plan_einsum(red: VReduce, out_frame: Tuple[str, ...],
                hull: Hull) -> EinsumSpec:
    """Plan VReduce(product-of-accesses) as one einsum over hull slices."""
    factors = _flatten_product(red.child)
    accesses: List[VAccess] = []
    for f in factors:
        if isinstance(f, VAccess):
            accesses.append(f)
        else:
            raise RaiseError("non-access factor inside reduction")
    red_dims = list(red.dims)
    red_vars = [d.var for d in red_dims]

    # Reduce dims with out-iterator-dependent bounds → widen + mask
    masks: List[MaskOperand] = []
    widened: List[LoopDim] = []
    extended_hull_lo = dict(hull.lo)
    extended_hull_hi = dict(hull.hi)
    for d in red_dims:
        lo_b, hi_b = d.lower, d.upper
        for bound, is_lower in ((d.lower, True), (d.upper, False)):
            dep = [v for v in bound.vars() if v in out_frame]
            if not dep:
                continue
            if len(dep) > 1 or bound.coeff(dep[0]) != 1:
                raise RaiseError("complex triangular reduce bound")
            ov = dep[0]
            rest = bound.drop([ov])
            if not rest.is_constant():
                raise RaiseError("symbolic triangular reduce bound")
            off = rest.const
            if is_lower:
                masks.append(MaskOperand(d.var, ov, ">=", off, ""))
                lo_b = extended_hull_lo[ov] + off
            else:
                masks.append(MaskOperand(d.var, ov, "<", off, ""))
                hi_b = extended_hull_hi[ov] + off - 1
        bad = [v for v in list(lo_b.vars()) + list(hi_b.vars())
               if v in out_frame or v in red_vars]
        if bad:
            raise RaiseError("unresolvable reduce bound")
        widened.append(LoopDim(d.var, lo_b, hi_b, d.step))
        extended_hull_lo[d.var] = lo_b
        extended_hull_hi[d.var] = hi_b

    # Letter assignment
    letter_of: Dict[str, str] = {}

    def letter(v: str) -> str:
        if v not in letter_of:
            if len(letter_of) >= len(_LETTERS):
                raise RaiseError("too many einsum dims")
            letter_of[v] = _LETTERS[len(letter_of)]
        return letter_of[v]

    operands: List[EinsumOperand] = []
    used_out: List[str] = []
    for acc in accesses:
        letters = ""
        for idx in acc.idx:
            ivars = [v for v in idx.vars()
                     if v in out_frame or v in red_vars]
            if len(ivars) == 0:
                letters += "."  # fixed index — sliced away, no letter
            elif len(ivars) == 1 and idx.coeff(ivars[0]) == 1:
                letters += letter(ivars[0])
                if ivars[0] in out_frame and ivars[0] not in used_out:
                    used_out.append(ivars[0])
            else:
                raise RaiseError("non-sliceable access index")
        letters = letters.replace(".", "")
        operands.append(EinsumOperand(acc, letters))

    for m in masks:
        m.letters = letter(m.row_var) + letter(m.col_var)
        for v in (m.row_var, m.col_var):
            if v in out_frame and v not in used_out:
                used_out.append(v)

    out_vars = tuple(v for v in out_frame if v in used_out)
    out_letters = "".join(letter(v) for v in out_vars)
    in_specs = [op.letters for op in operands] + [m.letters for m in masks]
    spec = ",".join(in_specs) + "->" + out_letters
    return EinsumSpec(operands, masks, out_letters, out_vars,
                      tuple(widened), spec)


# ---------------------------------------------------------------------------
# Write plans
# ---------------------------------------------------------------------------

@dataclass
class WritePlan:
    kind: str  # 'full' | 'slice' | 'masked' | 'diag' | 'scalar'
    # masked: conds from the hull (triangular out dims)
    conds: List[Tuple[str, str, str, int]] = field(default_factory=list)


def plan_write(stmt: CanonStmt, hull: Hull) -> WritePlan:
    if stmt.write_full or stmt.write_is_temp:
        return WritePlan("full")
    if not stmt.write_idx:
        return WritePlan("scalar")
    # diagonal pattern: several idx dims driven by the same iterator
    seen_iters: List[str] = []
    for idx in stmt.write_idx:
        ivs = [v for v in idx.vars()
               if v in {d.var for d in stmt.domain.dims}]
        if len(ivs) > 1:
            raise RaiseError("multi-iterator write index")
        if ivs:
            seen_iters.append(ivs[0])
    if len(set(seen_iters)) < len(seen_iters):
        if len(set(seen_iters)) == 1:
            return WritePlan("diag")
        raise RaiseError("repeated iterators across write dims")
    if hull.conds:
        return WritePlan("masked", list(hull.conds))
    return WritePlan("slice")
