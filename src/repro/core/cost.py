"""Cost model: profitability conditions + TPU roofline terms.

The paper's profitability conditions are "a threshold expression using loop
counts" (§4.3). We upgrade that to a roofline cost model — the same three
terms (compute / memory / collective) the launch-time planner and the
EXPERIMENTS.md analysis use — while keeping the simple loop-count form
available for the kernel dispatcher.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterable, List, Optional, Tuple

from . import backends
from .isl_lite import Affine, Domain, LoopDim
from .schedule import (FFTUnit, OpaqueUnit, PforUnit, RaisedUnit, Schedule,
                       SeqLoopUnit, Unit)
from .scop import CanonStmt, VAccess, VBin, VReduce, VUnary, vexpr_accesses


# ---------------------------------------------------------------------------
# Hardware model (TPU v5e target; CPU host for the offline container)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ChipSpec:
    name: str
    peak_flops: float        # FLOP/s (bf16 systolic)
    hbm_bw: float            # bytes/s
    ici_bw: float            # bytes/s per link
    hbm_bytes: float
    vmem_bytes: float


TPU_V5E = ChipSpec(
    name="tpu_v5e",
    peak_flops=197e12,
    hbm_bw=819e9,
    ici_bw=50e9,
    hbm_bytes=16 * 2**30,
    vmem_bytes=128 * 2**20,
)

# The host CPU in this container — used only for kernel-dispatch
# profitability thresholds, not for roofline reporting.
HOST_CPU = ChipSpec(
    name="host_cpu",
    peak_flops=5e10,
    hbm_bw=1e10,
    ici_bw=1e9,
    hbm_bytes=8 * 2**30,
    vmem_bytes=32 * 2**10,
)


@dataclass
class RooflineTerms:
    compute_s: float
    memory_s: float
    collective_s: float

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)


# ---------------------------------------------------------------------------
# Kernel-level FLOP estimation (profitability for the dispatcher)
# ---------------------------------------------------------------------------

def _card(domain_dims: Iterable[LoopDim], env: Dict[str, int]) -> float:
    d = Domain(tuple(domain_dims))
    try:
        return float(d.cardinality(env))
    except Exception:
        # unbound symbol: assume a nominal extent
        total = 1.0
        for dim in d.dims:
            ext = dim.upper - dim.lower
            if ext.is_constant():
                total *= max(1, ext.const)
            else:
                total *= 256.0
        return total


def _expr_flops_per_point(e, env: Dict[str, int]) -> float:
    if isinstance(e, VReduce):
        inner = _expr_flops_per_point(e.child, env) + 1.0
        return inner * max(1.0, _card(e.dims, env))
    if isinstance(e, VBin):
        return 1.0 + _expr_flops_per_point(e.left, env) \
            + _expr_flops_per_point(e.right, env)
    if isinstance(e, VUnary):
        return 1.0 + _expr_flops_per_point(e.operand, env)
    return 0.0


def stmt_flops(stmt: CanonStmt, env: Dict[str, int]) -> float:
    # out-domain card × per-point flops (reductions folded in)
    dims = list(stmt.domain.dims)
    pts = _card(dims, env)
    return pts * max(1.0, _expr_flops_per_point(stmt.rhs, env))


def schedule_flops(sched: Schedule, env: Dict[str, int]) -> float:
    total = 0.0

    def rec(units: List[Unit], mult: float):
        nonlocal total
        for u in units:
            if isinstance(u, RaisedUnit):
                total += mult * stmt_flops(u.stmt, env)
            elif isinstance(u, FFTUnit):
                total += mult * 5e4  # nominal per-call
            elif isinstance(u, (SeqLoopUnit, PforUnit)):
                ext = u.dim.upper - u.dim.lower
                if ext.is_constant():
                    m = max(1, ext.const)
                else:
                    try:
                        m = max(1, ext.evaluate(env))
                    except Exception:
                        m = 64
                rec(u.body, mult * m)

    rec(sched.units, 1.0)
    return total


# ---------------------------------------------------------------------------
# Profitability thresholds (decision-tree leaves, paper §4.1/§4.3)
# ---------------------------------------------------------------------------

# Accelerator dispatch is worth it only above this many FLOPs per call
# (device transfer + dispatch overheads dominate below it).
ACCEL_FLOP_THRESHOLD = 5e6

# Per-call accelerator overhead (host→device transfer + dispatch) used to
# calibrate the FLOP threshold from measured original-function latencies.
ACCEL_DISPATCH_OVERHEAD_S = 2e-3

# Distributing a pfor across workers is worth it above this much work.
DISTRIBUTE_FLOP_THRESHOLD = 1e7

# Per-chunk accelerator launch overhead on a worker (host→device staging
# + kernel dispatch for the jnp twin of a pfor body); conservative so
# tiny chunks stay on the np body. Owned by the backend registry (each
# backend's cost profile rides its registration); re-exported here for
# call sites that read the constants.
GPU_CHUNK_OVERHEAD_S = backends.GPU_CHUNK_OVERHEAD_S

# Host↔device staging bandwidth fallback when the profile carries no
# measured number (PCIe-gen3-ish, in GB/s).
GPU_XFER_GBS = backends.GPU_XFER_GBS

# Fixed per-task cost of dispatching one chunk to a worker process
# (serialize + pipe + schedule); measured on the container's pipes.
CLUSTER_TASK_OVERHEAD_S = 1.5e-3

# Conservative pipe/socket bandwidth fallback when the runtime has no
# measured transport number yet.
CLUSTER_TRANSPORT_MBS = 400.0


def accel_profitable(flops: float,
                     threshold: float = ACCEL_FLOP_THRESHOLD) -> bool:
    return flops >= threshold


def distribute_profitable(flops: float,
                          threshold: float = DISTRIBUTE_FLOP_THRESHOLD) -> bool:
    return flops >= threshold


def cluster_distribute_profitable(
    flops: float,
    payload_bytes: float,
    profiles: Iterable,
    n_chunks: int = 1,
    local_gflops: float = 1.0,
    overhead_s: float = CLUSTER_TASK_OVERHEAD_S,
    sliced_bytes: float = 0.0,
) -> bool:
    """Local-vs-distributed decision from measured device profiles.

    The paper's threshold expression generalized to a two-sided time
    estimate: run on the head at its measured FLOP rate, or ship the
    closure payload over the measured transport, burn a fixed dispatch
    overhead per chunk, and compute at the fleet's *aggregate* measured
    rate. Distribution wins only when the estimated distributed wall
    time (transfer + dispatch + compute) beats local execution — so a
    fleet of slow workers behind a thin pipe correctly loses to a fast
    head for small kernels, and per-worker heterogeneity is captured by
    summing each profile's own rate.

    ``payload_bytes`` is the *broadcast* part of the closure — it rides
    to every worker, so it costs ``n_workers × bytes`` on the head's
    serial transport. ``sliced_bytes`` is the chunk-sliceable part: the
    workers collectively receive it exactly once (each gets its rows),
    so it costs ``bytes`` total regardless of fleet size. The split is
    what flips marginal kernels with large sliceable inputs to
    distributed."""
    profiles = list(profiles)
    if not profiles:
        return False
    t_local = flops / max(1e-9, local_gflops * 1e9)
    agg_gflops = sum(max(1e-3, p.gflops) for p in profiles)
    mbs = [p.transport_mbs for p in profiles if p.transport_mbs > 0]
    transport_bs = (min(mbs) if mbs else CLUSTER_TRANSPORT_MBS) * 1e6
    # dispatch is serial on the head (one send per chunk), so the
    # per-chunk overhead does NOT amortize across workers
    wire_bytes = len(profiles) * payload_bytes + sliced_bytes
    t_dist = (flops / (agg_gflops * 1e9)
              + wire_bytes / max(1.0, transport_bs)
              + overhead_s * max(1, n_chunks))
    return t_dist < t_local


# ---------------------------------------------------------------------------
# Per-(unit, backend, worker-profile) pricing (heterogeneous chunk routing)
# ---------------------------------------------------------------------------

def chunk_backend_seconds(flops: float, nbytes: float, profile,
                          backend: str) -> float:
    """Estimated seconds for one pfor chunk of ``flops``/``nbytes`` on
    ``profile`` executing the ``backend`` body — the roofline max of the
    compute and data-movement terms, plus the accelerator's per-chunk
    launch overhead. This is the cell of the (unit, backend, worker)
    table the cluster prices instead of one kernel-level threshold.

    The formula is the backend's own ``chunk_seconds`` cost profile
    (:mod:`repro.core.backends`): np prices against host gflops/membw,
    jnp against the (real or simulated) GPU with staging bandwidth the
    device probe measured, pallas like jnp with both roofline terms
    scaled by its fused-kernel speedup."""
    bk = backends.get(backend)
    if bk.chunk_seconds is None:  # pragma: no cover — registry contract
        raise ValueError(f"backend {backend!r} has no cost profile")
    return bk.chunk_seconds(flops, nbytes, profile)


def _feasible(bk, profile) -> bool:
    return bk.feasible is None or bk.feasible(profile)


def pick_chunk_backend(flops: float, nbytes: float, profile,
                       allow_jnp: bool = True,
                       candidates: Optional[Tuple[str, ...]] = None) -> str:
    """Choose the cheapest body backend for one worker's chunk.

    ``candidates`` are the twin backends whose bodies actually exist for
    the unit (None keeps the legacy jnp-or-np contract). Only workers
    the backend declares itself feasible on (e.g. a real or simulated
    GPU) are priced against it; a zero FLOP estimate (direct calls that
    bypassed the dispatcher) degrades to capability tags — the
    highest-priority feasible candidate wins. Ties price to np: a twin
    must be *strictly* cheaper to leave the always-correct body."""
    if candidates is None:
        candidates = ("jnp",) if allow_jnp else ()
    live = [backends.get(c) for c in candidates
            if backends.is_registered(c)]
    live = [bk for bk in live if _feasible(bk, profile)]
    if not live:
        return "np"
    live.sort(key=lambda bk: -bk.priority)
    if flops <= 0:
        return live[0].name
    t_np = chunk_backend_seconds(flops, nbytes, profile, "np")
    best, best_t = "np", t_np
    for bk in live:
        t = bk.chunk_seconds(flops, nbytes, profile)
        if t < best_t:
            best, best_t = bk.name, t
    return best


def unit_backend_table(flops_per_worker: float, nbytes_per_worker: float,
                       profiles: Iterable, allow_jnp: bool = True,
                       candidates: Optional[Tuple[str, ...]] = None
                       ) -> List[str]:
    """Backend choice per worker profile for one pfor unit (in profile
    order) — the row of the (unit, backend, worker) pricing table the
    sharder consumes."""
    return [pick_chunk_backend(flops_per_worker, nbytes_per_worker, p,
                               allow_jnp, candidates)
            for p in profiles]


def backend_effective_gflops(profile, backend: str) -> float:
    """Throughput of ``profile`` when running its chosen backend — the
    chunk-sizing weight for heterogeneous fleets (a GPU worker on an
    accelerator body earns a proportionally larger chunk)."""
    bk = backends.get(backend)
    if bk.effective_gflops is None:  # pragma: no cover
        return max(1e-3, getattr(profile, "gflops", 1.0))
    return bk.effective_gflops(profile)


def calibrate_accel_threshold(
    samples: Iterable[Tuple[float, float]],
    default: float = ACCEL_FLOP_THRESHOLD,
    overhead_s: float = ACCEL_DISPATCH_OVERHEAD_S,
) -> float:
    """Per-machine FLOP threshold from tracer-recorded latencies.

    ``samples`` are ``(flops, seconds)`` pairs of the *original* function
    (the tracer measures it during warmup). Accelerator dispatch pays off
    once the non-accelerator alternative's runtime exceeds the fixed
    dispatch overhead, so the break-even is ``overhead × FLOP rate``
    (median across signatures). The measured rate of the interpreted
    original is a *lower bound* on the optimized np variant's rate — the
    variant the threshold actually arbitrates against — so the computed
    break-even is a lower bound on the true one: calibration only ever
    *raises* the threshold above the static default (a fast machine
    covers more FLOPs inside the dispatch overhead), never lowers it.
    Falls back to ``default`` when no usable trace exists; capped so one
    wild timing cannot disable the accelerator entirely."""
    rates = sorted(f / s for f, s in samples if f > 0 and s > 0)
    if not rates:
        return default
    med = rates[len(rates) // 2]
    thr = overhead_s * med
    return min(max(thr, default), default * 64.0)


# ---------------------------------------------------------------------------
# Fusion profitability (core/fusion.py gate)
# ---------------------------------------------------------------------------

# Allocator cost model for parallel temporaries (per backend). A fused
# producer whose array is contracted away also skips one allocation of
# ``points × dtype_bytes``; on the np backend that allocation is a malloc
# plus first-touch page faults (disproportionately expensive for large
# temps — the `elem_chain` np-vs-jnp anomaly in BENCH_fusion.json), while
# jnp's arena allocator amortizes it almost entirely.
ALLOC_BASE_S = {"np": 2e-6, "jnp": 5e-7}
ALLOC_BW = {"np": 8e9, "jnp": 80e9}   # first-touch bytes/s


def alloc_cost_s(backend: str, nbytes: float) -> float:
    """Seconds to materialize one fresh temp of ``nbytes`` on ``backend``."""
    base = ALLOC_BASE_S.get(backend, ALLOC_BASE_S["np"])
    bw = ALLOC_BW.get(backend, ALLOC_BW["np"])
    return base + nbytes / bw


def fusion_profitable(points: float, producer_flops_pp: float, uses: int,
                      dtype_bytes: int = 8,
                      spec: ChipSpec = HOST_CPU,
                      backend: str = "np") -> bool:
    """Contract a producer's array into its consumers?

    Roofline trade: contraction removes the intermediate's memory traffic
    (one store plus one load per use) *and* its allocation (the
    per-backend ``alloc_cost_s`` term), but re-evaluates the producer
    expression at every extra use site. Fuse when the time saved
    dominates the compute term added — i.e. exactly the paper-style
    "memory-traffic dominates" condition. A single-use contraction adds no
    compute and is always profitable."""
    if uses <= 1:
        return True
    saved_bytes = (1 + uses) * points * dtype_bytes
    extra_flops = (uses - 1) * producer_flops_pp * points
    saved_s = (saved_bytes / spec.hbm_bw
               + alloc_cost_s(backend, points * dtype_bytes))
    return extra_flops / spec.peak_flops <= saved_s


def pow2_bucket(n: int) -> Tuple[int, int]:
    """Enclosing power-of-two bucket (lo, hi], lo exclusive, hi inclusive.

    4 → (2, 4]; 100 → (64, 128]; 1 → (0, 1]. Shared by the profiler's
    hint tiers and the dispatcher's bucket-guard fast path."""
    if n <= 1:
        return (0, 1)
    hi = 1
    while hi < n:
        hi <<= 1
    return (hi >> 1, hi)


def expr_flops_per_point(e, env: Optional[Dict[str, int]] = None) -> float:
    """Public wrapper over the per-point FLOP estimator (fusion gate)."""
    return _expr_flops_per_point(e, env or {})


def domain_points(dims, env: Optional[Dict[str, int]] = None) -> float:
    """Public wrapper over domain cardinality with nominal fallbacks."""
    return _card(dims, env or {})


# ---------------------------------------------------------------------------
# Roofline helpers shared with the launch-time analysis
# ---------------------------------------------------------------------------

def roofline(flops: float, bytes_hbm: float, bytes_collective: float,
             chips: int, spec: ChipSpec = TPU_V5E) -> RooflineTerms:
    return RooflineTerms(
        compute_s=flops / (chips * spec.peak_flops),
        memory_s=bytes_hbm / (chips * spec.hbm_bw),
        collective_s=bytes_collective / (chips * spec.ici_bw),
    )
