"""Dependence analysis over canonical SCoP statements.

Client queries (all conservative — "maybe" means "assume dependence"):

  * ``accumulation_legal``  — can an explicit `w[f] += e` loop be converted
    to a reduction (the unification step that makes PolyBench List versions
    canonicalize identically to NumPy versions)?
  * ``loop_parallel``       — is an explicit loop dependence-free across
    iterations (candidate for the paper's inter-node `pfor`)?
  * ``access_chunk_sliceable`` / ``sliceable_partition`` — inside a pfor
    body over `v`, is an array provably indexed *only* by `v` on its
    leading axis (so a distributed chunk `[lo, hi)` needs just rows
    `[lo, hi)` shipped, instead of the whole array)?
  * ``distribution_legal``  — may statements that share a loop nest be
    split into separate full-domain operations (paper §4.2: "applies loop
    distribution to split different library calls while maximizing the
    iteration domain … mapped to a single library function call")?
  * ``absorption_write_legal`` — may a loop over `v` whose statement writes
    `W[f(v,…)]` be vectorized into a full-domain op (requires that no rhs
    read of W observes an element written by an *earlier* v-iteration)?
  * ``fusion_legal``        — may two adjacent loops with identical domains
    be merged into one (the fusion pass in core/fusion.py), i.e. no
    dependence between the bodies at *different* iterations?

Tests are GCD + Banerjee over the affine access functions extracted by
core/scop.py, using iteration-domain bounds where they are constant.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Tuple

from .isl_lite import Affine, Domain, LoopDim, affine_eq_may_hold
from .scop import (CanonStmt, FFTStmt, Item, LoopItem, OpaqueItem, VAccess,
                   vexpr_accesses)


def _const_bounds(dim: LoopDim) -> Tuple[Optional[int], Optional[int]]:
    lo = dim.lower.const if dim.lower.is_constant() else None
    hi = dim.upper.const - 1 if dim.upper.is_constant() else None
    return (lo, hi)


def _stmt_accesses(s: CanonStmt) -> Tuple[List[VAccess], List[VAccess]]:
    """(reads, writes) of a canonical statement."""
    reads = vexpr_accesses(s.rhs)
    writes = [VAccess(s.write_array, s.write_idx, s.dtype)]
    if s.aug is not None:
        reads = reads + writes  # w op= e reads w too
    return reads, writes


def _bounds_env(*stmts: CanonStmt) -> Dict[str, Tuple[Optional[int],
                                                      Optional[int]]]:
    env: Dict[str, Tuple[Optional[int], Optional[int]]] = {}
    for s in stmts:
        for d in list(s.domain.dims) + list(s.reduce_dims()):
            env[d.var] = _const_bounds(d)
    return env


def accesses_may_conflict(
    a: VAccess,
    b: VAccess,
    bounds: Dict[str, Tuple[Optional[int], Optional[int]]],
    rename: Dict[str, str],
) -> bool:
    """May a and b (same array) touch the same element, with b's iterators
    renamed per ``rename`` (to model a distinct iteration)?"""
    if a.array != b.array:
        return False
    if len(a.idx) != len(b.idx):
        return True  # rank confusion: be conservative
    env = {k: Affine.var(v) for k, v in rename.items()}
    for ia, ib in zip(a.idx, b.idx):
        ib2 = ib.substitute(env)
        bb = dict(bounds)
        for k, v in rename.items():
            if k in bounds:
                bb[v] = bounds[k]
        if not affine_eq_may_hold(ia, ib2, bb):
            return False  # this dimension can never match
    return True


# ---------------------------------------------------------------------------
# Query 1: accumulation → reduction conversion
# ---------------------------------------------------------------------------

def accumulation_legal(stmt: CanonStmt,
                       reduce_dims: List[LoopDim]) -> bool:
    """`w[f(outs)] += e(reads)` over the reduce iterators is a sum
    reduction iff the write index does not involve them and the rhs never
    reads the written array at a *different* element (reads provably
    disjoint from the write — e.g. ``B[k,j]`` with ``k >= i+1`` vs write
    ``B[i,j]`` — are fine)."""
    reduce_vars = [d.var for d in reduce_dims]
    dim_of = {d.var: d for d in reduce_dims}
    for idx in stmt.write_idx:
        if any(v in reduce_vars for v in idx.vars()):
            return False
    for acc in vexpr_accesses(stmt.rhs):
        if acc.array != stmt.write_array:
            continue
        if len(acc.idx) != len(stmt.write_idx):
            return False
        # safe iff every dim matches exactly OR some dim provably differs
        some_dim_disjoint = False
        all_dims_equal = True
        for ia, iw in zip(acc.idx, stmt.write_idx):
            diff = ia - iw
            if diff.is_zero():
                continue
            all_dims_equal = False
            if _provably_nonzero(diff, dim_of):
                some_dim_disjoint = True
        if not (all_dims_equal or some_dim_disjoint):
            return False
    return True


def _provably_nonzero(diff: Affine, dim_of: Dict[str, LoopDim]) -> bool:
    """Is diff ≠ 0 throughout the iteration space? Handles the pattern
    diff = k - i + c where k is a reduce var with lower bound i + d (so
    diff >= d + c) or upper bound i + d (so diff <= d - 1 + c)."""
    vars_ = list(diff.vars())
    red = [v for v in vars_ if v in dim_of]
    if len(red) != 1:
        return False
    k = red[0]
    ck = diff.coeff(k)
    if abs(ck) != 1:
        return False
    dim = dim_of[k]
    # rest = diff - ck*k must be exactly -ck * (bound-var part)
    rest = diff.drop([k])
    # lower bound: k >= lower ⇒ ck*k + rest >= ck*lower + rest (ck=1)
    if ck == 1:
        low = dim.lower * 1 + rest  # diff >= lower + rest
        if low.is_constant() and low.const > 0:
            return True
        # symbolic: lower + rest reduces to positive const after cancel
        if not low.is_constant():
            return False
        return False
    else:
        # ck == -1: diff = -k + rest <= -(lower) + rest
        hi = rest - dim.lower
        if hi.is_constant() and hi.const < 0:
            return True
        return False


def _provably_nonneg(diff: Affine, dim_of: Dict[str, LoopDim]) -> bool:
    """Is diff >= 0 throughout the iteration space? Handles diff = u - i + c
    where u is an iterator with lower bound i + d (so diff >= d + c)."""
    if diff.is_constant():
        return diff.const >= 0
    vars_ = [v for v in diff.vars() if v in dim_of]
    if len(vars_) != 1:
        return False
    u = vars_[0]
    if diff.coeff(u) != 1:
        return False
    # diff >= lower(u) + (diff - u)
    low = dim_of[u].lower + diff.drop([u])
    return low.is_constant() and low.const >= 0


def absorption_write_legal(stmt: CanonStmt, dim: LoopDim) -> bool:
    """May the explicit loop over ``dim`` be folded into the statement's
    domain when the write index uses the loop iterator?

    Vectorizing evaluates the whole rhs before any element is stored, so
    every rhs read of the written array must observe only elements written
    by the *same or a later* iteration of ``dim`` (forward reads see the
    original values either way; backward reads are a recurrence, e.g.
    ``a[i] = a[i-1] * 2`` — the loop must stay explicit)."""
    v = dim.var
    dim_of = {d.var: d
              for d in list(stmt.domain.dims) + list(stmt.reduce_dims())}
    dim_of[v] = dim
    for acc in vexpr_accesses(stmt.rhs):
        if acc.array != stmt.write_array:
            continue
        if len(acc.idx) != len(stmt.write_idx):
            return False
        for ia, iw in zip(acc.idx, stmt.write_idx):
            if iw.coeff(v) == 0 and ia.coeff(v) == 0:
                # dimension independent of v: atomic within one iteration
                continue
            if iw.coeff(v) != 1:
                return False
            if not _provably_nonneg(ia - iw, dim_of):
                return False
    return True


# ---------------------------------------------------------------------------
# Query 2: chunk sliceability (distributed data movement)
# ---------------------------------------------------------------------------

def access_chunk_sliceable(acc: VAccess, v: str) -> bool:
    """May this access be satisfied by shipping only rows ``[lo, hi)`` of
    the array's leading axis to the worker executing pfor chunk
    ``v in [lo, hi)``?

    True iff the leading index is *exactly* the pfor iterator (coefficient
    1, no other terms — an offset like ``A[v+1]`` would step outside the
    shipped rows) and ``v`` appears in no other index dimension (``W[v,v]``
    touches a column the chunk's rows don't bound). Whole-array accesses
    (empty index) read rows outside the chunk and are never sliceable."""
    if not acc.idx:
        return False
    if not (acc.idx[0] - Affine.var(v)).is_zero():
        return False
    return all(v not in idx.vars() for idx in acc.idx[1:])


def sliceable_partition(accesses_by_array: Dict[str, List[VAccess]],
                        v: str,
                        disqualified: frozenset = frozenset()) -> List[str]:
    """Arrays every one of whose accesses in a pfor body over ``v`` is
    chunk-sliceable (see :func:`access_chunk_sliceable`); ``disqualified``
    names arrays with non-affine/unknown accesses (opaque items, FFT
    whole-array reads, privatized locals) that must ship whole."""
    out: List[str] = []
    for array, accs in accesses_by_array.items():
        if array in disqualified or not accs:
            continue
        if all(access_chunk_sliceable(a, v) for a in accs):
            out.append(array)
    return out


# ---------------------------------------------------------------------------
# Query 3: explicit-loop parallelism (pfor detection)
# ---------------------------------------------------------------------------

def _collect_canon(items: List[Item]) -> Tuple[List[CanonStmt], bool]:
    """All CanonStmts under items; bool=True if an opaque/fft blocks
    analysis."""
    out: List[CanonStmt] = []
    blocked = False
    for it in items:
        if isinstance(it, CanonStmt):
            out.append(it)
        elif isinstance(it, LoopItem):
            sub, b = _collect_canon(it.body)
            out.extend(sub)
            blocked = blocked or b
        elif isinstance(it, FFTStmt):
            # fft reads src fully / writes out fully; treat as canon-like
            out.append(CanonStmt(
                write_array=it.out, write_idx=(), domain=Domain(()),
                rhs=VAccess(it.src, ()), write_full=True,
                label="fft-shim"))
        else:
            blocked = True
    return out, blocked


def _private_arrays(stmts: List[CanonStmt], params: frozenset) -> set:
    """Arrays whose first access within one iteration is a full overwrite:
    privatizable (one fresh copy per iteration), so they carry no
    loop-carried dependence. Kernel parameters escape and never qualify."""
    first: Dict[str, str] = {}
    for s in stmts:
        for acc in vexpr_accesses(s.rhs):
            first.setdefault(acc.array, "read")
        kind = "w_full" if (s.write_full or s.write_is_temp) else "other"
        if s.aug is not None:
            kind = "other"
        first.setdefault(s.write_array, kind)
    return {a for a, k in first.items()
            if k == "w_full" and a not in params}


def loop_parallel(loop: LoopItem, params=()) -> bool:
    """True iff no loop-carried dependence on loop.dim.var.

    For every (write W of S1, access A of S2) pair on the same array, ask
    whether W at iteration v can equal A at iteration v' ≠ v. We encode
    v' as a renamed variable and use the affine may-equal test; if all
    dimensions can simultaneously match AND the index functions do not pin
    v = v', the loop is not provably parallel."""
    stmts, blocked = _collect_canon(loop.body)
    if blocked:
        return False
    private = _private_arrays(stmts, frozenset(params))
    v = loop.dim.var
    vp = v + "__p"
    bounds = _bounds_env(*[s for s in stmts if isinstance(s, CanonStmt)])
    bounds[vp] = bounds.get(v, _const_bounds(loop.dim))
    for s1 in stmts:
        _, writes1 = _stmt_accesses(s1)
        for s2 in stmts:
            reads2, writes2 = _stmt_accesses(s2)
            for w in writes1:
                for a in reads2 + writes2:
                    if w.array != a.array:
                        continue
                    if w.array in private:
                        continue
                    if w is a and s1 is s2:
                        continue
                    if not accesses_may_conflict(w, a, bounds, {v: vp}):
                        continue
                    # Conflict possible under renaming. It is still fine if
                    # equality *forces* v == v' (same-iteration dep): check
                    # whether for every dim pair the difference depends on v
                    # in a way that pins v == v'.
                    if _pins_same_iteration(w, a, v, vp):
                        continue
                    return False
    return True


def _pins_same_iteration(w: VAccess, a: VAccess, v: str, vp: str) -> bool:
    """True if w.idx == a.idx[v→vp] implies v == vp (some dimension is
    c*v + f(params) on both sides with equal nonzero c)."""
    env = {v: Affine.var(vp)}
    for ia, ib in zip(w.idx, a.idx):
        ib2 = ib.substitute(env)
        diff = ia - ib2
        cv, cvp = diff.coeff(v), diff.coeff(vp)
        if cv != 0 and cv == -cvp:
            rest = diff.drop([v, vp])
            if rest.is_zero():
                return True
    return False


# ---------------------------------------------------------------------------
# Query 4: loop distribution legality
# ---------------------------------------------------------------------------

def distribution_legal(stmts: List[CanonStmt],
                       shared_vars: List[str]) -> bool:
    """May S1;S2;… inside a common nest over shared_vars be executed as
    'all iterations of S1, then all of S2, …'?

    Illegal iff some later statement S_b writes data that an earlier S_a
    accesses at a *later* iteration (a backward dependence S_b@(i) →
    S_a@(i') with i' > i). We conservatively reject whenever a later
    statement's write may conflict with an earlier statement's access at
    any *different* iteration of the shared vars."""
    bounds = _bounds_env(*stmts)
    rename = {vv: vv + "__p" for vv in shared_vars}
    for vv, vr in rename.items():
        bounds[vr] = bounds.get(vv, (None, None))
    for ib_ in range(len(stmts)):
        for ia_ in range(ib_):
            s_a, s_b = stmts[ia_], stmts[ib_]
            reads_a, writes_a = _stmt_accesses(s_a)
            _, writes_b = _stmt_accesses(s_b)
            for w in writes_b:
                for acc in reads_a + writes_a:
                    if w.array != acc.array:
                        continue
                    if not accesses_may_conflict(w, acc, bounds, rename):
                        continue
                    pinned = all(
                        _pins_same_iteration(w, acc, vv, vr)
                        for vv, vr in rename.items())
                    if pinned and rename:
                        continue  # only same-iteration conflicts: forward
                    return False
    return True


# ---------------------------------------------------------------------------
# Query 5: loop fusion legality (core/fusion.py)
# ---------------------------------------------------------------------------

def fusion_legal(before: List[CanonStmt], after: List[CanonStmt],
                 shared_vars: List[str]) -> bool:
    """May 'for v: before' followed by 'for v: after' (identical domains,
    iterators already renamed to the shared names) be merged into a single
    loop 'for v: before; after'?

    Fusing makes iteration i of ``after`` run before iteration i' > i of
    ``before``, and iteration i of ``before`` run before iteration i of
    ``after`` (instead of after all of them). Banerjee gives no dependence
    direction, so we conservatively require every cross-loop conflict on a
    shared array to pin the *same* iteration of every shared var — those
    dependences are preserved verbatim by fusion."""
    bounds = _bounds_env(*(list(before) + list(after)))
    rename = {vv: vv + "__p" for vv in shared_vars}
    for vv, vr in rename.items():
        bounds[vr] = bounds.get(vv, (None, None))
    for s1 in before:
        for s2 in after:
            reads1, writes1 = _stmt_accesses(s1)
            reads2, writes2 = _stmt_accesses(s2)
            pairs = [(w, a) for w in writes1 for a in reads2 + writes2]
            pairs += [(w, a) for w in writes2 for a in reads1 + writes1]
            for w, a in pairs:
                if w.array != a.array:
                    continue
                if not accesses_may_conflict(w, a, bounds, rename):
                    continue
                pinned = rename and all(
                    _pins_same_iteration(w, a, vv, vr)
                    for vv, vr in rename.items())
                if not pinned:
                    return False
    return True
