"""Polyhedral-lite scheduling (paper §4.2, built on the PolyAST policies).

Two optimization policies, exactly as the paper states them:

  * INTRA-NODE — "apply loop distribution to split different library calls
    while maximizing the iteration domain that can be mapped to a single
    library function call": explicit loops are *absorbed* into the domains
    of the canonical statements they enclose (turning accumulation loops
    into reductions), subject to dependence legality, so each statement
    becomes one maximal library call for raising.

  * INTER-NODE — "maximize outermost level parallelism": outermost loops
    that cannot be absorbed (e.g. they enclose materialization points like
    FFT) but are dependence-free across iterations become `pfor` units,
    tiled for distribution across workers (paper Fig 7c).
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple, Union

from . import dependence
from .isl_lite import Affine, Domain, LoopDim
from .scop import (CanonStmt, FFTStmt, Item, LoopItem, OpaqueItem,
                   ScopProgram, VAccess, VReduce, vexpr_accesses)


# ---------------------------------------------------------------------------
# Schedule units (consumed by codegen)
# ---------------------------------------------------------------------------

@dataclass
class RaisedUnit:
    stmt: CanonStmt


@dataclass
class FFTUnit:
    stmt: FFTStmt


@dataclass
class OpaqueUnit:
    item: OpaqueItem


@dataclass
class SeqLoopUnit:
    dim: LoopDim
    body: List["Unit"]


@dataclass
class PforUnit:
    """Iterations of ``dim`` are independent; body units treat dim.var as a
    bound scalar. ``tile`` is the distribution chunk (None = runtime).
    ``sliceable`` names captured arrays the body provably indexes only by
    ``dim.var`` on their leading axis — the cluster runtime ships each
    worker just its chunk's rows of those instead of broadcasting them
    (set by :func:`_pfor_sliceable` after fusion). ``jnp_feasible`` is
    the schedule-level pre-check for a per-unit accelerator twin body
    (no black-box statements anywhere in the body); codegen still owns
    the final word, since loop fallbacks only surface at emit time."""

    dim: LoopDim
    body: List["Unit"]
    tile: Optional[int] = None
    sliceable: Tuple[str, ...] = ()
    jnp_feasible: bool = True


Unit = Union[RaisedUnit, FFTUnit, OpaqueUnit, SeqLoopUnit, PforUnit]


@dataclass
class Schedule:
    program: ScopProgram
    units: List[Unit]
    # names of arrays written anywhere (for functional-backend returns)
    written: List[str] = field(default_factory=list)
    has_opaque: bool = False
    has_pfor: bool = False
    # telemetry from the producer–consumer fusion pass (core/fusion.py);
    # None when the pass was disabled or the entry predates it
    fusion: Optional[object] = None


# ---------------------------------------------------------------------------
# Loop absorption (intra-node maximization)
# ---------------------------------------------------------------------------

def _absorb_loop(loop: LoopItem) -> Optional[List[CanonStmt]]:
    """Try to fold the explicit loop into its statements' domains.
    Returns flattened CanonStmts or None if the loop must stay explicit."""
    flat: List[CanonStmt] = []
    for item in loop.body:
        if isinstance(item, CanonStmt):
            flat.append(item)
        elif isinstance(item, LoopItem):
            sub = _absorb_loop(item)
            if sub is None:
                return None
            flat.extend(sub)
        else:
            return None  # FFT / opaque: materialization point blocks

    v = loop.dim.var
    out: List[CanonStmt] = []
    for s in flat:
        writes_use = any(v in idx.vars() for idx in s.write_idx)
        rhs_use = any(
            v in a_idx.vars()
            for acc in vexpr_accesses(s.rhs) for a_idx in acc.idx)
        bounds_use = any(
            v in b.vars()
            for d in list(s.domain.dims) + list(s.reduce_dims())
            for b in (d.lower, d.upper))
        if writes_use:
            # v is an out iterator: prepend (outer-first domain order),
            # unless the rhs reads elements the loop wrote at an earlier
            # iteration (a recurrence — vectorizing would read stale data)
            if not dependence.absorption_write_legal(s, loop.dim):
                return None
            out.append(CanonStmt(
                write_array=s.write_array, write_idx=s.write_idx,
                domain=Domain((loop.dim,) + s.domain.dims),
                rhs=s.rhs, aug=s.aug, write_is_temp=s.write_is_temp,
                write_full=s.write_full, label=s.label, dtype=s.dtype))
        elif rhs_use or bounds_use:
            if s.aug == "+" and dependence.accumulation_legal(s, [loop.dim]):
                out.append(CanonStmt(
                    write_array=s.write_array, write_idx=s.write_idx,
                    domain=s.domain,
                    rhs=VReduce("sum", (loop.dim,), s.rhs),
                    aug="+", write_is_temp=s.write_is_temp,
                    write_full=s.write_full, label=s.label, dtype=s.dtype))
            else:
                return None  # last-value / recurrence: keep loop explicit
        else:
            reads_own_write = any(
                acc.array == s.write_array
                for acc in vexpr_accesses(s.rhs))
            if s.aug is None and not reads_own_write:
                out.append(s)  # loop-invariant: hoist (LICM)
            else:
                # aug or self-read: a recurrence independent of v —
                # executing it once is not executing it N times
                return None

    # Distribution legality: absorbing executes all iterations of each
    # statement before the next statement.
    if len(flat) > 1 and not dependence.distribution_legal(flat, [v]):
        return None
    return out


# ---------------------------------------------------------------------------
# Driver
# ---------------------------------------------------------------------------

def _schedule_items(items: List[Item], depth: int, distribute: bool,
                    params: frozenset) -> List[Unit]:
    units: List[Unit] = []
    for item in items:
        if isinstance(item, CanonStmt):
            units.append(RaisedUnit(item))
        elif isinstance(item, FFTStmt):
            units.append(FFTUnit(item))
        elif isinstance(item, OpaqueItem):
            units.append(OpaqueUnit(item))
        elif isinstance(item, LoopItem):
            absorbed = _absorb_loop(item)
            if absorbed is not None:
                units.extend(RaisedUnit(s) for s in absorbed)
                continue
            par = dependence.loop_parallel(item, params)
            body = _schedule_items(item.body, depth + 1, distribute, params)
            if par and depth == 0 and distribute:
                units.append(PforUnit(item.dim, body))
            else:
                units.append(SeqLoopUnit(item.dim, body))
        else:  # pragma: no cover
            raise TypeError(type(item))
    return units


def _pfor_sliceable(u: PforUnit) -> Tuple[str, ...]:
    """Per-array chunk sliceability for one pfor unit (ISSUE: the
    data-movement lever). Collects every access each array sees inside
    the body — reads, writes, aug-reads — and keeps arrays whose accesses
    are all provably ``arr[v, f(...)]`` with ``v`` the pfor iterator
    (:func:`dependence.access_chunk_sliceable`). Materialization points
    (FFT whole-array reads, opaque statements) and privatized locals
    (full overwrites / compiler temps — they never become closure cells)
    disqualify their arrays."""
    v = u.dim.var
    accesses: Dict[str, List] = {}
    disq: set = set()

    def add(acc) -> None:
        accesses.setdefault(acc.array, []).append(acc)

    def walk(units: List[Unit]) -> None:
        for unit in units:
            if isinstance(unit, RaisedUnit):
                s = unit.stmt
                if s.write_full or s.write_is_temp:
                    # assigned whole inside the body: a body-local
                    # (privatized) name, never a shipped closure cell
                    disq.add(s.write_array)
                else:
                    add(VAccess(s.write_array, s.write_idx, s.dtype))
                for acc in vexpr_accesses(s.rhs):
                    add(acc)
            elif isinstance(unit, FFTUnit):
                disq.add(unit.stmt.src)   # read whole per iteration
                disq.add(unit.stmt.out)
            elif isinstance(unit, OpaqueUnit):
                disq.update(unit.item.reads)
                disq.update(unit.item.writes)
            elif isinstance(unit, (SeqLoopUnit, PforUnit)):
                walk(unit.body)

    walk(u.body)
    return tuple(dependence.sliceable_partition(
        accesses, v, frozenset(disq)))


def _written_arrays(units: List[Unit]) -> List[str]:
    seen: List[str] = []

    def add(n: str):
        if n not in seen:
            seen.append(n)

    def rec(us: List[Unit]):
        for u in us:
            if isinstance(u, RaisedUnit):
                add(u.stmt.write_array)
            elif isinstance(u, FFTUnit):
                add(u.stmt.out)
            elif isinstance(u, OpaqueUnit):
                for w in u.item.writes:
                    add(w)
            elif isinstance(u, (SeqLoopUnit, PforUnit)):
                rec(u.body)

    rec(units)
    return seen


def schedule(program: ScopProgram, distribute: bool = True,
             fuse: bool = True,
             fusion_profile: str = "functional") -> Schedule:
    params = frozenset(n for n, _ in program.fn.params)
    units = _schedule_items(program.items, 0, distribute, params)
    sched = Schedule(program, units)
    # per-stage perf_counter stamps: the compiler turns these into
    # compile-pipeline spans/metrics (this module stays obs-free)
    stage_spans: List[Tuple[str, float, float]] = []
    if fuse:
        from . import fusion  # deferred: fusion → cost → schedule
        t0 = time.perf_counter()
        fusion.fuse(sched, profile=fusion_profile)
        stage_spans.append(("fusion", t0, time.perf_counter()))
    sched.written = _written_arrays(sched.units)
    # chunk-sliceability is a property of the *post-fusion* body: fusion
    # may rewrite accesses, so the analysis runs on what codegen will emit
    t0 = time.perf_counter()
    for u in _flatten(sched.units):
        if isinstance(u, PforUnit):
            u.sliceable = _pfor_sliceable(u)
            u.jnp_feasible = not any(
                isinstance(b, OpaqueUnit) for b in _flatten(u.body))
    stage_spans.append(("dependence", t0, time.perf_counter()))
    sched.stage_spans = stage_spans
    sched.has_opaque = any(
        isinstance(u, OpaqueUnit) for u in _flatten(sched.units))
    sched.has_pfor = any(
        isinstance(u, PforUnit) for u in _flatten(sched.units))
    return sched


def _flatten(units: List[Unit]):
    for u in units:
        yield u
        if isinstance(u, (SeqLoopUnit, PforUnit)):
            yield from _flatten(u.body)
