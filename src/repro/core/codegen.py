"""Code generation: scheduled SCoP → optimized Python source.

Two backends, mirroring the paper's §4.3 variants:
  * ``np``  — optimized CPU code (NumPy library mapping);
  * ``jnp`` — accelerator code (JAX; the TPU analogue of the paper's
    NumPy→CuPy conversion). Functional semantics: arrays are rebuilt with
    ``.at[]`` updates and written arrays are returned; the dispatcher
    copies results back into the caller's buffers.

The *whole-kernel* jnp variant is all-or-nothing, like the paper's CuPy
conversion: any black-box statement, loop fallback, or pfor makes it
infeasible (EmitError) and the decision tree keeps the optimized-NumPy
and original variants.

Backend selection is additionally **per unit** (the heterogeneous-fleet
refactor): when a kernel contains pfor units, the np variant emits *two*
chunk bodies per pfor — the usual in-place NumPy body plus, when the
unit's own body is accelerator-feasible, a jnp twin (``__pfor_body_N__jnp``)
that computes through ``__jxp`` (jax.numpy) and lands its writes in place
into the captured NumPy arrays (``xp.asarray`` at the store), so the
cluster runtime's sparse-diff gather works unchanged. Both bodies are
stamped ``__backend__`` and ``__sliceable__``, and the np body carries its
twin as ``__jnp__`` — the cluster runtime routes each worker's chunks to
whichever body its device profile prices cheaper.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Set, Tuple

from . import backends as backends_mod
from . import tir
from .isl_lite import Affine, LoopDim
from .raising import (EinsumSpec, Hull, MaskOperand, RaiseError, WritePlan,
                      compute_hull, normalize, plan_einsum, plan_write)
from .schedule import (FFTUnit, OpaqueUnit, PforUnit, RaisedUnit, Schedule,
                       SeqLoopUnit, Unit)
from .scop import (CanonStmt, VAccess, VBin, VConst, VExpr, VParam, VReduce,
                   VUnary, substitute_array_reads, vexpr_accesses)


class EmitError(Exception):
    pass


def _uses_red_var(e: VExpr, var: str) -> bool:
    if isinstance(e, VAccess):
        return any(var in idx.vars() for idx in e.idx)
    if isinstance(e, VBin):
        return _uses_red_var(e.left, var) or _uses_red_var(e.right, var)
    if isinstance(e, VUnary):
        return _uses_red_var(e.operand, var)
    if isinstance(e, VReduce):
        return _uses_red_var(e.child, var)
    return False


# ---------------------------------------------------------------------------
# Affine → Python
# ---------------------------------------------------------------------------

def affine_py(a: Affine) -> str:
    parts: List[str] = []
    for k, c in a.coeffs:
        if c == 1:
            parts.append(k)
        elif c == -1:
            parts.append(f"-{k}")
        else:
            parts.append(f"{c}*{k}")
    if a.const or not parts:
        parts.append(str(a.const))
    out = " + ".join(parts).replace("+ -", "- ")
    return out if len(parts) == 1 else f"({out})"


# ---------------------------------------------------------------------------
# Emitter
# ---------------------------------------------------------------------------

@dataclass
class EmitMeta:
    jax_ok: bool = True
    uses_pfor: bool = False
    pfor_count: int = 0
    raised_ops: List[str] = field(default_factory=list)
    # copied from the schedule's fusion pass so cached variants carry
    # their own telemetry (fused statements / contracted intermediates)
    fused_units: int = 0
    contracted_arrays: List[str] = field(default_factory=list)
    # pfor unit indices that got a jnp twin body (hybrid variant); the
    # exec namespace must bind __jxp (jax.numpy) when this is non-empty
    pfor_jnp_units: List[int] = field(default_factory=list)
    # subset of pfor_jnp_units whose twin also carries a vmappable
    # per-iteration function wired through __pfor_jit (compiled path);
    # the exec namespace must additionally bind __jax and __pfor_jit
    pfor_jit_units: List[int] = field(default_factory=list)
    # backend name → pfor unit indices that got that backend's twin
    # (registry-driven; pfor_jnp_units is kept as the jnp projection so
    # pre-registry cache entries and telemetry keep working). The exec
    # namespace must merge each listed backend's namespace() bindings.
    pfor_twin_units: Dict[str, List[int]] = field(default_factory=dict)


class Emitter:
    def __init__(self, sched: Schedule, backend: str):
        assert backend in ("np", "jnp")
        self.s = sched
        self.backend = backend
        self.lines: List[str] = []
        self.depth = 1
        self.bound: Set[str] = set()  # loop vars live as python scalars
        self.meta = EmitMeta()
        self.tmp_counter = itertools.count()
        # shape symbols for locally-defined arrays, emitted lazily right
        # after the defining statement: {array: [sym, …]}
        self.pending_syms: Dict[str, List[str]] = {}
        # name the backend module is bound to in the generated namespace
        # ("xp" normally; "__jxp" for the jnp twin of a pfor body, which
        # lives inside an np-variant whose "xp" is numpy)
        self.xp = "xp"
        # also try a jnp twin for each pfor unit (np variant only)
        self.pfor_jnp = False
        # hybrid chunk-body mode: compute with jnp but store in place
        # into *captured* numpy arrays (xp.asarray at the store) so the
        # worker's sparse-diff gather sees the writes; arrays fully
        # assigned inside the body are locals (jnp values) and take the
        # functional .at[] path instead
        self.store_np_captured = False
        self.body_locals: Set[str] = set()
        # jit-iteration mode: emit ONE pfor iteration as a pure function
        # of (g, __offs, *arrays) for vmap/jit via __pfor_jit. Captured
        # arrays indexed by the pfor var collapse to per-iteration row
        # variables; every other captured array becomes an explicit
        # parameter; writes land functionally in the row variables and
        # are returned for the caller to scatter.
        self.jit_iter = False
        self.jit_pfor_var: Optional[str] = None
        self.jit_params: Dict[str, int] = {}   # array name → arg position
        self.jit_rows: Dict[str, str] = {}     # array name → row variable
        self.jit_write_arrays: List[str] = []  # row arrays written (order)
        self._assign_log: List[str] = []       # assignment events, in order
        self._jit_future: List[Unit] = []      # units after current one
        self._jit_loop_depth = 0
        # row captures first touched inside a sequential loop: their
        # prelude depends only on (g, __offs, params), so it hoists
        # above the outermost loop instead of bailing the jit
        self._jit_hoist: List[str] = []

    def define_syms_for(self, arr: str) -> None:
        for sym in self.pending_syms.pop(arr, []):
            d = sym.rsplit("__d", 1)[1]
            self.w(f"{sym} = {arr}.shape[{d}]")
            self._note_assign(sym)

    # -- low-level -------------------------------------------------------
    def w(self, line: str) -> None:
        self.lines.append("    " * self.depth + line)

    def fresh(self, p: str = "v") -> str:
        return f"__{p}{next(self.tmp_counter)}"

    # -- frames ------------------------------------------------------------
    def free_dims(self, stmt: CanonStmt) -> List[LoopDim]:
        return [d for d in stmt.domain.dims if d.var not in self.bound]

    # -- expression emission -------------------------------------------
    def emit_expr(self, e: VExpr, frame: Tuple[str, ...],
                  hull: Hull) -> str:
        if isinstance(e, VConst):
            return repr(e.value)
        if isinstance(e, VParam):
            return e.name
        if isinstance(e, VUnary):
            inner = self.emit_expr(e.operand, frame, hull)
            if e.fn == "-":
                return f"(-{inner})"
            if e.fn.startswith("np."):
                return f"{self.xp}.{e.fn[3:]}({inner})"
            return f"{e.fn}({inner})"
        if isinstance(e, VBin):
            l = self.emit_expr(e.left, frame, hull)
            r = self.emit_expr(e.right, frame, hull)
            if e.op.startswith("np."):
                return f"{self.xp}.{e.op[3:]}({l}, {r})"
            return f"({l} {e.op} {r})"
        if isinstance(e, VAccess):
            return self.emit_access_aligned(e, frame, hull)
        if isinstance(e, VReduce):
            try:
                spec = plan_einsum(e, frame, hull)
                return self.emit_einsum(spec, frame, hull)
            except RaiseError:
                return self.emit_elementwise_sum(e, frame, hull)
        raise EmitError(f"cannot emit {type(e).__name__}")

    def emit_elementwise_sum(self, e: VReduce, frame: Tuple[str, ...],
                             hull: Hull) -> str:
        """Σ over rectangular reduce dims of an arbitrary elementwise
        expression: emit the expression over frame+reduce dims, then
        ``.sum(axis=…)`` (Table 2's sum_2D,axis=k raising)."""
        # reduce bounds must not depend on out iterators (else einsum+mask
        # was the only vectorized option and we fall back to loops)
        for d in e.dims:
            for b in (d.lower, d.upper):
                if any(v in frame for v in b.vars()):
                    raise RaiseError("triangular bound in elementwise sum")
            if not _uses_red_var(e.child, d.var):
                raise RaiseError("reduce var unused in child")
        frame2 = tuple(frame) + tuple(d.var for d in e.dims)
        hull2 = Hull(dict(hull.lo), dict(hull.hi), list(hull.conds))
        for d in e.dims:
            hull2.lo[d.var] = d.lower
            hull2.hi[d.var] = d.upper
        inner = self.emit_expr(e.child, frame2, hull2)
        axes = tuple(range(len(frame), len(frame2)))
        ax = axes[0] if len(axes) == 1 else axes
        self.meta.raised_ops.append("sum")
        return f"({inner}).sum(axis={ax})"

    def access_slices_and_dims(
        self, acc: VAccess, frame: Tuple[str, ...], hull: Hull,
        extra_lo: Dict[str, Affine] = None, extra_hi: Dict[str, Affine] = None,
    ) -> Tuple[str, List[str]]:
        """Slice string for an access + ordered iterator vars of its dims."""
        extra_lo = extra_lo or {}
        extra_hi = extra_hi or {}
        base, idx_list = acc.array, list(acc.idx)
        if self.jit_iter:
            base, idx_list = self._jit_rebase(acc.array, idx_list,
                                              is_write=False)
        comps: List[str] = []
        order: List[str] = []
        for idx in idx_list:
            ivars = [v for v in idx.vars()
                     if v in frame or v in extra_lo]
            if not ivars:
                comps.append(affine_py(idx))
                continue
            if len(ivars) > 1:
                raise RaiseError("multi-iterator access index")
            v = ivars[0]
            if idx.coeff(v) != 1:
                raise RaiseError("non-unit access stride")
            rest = idx.drop([v])
            lo = (extra_lo.get(v) or hull.lo[v]) + rest
            hi = (extra_hi.get(v) or hull.hi[v]) + rest
            comps.append(f"{affine_py(lo)}:{affine_py(hi)}")
            order.append(v)
        sl = f"{base}[{', '.join(comps)}]" if comps else base
        return sl, order

    # -- jit-iteration helpers ---------------------------------------------
    def _jit_rebase(self, array: str, idx: List[Affine],
                    is_write: bool) -> Tuple[str, List[Affine]]:
        """Route one array access for the jit-iteration function: body
        locals pass through; g-free captured arrays become parameters;
        ``A[g, …]`` accesses collapse onto A's row variable. Anything
        else (g in a later dim, non-identity g index) bails the jit."""
        g = self.jit_pfor_var
        if array in self.body_locals:
            return array, idx
        uses_g = [i for i, a in enumerate(idx) if g in a.vars()]
        if not uses_g:
            if array in self.jit_rows:
                raise EmitError("jit: whole-array access after row capture")
            if is_write:
                raise EmitError("jit: g-free write to captured array")
            self._jit_param(array)
            return array, idx
        if uses_g != [0] or affine_py(idx[0]) != g:
            raise EmitError("jit: non-row pfor indexing")
        return self._jit_row(array), list(idx[1:])

    def _note_assign(self, name: str) -> None:
        if self.jit_iter:
            self._assign_log.append(name)

    def _jit_param(self, array: str) -> int:
        if array not in self.jit_params:
            self.jit_params[array] = len(self.jit_params)
        return self.jit_params[array]

    def _jit_row(self, array: str) -> str:
        row = self.jit_rows.get(array)
        if row is not None:
            return row
        pos = self._jit_param(array)
        row = f"__row_{array}"
        line = f"{row} = {array}[{self.jit_pfor_var} - __offs[{pos}]]"
        if self._jit_loop_depth:
            # hoisted above the loop — not an in-loop assignment event
            self._jit_hoist.append(line)
        else:
            self.w(line)
            self._note_assign(row)
        self.jit_rows[array] = row
        self.body_locals.add(row)
        return row

    def align(self, expr: str, order: List[str],
              frame: Tuple[str, ...]) -> str:
        """Permute + None-expand an expression with dims `order` so it
        broadcasts in the frame."""
        if not order:
            return expr
        want = [v for v in frame if v in order]
        if want != order:
            perm = tuple(order.index(v) for v in want)
            if len(order) == 2 and perm == (1, 0):
                expr = f"{expr}.T"
            else:
                expr = f"{self.xp}.transpose({expr}, {perm})"
            order = want
        if list(frame) == order:
            return expr
        parts = []
        oi = 0
        for fv in frame:
            if oi < len(order) and order[oi] == fv:
                parts.append(":")
                oi += 1
            else:
                parts.append("None")
        # trailing-dim broadcasting handles leading missing dims already,
        # but explicit None keeps semantics obvious and general
        return f"{expr}[{', '.join(parts)}]"

    def emit_access_aligned(self, acc: VAccess, frame: Tuple[str, ...],
                            hull: Hull) -> str:
        sl, order = self.access_slices_and_dims(acc, frame, hull)
        return self.align(sl, order, frame)

    # -- einsum / dot ------------------------------------------------------
    def emit_einsum(self, spec: EinsumSpec, frame: Tuple[str, ...],
                    hull: Hull) -> str:
        red_lo = {d.var: d.lower for d in spec.reduce_dims}
        red_hi = {d.var: d.upper for d in spec.reduce_dims}
        op_strs: List[str] = []
        for op in spec.operands:
            sl, _ = self.access_slices_and_dims(op.access, frame, hull,
                                                red_lo, red_hi)
            op_strs.append(sl)
        for m in spec.masks:
            op_strs.append(self.mask_expr(m, frame, hull, red_lo, red_hi,
                                          for_einsum=True))
        result = self.dot_peephole(spec, op_strs)
        if result is None:
            opt = ", optimize=True" if self.backend == "np" else ""
            result = (f"{self.xp}.einsum('{spec.spec}', "
                      + ", ".join(op_strs) + opt + ")")
            self.meta.raised_ops.append(f"einsum:{spec.spec}")
        return self.align(result, list(spec.out_vars), frame)

    def dot_peephole(self, spec: EinsumSpec,
                     op_strs: List[str]) -> Optional[str]:
        """2-operand single-contraction einsum → np.dot (paper Fig. 6c)."""
        if not spec.is_dot2():
            return None
        (a, b), (sa, sb) = spec.operands, op_strs
        k = None
        shared = set(a.letters) & set(b.letters)
        if len(shared) != 1:
            return None
        k = shared.pop()
        if spec.out_letters and k in spec.out_letters:
            return None

        def arrange(letters: str, s: str, want_k_last: bool) -> Optional[str]:
            if len(letters) == 1:
                return s if letters == k else None
            if want_k_last:
                return s if letters[1] == k else f"{s}.T"
            return s if letters[0] == k else f"{s}.T"

        ea = arrange(a.letters, sa, want_k_last=True)
        eb = arrange(b.letters, sb, want_k_last=False)
        if ea is None or eb is None:
            return None
        # validate output letter order (i from A, j from B)
        a_out = a.letters.replace(k, "")
        b_out = b.letters.replace(k, "")
        if spec.out_letters != a_out + b_out:
            if spec.out_letters == b_out + a_out:
                ea, eb = (eb if len(b.letters) > 1 else eb,
                          ea)
                ea, eb = arrange(b.letters, sb, True), arrange(
                    a.letters, sa, False)
                if ea is None or eb is None:
                    return None
            else:
                return None
        self.meta.raised_ops.append("dot")
        return f"{self.xp}.dot({ea}, {eb})"

    # -- masks --------------------------------------------------------------
    def mask_expr(self, m: MaskOperand, frame, hull: Hull,
                  red_lo: Dict[str, Affine], red_hi: Dict[str, Affine],
                  for_einsum: bool) -> str:
        dlo = red_lo.get(m.row_var) or hull.lo[m.row_var]
        dhi = red_hi.get(m.row_var) or hull.hi[m.row_var]
        olo = red_lo.get(m.col_var) or hull.lo[m.col_var]
        ohi = red_hi.get(m.col_var) or hull.hi[m.col_var]
        n = affine_py(dhi - dlo)
        mm = affine_py(ohi - olo)
        big_k = (olo + m.offset) - dlo  # d >= o + K  (K affine)
        k = affine_py(big_k * -1)  # tri offset = -K
        dt = "" if for_einsum else ", dtype=bool"
        # tri(D, O, -K)[d, o] = (o <= d - K) = (d >= o + K)
        tri = f"{self.xp}.tri({n}, {mm}, {k}{dt})"
        if m.op == ">=":
            return tri
        return f"(1 - {tri})" if for_einsum else f"(~{tri})"

    def write_mask_expr(self, conds, frame: Tuple[str, ...],
                        hull: Hull) -> str:
        if len(frame) != 2:
            raise RaiseError("masked write needs 2-D frame")
        r, c = frame
        rlo, rhi = hull.lo[r], hull.hi[r]
        clo, chi = hull.lo[c], hull.hi[c]
        rn, cn = affine_py(rhi - rlo), affine_py(chi - clo)
        terms = []
        for dep, outer, op, off in conds:
            if dep == c and outer == r:
                big_k = (rlo + off) - clo
                k = affine_py(big_k - 1)
                tri = f"{self.xp}.tri({rn}, {cn}, {k}, dtype=bool)"
                terms.append(f"(~{tri})" if op == ">=" else tri)
            elif dep == r and outer == c:
                big_k = (clo + off) - rlo
                k = affine_py(big_k * -1)
                tri = f"{self.xp}.tri({rn}, {cn}, {k}, dtype=bool)"
                terms.append(tri if op == ">=" else f"(~{tri})")
            else:
                raise RaiseError("mask vars outside frame")
        return " & ".join(terms)

    # -- statement emission ---------------------------------------------
    def emit_raised(self, u: RaisedUnit) -> None:
        stmt = u.stmt
        try:
            self._emit_raised_fast(stmt)
        except (RaiseError, EmitError):
            if self.backend == "jnp":
                raise EmitError("loop fallback infeasible on accelerator")
            self._emit_loops(stmt)
        if stmt.write_full or stmt.write_is_temp:
            self.define_syms_for(stmt.write_array)

    def _emit_raised_fast(self, stmt: CanonStmt) -> None:
        dims = self.free_dims(stmt)
        hull = compute_hull(dims)
        if self.jit_iter:
            # a bound depending on the pfor var would become a traced
            # slice extent — shapes must stay static under jit
            gv = self.jit_pfor_var
            for d in dims:
                if gv in d.lower.vars() or gv in d.upper.vars():
                    raise EmitError("jit: pfor-var-dependent bound")
        # frame follows the WRITE's dim order (cov[j][i] = f(i,j) must
        # emit the rhs transposed), then any remaining domain iterators
        domain_order = [d.var for d in dims]
        write_order: List[str] = []
        for idx in stmt.write_idx:
            for v in idx.vars():
                if v in domain_order and v not in write_order:
                    write_order.append(v)
        frame = tuple(write_order +
                      [v for v in domain_order if v not in write_order])
        rhs = normalize(stmt.rhs)
        plan = plan_write(stmt, hull)
        expr = self.emit_expr(rhs, frame, hull)

        arr = stmt.write_array
        if plan.kind in ("full", "scalar"):
            # whole-name assignment inside a chunk body binds a body
            # local (privatization) — later partial writes to it take
            # the functional path in hybrid mode. That path emits
            # ``.at[]``, so hybrid locals must *be* jnp values even when
            # the defining expression is pure numpy arithmetic over
            # captured arrays — force the conversion at the definition
            # (free for values that are already jnp).
            self.body_locals.add(arr)
            self._note_assign(arr)
            if stmt.aug is None:
                rhs_src = expr
            else:
                rhs_src = f"{arr} {stmt.aug} ({expr})"
            if self.store_np_captured:
                rhs_src = f"{self.xp}.asarray({rhs_src})"
            self.w(f"{arr} = {rhs_src}")
            return

        if plan.kind == "diag":
            if self.jit_iter:
                raise EmitError("jit: diagonal write")
            v = frame[0]
            iv = self.fresh("ix")
            self.w(f"{iv} = {self.xp}.arange({affine_py(hull.lo[v])}, "
                   f"{affine_py(hull.hi[v])})")
            comps = []
            for idx in stmt.write_idx:
                ivars = [x for x in idx.vars() if x in frame]
                if ivars:
                    rest = idx.drop(ivars)
                    off = f" + {affine_py(rest)}" if not rest.is_zero() \
                        else ""
                    comps.append(f"{iv}{off}")
                else:
                    comps.append(affine_py(idx))
            tgt = f"{arr}[{', '.join(comps)}]"
            self._store(arr, ", ".join(comps), tgt, expr, stmt.aug)
            return

        # slice / masked
        warr, widx = arr, list(stmt.write_idx)
        if self.jit_iter:
            warr, widx = self._jit_rebase(arr, widx, is_write=True)
            if arr not in self.body_locals and arr not in \
                    self.jit_write_arrays:
                self.jit_write_arrays.append(arr)
        comps = []
        for idx in widx:
            ivars = [x for x in idx.vars() if x in frame]
            if not ivars:
                comps.append(affine_py(idx))
                continue
            v = ivars[0]
            rest = idx.drop([v])
            comps.append(f"{affine_py(hull.lo[v] + rest)}:"
                         f"{affine_py(hull.hi[v] + rest)}")
        sl = ", ".join(comps)
        tgt = f"{warr}[{sl}]" if sl else warr
        if plan.kind == "slice":
            self._store(warr, sl, tgt, expr, stmt.aug)
        else:  # masked
            mask = self.write_mask_expr(plan.conds, frame, hull)
            mv = self.fresh("m")
            self.w(f"{mv} = {mask}")
            if stmt.aug is None:
                combined = expr
            else:
                combined = f"{tgt} {stmt.aug} ({expr})"
            where = f"{self.xp}.where({mv}, {combined}, {tgt})"
            self._store(warr, sl, tgt, where, None)

    def _store(self, arr: str, sl: str, tgt: str, expr: str,
               aug: Optional[str]) -> None:
        self._note_assign(arr)
        if self.backend == "np" or (self.store_np_captured
                                    and arr not in self.body_locals):
            # hybrid jnp body: partial writes to *captured* arrays stay
            # in-place numpy stores (device→host at the boundary) so the
            # worker's sparse-diff gather sees them unchanged
            if self.backend != "np":
                expr = f"xp.asarray({expr})"
            if aug is None:
                self.w(f"{tgt} = {expr}")
            else:
                self.w(f"{tgt} {aug}= {expr}")
        elif not sl:
            # whole-value store on a row variable (jit-iteration mode):
            # a plain functional rebind
            if aug is None:
                self.w(f"{arr} = {expr}")
            elif aug in ("+", "*"):
                self.w(f"{arr} = {arr} {aug} ({expr})")
            else:
                raise EmitError(f"aug {aug} on accelerator")
        else:
            if aug is None:
                self.w(f"{arr} = {arr}.at[{sl}].set({expr})")
            elif aug == "+":
                self.w(f"{arr} = {arr}.at[{sl}].add({expr})")
            elif aug == "*":
                self.w(f"{arr} = {arr}.at[{sl}].multiply({expr})")
            else:
                raise EmitError(f"aug {aug} on accelerator")

    # -- loop fallback (np backend only) -----------------------------------
    def _emit_loops(self, stmt: CanonStmt) -> None:
        self.meta.jax_ok = False
        self.meta.raised_ops.append("loop-fallback")
        rhs = normalize(stmt.rhs)
        # A raised statement is atomic: the rhs is fully evaluated before
        # the store. A scalar loop nest loses that when the rhs reads the
        # written array at *other* elements (fusion builds such
        # statements, e.g. A[...] = dot(A[...], C)), so snapshot the
        # array and read the copy instead.
        self_reads = [
            acc for acc in vexpr_accesses(rhs)
            if acc.array == stmt.write_array
            and (len(acc.idx) != len(stmt.write_idx)
                 or any(not ia.equals(iw)
                        for ia, iw in zip(acc.idx, stmt.write_idx)))]
        if self_reads:
            snap = self.fresh("snap")
            self.w(f"{snap} = {self.xp}.array({stmt.write_array})")
            rhs = substitute_array_reads(
                rhs, stmt.write_array,
                lambda acc: VAccess(snap, acc.idx, acc.dtype))
        dims = self.free_dims(stmt)
        for d in dims:
            self.w(f"for {d.var} in range({affine_py(d.lower)}, "
                   f"{affine_py(d.upper)}, {d.step}):")
            self.depth += 1
        expr = self._scalar_expr(rhs)
        comps = [affine_py(i) for i in stmt.write_idx]
        if stmt.write_full or stmt.write_is_temp or not comps:
            tgt = stmt.write_array
        else:
            tgt = f"{stmt.write_array}[{', '.join(comps)}]"
        if stmt.aug is None:
            self.w(f"{tgt} = {expr}")
        else:
            self.w(f"{tgt} {stmt.aug}= {expr}")
        self.depth -= len(dims)

    def _scalar_expr(self, e: VExpr) -> str:
        if isinstance(e, VConst):
            return repr(e.value)
        if isinstance(e, VParam):
            return e.name
        if isinstance(e, VUnary):
            inner = self._scalar_expr(e.operand)
            if e.fn == "-":
                return f"(-{inner})"
            return f"{self.xp}.{e.fn[3:]}({inner})" if e.fn.startswith("np.") \
                else f"{e.fn}({inner})"
        if isinstance(e, VBin):
            l, r = self._scalar_expr(e.left), self._scalar_expr(e.right)
            if e.op.startswith("np."):
                return f"{self.xp}.{e.op[3:]}({l}, {r})"
            return f"({l} {e.op} {r})"
        if isinstance(e, VAccess):
            comps = [affine_py(i) for i in e.idx]
            return f"{e.array}[{', '.join(comps)}]" if comps else e.array
        if isinstance(e, VReduce):
            # emit an inline generator-sum (slow but correct)
            gens = "".join(
                f" for {d.var} in range({affine_py(d.lower)}, "
                f"{affine_py(d.upper)}, {d.step})" for d in e.dims)
            return f"sum({self._scalar_expr(e.child)}{gens})"
        raise EmitError(type(e).__name__)

    # -- other units ------------------------------------------------------
    def emit_fft(self, u: FFTUnit) -> None:
        st = u.stmt
        if self.jit_iter and st.src not in self.body_locals:
            if st.src in self.jit_rows:
                raise EmitError("jit: whole-array access after row capture")
            self._jit_param(st.src)
        axis = st.axis if st.axis is not None else -1
        n = f", n={affine_py(st.n)}" if st.n is not None else ""
        fn = f"{self.xp}.fft." + st.fn.split(".")[-1]
        self.body_locals.add(st.out)   # whole-name rebind (privatized)
        self._note_assign(st.out)
        self.w(f"{st.out} = {fn}({st.src}{n}, axis={axis})")
        self.meta.raised_ops.append("fft")
        self.define_syms_for(st.out)

    def emit_opaque(self, u: OpaqueUnit) -> None:
        if self.backend == "jnp":
            raise EmitError("black-box statement: accelerator infeasible")
        self.meta.jax_ok = False
        for s in u.item.stmts:
            for line in unparse_tir(s):
                self.w(line)

    def emit_seq_loop(self, u: SeqLoopUnit) -> None:
        if self.jit_iter:
            self._emit_jit_seq_loop(u)
            return
        d = u.dim
        self.w(f"for {d.var} in range({affine_py(d.lower)}, "
               f"{affine_py(d.upper)}, {d.step}):")
        self.depth += 1
        self.bound.add(d.var)
        if not u.body:
            self.w("pass")
        for b in u.body:
            self.emit_unit(b)
        self.bound.discard(d.var)
        self.depth -= 1

    def _emit_jit_seq_loop(self, u: SeqLoopUnit) -> None:
        """Sequential loop inside the jit-iteration function →
        ``lax.fori_loop`` with an explicit carry tuple: unrolling a
        long convergence loop (STAP runs 800 Richardson steps) would
        explode XLA compile time.

        Two passes: probe-emit the body as straight-line code to learn
        which names it assigns, then wrap those lines in a fori body
        function threading every previously-defined assigned name as
        carry. Names first defined inside the loop must not escape it —
        if a later unit reads one, the jit bails (eager fallback)."""
        d = u.dim
        if d.step != 1:
            raise EmitError("jit: non-unit sequential loop step")
        if not u.body:
            return
        defined_before = (set(self.body_locals)
                          | set(self.jit_rows.values())
                          | set(self._assign_log))
        log_at = len(self._assign_log)
        save_lines = list(self.lines)
        save_depth = self.depth
        save_bound = set(self.bound)
        pre_rows = len(self.jit_rows)
        body_at = len(self.lines)
        self._jit_loop_depth += 1
        self.bound.add(d.var)
        try:
            self._emit_jit_units(u.body)
        finally:
            self._jit_loop_depth -= 1
        body_lines = self.lines[body_at:]
        self.lines = save_lines
        self.depth = save_depth
        self.bound = save_bound

        # rows first captured during this loop hoist above it (their
        # preludes are in _jit_hoist), so they count as defined-before
        defined_before.update(list(self.jit_rows.values())[pre_rows:])
        if self._jit_loop_depth == 0 and self._jit_hoist:
            for ln in self._jit_hoist:
                self.w(ln)
            self._jit_hoist = []

        assigned_in = list(dict.fromkeys(self._assign_log[log_at:]))
        carry = [n for n in assigned_in if n in defined_before]
        fresh = [n for n in assigned_in
                 if n not in defined_before and not n.startswith("__")]
        if not carry:
            raise EmitError("jit: sequential loop carries no state")
        if fresh:
            escapes = set(fresh) & self._unit_reads(self._jit_future)
            if escapes:
                raise EmitError(
                    f"jit: loop-local names escape: {sorted(escapes)}")

        cs = ", ".join(carry) + ","
        cv = self.fresh("c")
        fv = self.fresh("fori")
        self.w(f"{cv} = ({cs})")
        self.w(f"def {fv}({d.var}, __c):")
        self.w(f"    ({cs}) = __c")
        pad = "    "
        self.lines.extend(pad + ln for ln in body_lines)
        self.w(f"    return ({cs})")
        self.w(f"{cv} = __jax.lax.fori_loop({affine_py(d.lower)}, "
               f"{affine_py(d.upper)}, {fv}, {cv})")
        self.w(f"({cs}) = {cv}")

    def _emit_jit_units(self, units: Sequence[Unit]) -> None:
        """Emit a unit list keeping ``_jit_future`` pointed at every
        unit that still runs after the current one (loop escape
        analysis needs the full continuation, not just siblings)."""
        outer = self._jit_future
        for i, b in enumerate(units):
            self._jit_future = list(units[i + 1:]) + outer
            self.emit_unit(b)
        self._jit_future = outer

    def _unit_reads(self, units: Sequence[Unit]) -> Set[str]:
        """Every name (array, scalar, iterator bound) a unit list might
        read — conservative, for loop-local escape analysis."""
        names: Set[str] = set()

        def expr(e: VExpr) -> None:
            if isinstance(e, VAccess):
                names.add(e.array)
                for idx in e.idx:
                    names.update(idx.vars())
            elif isinstance(e, VBin):
                expr(e.left)
                expr(e.right)
            elif isinstance(e, VUnary):
                expr(e.operand)
            elif isinstance(e, VReduce):
                for d in e.dims:
                    names.update(d.lower.vars())
                    names.update(d.upper.vars())
                expr(e.child)
            elif isinstance(e, VParam):
                names.add(e.name)

        def unit(u: Unit) -> None:
            if isinstance(u, RaisedUnit):
                st = u.stmt
                expr(st.rhs)
                names.add(st.write_array)  # read-modify on partial writes
                for idx in st.write_idx:
                    names.update(idx.vars())
                for d in st.domain.dims:
                    names.update(d.lower.vars())
                    names.update(d.upper.vars())
            elif isinstance(u, FFTUnit):
                names.add(u.stmt.src)
                names.add(u.stmt.out)
                if u.stmt.n is not None:
                    names.update(u.stmt.n.vars())
            elif isinstance(u, SeqLoopUnit):
                names.update(u.dim.lower.vars())
                names.update(u.dim.upper.vars())
                for b in u.body:
                    unit(b)
            else:
                raise EmitError("jit: opaque unit in continuation")

        for u in units:
            unit(u)
        return names

    def _emit_pfor_body(self, u: PforUnit, body_name: str) -> None:
        """One chunk-body function executing iterations [lo, hi)."""
        self.w(f"def {body_name}(__lo, __hi):")
        self.depth += 1
        self._emit_pfor_loop(u)
        self.depth -= 1

    def _emit_pfor_loop(self, u: PforUnit) -> None:
        d = u.dim
        self.w(f"for {d.var} in range(__lo, __hi, {d.step}):")
        self.depth += 1
        self.bound.add(d.var)
        if not u.body:
            self.w("pass")
        for b in u.body:
            self.emit_unit(b)
        self.bound.discard(d.var)
        self.depth -= 1

    def _emit_jit_iter(self, u: PforUnit, iter_name: str) -> None:
        """The per-iteration function for __pfor_jit: computes one pfor
        iteration g functionally and returns the written rows."""
        d = u.dim
        if d.step != 1:
            raise EmitError("jit: non-unit pfor step")
        if not u.body:
            raise EmitError("jit: empty pfor body")
        g = d.var
        entry_depth = self.depth
        self.depth += 1
        self.bound.add(g)
        self._jit_future = []
        self._emit_jit_units(u.body)
        if not self.jit_write_arrays:
            raise EmitError("jit: body writes no pfor rows")
        rows = ", ".join(self.jit_rows[a] for a in self.jit_write_arrays)
        self.w(f"return ({rows},)")
        self.bound.discard(g)
        self.depth = entry_depth
        params = ", ".join(self.jit_params)
        self.lines.insert(0, "    " * entry_depth
                          + f"def {iter_name}({g}, __offs, {params}):")

    def emit_pfor(self, u: PforUnit) -> None:
        if self.backend == "jnp":
            raise EmitError("pfor: accelerator variant not generated")
        self.meta.uses_pfor = True
        idx = self.meta.pfor_count
        self.meta.pfor_count += 1
        d = u.dim
        body_name = f"__pfor_body_{idx}"
        # the jnp twin re-emits the same units, so it needs the same
        # deferred shape symbols the np body is about to consume
        pending_before = {k: list(v) for k, v in self.pending_syms.items()}
        self._emit_pfor_body(u, body_name)
        # always emitted (even when empty) so the cluster runtime trusts
        # the body itself over any stale per-kernel fallback: these are
        # the arrays whose chunk rows alone satisfy every body access
        sliceable = tuple(getattr(u, "sliceable", ()) or ())
        self.w(f"{body_name}.__sliceable__ = {sliceable!r}")
        self.w(f"{body_name}.__backend__ = 'np'")
        # unit label: lets obs spans / trace rows name which pfor unit
        # of the kernel a chunk belongs to
        self.w(f"{body_name}.__unit__ = {idx}")
        if self.pfor_jnp and getattr(u, "jnp_feasible", True):
            # one twin per registered accelerator-feasible backend, in
            # registration order (jnp first keeps emitted source
            # byte-identical to the pre-registry pair for units no
            # other backend matches)
            for bk in backends_mod.twin_backends():
                twin_name = bk.emit_twin(self, u, body_name, idx,
                                         pending_before)
                if twin_name is None:
                    continue
                self.w(f"{twin_name}.__sliceable__ = {sliceable!r}")
                self.w(f"{twin_name}.__backend__ = '{bk.name}'")
                self.w(f"{twin_name}.__unit__ = {idx}")
                self.w(f"{body_name}.{bk.attr} = {twin_name}")
                self.meta.pfor_twin_units.setdefault(
                    bk.name, []).append(idx)
                if bk.name == "jnp":
                    self.meta.pfor_jnp_units.append(idx)
        tile = u.tile if u.tile is not None else "None"
        self.w(f"__pfor_run({body_name}, {affine_py(d.lower)}, "
               f"{affine_py(d.upper)}, {tile})")
        self.meta.raised_ops.append("pfor")

    def _try_emit_jnp_twin(self, u: PforUnit, body_name: str, idx: int,
                           pending_syms: Dict[str, List[str]]
                           ) -> Optional[str]:
        """Emit the accelerator twin of one pfor body, or None when the
        unit's body is jnp-infeasible (loop fallback / black box). The
        twin is a separate function scope, so its temp names and body
        locals are independent of the np body's.

        When the body additionally fits the stricter jit-iteration
        shape (pure row-parallel over the pfor var), the twin leads
        with a compiled fast path: a nested per-iteration function
        handed to __pfor_jit, which vmaps + jits it per pow2 iteration
        bucket and scatters the returned rows in place. The eager
        per-iteration loop stays behind it as the always-correct
        fallback (and as the path for workers without jax jit)."""
        jnp_name = f"{body_name}__jnp"
        sub = Emitter(self.s, "jnp")
        sub.xp = "__jxp"
        sub.store_np_captured = True
        sub.depth = self.depth + 1
        sub.bound = set(self.bound)
        sub.pending_syms = {k: list(v) for k, v in pending_syms.items()}
        try:
            sub._emit_pfor_loop(u)
        except (EmitError, RaiseError):
            return None

        jit = Emitter(self.s, "jnp")
        jit.xp = "__jxp"
        jit.jit_iter = True
        jit.jit_pfor_var = u.dim.var
        jit.depth = self.depth + 1
        jit.bound = set(self.bound)
        jit.pending_syms = {k: list(v) for k, v in pending_syms.items()}
        iter_name = f"__pfor_iter_{idx}"
        try:
            jit._emit_jit_iter(u, iter_name)
            jit_lines: Optional[List[str]] = jit.lines
        except (EmitError, RaiseError):
            jit_lines = None

        self.w(f"def {jnp_name}(__lo, __hi):")
        self.depth += 1
        if jit_lines:
            self.lines.extend(jit_lines)
            params = ", ".join(jit.jit_params)
            trail = "," if len(jit.jit_params) == 1 else ""
            wpos = tuple(jit.jit_params[a] for a in jit.jit_write_arrays)
            self.w(f"if __pfor_jit({iter_name}, __lo, __hi, "
                   f"({params}{trail}), {wpos!r}):")
            self.w("    return")
            self.meta.pfor_jit_units.append(idx)
        self.lines.extend(sub.lines)
        self.depth -= 1
        return jnp_name

    def emit_unit(self, u: Unit) -> None:
        if isinstance(u, RaisedUnit):
            self.emit_raised(u)
        elif isinstance(u, FFTUnit):
            self.emit_fft(u)
        elif isinstance(u, OpaqueUnit):
            self.emit_opaque(u)
        elif isinstance(u, SeqLoopUnit):
            self.emit_seq_loop(u)
        elif isinstance(u, PforUnit):
            self.emit_pfor(u)
        else:  # pragma: no cover
            raise TypeError(type(u))


# ---------------------------------------------------------------------------
# TIR unparse (black-box re-emission)
# ---------------------------------------------------------------------------

def unparse_expr(e: tir.Expr) -> str:
    if isinstance(e, tir.Const):
        return repr(e.value)
    if isinstance(e, tir.Name):
        return e.id
    if isinstance(e, tir.BinOp):
        return f"({unparse_expr(e.left)} {e.op} {unparse_expr(e.right)})"
    if isinstance(e, tir.UnaryOp):
        return f"(-{unparse_expr(e.operand)})"
    if isinstance(e, tir.Compare):
        return f"({unparse_expr(e.left)} {e.op} {unparse_expr(e.right)})"
    if isinstance(e, tir.Subscript):
        comps = []
        for i in e.indices:
            if isinstance(i, tir.IndexExpr):
                comps.append(unparse_expr(i.value))
            else:
                lo = unparse_expr(i.lo) if i.lo else ""
                hi = unparse_expr(i.hi) if i.hi else ""
                comps.append(f"{lo}:{hi}")
        return f"{unparse_expr(e.base)}[{', '.join(comps)}]"
    if isinstance(e, tir.Call):
        if e.fn == "method.T":
            return f"{unparse_expr(e.args[0])}.T"
        if e.fn == "method.shape":
            return f"{unparse_expr(e.args[0])}.shape"
        args = [unparse_expr(a) for a in e.args]
        if e.fn.startswith("method."):
            recv = args[0]
            rest = args[1:]
            call = f"{recv}.{e.fn[7:]}"
            args = rest
        elif e.fn.startswith("np."):
            call = "xp." + e.fn[3:]
        else:
            call = e.fn
        kw = [f"{k}={unparse_expr(v)}" for k, v in e.kwargs.items()]
        return f"{call}({', '.join(args + kw)})"
    raise EmitError(f"unparse {type(e).__name__}")


def unparse_tir(s: tir.Stmt, depth: int = 0) -> List[str]:
    pad = "    " * depth
    if isinstance(s, tir.Opaque):
        return [pad + ln for ln in s.src.splitlines()]
    if isinstance(s, tir.Assign):
        op = f"{s.aug}=" if s.aug else "="
        return [pad + f"{unparse_expr(s.target)} {op} "
                      f"{unparse_expr(s.value)}"]
    if isinstance(s, tir.For):
        step = unparse_expr(s.step) if s.step is not None else "1"
        out = [pad + f"for {s.var} in range({unparse_expr(s.lo)}, "
                     f"{unparse_expr(s.hi)}, {step}):"]
        for b in s.body:
            out.extend(unparse_tir(b, depth + 1))
        return out
    if isinstance(s, tir.If):
        out = [pad + f"if {unparse_expr(s.cond)}:"]
        for b in s.body:
            out.extend(unparse_tir(b, depth + 1))
        if s.orelse:
            out.append(pad + "else:")
            for b in s.orelse:
                out.extend(unparse_tir(b, depth + 1))
        return out
    if isinstance(s, tir.Return):
        return [pad + ("return" if s.value is None
                       else f"return {unparse_expr(s.value)}")]
    if isinstance(s, tir.ExprStmt):
        return [pad + unparse_expr(s.value)]
    raise EmitError(f"unparse stmt {type(s).__name__}")


# ---------------------------------------------------------------------------
# Whole-function assembly
# ---------------------------------------------------------------------------

@dataclass
class GeneratedVariant:
    source: str
    fn_name: str
    backend: str
    meta: EmitMeta
    returns_written: bool  # jnp variant returns tuple of written arrays
    written: List[str]


def generate(sched: Schedule, backend: str,
             pfor_jnp: bool = False) -> GeneratedVariant:
    """``pfor_jnp=True`` (np backend only) additionally emits a jnp twin
    for every accelerator-feasible pfor body — the per-unit backend
    variants the heterogeneous cluster routes between."""
    fn = sched.program.fn
    param_names = [n for n, _ in fn.params]
    em = Emitter(sched, backend)
    em.pfor_jnp = bool(pfor_jnp) and backend == "np"
    if sched.fusion is not None:
        em.meta.fused_units = sched.fusion.fused_units
        em.meta.contracted_arrays = list(sched.fusion.contracted_arrays)

    # Preamble: list→array conversion and shape symbols. Symbols for
    # arrays defined in the body are deferred until their definition.
    list_params = [n for n, t in fn.params if t.kind == "list"]
    array_params = [n for n, t in fn.params if t.is_array_like]
    for n in (array_params if backend == "jnp" else list_params):
        em.w(f"{n} = xp.asarray({n})")
    shape_syms = sorted({
        v
        for v in _all_affine_vars(sched)
        if "__d" in v
    })
    param_set = set(param_names)
    for sym in shape_syms:
        arr, d = sym.rsplit("__d", 1)
        if arr in param_set:
            em.w(f"{sym} = {arr}.shape[{d}]")
        else:
            em.pending_syms.setdefault(arr, []).append(sym)

    for u in sched.units:
        em.emit_unit(u)

    written_params = [wn for wn in sched.written if wn in param_names]
    if backend == "jnp":
        returned = written_params
    else:
        # np backend mutates ndarrays in place, but list-typed params were
        # converted to local arrays — return those for dispatcher copy-back
        returned = [wn for wn in written_params if wn in list_params]
    if returned:
        em.w("return (" + ", ".join(returned)
             + ("," if len(returned) == 1 else "") + ")")
    else:
        em.w("return None")

    name = f"{fn.name}__{backend}_opt"
    head = f"def {name}({', '.join(param_names)}):"
    src = head + "\n" + "\n".join(em.lines) + "\n"
    return GeneratedVariant(src, name, backend, em.meta,
                            bool(returned), returned)


def _all_affine_vars(sched: Schedule):
    out: Set[str] = set()

    def from_stmt(s: CanonStmt):
        for d in list(s.domain.dims) + list(s.reduce_dims()):
            out.update(d.lower.vars())
            out.update(d.upper.vars())
        for idx in s.write_idx:
            out.update(idx.vars())
        for acc_idx in _stmt_access_vars(s.rhs):
            out.update(acc_idx)

    def rec(units):
        for u in units:
            if isinstance(u, RaisedUnit):
                from_stmt(u.stmt)
            elif isinstance(u, FFTUnit):
                if u.stmt.n is not None:
                    out.update(u.stmt.n.vars())
            elif isinstance(u, (SeqLoopUnit, PforUnit)):
                out.update(u.dim.lower.vars())
                out.update(u.dim.upper.vars())
                rec(u.body)

    rec(sched.units)
    return out


def _stmt_access_vars(e: VExpr):
    if isinstance(e, VAccess):
        yield [v for idx in e.idx for v in idx.vars()]
    elif isinstance(e, VBin):
        yield from _stmt_access_vars(e.left)
        yield from _stmt_access_vars(e.right)
    elif isinstance(e, VUnary):
        yield from _stmt_access_vars(e.operand)
    elif isinstance(e, VReduce):
        for d in e.dims:
            yield list(d.lower.vars()) + list(d.upper.vars())
        yield from _stmt_access_vars(e.child)
