"""Worker-process main loop.

One worker = one OS process holding: a pipe back to the head, a local
object cache (its shard of the object plane), a cache of pfor body
blobs (skeleton + broadcast cells, assembled lazily), and the device
profile it measured at startup.

The loop is deliberately single-threaded: the head resolves every
object transfer *before* dispatching a task, so a worker never needs to
service a fetch while computing — no cross-worker deadlock is possible
by construction.

Wire protocol (pickled tuples over a ``multiprocessing`` connection —
the same framing a TCP transport would use):

  head → worker: ("task", tid, spec)
                 | ("blob", bid, skeleton_or_None, {cell: value})
                 | ("unblob", bid) | ("get", oid) | ("free", oid)
                 | ("ping", payload) | ("profile",) | ("shutdown",)
                 | ("rekey", authkey) | ("chaos", op, arg)
                 | ("welcome", wid) | ("denied", reason)   # handshake
  worker → head: ("hello", profile, t_mono)
                 | ("done", tid, oid, nbytes, payload, ran_backend,
                    spans_or_None, accel_stats_or_None)
                 | ("err", tid, message, traceback)
                 | ("obj", oid, payload) | ("pong", nbytes, t_mono)
                 | ("hb", t_mono)
                 | ("attach", wid, attempts) | ("join", sim_gpu)

where ``payload`` is ``("v", value)`` when the value travels with the
message and ``None`` when it stayed (or was not found) on the worker —
the wrapper keeps a task that legitimately *returns* ``None``
distinguishable from a result that was kept remote.

A "blob" message with ``skeleton=None`` is a *delta*: the worker already
holds the body's skeleton and receives only the cells whose content hash
changed on the head (the serving-loop path). Blob bodies persist across
pfor calls; after every chunk the written broadcast cells are rolled
back to pristine, so the head's record of what each worker holds stays
content-exact.

Tracing (``repro.obs``): when a task spec carries ``trace=True`` the
worker measures its execution phases — deserialize (body assembly),
restore (sliced-cell rebase), run, diff — as ``(name, t0, t1, args)``
tuples on its own ``time.perf_counter()`` clock and piggybacks them on
the "done" message; no extra round-trips. The ``t_mono`` stamp on
"hello"/"pong" replies is what lets the head estimate this worker's
clock offset and land the spans on one aligned timeline.
"""

from __future__ import annotations

import pickle
import sys
import threading
import time
import traceback
from typing import Any, Dict, Optional, Tuple

import numpy as np

from . import accel
from .device import measure_profile
from .serial import assemble_fn, closure_arrays, loads_fn, rebase_chunk

# results at or below this many bytes ride back inline with "done"
INLINE_MAX = 32 * 1024


def _chunk_updates(body, lo: int, hi: int, written: Tuple[str, ...],
                   spans=None) -> Dict[str, tuple]:
    """Run a pfor chunk and extract its disjoint-region writes.

    The chunk writes in place into the *worker's* copies of the captured
    arrays; the head needs (indices, values) per written array to merge
    into the real ones. ``written`` (from the kernel's schedule) narrows
    the diff to arrays the pfor body can write; when empty we
    conservatively diff every captured array. Sliced arrays hold only
    the chunk's rows, so their update indices are chunk-local — the head
    re-bases them during the gather.

    Written arrays are rolled back to their pre-run contents afterwards
    (success *or* failure): cached broadcast cells must stay equal to
    what the head last shipped for the changed-cells-only protocol to be
    sound, and a retried chunk must never diff against a previous
    attempt's partial writes."""
    arrays = {n: v for n, v in closure_arrays(body).items()
              if isinstance(v, np.ndarray)}
    targets = {n: a for n, a in arrays.items()
               if not written or n in written}
    snaps = {n: a.copy() for n, a in targets.items()}
    try:
        t0 = time.perf_counter()
        body(lo, hi)
        t1 = time.perf_counter()
        if spans is not None:
            spans.append(("run", t0, t1, None))
        updates: Dict[str, tuple] = {}
        for name, arr in targets.items():
            mask = np.asarray(arr != snaps[name])
            if mask.any():
                idx = np.flatnonzero(mask.ravel())
                updates[name] = (idx, np.asarray(arr.ravel()[idx]))
        if spans is not None:
            spans.append(("diff", t1, time.perf_counter(), None))
        return updates
    finally:
        for name, arr in targets.items():
            np.copyto(np.asarray(arr), snaps[name])


class WorkerState:
    def __init__(self, wid: int, sim_gpu: bool = False):
        self.wid = wid
        self.sim_gpu = sim_gpu    # pose as a GPU worker (hetero CI/demo)
        self.objects: Dict[int, Any] = {}     # local object-plane shard
        self.blob_skel: Dict[int, bytes] = {}
        self.blob_cells: Dict[int, Dict[str, Any]] = {}
        self.bodies: Dict[int, tuple] = {}    # bid → (fn, name→cell)
        # (bid, name, lo, hi) → cached chunk rows: the head skips
        # re-shipping rows whose content hash it already sent here
        self.sliced_rows: Dict[tuple, np.ndarray] = {}
        self.tasks_run = 0
        self.chunks_run = 0

    # -- blob cache --------------------------------------------------------
    def update_blob(self, bid: int, skeleton, delta: Dict[str, bytes]
                    ) -> None:
        """Install a blob skeleton and/or changed broadcast cells. The
        delta carries the head's per-cell pickles (the exact bytes it
        content-hashed), so what this worker holds is byte-equal to the
        head's bookkeeping."""
        if skeleton is not None:
            self.blob_skel[bid] = skeleton
            self.bodies.pop(bid, None)   # re-assemble with the new code
            self.blob_cells[bid] = {}
        cells = self.blob_cells.setdefault(bid, {})
        entry = self.bodies.get(bid)
        for name, pkl in delta.items():
            val = pickle.loads(pkl)
            cells[name] = val
            if isinstance(val, np.ndarray):
                # broadcast cells persist across chunk tasks (rollback
                # keeps them pristine), so their device copies can too
                accel.remember(val)
            if entry is not None and name in entry[1]:
                # live body: swap the changed cell in place
                entry[1][name].cell_contents = val

    def drop_blob(self, bid: int) -> None:
        self.blob_skel.pop(bid, None)
        self.blob_cells.pop(bid, None)
        self.bodies.pop(bid, None)
        for key in [k for k in self.sliced_rows if k[0] == bid]:
            del self.sliced_rows[key]

    def _body_for(self, bid: int) -> tuple:
        entry = self.bodies.get(bid)
        if entry is None:
            skel = self.blob_skel.get(bid)
            if skel is None:
                # the marker tells the head its shipped-state record for
                # us is stale (dropped blob message / restarted worker):
                # it resets the record so the retry re-ships in full
                raise KeyError(f"blob-missing:{bid}")
            entry = assemble_fn(skel, self.blob_cells[bid])
            self.bodies[bid] = entry
        return entry

    # -- task execution ---------------------------------------------------
    def resolve_args(self, wire_args) -> list:
        out = []
        for entry in wire_args:
            kind = entry[0]
            if kind == "val":
                out.append(entry[1])
            elif kind == "obj":            # value attached by the head
                # deliberately NOT cached: the head only ever resolves
                # ("loc", oid) against objects this worker *produced*,
                # so retaining relayed args would only leak memory
                out.append(entry[2])
            elif kind == "loc":            # resident here already
                out.append(self.objects[entry[1]])
            else:  # pragma: no cover
                raise ValueError(f"bad arg entry {kind!r}")
        return out

    def run_task(self, spec, spans=None) -> Any:
        if spec["kind"] == "chunk":
            lo, hi = spec["lo"], spec["hi"]
            bid = spec["blob_id"]
            t0 = time.perf_counter()
            body, cellmap = self._body_for(bid)
            t1 = time.perf_counter()
            for name, wire in (spec.get("sliced") or {}).items():
                # per-chunk rows, re-based so the body's global leading-
                # axis indices resolve. ("rows", arr) carries fresh rows
                # (cached for next time); ("keep",) means the head's
                # content hash matched what it last shipped for this
                # exact range — rollback keeps the cached copy pristine,
                # so reuse is byte-exact
                if wire[0] == "keep":
                    rows = self.sliced_rows.get((bid, name, lo, hi))
                    if rows is None:
                        # stale head record (restart/drop): the marker
                        # makes the head reset it and re-ship in full
                        raise KeyError(f"rows-missing:{bid}")
                else:
                    rows = wire[1]
                    self.sliced_rows[(bid, name, lo, hi)] = rows
                    accel.remember(rows)
                cellmap[name].cell_contents = rebase_chunk(rows, lo)
            if spans is not None:
                spans.append(("deserialize", t0, t1, None))
                spans.append(("restore", t1, time.perf_counter(), None))
            self.chunks_run += 1
            return _chunk_updates(body, lo, hi,
                                  tuple(spec.get("written") or ()),
                                  spans)
        fn = loads_fn(spec["fn_blob"])
        args = self.resolve_args(spec["args"])
        self.tasks_run += 1
        t0 = time.perf_counter()
        result = fn(*args)
        if spans is not None:
            spans.append(("run", t0, time.perf_counter(), None))
        return result


def _make_link(conn, wid: Optional[int], sim_gpu: bool):
    """Build the transport link: an inherited pipe connection, or a
    ``("tcp", address, authkey)`` endpoint the worker dials (and
    re-dials, with exponential backoff) itself."""
    from .transport import PipeLink, ReconnectingClient
    if isinstance(conn, tuple) and conn and conn[0] == "tcp":
        _, address, authkey = conn
        link = ReconnectingClient(address, authkey, wid=wid,
                                  sim_gpu=sim_gpu)
        link.connect()   # attach/join handshake resolves our wid
        return link
    return PipeLink(conn)


def worker_main(conn, wid: Optional[int] = None, sim_gpu: bool = False,
                hb_interval_s: float = 0.0) -> None:
    """Entry point of the worker process. ``conn`` is an inherited pipe
    connection or a ``("tcp", (host, port), authkey)`` endpoint (the
    multi-host path — also reachable via ``python -m
    repro.distrib.worker --connect host:port --authkey <hex>`` from any
    machine). ``sim_gpu`` makes the profile pose as a GPU worker
    (jax-CPU execution) so heterogeneous routing is exercisable on
    GPU-less hosts; the env var ``REPRO_DISTRIB_SIM_GPU`` (see
    :mod:`.device`) does the same by wid.

    With ``hb_interval_s > 0`` a daemon thread sends ``("hb", t_mono)``
    liveness beacons; they are ``droppable`` — a disconnected TCP window
    simply skips beats rather than queueing a burst for later."""
    from .transport import WorkerFencedError
    try:
        link = _make_link(conn, wid, sim_gpu)
    except (WorkerFencedError, OSError, EOFError):
        return   # head unreachable or this wid is fenced: nothing to do
    wid = getattr(link, "wid", wid) if wid is None else wid
    state = WorkerState(wid, sim_gpu=sim_gpu)
    stop = threading.Event()
    hb_silenced = threading.Event()   # chaos: hang with silent beacons

    def _heartbeat() -> None:
        while not stop.wait(hb_interval_s):
            if hb_silenced.is_set():
                continue
            link.send(("hb", time.perf_counter()), droppable=True)

    if hb_interval_s and hb_interval_s > 0:
        threading.Thread(target=_heartbeat, name=f"worker-hb-{wid}",
                         daemon=True).start()
    try:
        # the perf_counter stamp rides right next to the send so the
        # head's receive-time-minus-stamp offset estimate is bounded by
        # one one-way pipe latency, not by profile-measurement time
        link.send(("hello",
                   measure_profile(wid, sim_gpu=sim_gpu or None)
                   .as_dict(), time.perf_counter()))
    except (EOFError, OSError, BrokenPipeError):
        stop.set()
        return
    slow_s = 0.0   # chaos: injected per-task latency
    while True:
        try:
            msg = link.recv()
        except (EOFError, OSError):
            break  # head is gone (or this link is fenced)
        kind = msg[0]
        try:
            if kind == "task":
                _, tid, spec = msg
                if slow_s > 0:
                    time.sleep(slow_s)
                spans = [] if spec.get("trace") else None
                try:
                    result = state.run_task(spec, spans)
                except BaseException as exc:  # noqa: BLE001
                    link.send(("err", tid, repr(exc),
                               traceback.format_exc()))
                    continue
                oid = spec["out_oid"]
                nbytes = int(getattr(result, "nbytes", 0) or 0)
                # chunk dones echo which body backend actually *ran* —
                # the head's executed-chunk telemetry must not trust
                # dispatch intent (a jnp chunk may have been downgraded
                # and re-run as np)
                ran = (spec.get("backend", "np")
                       if spec["kind"] == "chunk" else None)
                # chunk dones also carry the accel counter deltas
                # (jit hits/recompiles, residency — plus the pallas
                # runtime's call counters when a pallas twin imported
                # it; sys.modules avoids dragging jax into pure-np
                # workers) for head aggregation
                wstats = (accel.take_stats()
                          if spec["kind"] == "chunk" else None)
                if wstats is not None:
                    plk = sys.modules.get("repro.kernels.api")
                    if plk is not None:
                        wstats.update(plk.take_stats())
                if spec.get("gather") or nbytes <= INLINE_MAX:
                    link.send(("done", tid, oid, nbytes, ("v", result),
                               ran, spans, wstats))
                else:
                    state.objects[oid] = result
                    link.send(("done", tid, oid, nbytes, None, ran,
                               spans, wstats))
            elif kind == "blob":
                _, bid, skeleton, delta = msg
                state.update_blob(bid, skeleton, delta)
            elif kind == "unblob":
                state.drop_blob(msg[1])
            elif kind == "free":
                # ownership moved to the head (post-fetch): drop our copy
                state.objects.pop(msg[1], None)
            elif kind == "get":
                oid = msg[1]
                if oid in state.objects:
                    link.send(("obj", oid, ("v", state.objects[oid])))
                else:
                    link.send(("obj", oid, None))
            elif kind == "ping":
                link.send(("pong", len(msg[1]), time.perf_counter()))
            elif kind == "profile":
                # re-measure on request: the head serializes these so
                # fleet micro-benchmarks never contend with each other
                link.send(("hello",
                           measure_profile(state.wid,
                                           sim_gpu=state.sim_gpu or None)
                           .as_dict(), time.perf_counter()))
            elif kind == "rekey":
                # the head rotated the transport authkey; future
                # reconnects must present the new one
                link.set_authkey(msg[1])
            elif kind == "chaos":
                _, op, arg = msg
                if op == "hang":
                    arg = arg or {}
                    if arg.get("silence_hb", True):
                        hb_silenced.set()
                    secs = arg.get("seconds")
                    time.sleep(secs if secs is not None else 1e9)
                    hb_silenced.clear()
                elif op == "slow":
                    slow_s = float(arg or 0.0)
                elif op == "drop_conn":
                    link.drop()
                elif op == "babble":
                    # deliberately malformed: too short to unpack
                    link.send(("done",), droppable=True)
                elif op == "exit":
                    break
            elif kind == "shutdown":
                break
        except (EOFError, OSError, BrokenPipeError):
            break
    stop.set()
    link.close()


def _main() -> None:   # pragma: no cover - exercised via subprocess
    """CLI for joining a worker to a remote head over TCP:

        python -m repro.distrib.worker \\
            --connect HOST:PORT --authkey HEX [--sim-gpu] [--hb 1.0]
    """
    import argparse
    ap = argparse.ArgumentParser(description="join a cluster head")
    ap.add_argument("--connect", required=True, metavar="HOST:PORT")
    ap.add_argument("--authkey", required=True,
                    help="hex-encoded transport authkey")
    ap.add_argument("--sim-gpu", action="store_true")
    ap.add_argument("--hb", type=float, default=1.0,
                    help="heartbeat interval seconds (0 disables)")
    ns = ap.parse_args()
    host, _, port = ns.connect.rpartition(":")
    worker_main(("tcp", (host, int(port)), bytes.fromhex(ns.authkey)),
                wid=None, sim_gpu=ns.sim_gpu, hb_interval_s=ns.hb)


if __name__ == "__main__":   # pragma: no cover
    _main()
