"""Worker-process main loop.

One worker = one OS process holding: a pipe back to the head, a local
object cache (its shard of the object plane), a cache of deserialized
pfor body blobs, and the device profile it measured at startup.

The loop is deliberately single-threaded: the head resolves every
object transfer *before* dispatching a task, so a worker never needs to
service a fetch while computing — no cross-worker deadlock is possible
by construction.

Wire protocol (pickled tuples over a ``multiprocessing`` connection —
the same framing a TCP transport would use):

  head → worker: ("task", tid, spec) | ("blob", bid, bytes)
                 | ("unblob", bid) | ("get", oid) | ("free", oid)
                 | ("ping", payload) | ("profile",) | ("shutdown",)
  worker → head: ("hello", profile) | ("done", tid, oid, nbytes, payload)
                 | ("err", tid, message, traceback)
                 | ("obj", oid, payload) | ("pong", nbytes)

where ``payload`` is ``("v", value)`` when the value travels with the
message and ``None`` when it stayed (or was not found) on the worker —
the wrapper keeps a task that legitimately *returns* ``None``
distinguishable from a result that was kept remote.
"""

from __future__ import annotations

import traceback
from typing import Any, Dict, Tuple

import numpy as np

from .device import measure_profile
from .serial import closure_arrays, loads_fn

# results at or below this many bytes ride back inline with "done"
INLINE_MAX = 32 * 1024


def _chunk_updates(body, lo: int, hi: int,
                   written: Tuple[str, ...]) -> Dict[str, tuple]:
    """Run a pfor chunk and extract its disjoint-region writes.

    The chunk writes in place into the *worker's* copies of the captured
    arrays; the head needs (indices, values) per written array to merge
    into the real ones. ``written`` (from the kernel's schedule) narrows
    the diff to arrays the pfor body can write; when empty we
    conservatively diff every captured array."""
    arrays = {n: v for n, v in closure_arrays(body).items()
              if isinstance(v, np.ndarray)}
    targets = {n: a for n, a in arrays.items()
               if not written or n in written}
    snaps = {n: a.copy() for n, a in targets.items()}
    try:
        body(lo, hi)
    except BaseException:
        # roll the cached body's arrays back to pristine: a retry of
        # this chunk (possibly on this same worker) must not diff
        # against this attempt's partial writes — values equal to the
        # poisoned snapshot would silently vanish from the gather
        for name, arr in targets.items():
            np.copyto(arr, snaps[name])
        raise
    updates: Dict[str, tuple] = {}
    for name, arr in targets.items():
        mask = arr != snaps[name]
        if mask.any():
            idx = np.flatnonzero(mask.ravel())
            updates[name] = (idx, arr.ravel()[idx])
    return updates


class WorkerState:
    def __init__(self, wid: int):
        self.wid = wid
        self.objects: Dict[int, Any] = {}     # local object-plane shard
        self.bodies: Dict[int, Any] = {}      # blob_id → deserialized fn
        self.blob_bytes: Dict[int, bytes] = {}
        self.tasks_run = 0
        self.chunks_run = 0

    # -- task execution ---------------------------------------------------
    def resolve_args(self, wire_args) -> list:
        out = []
        for entry in wire_args:
            kind = entry[0]
            if kind == "val":
                out.append(entry[1])
            elif kind == "obj":            # value attached by the head
                # deliberately NOT cached: the head only ever resolves
                # ("loc", oid) against objects this worker *produced*,
                # so retaining relayed args would only leak memory
                out.append(entry[2])
            elif kind == "loc":            # resident here already
                out.append(self.objects[entry[1]])
            else:  # pragma: no cover
                raise ValueError(f"bad arg entry {kind!r}")
        return out

    def run_task(self, spec) -> Any:
        if spec["kind"] == "chunk":
            bid = spec["blob_id"]
            body = self.bodies.get(bid)
            if body is None:
                body = loads_fn(self.blob_bytes[bid])
                self.bodies[bid] = body
            self.chunks_run += 1
            return _chunk_updates(body, spec["lo"], spec["hi"],
                                  tuple(spec.get("written") or ()))
        fn = loads_fn(spec["fn_blob"])
        args = self.resolve_args(spec["args"])
        self.tasks_run += 1
        return fn(*args)


def worker_main(conn, wid: int) -> None:
    """Entry point of the spawned worker process."""
    state = WorkerState(wid)
    try:
        conn.send(("hello", measure_profile(wid).as_dict()))
    except (EOFError, OSError, BrokenPipeError):
        return
    while True:
        try:
            msg = conn.recv()
        except (EOFError, OSError):
            break  # head is gone
        kind = msg[0]
        try:
            if kind == "task":
                _, tid, spec = msg
                try:
                    result = state.run_task(spec)
                except BaseException as exc:  # noqa: BLE001
                    conn.send(("err", tid, repr(exc),
                               traceback.format_exc()))
                    continue
                oid = spec["out_oid"]
                nbytes = int(getattr(result, "nbytes", 0) or 0)
                if spec.get("gather") or nbytes <= INLINE_MAX:
                    conn.send(("done", tid, oid, nbytes, ("v", result)))
                else:
                    state.objects[oid] = result
                    conn.send(("done", tid, oid, nbytes, None))
            elif kind == "blob":
                _, bid, blob = msg
                state.blob_bytes[bid] = blob
            elif kind == "unblob":
                state.blob_bytes.pop(msg[1], None)
                state.bodies.pop(msg[1], None)
            elif kind == "free":
                # ownership moved to the head (post-fetch): drop our copy
                state.objects.pop(msg[1], None)
            elif kind == "get":
                oid = msg[1]
                if oid in state.objects:
                    conn.send(("obj", oid, ("v", state.objects[oid])))
                else:
                    conn.send(("obj", oid, None))
            elif kind == "ping":
                conn.send(("pong", len(msg[1])))
            elif kind == "profile":
                # re-measure on request: the head serializes these so
                # fleet micro-benchmarks never contend with each other
                conn.send(("hello", measure_profile(state.wid).as_dict()))
            elif kind == "shutdown":
                break
        except (EOFError, OSError, BrokenPipeError):
            break
    try:
        conn.close()
    except OSError:
        pass
