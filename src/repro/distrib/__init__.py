"""Multi-process cluster runtime (the paper's Ray deployment tier, §4.3).

raylite (:mod:`repro.runtime`) models a cluster with threads inside one
process; this package crosses real OS-process boundaries:

  * a **head** scheduler (:class:`ClusterRuntime`) spawns worker
    *processes* and talks to them over pipes or — with
    ``transport="tcp"`` — authenticated sockets workers can join from
    any host (:mod:`.transport`: authkey handshake + rotation,
    reconnect with exponential backoff, heartbeats, elastic
    join/drain); :mod:`.chaos` injects deterministic faults into all
    of it;
  * each worker measures a **device profile** at startup (CPU count,
    memory, matmul GFLOP/s, memory bandwidth, GPU presence) that feeds a
    **placement-aware scheduler** with data-locality affinity;
  * a serialized **object plane**: results live where they were produced
    (ObjectRef ownership), move on demand, and survive worker-process
    death via lineage replay;
  * ``pfor`` loops compiled by :func:`repro.core.compiler.optimize`
    shard dependence-free chunks across workers — chunk sizes
    proportional to measured capability — with disjoint-region writes
    gathered on the head.

    from repro.distrib import ClusterRuntime
    rt = ClusterRuntime(workers=4)
    ck = compile_kernel(stap_kernel, runtime=rt)   # pfor → processes
    ref = rt.submit(fn, *args)                     # or raw DAG tasks
    rt.get(ref)
"""

from .chaos import ChaosPlan, ChaosWire
from .cluster import ClusterRuntime, ClusterTaskError
from .device import DeviceProfile, measure_profile
from .objects import ClusterRef, ObjectMeta, ObjectPlane, TaskSpec
from .placement import PlacementScheduler, PlacementWeights, WorkerView
from .serial import (ChunkSlice, ClosureParts, assemble_fn, dumps_fn,
                     loads_fn, payload_split_nbytes, rebase_chunk,
                     split_fn)
from .transport import (HeadListener, PipeLink, ReconnectingClient,
                        WorkerFencedError, new_authkey)

__all__ = [
    "ChaosPlan", "ChaosWire", "ChunkSlice", "ClosureParts",
    "ClusterRuntime", "ClusterTaskError", "ClusterRef", "DeviceProfile",
    "HeadListener", "ObjectMeta", "ObjectPlane", "PipeLink",
    "PlacementScheduler", "PlacementWeights", "ReconnectingClient",
    "TaskSpec", "WorkerFencedError", "WorkerView", "assemble_fn",
    "dumps_fn", "loads_fn", "measure_profile", "new_authkey",
    "payload_split_nbytes", "rebase_chunk", "split_fn",
]
