"""Device-side acceleration for jnp twin chunk bodies.

``pfor_jit`` is the fast path stamped into every accelerator-feasible
pfor twin body: instead of dispatching one eager jnp op stream per pfor
iteration, the twin hands its per-iteration function here and we

  * vmap it over a pow2-bucketed iteration index (the profiler's bucket
    tiers, via :func:`repro.core.cost.pow2_bucket`), so a serving loop
    hits the same compiled executable on call 2 even when
    capability-proportional chunking jitters the chunk size;
  * jit-compile once per (iteration code, baked scalars, bucket, array
    signature) and cache the executable process-wide, with recompile /
    hit / fallback telemetry;
  * keep ``remember()``-ed host arrays (worker blob cells and cached
    chunk rows) device-resident between calls instead of re-staging
    through ``asarray`` every round;
  * scatter only the real rows ``[lo, hi)`` back into the captured
    numpy arrays, so the worker's sparse-diff gather sees exactly the
    writes the eager body would have made.

``pfor_jit`` returns False whenever anything — missing jax, an
unbakeable closure cell, a trace or run failure — prevents the compiled
path; the twin then falls through to its eager per-iteration loop,
which is always correct. Failures are negatively cached so a shape that
cannot trace pays the probe once, not every round.
"""

from __future__ import annotations

import os
import time
from collections import OrderedDict
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

__all__ = ["pfor_jit", "remember", "take_stats", "stats", "reset",
           "WIRE_STAT_KEYS"]

# Every counter key a worker may piggyback on a chunk "done" message —
# this module's jit/residency counters plus the pallas runtime's call
# counters (repro.kernels.api, drained the same way). The cluster's
# head-side aggregation derives its key set from this tuple, so adding
# a worker-side counter is a one-place change.
WIRE_STAT_KEYS = ("jit_hits", "jit_recompiles", "jit_fallbacks",
                  "jit_compile_s", "resident_hits", "resident_stages",
                  "resident_cells", "pallas_calls",
                  "pallas_interpret_calls")

# scalar types a closure cell may hold and still be baked into the
# compile-cache key (anything else → eager fallback)
_BAKEABLE = (int, float, complex, bool, str, bytes, type(None), np.generic)

_UNSET = object()
_JAX: Any = _UNSET

# (iter code, baked consts, bucket, array sig) → jitted callable, or
# None marking a combination that failed to trace/run (negative cache)
_COMPILED: Dict[tuple, Any] = {}

# (data ptr, shape, strides, dtype) → [host array (strong ref),
# {pad_rows: device array}]. Keyed by buffer layout, not object id,
# because chunk bodies see a *fresh* re-based view of the cached rows
# array every task — same buffer, new Python object. The strong ref
# pins the buffer so the pointer cannot be recycled by a different
# array while the entry lives; the LRU byte budget bounds how much
# host memory residency can pin.
_RESIDENT: "OrderedDict[tuple, List[Any]]" = OrderedDict()
_RESIDENT_BYTES = 0

_STATS: Dict[str, float] = {}


def _budget_bytes() -> int:
    try:
        mb = float(os.environ.get("REPRO_DISTRIB_RESIDENT_MB", "256"))
    except ValueError:
        mb = 256.0
    return int(mb * (1 << 20))


def _bump(key: str, val: float = 1) -> None:
    _STATS[key] = _STATS.get(key, 0) + val


def stats() -> Dict[str, float]:
    """Counters accumulated since the last :func:`take_stats`."""
    return dict(_STATS)


def take_stats() -> Dict[str, float]:
    """Drain and return the counter deltas ({} when nothing happened).

    The worker appends this to each chunk-task ``done`` message so the
    head can aggregate jit/residency telemetry fleet-wide.
    """
    out = dict(_STATS)
    _STATS.clear()
    return out


def reset() -> None:
    """Forget compiled executables, device residents, and counters
    (test isolation)."""
    global _RESIDENT_BYTES
    _COMPILED.clear()
    _RESIDENT.clear()
    _RESIDENT_BYTES = 0
    _STATS.clear()


def _jax():
    """jax with x64 enabled, or None when unavailable (cached)."""
    global _JAX
    if _JAX is not _UNSET:
        return _JAX
    try:
        import jax

        jax.config.update("jax_enable_x64", True)
        import jax.numpy  # noqa: F401  (force the submodule in)
    except Exception:
        _JAX = None
        return None
    _JAX = jax
    return jax


def remember(arr) -> None:
    """Register a host array as residency-eligible.

    Only arrays whose content is identity-stable between chunk tasks
    qualify: worker blob cells (replaced wholesale by ``update_blob``
    when they change) and cached chunk-row arrays (replaced when the
    head re-ships rows). The worker's snapshot/rollback in
    ``_chunk_updates`` guarantees the host copy is pristine again after
    every task, so a device copy staged once stays valid until the
    object itself is swapped out.
    """
    global _RESIDENT_BYTES
    if not isinstance(arr, np.ndarray) or arr.nbytes > _budget_bytes():
        return
    key = _reskey(arr)
    ent = _RESIDENT.get(key)
    if ent is not None:
        if ent[0] is arr:
            _RESIDENT.move_to_end(key)
            return
        # same layout, different object (pointer recycled after the old
        # entry's array died elsewhere): staged copies may be stale
        _RESIDENT_BYTES -= ent[0].nbytes
        del _RESIDENT[key]
    _RESIDENT[key] = [arr, {}]
    _RESIDENT_BYTES += arr.nbytes
    while _RESIDENT_BYTES > _budget_bytes() and len(_RESIDENT) > 1:
        _, old = _RESIDENT.popitem(last=False)
        _RESIDENT_BYTES -= old[0].nbytes


def _reskey(arr: np.ndarray) -> tuple:
    return (arr.__array_interface__["data"][0], arr.shape,
            arr.strides, str(arr.dtype))


def _stage(jax, jnp, raw: np.ndarray, pad_rows: int):
    dev = jax.device_put(raw)
    if pad_rows and raw.ndim and pad_rows > raw.shape[0]:
        widths = [(0, pad_rows - raw.shape[0])] + [(0, 0)] * (raw.ndim - 1)
        dev = jnp.pad(dev, widths)
    return dev


def _device_array(jax, jnp, host, sliced: bool, pad_rows: int):
    """Device handle for one captured array, through the residency
    cache when the underlying host buffer is registered."""
    raw = np.asarray(host)
    key = _reskey(raw)
    ent = _RESIDENT.get(key)
    if ent is not None:
        _RESIDENT.move_to_end(key)
        cache = ent[1]
        dev = cache.get(pad_rows)
        if dev is not None:
            _bump("resident_hits")
            return dev
        dev = _stage(jax, jnp, raw, pad_rows)
        if not cache:
            _bump("resident_cells")
        cache[pad_rows] = dev
        _bump("resident_stages")
        return dev
    _bump("resident_stages")
    return _stage(jax, jnp, raw, pad_rows)


def pfor_jit(iter_fn, lo: int, hi: int, arrays: Sequence[Any],
             write_pos: Sequence[int]) -> bool:
    """Run ``iter_fn(g, offs, *arrays)`` for every g in [lo, hi) as one
    vmapped, jit-compiled call, scattering the returned rows back into
    ``arrays[p]`` for each p in ``write_pos``.

    Returns True when the compiled path ran (the caller's eager loop
    must be skipped), False when the caller must fall back to it.
    """
    if os.environ.get("REPRO_DISTRIB_JIT", "1").lower() in ("0", "false"):
        return False
    n = int(hi) - int(lo)
    if n <= 0:
        return True
    jax = _jax()
    if jax is None:
        _bump("jit_fallbacks")
        return False
    jnp = jax.numpy

    # closure cells become baked constants of the compiled executable —
    # they are part of the cache key, so they must be hashable scalars
    consts: List[Any] = []
    for cell in (iter_fn.__closure__ or ()):
        try:
            v = cell.cell_contents
        except ValueError:
            _bump("jit_fallbacks")
            return False
        if not isinstance(v, _BAKEABLE):
            _bump("jit_fallbacks")
            return False
        consts.append(v)

    from repro.core.cost import pow2_bucket

    bucket = int(pow2_bucket(n)[1])

    sig: List[tuple] = []
    offs: List[int] = []
    devs: List[Any] = []
    try:
        for a in arrays:
            sliced = hasattr(a, "_chunk_base")
            base = int(getattr(a, "_chunk_base", 0) or 0)
            raw = np.asarray(a)
            pad_rows = bucket if (sliced and raw.ndim) else 0
            shape = raw.shape[1:] if (sliced and raw.ndim) else raw.shape
            sig.append((str(raw.dtype), tuple(shape), sliced))
            offs.append(base)
            devs.append(_device_array(jax, jnp, raw, sliced, pad_rows))
    except Exception:
        _bump("jit_fallbacks")
        return False

    key = (iter_fn.__code__, tuple(consts), bucket, tuple(sig))
    fn = _COMPILED.get(key, _UNSET)
    if fn is None:  # known-bad: failed to trace/run before
        _bump("jit_fallbacks")
        return False

    # padded lanes re-run the last real iteration (clip) — their rows
    # are computed and discarded, so pad rows of the inputs never feed a
    # result that survives the scatter below
    idx = jnp.clip(jnp.arange(lo, lo + bucket), lo, hi - 1)
    offs_arr = jnp.asarray(np.asarray(offs, dtype=np.int64))

    if fn is _UNSET:
        captured = iter_fn  # pin: later cache hits reuse this closure,
        # which is semantically identical (same code + same baked cells)

        def _run(idx, offs, *arrs):
            return jax.vmap(lambda g: captured(g, offs, *arrs))(idx)

        fn = jax.jit(_run)
        t0 = time.perf_counter()
        try:
            out = jax.block_until_ready(fn(idx, offs_arr, *devs))
        except Exception:
            _COMPILED[key] = None
            _bump("jit_fallbacks")
            return False
        _bump("jit_compile_s", time.perf_counter() - t0)
        _bump("jit_recompiles")
        _COMPILED[key] = fn
    else:
        try:
            out = jax.block_until_ready(fn(idx, offs_arr, *devs))
        except Exception:
            _bump("jit_fallbacks")
            return False
        _bump("jit_hits")

    outs = out if isinstance(out, tuple) else (out,)
    for pos, rows in zip(write_pos, outs):
        a = arrays[pos]
        off = int(getattr(a, "_chunk_base", 0) or 0)
        host = np.asarray(a)
        host[lo - off:hi - off] = np.asarray(rows[:n])
    return True
