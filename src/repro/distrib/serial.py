"""Function/closure serialization for cross-process task shipment.

``pickle`` refuses locally-defined functions and closures — exactly what
the generated ``__pfor_body_N`` chunk functions are. This module encodes a
function as:

  * its code object (``marshal`` — same interpreter on both ends, which
    the spawned-worker model guarantees);
  * its closure cell values (pickled — this is how the captured kernel
    arrays travel to the worker);
  * the globals it references, each as a module-by-name marker (``xp`` →
    re-import ``numpy`` on the worker), a pickled value, or the
    ``__pfor_run`` sentinel (a nested pfor inside a shipped chunk runs
    sequentially on the worker — one level of distribution is enough);
  * name / defaults.

Everything lands in one ``bytes`` blob; :func:`loads_fn` rebuilds a real
function with fresh cells on the receiving process.
"""

from __future__ import annotations

import importlib
import marshal
import pickle
import types
from typing import Any, Dict, List, Tuple

_PICKLE_PROTO = 4

# Global-slot markers
_MOD = "mod"        # re-import module by name
_VAL = "val"        # pickled value
_PFOR = "pfor"      # substitute the worker's sequential __pfor_run
_SKIP = "skip"      # unpicklable and unknown: leave unbound


def _sequential_pfor_run(body, lo, hi, tile):
    """Worker-side stand-in for nested pfor hooks: run the chunk inline
    (the head already sharded the outermost level across processes)."""
    if hi > lo:
        body(lo, hi)


def _referenced_globals(code) -> List[str]:
    """All global names a code object (or its nested code consts) loads."""
    names = list(code.co_names)
    for const in code.co_consts:
        if isinstance(const, types.CodeType):
            names.extend(_referenced_globals(const))
    seen, out = set(), []
    for n in names:
        if n not in seen:
            seen.add(n)
            out.append(n)
    return out


def dumps_fn(fn) -> bytes:
    """Encode a function — closures included — into a shippable blob."""
    code = fn.__code__
    cells: List[bytes] = []
    for cell in (fn.__closure__ or ()):
        cells.append(pickle.dumps(cell.cell_contents,
                                  protocol=_PICKLE_PROTO))
    gslots: Dict[str, Tuple[str, Any]] = {}
    for name in _referenced_globals(code):
        if name not in fn.__globals__:
            continue
        val = fn.__globals__[name]
        if name == "__pfor_run":
            gslots[name] = (_PFOR, None)
        elif isinstance(val, types.ModuleType):
            gslots[name] = (_MOD, val.__name__)
        else:
            try:
                gslots[name] = (_VAL, pickle.dumps(
                    val, protocol=_PICKLE_PROTO))
            except Exception:
                gslots[name] = (_SKIP, None)
    payload = {
        "code": marshal.dumps(code),
        "cells": cells,
        "freevars": code.co_freevars,
        "globals": gslots,
        "name": fn.__name__,
        "defaults": pickle.dumps(fn.__defaults__, protocol=_PICKLE_PROTO),
        "kwdefaults": pickle.dumps(fn.__kwdefaults__,
                                   protocol=_PICKLE_PROTO),
    }
    return pickle.dumps(payload, protocol=_PICKLE_PROTO)


def loads_fn(blob: bytes):
    """Rebuild a function serialized by :func:`dumps_fn`.

    The result carries fresh closure cells holding the *worker's* copies
    of the captured objects; ``fn.__closure__`` is the worker-side handle
    used to read arrays back out after a chunk runs."""
    payload = pickle.loads(blob)
    code = marshal.loads(payload["code"])
    g: Dict[str, Any] = {"__builtins__": __builtins__}
    for name, (kind, data) in payload["globals"].items():
        if kind == _MOD:
            g[name] = importlib.import_module(data)
        elif kind == _VAL:
            g[name] = pickle.loads(data)
        elif kind == _PFOR:
            g[name] = _sequential_pfor_run
        # _SKIP: unbound — a NameError on use is the honest failure mode
    cells = tuple(types.CellType(pickle.loads(c))
                  for c in payload["cells"])
    fn = types.FunctionType(code, g, payload["name"],
                            pickle.loads(payload["defaults"]), cells)
    kwdefaults = payload.get("kwdefaults")
    if kwdefaults is not None:
        fn.__kwdefaults__ = pickle.loads(kwdefaults)
    return fn


def closure_arrays(fn) -> Dict[str, Any]:
    """Name → value for every closure cell of ``fn`` (by free-var name)."""
    out: Dict[str, Any] = {}
    for name, cell in zip(fn.__code__.co_freevars, fn.__closure__ or ()):
        out[name] = cell.cell_contents
    return out


def payload_nbytes(fn) -> int:
    """Rough shipment size of a closure: bytes of captured ndarrays."""
    total = 0
    for v in closure_arrays(fn).values():
        nb = getattr(v, "nbytes", None)
        if nb is not None:
            total += int(nb)
    return total
