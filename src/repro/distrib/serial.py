"""Function/closure serialization for cross-process task shipment.

``pickle`` refuses locally-defined functions and closures — exactly what
the generated ``__pfor_body_N`` chunk functions are. This module encodes a
function as:

  * its code object (``marshal`` — same interpreter on both ends, which
    the spawned-worker model guarantees);
  * its closure cell values (pickled — this is how the captured kernel
    arrays travel to the worker);
  * the globals it references, each as a module-by-name marker (``xp`` →
    re-import ``numpy`` on the worker), a pickled value, or the
    ``__pfor_run`` sentinel (a nested pfor inside a shipped chunk runs
    sequentially on the worker — one level of distribution is enough);
  * name / defaults.

Everything lands in one ``bytes`` blob; :func:`loads_fn` rebuilds a real
function with fresh cells on the receiving process.

For pfor bodies the monolithic blob is additionally *split*
(:func:`split_fn`) into a content-hashed skeleton, individually hashed
broadcast cells, and live sliceable arrays whose chunk rows ship per
task — the decomposition behind the cluster's persistent blob cache and
chunk-sliced argument shipping (:class:`ClosureParts`,
:class:`ChunkSlice`).
"""

from __future__ import annotations

import hashlib
import importlib
import marshal
import pickle
import types
import weakref
from dataclasses import dataclass, field
from typing import Any, Dict, List, Sequence, Tuple

import numpy as np

_PICKLE_PROTO = 4

# Global-slot markers
_MOD = "mod"        # re-import module by name
_VAL = "val"        # pickled value
_PFOR = "pfor"      # substitute the worker's sequential __pfor_run
_JIT = "jit"        # substitute the worker's __pfor_jit fast path
_SKIP = "skip"      # unpicklable and unknown: leave unbound


def _sequential_pfor_run(body, lo, hi, tile):
    """Worker-side stand-in for nested pfor hooks: run the chunk inline
    (the head already sharded the outermost level across processes)."""
    if hi > lo:
        body(lo, hi)


def _referenced_globals(code) -> List[str]:
    """All global names a code object (or its nested code consts) loads."""
    names = list(code.co_names)
    for const in code.co_consts:
        if isinstance(const, types.CodeType):
            names.extend(_referenced_globals(const))
    seen, out = set(), []
    for n in names:
        if n not in seen:
            seen.add(n)
            out.append(n)
    return out


def _skeleton_dict(fn) -> Dict[str, Any]:
    """Everything shippable about a function *except* its cell values:
    code, free-var order, resolved globals, name, defaults."""
    code = fn.__code__
    gslots: Dict[str, Tuple[str, Any]] = {}
    for name in _referenced_globals(code):
        if name not in fn.__globals__:
            continue
        val = fn.__globals__[name]
        if name == "__pfor_run":
            gslots[name] = (_PFOR, None)
        elif name == "__pfor_jit":
            gslots[name] = (_JIT, None)
        elif isinstance(val, types.ModuleType):
            gslots[name] = (_MOD, val.__name__)
        else:
            try:
                gslots[name] = (_VAL, pickle.dumps(
                    val, protocol=_PICKLE_PROTO))
            except Exception:
                gslots[name] = (_SKIP, None)
    return {
        "code": marshal.dumps(code),
        "freevars": code.co_freevars,
        "globals": gslots,
        "name": fn.__name__,
        "defaults": pickle.dumps(fn.__defaults__, protocol=_PICKLE_PROTO),
        "kwdefaults": pickle.dumps(fn.__kwdefaults__,
                                   protocol=_PICKLE_PROTO),
    }


def _build_globals(payload: Dict[str, Any]) -> Dict[str, Any]:
    g: Dict[str, Any] = {"__builtins__": __builtins__}
    for name, (kind, data) in payload["globals"].items():
        if kind == _MOD:
            if data.split(".")[0] == "jax":
                # jnp twin bodies carry float64 semantics; the head
                # enabled x64 before generating them, so the worker must
                # match before jax traces anything (see compiler.py).
                # A worker that cannot enable x64 would silently compute
                # f32 results for f64 twins — that must surface as a
                # task error (the head counts it and downgrades this
                # worker's chunks to the np body via TaskSpec.alt), not
                # as quietly wrong numerics. Import failures fall
                # through to import_module below for the honest error.
                try:
                    import jax
                except Exception:
                    jax = None
                if jax is not None:
                    try:
                        jax.config.update("jax_enable_x64", True)
                    except Exception as exc:
                        raise RuntimeError(
                            f"x64-enable-failed: {exc!r}") from exc
            g[name] = importlib.import_module(data)
        elif kind == _VAL:
            g[name] = pickle.loads(data)
        elif kind == _PFOR:
            g[name] = _sequential_pfor_run
        elif kind == _JIT:
            from .accel import pfor_jit
            g[name] = pfor_jit
        # _SKIP: unbound — a NameError on use is the honest failure mode
    return g


def _make_fn(payload: Dict[str, Any], cells: Tuple) -> types.FunctionType:
    code = marshal.loads(payload["code"])
    fn = types.FunctionType(code, _build_globals(payload),
                            payload["name"],
                            pickle.loads(payload["defaults"]), cells)
    kwdefaults = payload.get("kwdefaults")
    if kwdefaults is not None:
        fn.__kwdefaults__ = pickle.loads(kwdefaults)
    return fn


def dumps_fn(fn) -> bytes:
    """Encode a function — closures included — into a shippable blob."""
    payload = _skeleton_dict(fn)
    payload["cells"] = [pickle.dumps(cell.cell_contents,
                                     protocol=_PICKLE_PROTO)
                        for cell in (fn.__closure__ or ())]
    return pickle.dumps(payload, protocol=_PICKLE_PROTO)


def loads_fn(blob: bytes):
    """Rebuild a function serialized by :func:`dumps_fn`.

    The result carries fresh closure cells holding the *worker's* copies
    of the captured objects; ``fn.__closure__`` is the worker-side handle
    used to read arrays back out after a chunk runs."""
    payload = pickle.loads(blob)
    cells = tuple(types.CellType(pickle.loads(c))
                  for c in payload["cells"])
    return _make_fn(payload, cells)


# ---------------------------------------------------------------------------
# Chunk-sliced shipment (the data-movement layer)
# ---------------------------------------------------------------------------

class ChunkSlice(np.ndarray):
    """Rows ``[base, base+n)`` of a larger array, indexed with *global*
    leading-axis coordinates.

    A pfor chunk body generated for iterations ``[lo, hi)`` indexes its
    sliceable arrays as ``arr[v, ...]`` with ``v`` in the global range;
    the worker only holds the shipped rows, so the leading index is
    re-based by ``-base`` on the way in. Derived views and arithmetic
    results reset ``base`` to 0 (``__array_finalize__``), so only the
    explicitly wrapped top-level cell re-bases. Out-of-chunk accesses
    raise rather than wrap around — the sliceability analysis proves they
    cannot happen, so one firing means a miscompile, not silent
    corruption."""

    _chunk_base = 0

    def __array_finalize__(self, obj):
        self._chunk_base = 0

    def _rebase(self, key):
        base = self._chunk_base
        if not base:
            return key
        if isinstance(key, tuple):
            return (self._rebase0(key[0], base),) + key[1:]
        return self._rebase0(key, base)

    @staticmethod
    def _rebase0(k, base):
        if isinstance(k, (int, np.integer)):
            j = int(k) - base
            if j < 0:
                raise IndexError(
                    f"chunk-sliced access to row {int(k)} below chunk "
                    f"base {base} (sliceability misclassification?)")
            return j
        if isinstance(k, slice):
            lo = None if k.start is None else k.start - base
            hi = None if k.stop is None else k.stop - base
            if (lo is not None and lo < 0) or (hi is not None and hi < 0):
                raise IndexError(
                    f"chunk-sliced access {k} below chunk base {base}")
            return slice(lo, hi, k.step)
        raise IndexError(
            f"chunk-sliced array indexed by {type(k).__name__} on the "
            f"leading axis (only the pfor iterator is provably in-chunk)")

    # both accessors go through a base-class view: ndarray.__setitem__
    # on a subclass re-enters the Python-level __getitem__ with the
    # already-rebased key (numpy's subview assignment path), which would
    # rebase twice. The plain view also means directly indexed results
    # are ordinary ndarrays — only the top-level cell re-bases.
    def __getitem__(self, key):
        return self.view(np.ndarray)[self._rebase(key)]

    def __setitem__(self, key, value):
        self.view(np.ndarray)[self._rebase(key)] = value


def rebase_chunk(arr: np.ndarray, base: int) -> ChunkSlice:
    """Wrap a shipped chunk so global leading-axis indices resolve."""
    view = arr.view(ChunkSlice)
    view._chunk_base = int(base)
    return view


def _hash(data: bytes) -> str:
    return hashlib.sha256(data).hexdigest()


# skeleton bytes/hash per code object: a serving loop re-creates the
# same pfor body closure every call, and re-pickling the (identical)
# skeleton per dispatch is pure hot-path waste. Only cacheable when the
# skeleton is a pure function of the code object — no pickled-value
# globals and no defaults, which generated pfor bodies satisfy (their
# globals are module markers and the __pfor_run sentinel).
_SKELETON_CACHE: "weakref.WeakKeyDictionary" = weakref.WeakKeyDictionary()


def _skeleton_for(fn) -> Tuple[bytes, str]:
    code = fn.__code__
    hit = _SKELETON_CACHE.get(code)
    if hit is not None:
        return hit
    d = _skeleton_dict(fn)
    blob = pickle.dumps(d, protocol=_PICKLE_PROTO)
    h = _hash(blob)
    if (fn.__defaults__ is None and fn.__kwdefaults__ is None
            and all(kind != _VAL for kind, _ in d["globals"].values())):
        _SKELETON_CACHE[code] = (blob, h)
    return blob, h


@dataclass
class ClosureParts:
    """A pfor body decomposed for slice-aware, cache-aware shipment.

    ``skeleton`` (code + globals + defaults, no cell values) broadcasts
    once per worker and is content-addressed by ``code_hash``;
    ``cell_pkls`` are the broadcast cells, individually pickled and
    hashed so a serving loop re-ships only the ones that changed;
    ``sliced`` keeps live references to the sliceable arrays — each chunk
    task ships just its ``[lo, hi)`` rows of them. ``backend`` tags which
    body variant the skeleton encodes ("np" or "jnp"); backend twins of
    the same pfor close over the same cells, so
    :func:`split_fn_variants` builds their parts sharing one
    content-addressed cell store (each cell pickled and hashed once)."""

    skeleton: bytes
    code_hash: str
    struct_sig: str
    cell_pkls: Dict[str, bytes] = field(default_factory=dict)
    cell_hashes: Dict[str, str] = field(default_factory=dict)
    sliced: Dict[str, np.ndarray] = field(default_factory=dict)
    backend: str = "np"

    @property
    def blob_key(self) -> Tuple[str, str]:
        return (self.code_hash, self.struct_sig)

    def broadcast_nbytes(self) -> int:
        return len(self.skeleton) + sum(
            len(b) for b in self.cell_pkls.values())


def split_fn(fn, sliceable: Sequence[str] = (),
             backend: str = "np",
             _cell_memo: Dict[int, Tuple[bytes, str]] = None
             ) -> ClosureParts:
    """Decompose a closure into skeleton + per-cell payloads.

    Cells named in ``sliceable`` that hold ndarrays stay live (shipped
    per chunk as row slices); every other cell is pickled and
    content-hashed for the changed-cells-only re-ship protocol.
    ``_cell_memo`` (id(value) → (pickle, hash)) lets backend twins of
    one body share the pickling work — see :func:`split_fn_variants`."""
    skeleton, code_hash = _skeleton_for(fn)
    sliceable = set(sliceable)
    memo = _cell_memo if _cell_memo is not None else {}
    sig_parts: List[str] = []
    cell_pkls: Dict[str, bytes] = {}
    cell_hashes: Dict[str, str] = {}
    sliced: Dict[str, np.ndarray] = {}

    def pickled(val) -> Tuple[bytes, str]:
        hit = memo.get(id(val))
        if hit is None:
            pkl = pickle.dumps(val, protocol=_PICKLE_PROTO)
            hit = (pkl, _hash(pkl))
            memo[id(val)] = hit
        return hit

    for name, val in closure_arrays(fn).items():
        if (name in sliceable and isinstance(val, np.ndarray)
                and val.ndim >= 1):
            sliced[name] = val
            sig_parts.append(f"{name}:S{val.shape}:{val.dtype}")
        elif isinstance(val, np.ndarray):
            cell_pkls[name], cell_hashes[name] = pickled(val)
            sig_parts.append(f"{name}:B{val.shape}:{val.dtype}")
        else:
            cell_pkls[name], cell_hashes[name] = pickled(val)
            sig_parts.append(f"{name}:v{type(val).__name__}")
    return ClosureParts(skeleton=skeleton, code_hash=code_hash,
                        struct_sig=";".join(sig_parts),
                        cell_pkls=cell_pkls, cell_hashes=cell_hashes,
                        sliced=sliced, backend=backend)


def split_fn_variants(bodies: Dict[str, Any],
                      sliceable: Sequence[str] = ()
                      ) -> Dict[str, ClosureParts]:
    """Backend → ClosureParts for the variant bodies of one pfor.

    Backend names must be registered (:mod:`repro.core.backends`) — the
    bodies dict is keyed by codegen's ``__backend__`` stamps, which the
    registry produced, so an unknown key here means a mismatched or
    hand-rolled body and is worth failing loudly over.

    Twin bodies are closures over the *same* enclosing scope, so their
    cells hold identical objects — each value is pickled and hashed once
    and the resulting content-addressed entries are shared across the
    per-backend parts (persistent-blob reuse survives backend tagging)."""
    from repro.core import backends as _backends

    unknown = [bk for bk in bodies if not _backends.is_registered(bk)]
    if unknown:
        raise ValueError(
            f"unregistered backend name(s) {unknown} in variant bodies "
            f"(registered: {_backends.names()})")
    memo: Dict[int, Tuple[bytes, str]] = {}
    return {bk: split_fn(fn, sliceable, backend=bk, _cell_memo=memo)
            for bk, fn in bodies.items()}


def assemble_fn(skeleton: bytes, cell_values: Dict[str, Any]):
    """Worker-side: rebuild a function from a shipped skeleton plus cell
    values by name. Names absent from ``cell_values`` (the sliced arrays,
    delivered per chunk) get empty cells to be filled before each run.

    Returns ``(fn, cellmap)`` where ``cellmap`` maps free-var name → cell
    object, the mutation handle for changed-cell updates and per-chunk
    slice installation."""
    payload = pickle.loads(skeleton)
    cellmap: Dict[str, Any] = {}
    cells = []
    for name in payload["freevars"]:
        cell = (types.CellType(cell_values[name])
                if name in cell_values else types.CellType())
        cellmap[name] = cell
        cells.append(cell)
    return _make_fn(payload, tuple(cells)), cellmap


def payload_split_nbytes(fn, sliceable: Sequence[str] = ()
                         ) -> Tuple[int, int]:
    """(broadcast_bytes, sliced_bytes) of a closure's captured ndarrays.

    Broadcast arrays ship once *per worker*; sliced arrays ship once
    *total* (each worker gets its rows) — the cost model weighs them
    accordingly."""
    sliceable = set(sliceable)
    bcast = sliced = 0
    for name, v in closure_arrays(fn).items():
        nb = getattr(v, "nbytes", None)
        if nb is None:
            continue
        if name in sliceable and getattr(v, "ndim", 0) >= 1:
            sliced += int(nb)
        else:
            bcast += int(nb)
    return bcast, sliced


def closure_arrays(fn) -> Dict[str, Any]:
    """Name → value for every closure cell of ``fn`` (by free-var name)."""
    out: Dict[str, Any] = {}
    for name, cell in zip(fn.__code__.co_freevars, fn.__closure__ or ()):
        out[name] = cell.cell_contents
    return out


