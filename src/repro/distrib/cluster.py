"""ClusterRuntime: head scheduler over spawned worker processes.

The multi-process generalization of :class:`repro.runtime.tasks.
TaskRuntime` (raylite). Same duck-typed surface the compiled kernels
use — ``submit`` / ``get`` / ``wait`` / ``stats`` — plus the
``pfor_shards`` protocol :mod:`repro.core.pfor` dispatches to when its
runtime crosses process boundaries:

  * workers are real OS processes (``multiprocessing`` transport, fork
    or spawn), each reporting a measured :class:`DeviceProfile`;
  * placement goes through :class:`PlacementScheduler` — capability +
    data-locality − load — and pfor chunks are sized proportional to
    each worker's measured GFLOP/s (heterogeneous fleets get uneven,
    balanced-by-time chunks);
  * the object plane keeps results where they were produced and moves
    them on demand; every task's serialized spec is its lineage record,
    so objects lost to a worker-process death are replayed on the
    survivors (``kill_worker`` + ``get`` is the recovery drill);
  * data movement is slice-aware: arrays the schedule proves are indexed
    only by the pfor var on their leading axis ship as per-chunk row
    slices (``payload / n_workers`` each) instead of broadcasting, and
    pfor bodies persist on the workers under content-addressed blob ids
    so a serving loop re-ships only the cells that changed
    (``sliced_args`` / ``blob_hits`` / ``cells_skipped`` telemetry);
  * ``cache_dir`` points the runtime at a (shareable) variant-cache
    directory so a fleet of runtimes warm-starts compilation from one
    store (:meth:`compile`).
"""

from __future__ import annotations

import hashlib
import itertools
import logging
import multiprocessing as mp
import os
import signal
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro import obs

from repro.core import backends as backends_mod

from .accel import WIRE_STAT_KEYS as accel_wire_stat_keys
from .chaos import ChaosPlan, ChaosWire
from .device import DeviceProfile, measure_profile, sim_gpu_for
from .objects import (HEAD, LOST, REMOTE, ClusterRef, ObjectPlane,
                      TaskSpec)
from .placement import PlacementScheduler, PlacementWeights, WorkerView
from .serial import (ClosureParts, closure_arrays, dumps_fn,
                     split_fn_variants)
from .transport import HeadListener

log = logging.getLogger("repro.distrib")

# worker errors carrying this marker mean "I don't hold that body blob"
# (a dropped/evicted blob message): the head resets its shipped-state
# bookkeeping for the worker so the resubmit re-ships in full
BLOB_MISSING = "blob-missing"

# worker errors carrying this marker mean "I don't hold chunk rows you
# told me to keep" (restarted worker / dropped rows cache): the head
# forgets its shipped-rows records for the worker so retries re-ship
ROWS_MISSING = "rows-missing"

# a worker whose jax cannot enable float64 raises this marker instead
# of silently running the jnp twin in f32; the retry downgrades to np
X64_FAILED = "x64-enable-failed"


class ClusterTaskError(RuntimeError):
    pass


@dataclass
class _BlobRec:
    """One persistent pfor-body identity: (code hash, cell struct sig) →
    a stable blob id the workers cache under. ``seq`` orders LRU
    eviction; per-worker shipped state lives on the worker handles (it
    must die with them)."""

    bid: int
    key: tuple
    seq: int = 0
    # latest ClosureParts seen for this identity, kept so a joining or
    # respawned worker can be pre-warmed with the serving loop's hot
    # bodies (bounded by the blob cache's LRU cap)
    parts: Optional[ClosureParts] = None


@dataclass
class _TaskErr:
    message: str
    traceback: str = ""

    def __str__(self) -> str:
        return self.message


@dataclass
class _TaskState:
    spec: TaskSpec
    wid: Optional[int] = None
    finished: bool = False
    error: Optional[str] = None
    event: threading.Event = field(default_factory=threading.Event)
    # tracing: the in-flight span begun at dispatch (ended by whichever
    # thread observes completion — obs tokens are end-idempotent, so a
    # resubmit racing its own late "done" records the span once) and
    # the base args stamped onto this chunk's worker-side spans
    token: Any = None
    span_meta: Optional[Dict[str, Any]] = None
    # active liveness: optional wall deadline for each dispatch of this
    # task, monotonic stamp of the last dispatch, and the wids that have
    # already run (or hung on) it — deadline expiry resubmits elsewhere
    deadline_s: Optional[float] = None
    dispatched_at: Optional[float] = None
    tried: List[int] = field(default_factory=list)


class _WorkerHandle:
    def __init__(self, wid: int, proc, conn, sim_gpu: bool = False):
        self.wid = wid
        self.proc = proc          # None for externally-joined workers
        self.conn = conn          # None while a TCP worker is attaching
        self.sim_gpu = sim_gpu   # respawns inherit the GPU pose
        self.profile: Optional[DeviceProfile] = None
        self.hello = threading.Event()
        # head_perf_counter − worker_perf_counter, estimated from the
        # t_mono stamps piggybacked on hello/pong replies (see
        # note_clock); None until the first stamped reply lands
        self.clock_offset: Optional[float] = None
        self.alive = True
        self.draining = False   # clean scale-down, not a failure
        self.drain_sent = False  # monitor sent the drain-shutdown once
        # liveness bookkeeping: monotonic stamp of the last message seen
        # from this worker (any kind — a busy worker's "done" counts as
        # proof of life), and — TCP only — the monotonic instant at
        # which a lost connection stops being "suspect, may reconnect"
        # and becomes a death
        self.last_msg = time.monotonic()
        self.suspect_deadline: Optional[float] = None
        self.no_grace = False   # heartbeat expiry: skip reconnect grace
        self.inflight: set = set()
        self.blobs: set = set()                    # bids with skeleton
        self.blob_cells: Dict[int, Dict[str, str]] = {}  # bid→cell→hash
        # (bid, name, lo, hi) → content hash of the chunk rows last
        # shipped there: a serving loop re-dispatching the same range
        # with unchanged rows sends a ("keep",) marker instead
        self.sliced_rows: Dict[tuple, str] = {}
        # the hello carrying a failed-GPU-probe reason is counted into
        # the faults scope once per worker, not once per re-profile
        self.gpu_probe_fault_counted = False
        self.send_lock = threading.Lock()

    def note_clock(self, t_worker: float) -> None:
        """Refine this worker's clock offset from one stamped reply.
        ``recv_time − t_worker`` over-estimates the true offset by
        exactly the reply's one-way latency, so the *minimum* across
        samples (startup hello, every profile/ping handshake) is the
        tightest estimate — error bounded by the best observed one-way
        trip, well inside the handshake RTT."""
        off = time.perf_counter() - t_worker
        if self.clock_offset is None or off < self.clock_offset:
            self.clock_offset = off

    def send(self, msg) -> None:
        with self.send_lock:
            if self.conn is None:
                raise OSError(f"worker {self.wid} not attached")
            try:
                self.conn.send(msg)
            except TypeError as exc:
                # mp.Connection.close() nulls its handle without a lock;
                # a send racing a concurrent close can reach os.write
                # with handle=None → "TypeError: 'NoneType' object
                # cannot be interpreted as an integer". The connection
                # is dead either way — surface it as the OSError every
                # caller already handles (tracked flaky, pre-PR5).
                raise OSError(f"connection closed under send: {exc}")

    def close_conn(self) -> None:
        """Close the link without racing an in-flight :meth:`send` (the
        lock serializes us behind it; later sends fail cleanly)."""
        with self.send_lock:
            if self.conn is None:
                return
            try:
                self.conn.close()
            except OSError:
                pass
            self.conn = None

    def forget_blobs(self) -> None:
        """Reset the shipped-state bookkeeping — the worker told us it
        does not hold a blob we think it has (a chaos-dropped blob
        message, or a reconnect after a worker-side restart). The next
        :meth:`ship_blob` re-sends skeleton + every cell."""
        with self.send_lock:
            self.blobs.clear()
            self.blob_cells.clear()
            self.sliced_rows.clear()

    def ship_blob(self, bid: int, parts: ClosureParts) -> "Tuple[int, int]":
        """Bring this worker's cached copy of blob ``bid`` up to date:
        skeleton if it never saw the body, plus exactly the broadcast
        cells whose content hash changed since the last ship. Atomic
        under the send lock so concurrent dispatchers of the same blob
        don't double-ship (and so the blob always precedes the task
        message that references it on the pipe). Returns
        ``(cells_shipped, bytes_shipped)``."""
        with self.send_lock:
            shipped = self.blob_cells.setdefault(bid, {})
            need_skel = bid not in self.blobs
            delta = {nm: pkl for nm, pkl in parts.cell_pkls.items()
                     if shipped.get(nm) != parts.cell_hashes[nm]}
            if not need_skel and not delta:
                return 0, 0
            if self.conn is None:
                raise OSError(f"worker {self.wid} not attached")
            skel = parts.skeleton if need_skel else None
            self.conn.send(("blob", bid, skel, delta))
            self.blobs.add(bid)
            for nm in delta:
                shipped[nm] = parts.cell_hashes[nm]
            return len(delta), (len(skel or b"")
                                + sum(len(p) for p in delta.values()))


class ClusterRuntime:
    """Head process of the multi-process cluster.

    Telemetry counters below are class-level :class:`obs.MetricAttr`
    descriptors: the attribute reads/writes every existing call site
    (and test) uses are unchanged, but the values live in the unified
    ``obs.metrics`` registry under this instance's ``cluster#N`` scope —
    one store for stats(), bench rows, and traces."""

    replays = obs.MetricAttr("replays")
    resubmits = obs.MetricAttr("resubmits")
    worker_deaths = obs.MetricAttr("worker_deaths")
    pfor_runs = obs.MetricAttr("pfor_runs")
    chunks_dispatched = obs.MetricAttr("chunks_dispatched")
    bytes_shipped = obs.MetricAttr("bytes_shipped")
    gpu_chunks = obs.MetricAttr("gpu_chunks")
    cpu_chunks = obs.MetricAttr("cpu_chunks")
    # chunks shipped with a pallas-lowered body, and chunks that fell
    # off the pallas step of a TaskSpec.alt degradation chain
    pallas_chunks = obs.MetricAttr("pallas_chunks")
    pallas_fallbacks = obs.MetricAttr("pallas_fallbacks")
    sliced_args = obs.MetricAttr("sliced_args")
    bytes_saved_sliced = obs.MetricAttr("bytes_saved_sliced")
    blob_hits = obs.MetricAttr("blob_hits")
    blob_misses = obs.MetricAttr("blob_misses")
    cells_shipped = obs.MetricAttr("cells_shipped")
    cells_skipped = obs.MetricAttr("cells_skipped")
    rows_skipped = obs.MetricAttr("rows_skipped")
    bytes_saved_rows = obs.MetricAttr("bytes_saved_rows")
    # worker-side accel counters, aggregated off chunk "done" messages
    jit_hits = obs.MetricAttr("jit_hits")
    jit_recompiles = obs.MetricAttr("jit_recompiles")
    jit_fallbacks = obs.MetricAttr("jit_fallbacks")
    jit_compile_s = obs.MetricAttr("jit_compile_s")
    resident_hits = obs.MetricAttr("resident_hits")
    resident_stages = obs.MetricAttr("resident_stages")
    resident_cells = obs.MetricAttr("resident_cells")
    pallas_calls = obs.MetricAttr("pallas_calls")
    pallas_interpret_calls = obs.MetricAttr("pallas_interpret_calls")

    # keys of the per-chunk accel stats dict the head aggregates
    # (declared by the accel module so worker-side counters — jit,
    # residency, pallas kernel calls — stay a one-place change)
    _ACCEL_KEYS = accel_wire_stat_keys

    def __init__(self, workers: int = 2, *,
                 start_method: Optional[str] = None,
                 max_attempts: int = 3,
                 respawn: bool = True,
                 cache_dir: Optional[str] = None,
                 weights: PlacementWeights = PlacementWeights(),
                 hello_timeout_s: float = 30.0,
                 sim_gpu_workers: Sequence[int] = (),
                 trace=None,
                 transport: str = "pipe",
                 address: Tuple[str, int] = ("127.0.0.1", 0),
                 authkey: Optional[bytes] = None,
                 hb_interval_s: float = 1.0,
                 hb_miss_budget: int = 15,
                 reconnect_grace_s: float = 3.0,
                 task_deadline_s: Optional[float] = None,
                 quorum: int = 1,
                 degrade_local: bool = True,
                 pipeline_depth: int = 2,
                 np_only: bool = False,
                 chaos: Optional[ChaosPlan] = None):
        if start_method is None:
            # GPU-capable workers (real or posing) may execute jnp twin
            # bodies, and XLA does not survive a fork of a head that has
            # already touched jax — those fleets must spawn fresh
            # interpreters. CPU-only fleets keep the fast fork default.
            gpu_possible = (bool(sim_gpu_workers)
                            or os.environ.get("REPRO_DISTRIB_SIM_GPU")
                            or os.environ.get("REPRO_DISTRIB_PROBE_GPU")
                            == "1")
            if gpu_possible:
                start_method = "spawn"
            else:
                start_method = ("fork"
                                if "fork" in mp.get_all_start_methods()
                                else "spawn")
        self._ctx = mp.get_context(start_method)
        self.start_method = start_method
        self.max_attempts = max_attempts
        self.respawn = respawn
        if transport not in ("pipe", "tcp"):
            raise ValueError(f"unknown transport {transport!r}")
        self.transport = transport
        self.hb_interval_s = hb_interval_s
        self.hb_miss_budget = hb_miss_budget
        self.reconnect_grace_s = reconnect_grace_s
        self.task_deadline_s = task_deadline_s
        self.quorum = max(1, quorum)
        self.degrade_local = degrade_local
        # pfor pipelining: each worker's iteration share splits into
        # this many sub-chunks, gathered as-completed — ship(k+1) and
        # gather(k-1) overlap compute(k). Depth 1 restores the
        # one-chunk-per-worker synchronous round.
        self.pipeline_depth = max(1, int(pipeline_depth))
        # np_only suppresses jnp twin routing (every chunk runs the np
        # body) — the control arm for hetero speedup comparisons
        self.np_only = bool(np_only)
        self.chaos = chaos
        self.listener: Optional[HeadListener] = None
        self.address: Optional[Tuple[str, int]] = None
        # bounded journal of fault events (death/respawn/rejoin/replay/
        # degrade…) for the chaos-drill artifact beside BENCH_distrib
        self.fault_events: List[Dict[str, Any]] = []
        self._fenced_wids: set = set()
        self.plane = ObjectPlane()
        self.scheduler = PlacementScheduler(weights)
        self._lock = threading.Lock()
        self._handles: Dict[int, _WorkerHandle] = {}
        self._tasks: Dict[int, _TaskState] = {}
        self._producer: Dict[int, int] = {}     # oid → producing task
        self._task_ids = itertools.count(1)
        self._wids = itertools.count(0)
        self._blob_ids = itertools.count(1)
        # persistent body-blob identities: a serving loop calling the
        # same compiled kernel re-ships only changed cells, never the
        # skeleton (LRU-capped; per-worker shipped state is on handles)
        self._blob_cache: Dict[tuple, _BlobRec] = {}
        self._blob_seq = itertools.count(1)
        self.max_cached_blobs = 32
        self._fetch_events: Dict[int, threading.Event] = {}
        self._pongs: Dict[int, "threading.Event"] = {}
        self._shutdown = False
        # tracing: ``trace`` is False/None (off unless REPRO_TRACE=1),
        # True, or a path — a path additionally exports the Chrome
        # trace there at shutdown
        self._trace_path = trace if isinstance(trace, str) else None
        if trace:
            obs.enable()
        self.trace = obs.enabled() if trace is None else bool(trace)
        # unified metrics: this runtime's scope in the obs registry; the
        # MetricAttr class descriptors above resolve against it, so it
        # must exist before the zeroing assignments below
        self._mscope = obs.metrics.unique_scope("cluster")
        self._phase = self._mscope.sub("phase")
        # fault-event counters (cluster#N.faults.*): every recovery path
        # increments here so drills/CI can assert "recovery happened"
        self._faults = self._mscope.sub("faults")
        self._round_seq = itertools.count()
        self._round_busy: Dict[int, float] = {}     # round → worker-busy s
        self._round_compute: Dict[int, float] = {}  # round → Σ run-span s
        # telemetry
        self.replays = 0
        self.resubmits = 0
        self.worker_deaths = 0
        self.pfor_runs = 0
        self.chunks_dispatched = 0
        self.bytes_shipped = 0
        # heterogeneous routing telemetry: chunks dispatched per chosen
        # body backend, per-pfor-body backend mix, and — the ground
        # truth — chunks whose "done" message confirmed execution per
        # backend (dispatch intent can be overtaken by an error-path
        # downgrade)
        self.gpu_chunks = 0            # chunks dispatched on the jnp twin
        self.cpu_chunks = 0            # chunks dispatched on the np body
        self.unit_backend = self._mscope.dictmetric("unit_backend")
        self.chunks_executed = self._mscope.dictmetric("chunks_executed")
        # rebalance visibility: chunks confirmed executed per worker id —
        # a mid-loop join shows up as a new key accumulating its
        # capability-proportional share
        self.chunks_executed_by_worker = \
            self._mscope.dictmetric("chunks_executed_by_worker")
        # data-movement telemetry (chunk slicing + blob cache)
        self.sliced_args = 0           # array args shipped as row slices
        self.bytes_saved_sliced = 0    # vs shipping each chunk the whole
        self.blob_hits = 0             # pfor calls reusing a cached body
        self.blob_misses = 0
        self.cells_shipped = 0         # broadcast cells actually sent
        self.cells_skipped = 0         # unchanged cells NOT re-sent
        self.rows_skipped = 0          # sliced chunk rows NOT re-sent
        self.bytes_saved_rows = 0      # vs re-shipping them every round
        # device-acceleration telemetry (worker accel counters riding
        # back on chunk "done" messages)
        self.jit_hits = 0              # compiled twin executions
        self.jit_recompiles = 0        # fresh XLA compilations
        self.jit_fallbacks = 0         # eager-loop fallbacks
        self.jit_compile_s = 0.0       # seconds spent compiling
        self.resident_hits = 0         # device arrays reused in place
        self.resident_stages = 0       # host→device stagings performed
        self.resident_cells = 0        # distinct arrays made resident
        # head-local capability (the "stay local" side of profitability)
        self.local_profile = measure_profile(-1)
        self.variant_cache = None
        if cache_dir is not None:
            from repro.profiler.cache import VariantCache
            self.variant_cache = VariantCache(cache_dir)
        if transport == "tcp":
            self.listener = HeadListener(address, authkey=authkey)
            self.address = self.listener.address
            threading.Thread(target=self._accept_loop,
                             name="cluster-accept", daemon=True).start()
        sim_set = set(sim_gpu_workers)
        for i in range(workers):
            self._spawn_worker(sim_gpu=i in sim_set)
        self._await_hellos(hello_timeout_s)
        self._reprofile_sequentially()
        self._measure_transport()
        # liveness + deadline monitor (no-op work on an idle pipe fleet)
        threading.Thread(target=self._monitor_loop,
                         name="cluster-monitor", daemon=True).start()

    # -- worker lifecycle -------------------------------------------------
    def _fault_event(self, kind: str, **detail) -> None:
        """Count one fault/recovery event (``cluster#N.faults.<kind>``)
        and journal it (bounded) for the chaos-drill artifact."""
        self._faults.inc(kind, 1)
        ev = {"t": time.monotonic(), "kind": kind}
        ev.update(detail)
        with self._lock:
            self.fault_events.append(ev)
            if len(self.fault_events) > 4096:
                del self.fault_events[:2048]

    def _spawn_worker(self, sim_gpu: bool = False) -> _WorkerHandle:
        from .worker import worker_main
        wid = next(self._wids)
        # resolve the env-var pose here (not in the worker): a respawn
        # gets a fresh wid that would no longer match the env wid list,
        # and the replacement must inherit its predecessor's pose
        sim_gpu = sim_gpu or sim_gpu_for(wid)
        if self.transport == "tcp":
            # the child dials back in over the socket; its handle has no
            # conn until the accept loop attaches it
            endpoint = ("tcp", self.address, self.listener.authkey)
            head_conn = None
        else:
            head_conn, worker_conn = self._ctx.Pipe(duplex=True)
            endpoint = worker_conn
        proc = self._ctx.Process(
            target=worker_main,
            args=(endpoint, wid, sim_gpu, self.hb_interval_s),
            name=f"cluster-worker-{wid}", daemon=True)
        proc.start()
        if self.transport != "tcp":
            worker_conn.close()  # child's end lives in the child now
            head_conn = self._wrap_chaos(head_conn, wid)
        wh = _WorkerHandle(wid, proc, head_conn, sim_gpu=sim_gpu)
        with self._lock:
            self._handles[wid] = wh
        if self.transport == "tcp":
            # give the dial-in the same grace a reconnect would get
            wh.suspect_deadline = time.monotonic() + max(
                self.reconnect_grace_s, 10.0)
        else:
            t = threading.Thread(target=self._recv_loop, args=(wh, head_conn),
                                 name=f"cluster-recv-{wid}", daemon=True)
            t.start()
        return wh

    def _wrap_chaos(self, conn, wid: int):
        if self.chaos is not None:
            return ChaosWire(conn, self.chaos, peer=wid)
        return conn

    def _attach_conn(self, wh: _WorkerHandle, conn,
                     rejoin: bool = False) -> None:
        """Bind an authenticated TCP connection to a worker handle and
        start its receiver. The welcome goes out before the handle sees
        the conn, so it is guaranteed to be the first head→worker
        message on the wire."""
        conn.send(("welcome", wh.wid))
        wire = self._wrap_chaos(conn, wh.wid)
        with wh.send_lock:
            old = wh.conn
            wh.conn = wire
        if old is not None:
            try:
                old.close()
            except OSError:
                pass
        with self._lock:
            wh.last_msg = time.monotonic()
            wh.suspect_deadline = None
        if rejoin:
            self._fault_event("rejoins", wid=wh.wid)
        threading.Thread(target=self._recv_loop, args=(wh, wire),
                         name=f"cluster-recv-{wh.wid}",
                         daemon=True).start()

    def _accept_loop(self) -> None:
        """TCP transport: authenticate and route every inbound
        connection — spawned workers attaching/reattaching under a known
        wid, or external workers joining for a fresh one."""
        while not self._shutdown:
            try:
                conn = self.listener.accept()
            except OSError:
                if self._shutdown:
                    return
                continue
            except Exception:
                # failed auth (counted by the listener) or a garbled
                # handshake — never the accept thread's death
                self._fault_event("auth_failures")
                continue
            try:
                if not conn.poll(10.0):
                    conn.close()
                    continue
                msg = conn.recv()
            except (EOFError, OSError):
                continue
            try:
                self._route_attach(conn, msg)
            except (EOFError, OSError):
                try:
                    conn.close()
                except OSError:
                    pass

    def _route_attach(self, conn, msg) -> None:
        kind = msg[0]
        if kind == "attach":
            wid = int(msg[1])
            attempts = int(msg[2]) if len(msg) > 2 else 0
            chaos = self.chaos
            with self._lock:
                wh = self._handles.get(wid)
                # a re-attach is any attach from a worker that already
                # completed its hello (a clean socket drop re-dials with
                # zero *failed* attempts, but it is still a rejoin)
                rejoin = (wh is not None
                          and (attempts > 0 or wh.hello.is_set()))
                fenced = (wid in self._fenced_wids
                          or (chaos is not None and rejoin
                              and wid in chaos.refuse_rejoin))
            if wh is None or not wh.alive or fenced:
                self._fault_event("fenced", wid=wid)
                conn.send(("denied", f"worker {wid} is fenced"))
                conn.close()
                return
            if attempts > 0:
                self._faults.inc("reconnect_attempts", attempts)
            self._attach_conn(wh, conn, rejoin=rejoin)
        elif kind == "join":
            sim_gpu = bool(msg[1]) if len(msg) > 1 else False
            wh = _WorkerHandle(next(self._wids), None, None,
                               sim_gpu=sim_gpu)
            with self._lock:
                self._handles[wh.wid] = wh
            self._attach_conn(wh, conn)
            self._fault_event("joins", wid=wh.wid)
            # capability + transport measurement happen on the caller's
            # add-worker path (or lazily via the hello profile for a
            # worker that joined on its own)
        else:
            conn.send(("denied", f"bad handshake {msg!r}"))
            conn.close()

    def _await_hellos(self, timeout_s: float) -> None:
        deadline = time.monotonic() + timeout_s
        with self._lock:
            handles = list(self._handles.values())
        for wh in handles:
            if not wh.hello.wait(max(0.1, deadline - time.monotonic())):
                raise TimeoutError(
                    f"worker {wh.wid} never said hello")

    def _reprofile_sequentially(self) -> None:
        """Startup hellos carry profiles measured while every worker was
        booting at once — on a small host they contend and under-report.
        Re-measure one worker at a time for honest capability weights."""
        with self._lock:
            handles = [wh for wh in self._handles.values() if wh.alive]
        for wh in handles:
            self._reprofile(wh)

    def _reprofile(self, wh: _WorkerHandle) -> None:
        wh.hello.clear()
        try:
            wh.send(("profile",))
        except OSError:
            return
        wh.hello.wait(10.0)

    def _measure_transport(self, nbytes: int = 1 << 20) -> None:
        with self._lock:
            handles = [wh for wh in self._handles.values() if wh.alive]
        for wh in handles:
            self._ping_transport(wh, nbytes)

    def _ping_transport(self, wh: _WorkerHandle,
                        nbytes: int = 1 << 20) -> None:
        payload = b"\0" * nbytes
        ev = threading.Event()
        self._pongs[wh.wid] = ev
        t0 = time.perf_counter()
        try:
            wh.send(("ping", payload))
        except OSError:
            self._pongs.pop(wh.wid, None)
            return
        if ev.wait(5.0) and wh.profile is not None:
            dt = max(1e-9, time.perf_counter() - t0)
            # the payload travels one way (the pong is a few bytes), so
            # dt covers ~nbytes of transfer plus one scheduling round
            # trip — credit nbytes/dt, a slight *under*estimate
            wh.profile.transport_mbs = round(nbytes / dt / 1e6, 1)
        self._pongs.pop(wh.wid, None)

    def _recv_loop(self, wh: _WorkerHandle, conn) -> None:
        while True:
            try:
                msg = conn.recv()
            except (EOFError, OSError):
                break
            except Exception:
                # e.g. TypeError when a concurrent close() nulled the
                # handle mid-read: any recv failure means the connection
                # is unusable — treat it as the worker's death, never as
                # a reason to crash the receiver thread
                break
            wh.last_msg = time.monotonic()
            try:
                self._handle(wh, msg)
            except Exception:
                # a malformed message must not kill the receiver — but
                # protocol corruption has to be visible, not swallowed
                self._faults.inc("malformed_msgs", 1)
                log.warning("malformed message from worker %d: %.120r",
                            wh.wid, msg)
        self._on_conn_lost(wh, conn)

    def _on_conn_lost(self, wh: _WorkerHandle, conn) -> None:
        """One receiver's connection died. On the pipe transport (or at
        shutdown/drain) that *is* the worker's death; on TCP the worker
        gets a reconnect grace window and becomes *suspect* — the
        monitor declares death only if the grace expires un-reattached."""
        with self._lock:
            stale = wh.conn is not None and wh.conn is not conn
        if stale:
            return   # a reattach already replaced this conn; old thread
        if (self.transport == "tcp" and not self._shutdown
                and not wh.draining and not wh.no_grace and wh.alive):
            with wh.send_lock:
                if wh.conn is conn:
                    wh.conn = None
            try:
                conn.close()
            except OSError:
                pass
            with self._lock:
                if wh.suspect_deadline is None:
                    wh.suspect_deadline = (time.monotonic()
                                           + self.reconnect_grace_s)
            self._fault_event("conn_lost", wid=wh.wid)
            return
        self._on_worker_death(wh)

    def _handle(self, wh: _WorkerHandle, msg) -> None:
        kind = msg[0]
        if kind == "hb":
            if len(msg) > 1:
                wh.note_clock(msg[1])
            return   # last_msg already stamped by the recv loop
        if kind == "hello":
            wh.profile = DeviceProfile.from_dict(msg[1])
            if len(msg) > 2:
                wh.note_clock(msg[2])
            reason = getattr(wh.profile, "gpu_probe_error", "")
            if reason and not wh.gpu_probe_fault_counted:
                # the probe failing silently is how the 0.006x hetero
                # regression hid: a "GPU" fleet quietly priced as CPUs
                wh.gpu_probe_fault_counted = True
                self._fault_event("gpu_probe_failures", wid=wh.wid,
                                  reason=reason)
                log.warning("worker %d GPU probe failed: %s",
                            wh.wid, reason)
            wh.hello.set()
        elif kind == "done":
            _, tid, oid, nbytes, payload = msg[:5]
            ran = msg[5] if len(msg) > 5 else None
            wspans = msg[6] if len(msg) > 6 else None
            wstats = msg[7] if len(msg) > 7 else None
            if wstats:
                # worker accel counter deltas (jit cache, residency)
                # piggybacked on chunk dones — aggregate fleet-wide.
                # Duplicates are harmless here: the deltas were drained
                # on the worker, so a chaos-duplicated done carries {}
                for k in self._ACCEL_KEYS:
                    v = wstats.get(k)
                    if v:
                        setattr(self, k, getattr(self, k) + v)
            with self._lock:
                ts = self._tasks.get(tid)
                wh.inflight.discard(tid)
                # drop duplicates: a chaos-duplicated "done", or a slow
                # worker completing a task a deadline already resubmitted
                # elsewhere — counting (or fulfilling) twice would skew
                # telemetry and resurrect released objects
                if (ts is not None and ts.finished) \
                        or not self.plane.contains(oid):
                    return
                if ran is not None:
                    # what actually *executed* (vs the dispatch-intent
                    # gpu_chunks/cpu_chunks counters, which a mid-flight
                    # backend downgrade can overtake)
                    self.chunks_executed[ran] = \
                        self.chunks_executed.get(ran, 0) + 1
                    self.chunks_executed_by_worker[wh.wid] = \
                        self.chunks_executed_by_worker.get(wh.wid, 0) + 1
            if wspans and ts is not None and self.trace:
                # worker spans land *before* the result fulfills, so a
                # gather that returns has this chunk's busy seconds
                # already accumulated into its round
                self._ingest_worker_spans(wh, ts, ran, wspans)
            if payload is not None:
                self.plane.fulfill_inline(oid, payload[1])
            else:
                self.plane.fulfill_remote(oid, wh.wid, nbytes)
            if ts is not None:
                if ts.token is not None:
                    # park the in-flight span on the worker's track so
                    # the viewer nests the remote phases under it
                    ts.token.tid = obs.worker_tid(wh.wid)
                    obs.end(ts.token, wid=wh.wid, ran=ran)
                ts.finished = True
                ts.event.set()
        elif kind == "err":
            _, tid, message, tb = msg
            with self._lock:
                ts = self._tasks.get(tid)
                wh.inflight.discard(tid)
            if BLOB_MISSING in (message or ""):
                # the worker lacks a body blob we believe it holds (a
                # dropped/evicted blob message): reset its shipped-state
                # so the retry re-ships skeleton + cells in full
                wh.forget_blobs()
                self._fault_event("blob_missing", wid=wh.wid, task=tid)
            if ROWS_MISSING in (message or ""):
                # the worker lacks chunk rows our hash record says it
                # cached (restart/drop): forget the records so retries
                # re-ship rows in full
                with wh.send_lock:
                    wh.sliced_rows.clear()
                self._fault_event("rows_missing", wid=wh.wid, task=tid)
            if X64_FAILED in (message or ""):
                # the worker's jax refused float64 — its jnp twin would
                # silently compute in f32. The error path already
                # degrades the retry to the np body (TaskSpec.alt);
                # count the event so CI can see it happened
                self._fault_event("x64_enable_failed", wid=wh.wid,
                                  task=tid)
            if ts is None or ts.finished:
                return
            ts.spec.attempts += 1
            if ts.spec.attempts < self.max_attempts and not self._shutdown:
                self._maybe_downgrade_backend(ts.spec)
                self.resubmits += 1
                self._fault_event("retries", task=tid, wid=wh.wid)
                threading.Thread(target=self._dispatch, args=(ts,),
                                 daemon=True).start()
            else:
                ts.error = message
                obs.end(ts.token, error=True)
                self.plane.fulfill_inline(ts.spec.out.oid,
                                          _TaskErr(message, tb))
                ts.finished = True
                ts.event.set()
        elif kind == "obj":
            _, oid, payload = msg
            if payload is not None:
                self.plane.promote(oid, payload[1])
                try:
                    # ownership moved here; the worker's copy would
                    # never be read again (the head now serves it)
                    wh.send(("free", oid))
                except OSError:
                    pass
            ev = self._fetch_events.pop(oid, None)
            if ev is not None:
                ev.set()
        elif kind == "pong":
            if len(msg) > 2:
                wh.note_clock(msg[2])
            ev = self._pongs.get(wh.wid)
            if ev is not None:
                ev.set()

    def _ingest_worker_spans(self, wh: _WorkerHandle, ts: _TaskState,
                             ran: Optional[str], wspans) -> None:
        """Land one task's worker-side spans on the head timeline. The
        worker measured them on its own monotonic clock; the handle's
        offset estimate re-bases them, and the per-round busy/compute
        accumulators behind the ``idle_s``/``compute_s`` phase metrics
        pick up their totals."""
        rec = obs.recorder()
        track = obs.worker_tid(wh.wid)
        rec.name_track(0, track, f"worker{wh.wid}")
        base: Dict[str, Any] = {"task": ts.spec.task_id, "wid": wh.wid}
        if ts.span_meta:
            base.update(ts.span_meta)
        if ran is not None:
            base["backend"] = ran
        busy = rec.record_external(wspans,
                                   offset=wh.clock_offset or 0.0,
                                   pid=0, tid=track, base_args=base)
        rid = (ts.span_meta or {}).get("round")
        if rid is None:
            return
        compute = sum(max(0.0, s[2] - s[1]) for s in wspans
                      if s[0] == "run")
        with self._lock:
            self._round_busy[rid] = \
                self._round_busy.get(rid, 0.0) + busy
            self._round_compute[rid] = \
                self._round_compute.get(rid, 0.0) + compute

    def _on_worker_death(self, wh: _WorkerHandle) -> None:
        with self._lock:
            if not wh.alive:
                return
            wh.alive = False
            self._handles.pop(wh.wid, None)
            inflight = list(wh.inflight)
            wh.inflight.clear()
            clean = self._shutdown or wh.draining
            self._fenced_wids.add(wh.wid)   # a dead wid never reattaches
        wh.close_conn()
        if clean:
            if wh.draining and not self._shutdown:
                # a drained worker may still own objects nobody fetched:
                # mark them LOST so lineage replays them on demand (the
                # monitor tries to pull them to the head *before* the
                # drain completes, making this the uncommon path)
                self.plane.mark_worker_lost(wh.wid)
                self._fault_event("drains", wid=wh.wid)
            return
        self.worker_deaths += 1
        self._fault_event("worker_deaths", wid=wh.wid)
        self.plane.mark_worker_lost(wh.wid)
        if self.respawn and wh.proc is not None:
            with obs.span("respawn", cat="fault", wid=wh.wid):
                nw = self._spawn_worker(sim_gpu=wh.sim_gpu)
                if nw.hello.wait(10.0):
                    # the boot-time probe may have contended with
                    # whatever killed its predecessor: re-measure like
                    # at startup so chunk weights and profitability
                    # stay honest
                    self._reprofile(nw)
                    self._ping_transport(nw)
                    self._prewarm_blobs(nw)
            self._fault_event("respawns", wid=nw.wid, replaced=wh.wid)
        # in-flight tasks died with the process: resubmit on survivors
        for tid in inflight:
            with self._lock:
                ts = self._tasks.get(tid)
            if ts is None or ts.finished:
                continue
            ts.spec.attempts += 1
            if ts.spec.attempts >= self.max_attempts:
                ts.error = f"worker {wh.wid} died; attempts exhausted"
                obs.end(ts.token, error=True)
                self.plane.fulfill_inline(ts.spec.out.oid,
                                          _TaskErr(ts.error))
                ts.finished = True
                ts.event.set()
                continue
            self.resubmits += 1
            threading.Thread(target=self._dispatch, args=(ts,),
                             daemon=True).start()

    # -- active liveness ---------------------------------------------------
    def _monitor_loop(self) -> None:
        """Periodic liveness sweep: reap suspects whose reconnect grace
        expired, declare heartbeat-silent workers dead, complete clean
        drains, and enforce per-task deadlines. Replaces the passive
        "recv failed ⇒ dead" model with an active one."""
        while not self._shutdown:
            time.sleep(0.1)
            if self._shutdown:
                return
            now = time.monotonic()
            with self._lock:
                handles = list(self._handles.values())
            hb_limit = (self.hb_interval_s * self.hb_miss_budget
                        if self.hb_interval_s > 0 else None)
            for wh in handles:
                if not wh.alive or self._shutdown:
                    continue
                if wh.suspect_deadline is not None:
                    if now > wh.suspect_deadline:
                        self._fault_event("reconnect_grace_expired",
                                          wid=wh.wid)
                        self._on_worker_death(wh)
                    continue
                if (hb_limit is not None and wh.conn is not None
                        and not wh.draining and wh.hello.is_set()
                        and now - wh.last_msg > hb_limit):
                    # silent past the miss budget: treat as dead even
                    # though the socket looks healthy (hung process) —
                    # no reconnect grace, its state is not trustworthy
                    wh.no_grace = True
                    self._fault_event("hb_expired", wid=wh.wid,
                                      age_s=round(now - wh.last_msg, 3))
                    # declare death here rather than via the recv loop:
                    # closing the fd does not wake a thread blocked in
                    # read() on it, and a hung-but-silent worker sends
                    # nothing that would (_on_worker_death is idempotent,
                    # so the receiver's eventual exit is a no-op)
                    wh.close_conn()
                    self._on_worker_death(wh)
                    continue
                if wh.draining and not wh.inflight and not wh.drain_sent:
                    wh.drain_sent = True
                    # pull its objects home while it is still live so
                    # the drain loses nothing (anything missed goes
                    # LOST and replays via lineage)
                    for oid in list(self.plane.resident_on(wh.wid)):
                        self._fetch(oid)
                    try:
                        wh.send(("shutdown",))
                    except OSError:
                        pass
            self._check_deadlines(now)

    def _forensic(self, ts: _TaskState) -> str:
        """One task's timeout forensics: id, attempt count, placement,
        and how stale its worker's last heartbeat is."""
        wid = ts.wid
        wh = self._handle_for(wid) if wid is not None else None
        if wh is not None:
            age = f"last heartbeat {time.monotonic() - wh.last_msg:.2f}s ago"
        elif wid is not None:
            age = "worker gone"
        else:
            age = "never dispatched"
        return (f"task {ts.spec.task_id} (kind={ts.spec.kind}, "
                f"attempt {ts.spec.attempts + 1}/{self.max_attempts}, "
                f"worker {wid}, {age})")

    def _timeout_forensics(self, ref: ClusterRef) -> str:
        with self._lock:
            tid = self._producer.get(ref.oid)
            ts = self._tasks.get(tid) if tid is not None else None
        if ts is None:
            return f"timed out waiting for {ref}"
        return f"timed out waiting for {ref}: {self._forensic(ts)}"

    def _check_deadlines(self, now: float) -> None:
        with self._lock:
            expired = []
            for ts in self._tasks.values():
                dl = (ts.deadline_s if ts.deadline_s is not None
                      else self.task_deadline_s)
                if dl is None or ts.finished or ts.dispatched_at is None:
                    continue
                if now - ts.dispatched_at > dl:
                    # claim this expiry (one per dispatch; _dispatch
                    # re-stamps on the resubmit). The hung worker keeps
                    # the tid in its inflight set on purpose: the load
                    # penalty steers placement away from it.
                    ts.dispatched_at = None
                    expired.append((ts, dl))
        for ts, dl in expired:
            forensic = self._forensic(ts)
            self._fault_event("deadline_expired", task=ts.spec.task_id,
                              wid=ts.wid, deadline_s=dl)
            log.warning("deadline expired: %s", forensic)
            ts.spec.attempts += 1
            if ts.spec.attempts < self.max_attempts and not self._shutdown:
                self.resubmits += 1
                self._fault_event("retries", task=ts.spec.task_id,
                                  wid=ts.wid)
                threading.Thread(target=self._dispatch, args=(ts,),
                                 daemon=True).start()
            else:
                ts.error = (f"missed its {dl}s deadline and exhausted "
                            f"the retry budget: {forensic}")
                obs.end(ts.token, error=True)
                self.plane.fulfill_inline(ts.spec.out.oid,
                                          _TaskErr(ts.error))
                ts.finished = True
                ts.event.set()

    def _maybe_downgrade_backend(self, spec: TaskSpec) -> None:
        """A chunk that *errored* on a worker retries one step down its
        ``TaskSpec.alt`` degradation chain (registry-ordered, e.g.
        pallas → jnp → np) — a worker whose accelerator runtime is
        broken/missing, or a pallas lowering that fails at run time,
        must not burn every attempt on the same body.

        ``alt`` holds either a tuple of ``(backend, blob_id, parts)``
        steps (registry chains) or a single such triple (pre-registry
        single-step form, still accepted)."""
        if spec.kind != "chunk" or spec.backend == "np" \
                or spec.alt is None:
            return
        if spec.backend == "pallas":
            self.pallas_fallbacks += 1
        steps = spec.alt if isinstance(spec.alt[0], tuple) \
            else (spec.alt,)
        spec.backend, spec.blob_id, spec.parts = steps[0]
        rest = tuple(steps[1:])
        spec.alt = rest if rest else None
        spec.device_pref = backends_mod.get(spec.backend).device_pref \
            if backends_mod.is_registered(spec.backend) else "cpu"

    # -- placement + dispatch ---------------------------------------------
    def _views(self) -> List[WorkerView]:
        with self._lock:
            handles = [wh for wh in self._handles.values()
                       if wh.alive and wh.profile is not None
                       and not wh.draining and wh.conn is not None
                       and wh.suspect_deadline is None]
            return [WorkerView(wh.wid, wh.profile, len(wh.inflight),
                               self.plane.resident_on(wh.wid))
                    for wh in handles]

    def _handle_for(self, wid: int) -> Optional[_WorkerHandle]:
        with self._lock:
            return self._handles.get(wid)

    def _ensure_arg_ready(self, ref: ClusterRef,
                          timeout: Optional[float] = 60.0) -> None:
        deadline = None if timeout is None else time.monotonic() + timeout
        while True:
            meta = self.plane.meta(ref.oid)
            if meta.state in (HEAD, REMOTE):
                return
            if meta.state == LOST:
                self._replay(ref.oid)
            self.plane.wait_ready(ref.oid, 0.05)
            if deadline is not None and time.monotonic() > deadline:
                raise TimeoutError(
                    f"arg never became ready: "
                    f"{self._timeout_forensics(ref)}")

    def _dispatch(self, ts: _TaskState) -> None:
        """Place and send one task; blocks until its ref args are ready
        (and replayed, if lost). Retries placement while workers die."""
        spec = ts.spec
        while not self._shutdown:
            # re-resolve on every attempt: an arg can turn LOST between
            # placement retries (its owner died under us) and only this
            # path triggers its replay
            for ref in spec.args:
                if isinstance(ref, ClusterRef):
                    self._ensure_arg_ready(ref)
                    meta = self.plane.meta(ref.oid)
                    if (meta.state == HEAD
                            and isinstance(meta.value, _TaskErr)):
                        # a failed upstream must poison dependents, not
                        # travel to a worker as an argument value
                        ts.error = f"upstream task failed: {meta.value}"
                        obs.end(ts.token, error=True)
                        self.plane.fulfill_inline(spec.out.oid,
                                                  _TaskErr(ts.error))
                        ts.finished = True
                        ts.event.set()
                        return
            views = self._views()
            if not views:
                if not self.respawn and self.workers_alive() == 0:
                    # the whole fleet is gone and nothing will replace
                    # it: fail the task so waiters raise instead of
                    # spinning forever
                    ts.error = "no live workers and respawn disabled"
                    obs.end(ts.token, error=True)
                    self.plane.fulfill_inline(spec.out.oid,
                                              _TaskErr(ts.error))
                    ts.finished = True
                    ts.event.set()
                    return
                time.sleep(0.05)
                continue
            if ts.tried:
                # a retry (error, death, or expired deadline) prefers a
                # worker that has not already failed/hung on this task
                fresh = [v for v in views if v.wid not in ts.tried]
                if fresh:
                    views = fresh
            arg_bytes = {a.oid: self.plane.meta(a.oid).nbytes
                         for a in spec.args
                         if isinstance(a, ClusterRef)}
            wid = self.scheduler.place(spec, views, arg_bytes)
            wh = self._handle_for(wid)
            if wh is None or not wh.alive:
                continue
            try:
                wire = self._wire_spec(spec, wh)
                with self._lock:
                    wh.inflight.add(spec.task_id)
                ts.wid = wid
                if wid not in ts.tried:
                    ts.tried.append(wid)
                wh.send(("task", spec.task_id, wire))
                ts.dispatched_at = time.monotonic()
                if spec.kind == "chunk":
                    self._count_chunk_shipment(spec)
                return
            except (OSError, BrokenPipeError, ValueError):
                with self._lock:
                    wh.inflight.discard(spec.task_id)
                time.sleep(0.02)  # worker died under us; replace + retry

    def _count_chunk_shipment(self, spec: TaskSpec) -> None:
        """Backend-routing telemetry for one *delivered* chunk task (a
        worker-death resubmit re-ships for real and re-counts). The
        per-arg sliced counters live in :meth:`_wire_spec`, where the
        ship-vs-keep decision is made."""
        if spec.backend == "np":
            self.cpu_chunks += 1
        else:
            self.gpu_chunks += 1
            if spec.backend == "pallas":
                self.pallas_chunks += 1

    def _wire_spec(self, spec: TaskSpec, wh: _WorkerHandle) -> Dict:
        """Encode a task for the wire, resolving every ref arg so the
        worker never has to fetch mid-task (locality keeps this cheap:
        the scheduler prefers the owner of the biggest inputs)."""
        wire_args = []
        for a in spec.args:
            if not isinstance(a, ClusterRef):
                wire_args.append(("val", a))
                continue
            meta = self.plane.meta(a.oid)
            if meta.state == HEAD:
                wire_args.append(("obj", a.oid, meta.value))
            elif meta.state == REMOTE and meta.owner == wh.wid:
                wire_args.append(("loc", a.oid))
            elif meta.state == REMOTE:
                # transfer on demand, relayed through the head
                got = self._fetch(a.oid)
                if got is None:
                    # owner died mid-fetch: force a dispatch retry,
                    # which re-resolves (and replays) the arg
                    raise ValueError(f"arg {a} fetch failed")
                wire_args.append(("obj", a.oid, got[1]))
            else:
                raise ValueError(f"arg {a} not ready")
        wire = {"kind": spec.kind, "out_oid": spec.out.oid,
                "gather": spec.gather, "args": wire_args}
        if self.trace:
            wire["trace"] = True   # worker measures + returns its spans
        if spec.kind == "chunk":
            parts: ClosureParts = spec.parts
            t0 = time.perf_counter()
            # blob counters update here because ship_blob really sent
            # (or raised); sliced counters wait until the task message
            # itself lands, in _count_chunk_shipment — a placement retry
            # must not inflate them
            cells, nbytes = wh.ship_blob(spec.blob_id, parts)
            self.cells_shipped += cells
            self.cells_skipped += len(parts.cell_pkls) - cells
            self.bytes_shipped += nbytes
            # per-chunk rows of the sliceable arrays: each worker gets
            # payload/n instead of the whole closure (ROADMAP item #1).
            # Content-hashed per (blob, name, range) and per worker: a
            # serving loop re-dispatching unchanged rows to the same
            # worker sends a ("keep",) marker instead of the bytes —
            # the worker reuses the rows it cached last round (its
            # rollback keeps them byte-exact)
            sliced_wire = {}
            for nm in spec.sliced:
                arr = parts.sliced.get(nm)
                if arr is None:
                    # ``spec.sliced`` is the round-level union from the
                    # np body; a twin capturing fewer arrays (a fused
                    # pallas call, a degraded-away backend) has nothing
                    # to ship for the rest
                    continue
                rows = arr[spec.lo:spec.hi]
                rb = int(rows.nbytes)
                h = hashlib.sha256(rows.tobytes()).hexdigest()
                rk = (spec.blob_id, nm, spec.lo, spec.hi)
                self.sliced_args += 1
                self.bytes_saved_sliced += int(arr.nbytes) - rb
                with wh.send_lock:
                    keep = wh.sliced_rows.get(rk) == h
                    if not keep:
                        wh.sliced_rows[rk] = h
                if keep:
                    sliced_wire[nm] = ("keep",)
                    self.rows_skipped += 1
                    self.bytes_saved_rows += rb
                else:
                    sliced_wire[nm] = ("rows", rows)
                    self.bytes_shipped += rb
            t1 = time.perf_counter()
            self._phase.add_time("ship_s", t1 - t0)
            if self.trace:
                obs.recorder().record(
                    "ship", "pfor", t0, t1,
                    args={"task": spec.task_id, "wid": wh.wid,
                          "cells": cells, "bytes": nbytes})
            wire.update(blob_id=spec.blob_id, lo=spec.lo, hi=spec.hi,
                        written=spec.written, sliced=sliced_wire,
                        backend=spec.backend)
        else:
            wire["fn_blob"] = spec.fn_blob
        return wire

    # -- public API --------------------------------------------------------
    def submit(self, fn, *args, device_pref: str = "",
               est_flops: float = 0.0) -> ClusterRef:
        """Asynchronously run ``fn(*args)`` on some worker process.
        Args may be plain picklable values or :class:`ClusterRef`."""
        tid = next(self._task_ids)
        out = self.plane.new_ref(tid)
        spec = TaskSpec(tid, "fn", dumps_fn(fn), tuple(args), out,
                        device_pref=device_pref, est_flops=est_flops)
        ts = _TaskState(spec)
        with self._lock:
            self._tasks[tid] = ts
            self._producer[out.oid] = tid
        pending = any(isinstance(a, ClusterRef)
                      and self.plane.meta(a.oid).state not in (HEAD, REMOTE)
                      for a in args)
        if pending:
            threading.Thread(target=self._dispatch, args=(ts,),
                             daemon=True).start()
        else:
            self._dispatch(ts)
        return out

    def submit_batch(self, fn, arg_tuples: Sequence[Tuple[Any, ...]],
                     device_pref: str = "",
                     est_flops: float = 0.0) -> List[ClusterRef]:
        """Batched submission: one ``fn`` over many argument tuples.
        The function serializes once (every spec shares the blob) and
        all tasks register under one lock before dispatch fans out —
        the serving plane's coalesced fall-through path for plain
        callables."""
        if not arg_tuples:
            return []
        blob = dumps_fn(fn)
        states: List[_TaskState] = []
        refs: List[ClusterRef] = []
        with self._lock:
            for args in arg_tuples:
                tid = next(self._task_ids)
                out = self.plane.new_ref(tid)
                spec = TaskSpec(tid, "fn", blob, tuple(args), out,
                                device_pref=device_pref,
                                est_flops=est_flops)
                ts = _TaskState(spec)
                self._tasks[tid] = ts
                self._producer[out.oid] = tid
                states.append(ts)
                refs.append(out)
        for ts in states:
            pending = any(
                isinstance(a, ClusterRef)
                and self.plane.meta(a.oid).state not in (HEAD, REMOTE)
                for a in ts.spec.args)
            if pending:
                threading.Thread(target=self._dispatch, args=(ts,),
                                 daemon=True).start()
            else:
                self._dispatch(ts)
        return refs

    def put(self, value: Any) -> ClusterRef:
        return self.plane.put_local(value)

    def release(self, ref: ClusterRef) -> None:
        """Drop every head-side record of ``ref``: its lineage (task +
        producer entries), its directory slot, and — when a worker owns
        the value — the worker's copy. After this the object can never
        be fetched or replayed; callers own the ordering (release a
        chain only after anchoring a replacement lineage root).
        Long-lived serving loops call this to hold head memory flat."""
        with self._lock:
            tid = self._producer.pop(ref.oid, None)
            if tid is not None:
                self._tasks.pop(tid, None)
        if not self.plane.contains(ref.oid):
            return
        meta = self.plane.meta(ref.oid)
        if meta.state == REMOTE and meta.owner is not None:
            wh = self._handle_for(meta.owner)
            if wh is not None and wh.alive:
                try:
                    wh.send(("free", ref.oid))
                except OSError:
                    pass
        self.plane.release(ref.oid)

    def get(self, ref_or_refs, timeout: Optional[float] = 60.0):
        if isinstance(ref_or_refs, list):
            return [self.get(r, timeout) for r in ref_or_refs]
        ref: ClusterRef = ref_or_refs
        deadline = None if timeout is None else time.monotonic() + timeout
        while True:
            meta = self.plane.meta(ref.oid)
            if meta.state == HEAD:
                if isinstance(meta.value, _TaskErr):
                    raise ClusterTaskError(str(meta.value))
                return meta.value
            if meta.state == REMOTE:
                got = self._fetch(ref.oid)
                if got is not None:
                    return got[1]
                time.sleep(0.02)   # owner dying; wait for the LOST mark
            elif meta.state == LOST:
                self._replay(ref.oid)
            self.plane.wait_ready(ref.oid, 0.05)
            if deadline is not None and time.monotonic() > deadline:
                raise TimeoutError(self._timeout_forensics(ref))

    def wait(self, refs: Sequence[ClusterRef], num_returns: int = 1,
             timeout: Optional[float] = None,
             on_timeout: str = "return"):
        """ray.wait analogue: (ready, pending). With
        ``on_timeout="raise"``, a timeout raises :class:`TimeoutError`
        naming every still-pending task, its placed worker, and how
        stale that worker's last heartbeat is (the default keeps ray's
        return-what-you-have contract)."""
        deadline = None if timeout is None else time.monotonic() + timeout
        ready, pending = [], list(refs)
        while len(ready) < num_returns and pending:
            for r in list(pending):
                if self.plane.meta(r.oid).state in (HEAD, REMOTE):
                    ready.append(r)
                    pending.remove(r)
            if len(ready) >= num_returns:
                break
            if deadline is not None and time.monotonic() > deadline:
                if on_timeout == "raise" and len(ready) < num_returns:
                    detail = "; ".join(self._timeout_forensics(r)
                                       for r in pending)
                    raise TimeoutError(
                        f"wait: {len(ready)}/{num_returns} ready after "
                        f"{timeout}s — pending: {detail}")
                break
            time.sleep(0.005)
        return ready, pending

    def _fetch(self, oid: int) -> Optional[tuple]:
        """Pull a remote object to the head (transfer on demand).
        Returns ``("v", value)`` on success — the wrapper keeps a stored
        ``None`` distinguishable from failure — or ``None`` when the
        owner is gone (caller falls through to the LOST/replay path)."""
        meta = self.plane.meta(oid)
        if meta.state == HEAD:
            return ("v", meta.value)
        wh = self._handle_for(meta.owner) if meta.owner is not None \
            else None
        if wh is None or not wh.alive:
            return None
        ev = self._fetch_events.setdefault(oid, threading.Event())
        try:
            wh.send(("get", oid))
        except OSError:
            self._fetch_events.pop(oid, None)
            return None
        deadline = time.monotonic() + 30.0
        while not ev.wait(0.05):
            if not wh.alive:      # owner died before replying
                self._fetch_events.pop(oid, None)
                return None
            if time.monotonic() > deadline:
                self._fetch_events.pop(oid, None)
                return None
        meta = self.plane.meta(oid)
        return ("v", meta.value) if meta.state == HEAD else None

    # -- lineage replay ----------------------------------------------------
    def _replay(self, oid: int) -> None:
        """Recompute a LOST object from its serialized task spec; the
        spec's own lost ref args replay transitively via dispatch."""
        with self._lock:
            tid = self._producer.get(oid)
            ts = self._tasks.get(tid) if tid is not None else None
        if ts is None:
            raise ClusterTaskError(
                f"object {oid} lost and has no lineage (direct put?)")
        if not self.plane.try_reset_lost(oid):
            return  # someone else already replayed it
        self.replays += 1
        self._fault_event("lineage_replays", task=ts.spec.task_id,
                          oid=oid)
        ts.finished = False
        ts.event = threading.Event()
        with obs.span("replay", cat="fault", task=ts.spec.task_id,
                      oid=oid):
            self._dispatch(ts)

    # -- pfor sharding (the repro.core.pfor protocol) ----------------------
    def _blob_for(self, parts: ClosureParts) -> int:
        """Stable blob id for a body identity (code hash + cell shapes/
        dtypes). A hit means every worker that already holds the skeleton
        re-receives at most the cells that changed — the serving-loop
        fast path."""
        with self._lock:
            rec = self._blob_cache.get(parts.blob_key)
            if rec is not None:
                rec.seq = next(self._blob_seq)
                rec.parts = parts   # freshest cells win the prewarm
                self.blob_hits += 1
                return rec.bid
            self.blob_misses += 1
            rec = _BlobRec(next(self._blob_ids), parts.blob_key,
                           next(self._blob_seq), parts=parts)
            self._blob_cache[parts.blob_key] = rec
            evict = []
            while len(self._blob_cache) > self.max_cached_blobs:
                victim = min(self._blob_cache.values(),
                             key=lambda r: r.seq)
                del self._blob_cache[victim.key]
                evict.append(victim.bid)
            bid = rec.bid
        for old in evict:
            self._drop_blob(old)
        return bid

    def _drop_blob(self, bid: int) -> None:
        with self._lock:
            handles = [wh for wh in self._handles.values() if wh.alive]
        for wh in handles:
            # under the send lock: ship_blob reads/updates the same
            # bookkeeping under it, so eviction can't interleave with a
            # delta ship and desync what the worker actually holds (a
            # task racing past an eviction still recovers — the worker
            # errors on the missing blob and the resubmit re-ships it)
            with wh.send_lock:
                if bid not in wh.blobs or wh.conn is None:
                    continue
                try:
                    wh.conn.send(("unblob", bid))
                except OSError:
                    pass
                wh.blobs.discard(bid)
                wh.blob_cells.pop(bid, None)
                for k in [k for k in wh.sliced_rows if k[0] == bid]:
                    del wh.sliced_rows[k]

    def _prewarm_blobs(self, wh: _WorkerHandle) -> None:
        """Ship every cached persistent body (skeleton + cells) to a
        worker that just joined or respawned, so its first serving-loop
        chunk starts warm instead of paying the full broadcast."""
        with self._lock:
            recs = [r for r in self._blob_cache.values()
                    if r.parts is not None]
        for rec in recs:
            try:
                cells, nbytes = wh.ship_blob(rec.bid, rec.parts)
                self.cells_shipped += cells
                self.bytes_shipped += nbytes
            except OSError:
                return   # died/unattached mid-warm; dispatch recovers

    @staticmethod
    def _merge_updates(arrays: Dict[str, np.ndarray], updates,
                       spec: TaskSpec) -> None:
        """Apply one chunk's sparse writes to the head's live arrays.
        Sliced arrays report chunk-local flat indices (the worker only
        held rows ``[lo, hi)``): re-base by ``lo`` leading-axis rows.
        An update for an array the head cannot see is a contract
        violation — dropping it would silently lose writes."""
        for name, (idx, vals) in (updates or {}).items():
            arr = arrays.get(name)
            if arr is None:
                raise ClusterTaskError(
                    f"pfor chunk [{spec.lo}, {spec.hi}) returned writes "
                    f"for {name!r}, which is not a captured ndarray of "
                    f"the body — refusing to drop them silently")
            if name in spec.sliced:
                stride = 1
                for d in arr.shape[1:]:
                    stride *= int(d)
                idx = np.asarray(idx, dtype=np.int64) + spec.lo * stride
            arr[np.unravel_index(idx, arr.shape)] = vals

    def _await_quorum(self, views: List[WorkerView],
                      wait_s: float = 5.0) -> List[WorkerView]:
        """Give a collapsing fleet a beat to respawn/rejoin before
        declaring it below quorum."""
        deadline = time.monotonic() + wait_s
        while len(views) < self.quorum and time.monotonic() < deadline:
            if not self.respawn and self.workers_alive() < self.quorum:
                break   # nothing will replace the dead
            time.sleep(0.05)
            views = self._views()
        return views

    def _gather_chunk(self, ref: ClusterRef, spec: TaskSpec,
                      arrays: Dict[str, np.ndarray], body, rid: int,
                      tracing: bool, ph) -> None:
        """Block on one chunk's result and merge its sparse writes.
        No per-chunk gather timeout: a healthy chunk may legitimately
        compute for minutes; hangs surface via heartbeat expiry or
        ``deadline_s`` resubmission, both bounded by max_attempts."""
        g0 = time.perf_counter()
        try:
            updates = self.get(ref, timeout=None)
        except ClusterTaskError:
            if not self.degrade_local:
                raise
            # this chunk terminally failed (retry budget spent, or the
            # fleet died under it): run it in-process — the body's
            # closure writes the head's live arrays directly, so no
            # merge is needed
            self._fault_event("degraded_chunks", task=spec.task_id,
                              lo=spec.lo, hi=spec.hi)
            log.warning("pfor chunk [%d, %d) degraded to "
                        "local execution", spec.lo, spec.hi)
            with obs.span("degraded_chunk", cat="fault",
                          task=spec.task_id):
                body(spec.lo, spec.hi)
            updates = None
        g1 = time.perf_counter()
        self._merge_updates(arrays, updates, spec)
        g2 = time.perf_counter()
        ph.add_time("gather_s", g1 - g0)
        ph.add_time("merge_s", g2 - g1)
        if tracing:
            rec = obs.recorder()
            rec.record("gather", "pfor", g0, g1,
                       args={"round": rid, "task": spec.task_id})
            rec.record("merge", "pfor", g1, g2,
                       args={"round": rid, "task": spec.task_id})

    def _gather_pipelined(self, chunks, arrays: Dict[str, np.ndarray],
                          body, rid: int, tracing: bool, ph) -> None:
        """As-completed gather: merge each sub-chunk the moment its
        result lands, while the rest of the round is still computing.
        pfor chunks write disjoint regions, so merges commute — the
        result is bitwise-identical to the in-order gather. The
        ``overlap_s`` phase metric accumulates head-side gather/merge
        seconds spent while at least one chunk was still in flight —
        exactly the wall time the synchronous round serialized."""
        with self._lock:
            pend = [(ref, spec, self._tasks.get(spec.task_id))
                    for ref, spec in chunks]
        overlap = 0.0
        while pend:
            ready = [p for p in pend
                     if p[2] is None or p[2].event.is_set()]
            if not ready:
                # head blocked on in-flight results: this is *overlapped*
                # wall (workers are computing under it), so it reports
                # as wait_s, distinct from the gather_s fetch/merge work
                w0 = time.perf_counter()
                pend[0][2].event.wait(0.005)
                ph.add_time("wait_s", time.perf_counter() - w0)
                continue
            for p in ready:
                pend.remove(p)
                g0 = time.perf_counter()
                self._gather_chunk(p[0], p[1], arrays, body, rid,
                                   tracing, ph)
                if pend:
                    overlap += time.perf_counter() - g0
        ph.add_time("overlap_s", overlap)

    def pfor_shards(self, body, lo: int, hi: int,
                    tile: Optional[int] = None,
                    written: Sequence[str] = (),
                    sliceable: Sequence[str] = (),
                    est_flops: float = 0.0,
                    deadline_s: Optional[float] = None) -> None:
        """Execute a generated pfor body across worker processes.

        The body skeleton + broadcast cells persist on the workers under
        a content-addressed blob id (re-shipped cell-by-cell only when
        their hashes change); arrays in ``sliceable`` — proven by the
        schedule to be indexed only by the pfor var on their leading
        axis — ship as per-chunk row slices, so their total traffic is
        ``payload`` instead of ``payload × n_workers``. Chunk tasks
        return sparse updates for the written arrays, which merge into
        the head's live arrays — pfor iterations write disjoint regions,
        so the merge needs no conflict resolution.

        Heterogeneous routing: when the body carries registered-backend
        twins (``body.__jnp__``/``body.__pallas__``/…, emitted per pfor
        unit by codegen), each worker's backend is priced from its
        device profile (:func:`repro.core.cost.pick_chunk_backend` over
        ``est_flops`` and the payload bytes, candidates = the twins
        that actually exist), chunks are sized by the *chosen-backend*
        throughput, and placement routes them via the backend's
        ``device_pref`` — so a mixed fleet runs GPU workers on an
        accelerator body and CPU workers on the np body of the same
        pfor, gathered into one result. All bodies share the
        content-addressed cell store, so serving-loop blob reuse
        survives backend tagging."""
        n = hi - lo
        if n <= 0:
            return
        tracing = self.trace
        rid = next(self._round_seq)
        ph = self._phase
        rt0 = time.perf_counter()
        arrays = {n_: v for n_, v in closure_arrays(body).items()
                  if isinstance(v, np.ndarray)}
        # trust-but-verify the analysis against the live values: slicing
        # needs a real ndarray whose leading axis covers the iteration
        # range (anything else degrades to broadcast, never to an error)
        slice_names = tuple(
            nm for nm in dict.fromkeys(sliceable)
            if nm in arrays and arrays[nm].ndim >= 1
            and lo >= 0 and arrays[nm].shape[0] >= hi)
        bodies = {"np": body}
        if not self.np_only:
            # codegen stamps each registered backend's twin onto the np
            # body under the backend's attr (__jnp__, __pallas__, …)
            for bk_obj in backends_mod.twin_backends():
                twin = getattr(body, bk_obj.attr, None)
                if twin is not None:
                    bodies[bk_obj.name] = twin
        candidates = tuple(b for b in bodies if b != "np")
        t_split0 = time.perf_counter()
        parts_by = split_fn_variants(bodies, slice_names)
        t_split1 = time.perf_counter()
        views = self._views()
        if len(views) < self.quorum:
            views = self._await_quorum(views)
        if len(views) < self.quorum or not views:
            if not self.degrade_local:
                raise ClusterTaskError(
                    f"no quorum for pfor: {len(views)} live workers "
                    f"< quorum {self.quorum}")
            # fleet collapsed and nothing will replace it: degrade to
            # local in-process execution — the body's closure holds the
            # head's live arrays, so calling it directly is the
            # single-process semantics of the same loop
            self._fault_event("degraded_local_runs",
                              name=body.__name__, lo=lo, hi=hi)
            log.warning("pfor %s degraded to local execution "
                        "(%d live workers < quorum %d)",
                        body.__name__, len(views), self.quorum)
            with obs.span("degraded_local", cat="fault",
                          body=body.__name__):
                body(lo, hi)
            self.pfor_runs += 1
            ph.add_time("round_s", time.perf_counter() - rt0)
            return
        # price the (unit, backend, worker) cells: each view gets the
        # backend whose roofline+transport estimate is cheaper for its
        # expected share of the iteration space
        from repro.core import cost as cost_model
        per_bytes = (sum(int(a.nbytes) for a in
                         parts_by["np"].sliced.values()) / len(views)
                     + parts_by["np"].broadcast_nbytes())
        backends = cost_model.unit_backend_table(
            est_flops / len(views), per_bytes,
            [v.profile for v in views],
            allow_jnp=bool(candidates), candidates=candidates)
        hetero = any(b != "np" for b in backends)
        # register every blob this run may use: the chosen backends
        # plus each one's degradation-chain members ("np" always — it
        # is the terminal fallback); workers receive a blob only when a
        # chunk referencing it is dispatched to them
        need = set(backends) | {"np"}
        for bk in tuple(need):
            need.update(b for b in backends_mod.degradation_chain(bk)
                        if b in bodies)
        bids = {bk: self._blob_for(parts_by[bk]) for bk in sorted(need)}
        if tile:
            ranges = [range(t, min(t + tile, hi))
                      for t in range(lo, hi, tile)]
            # explicit tiling decouples chunks from views: approximate
            # the fleet's backend mix by cycling the per-view choices
            chunk_backends = [backends[i % len(backends)]
                              for i in range(len(ranges))]
            chunk_prefs: List[Optional[int]] = [None] * len(ranges)
        else:
            # chosen-backend throughput, with skew clamped to 4x: a
            # probe that mis-measured on a throttled host must not
            # starve the run (genuine heterogeneity up to 4x shows)
            rates = [cost_model.backend_effective_gflops(v.profile, bk)
                     for v, bk in zip(views, backends)]
            top = max(rates)
            weights = [max(r, 0.25 * top) for r in rates]
            # drop_empty=False: ranges stay index-aligned with views so
            # each chunk pairs with the backend priced for *its* view
            # even when some worker's share rounds to zero
            ranges = self.scheduler.proportional_chunks(
                lo, hi, weights, drop_empty=False)
            chunk_backends = list(backends)
            # ranges stay index-aligned with views: chunk i was sized
            # for view i's throughput, so placement gets a soft
            # affinity to that worker
            chunk_prefs = [v.wid for v in views]
        depth = self.pipeline_depth
        if not tile and depth > 1:
            # pipelining: each worker share splits into `depth`
            # contiguous sub-chunks (backend + affinity preserved),
            # gathered as-completed below — the head ships sub-chunk
            # k+1 and merges k-1 while the worker computes k, instead
            # of the whole fleet idling through one synchronous barrier
            sub_r: List[range] = []
            sub_b: List[str] = []
            sub_p: List[Optional[int]] = []
            for r, bk, pw in zip(ranges, chunk_backends, chunk_prefs):
                d = max(1, min(depth, len(r)))
                edges = np.linspace(r.start, r.stop, d + 1).astype(int)
                for c in range(d):
                    sub_r.append(range(int(edges[c]),
                                       int(edges[c + 1])))
                    sub_b.append(bk)
                    sub_p.append(pw)
            ranges, chunk_backends, chunk_prefs = sub_r, sub_b, sub_p
        ub = self.unit_backend.setdefault(
            f"{body.__name__}@{parts_by['np'].code_hash[:8]}", {})
        # plan phase = everything so far except the split (body
        # serialization), which reports on its own — the two segments
        # around it both count as planning
        t_plan1 = time.perf_counter()
        ph.add_time("plan_s", (t_split0 - rt0) + (t_plan1 - t_split1))
        ph.add_time("split_s", t_split1 - t_split0)
        if tracing:
            rec = obs.recorder()
            rec.record("plan", "pfor", rt0, t_split0,
                       args={"round": rid})
            rec.record("split", "pfor", t_split0, t_split1,
                       args={"round": rid})
            rec.record("plan", "pfor", t_split1, t_plan1,
                       args={"round": rid})
        chunks = []
        for r, bk, pw in zip(ranges, chunk_backends, chunk_prefs):
            if len(r) == 0:
                continue
            tid = next(self._task_ids)
            out = self.plane.new_ref(tid)
            alt = None
            if bk != "np":
                # registry-ordered degradation chain (pallas → jnp →
                # np): each erroring attempt pops one step off
                chain = [b for b in backends_mod.degradation_chain(bk)
                         if b in bodies]
                alt = tuple((b, bids[b], parts_by[b]) for b in chain)
            spec = TaskSpec(tid, "chunk", None, (), out,
                            blob_id=bids[bk],
                            lo=r.start, hi=r.stop,
                            written=tuple(written),
                            sliced=slice_names, parts=parts_by[bk],
                            gather=True, backend=bk, alt=alt,
                            pref_wid=pw,
                            device_pref=(
                                backends_mod.get(bk).device_pref
                                if hetero else ""))
            ts = _TaskState(spec, deadline_s=deadline_s)
            if tracing:
                ts.span_meta = {"round": rid, "lo": r.start,
                                "hi": r.stop}
                ts.token = obs.begin("chunk_inflight", cat="pfor",
                                     round=rid, task=tid, lo=r.start,
                                     hi=r.stop, backend=bk)
            with self._lock:
                self._tasks[tid] = ts
                self._producer[out.oid] = tid
            self._dispatch(ts)
            chunks.append((out, spec))
            self.chunks_dispatched += 1
            ub[bk] = ub.get(bk, 0) + 1
        t_disp1 = time.perf_counter()
        # dispatch wall includes the per-chunk shipping done inside
        # _wire_spec — ship_s (accumulated there) is its subset
        ph.add_time("dispatch_s", t_disp1 - t_plan1)
        if tracing:
            obs.recorder().record("dispatch", "pfor", t_plan1, t_disp1,
                                  args={"round": rid,
                                        "chunks": len(chunks)})
        self.pfor_runs += 1
        try:
            if depth > 1 and len(chunks) > 1:
                self._gather_pipelined(chunks, arrays, body, rid,
                                       tracing, ph)
            else:
                # depth-1 synchronous round: gather in dispatch order
                for ref, spec in chunks:
                    self._gather_chunk(ref, spec, arrays, body, rid,
                                       tracing, ph)
        finally:
            # chunk updates are consumed; their lineage window is over.
            # Drop every per-chunk record so a serving loop calling the
            # kernel forever holds the head's memory flat. The blob
            # stays resident on the workers — that persistence is what
            # the next call's blob_hit re-uses.
            with self._lock:
                for ref, _ in chunks:
                    tid = self._producer.pop(ref.oid, None)
                    if tid is not None:
                        self._tasks.pop(tid, None)
            for ref, _ in chunks:
                self.plane.release(ref.oid)
            # if another caller's LRU churn evicted a blob of this run
            # while our chunks were in flight, a dispatch/resubmit may
            # have resurrected it on some worker after the unblob —
            # with no head-side record left, nothing would ever free
            # it. Drop each used blob again now that the run is over.
            for bk, bid in bids.items():
                with self._lock:
                    rec = self._blob_cache.get(parts_by[bk].blob_key)
                    evicted = rec is None or rec.bid != bid
                if evicted:
                    self._drop_blob(bid)
            rt1 = time.perf_counter()
            wall = rt1 - rt0
            ph.add_time("round_s", wall)
            with self._lock:
                busy = self._round_busy.pop(rid, 0.0)
                compute = self._round_compute.pop(rid, 0.0)
            if tracing:
                # compute = Σ worker "run" spans; idle = fleet capacity
                # the round left on the table (round wall × workers −
                # everything the workers spent on our chunks)
                nw = max(1, len(views))
                ph.add_time("compute_s", compute)
                ph.add_time("idle_s", max(0.0, wall * nw - busy))
                obs.recorder().record(
                    "pfor_round", "pfor", rt0, rt1,
                    args={"round": rid, "name": body.__name__,
                          "unit": getattr(body, "__unit__", None),
                          "chunks": len(chunks), "workers": nw,
                          "depth": depth})

    def distribute_profitable(self, flops: float, payload_bytes: int,
                              n_chunks: int,
                              sliced_bytes: float = 0.0) -> bool:
        """Local-vs-distributed decision from the measured device
        profiles (consumed by :mod:`repro.core.pfor`).
        ``payload_bytes`` is the broadcast part of the closure (rides to
        every worker); ``sliced_bytes`` is the chunk-sliceable part
        (ships once total, split across workers)."""
        from repro.core import cost
        profiles = self.profiles()
        return cost.cluster_distribute_profitable(
            flops, payload_bytes, profiles,
            max(1, n_chunks),
            local_gflops=self.local_profile.gflops,
            sliced_bytes=sliced_bytes)

    # -- compilation against the shared variant store ----------------------
    def compile(self, fn, **kw):
        """Compile a kernel bound to this runtime, warm-starting from the
        shared variant cache when ``cache_dir`` was given (a fleet of
        runtimes pointed at one directory compiles each kernel once)."""
        from repro.core.compiler import compile_kernel
        kw.setdefault("cache", self.variant_cache)
        kw.setdefault("workers", max(1, len(self._views())))
        return compile_kernel(fn, runtime=self, **kw)

    # -- fault injection / ops --------------------------------------------
    def kill_worker(self, wid: Optional[int] = None) -> Optional[int]:
        """SIGKILL a worker process (fault-injection drill). Lineage +
        resubmission recover its objects and in-flight tasks."""
        with self._lock:
            live = [wh for wh in self._handles.values()
                    if wh.alive and wh.proc is not None]
            if not live:
                return None
            victim = live[0]
            if wid is not None:
                for wh in live:
                    if wh.wid == wid:
                        victim = wh
                        break
        try:
            os.kill(victim.proc.pid, signal.SIGKILL)
        except (OSError, ProcessLookupError):
            return None
        return victim.wid

    # -- elastic membership ------------------------------------------------
    def add_worker(self, sim_gpu: bool = False,
                   timeout_s: float = 30.0) -> Optional[int]:
        """Grow the fleet by one mid-serving-loop: spawn, wait for its
        hello, re-measure capability + transport, and pre-warm it with
        the cached persistent bodies so the very next pfor round gives
        it its capability-proportional chunk share."""
        wh = self._spawn_worker(sim_gpu=sim_gpu)
        if not wh.hello.wait(timeout_s):
            return None
        self._reprofile(wh)
        self._ping_transport(wh)
        self._prewarm_blobs(wh)
        self._fault_event("joins", wid=wh.wid)
        return wh.wid

    def drain_worker(self, wid: Optional[int] = None) -> Optional[int]:
        """Shrink the fleet by one, cleanly: the worker takes no new
        chunks, finishes its in-flight tasks, hands its objects back to
        the head, then exits (all driven by the monitor)."""
        with self._lock:
            live = [wh for wh in self._handles.values()
                    if wh.alive and not wh.draining]
            if wid is not None:
                live = [wh for wh in live if wh.wid == wid]
            if not live:
                return None
            victim = live[-1]
            victim.draining = True
        return victim.wid

    def scale_to(self, n: int) -> None:
        """Elastic resize to ``n`` live workers: grows via
        :meth:`add_worker` (profiled + pre-warmed), shrinks by marking
        workers draining — they finish in-flight work and exit cleanly
        once the monitor sees them idle."""
        with self._lock:
            live = [wh for wh in self._handles.values()
                    if wh.alive and not wh.draining]
        delta = n - len(live)
        if delta > 0:
            for _ in range(delta):
                self.add_worker()
        elif delta < 0:
            for wh in live[:-delta]:
                self.drain_worker(wh.wid)

    def rotate_authkey(self, new: Optional[bytes] = None) -> bytes:
        """Swap the TCP transport's authkey. Connected workers learn
        the new key in-band (``rekey``) so their future reconnects keep
        working; anything holding the old key fails the challenge."""
        if self.listener is None:
            raise RuntimeError("authkey rotation needs transport='tcp'")
        key = self.listener.rotate(new)
        with self._lock:
            handles = [wh for wh in self._handles.values() if wh.alive]
        for wh in handles:
            try:
                wh.send(("rekey", key))
            except OSError:
                pass
        self._fault_event("rekeys")
        return key

    def queue_depth(self) -> int:
        """Unfinished tasks (duck-typed parity with TaskRuntime's pool
        depth — what the elastic controller scales on)."""
        with self._lock:
            return sum(1 for t in self._tasks.values() if not t.finished)

    def profiles(self) -> List[DeviceProfile]:
        with self._lock:
            return [wh.profile for wh in self._handles.values()
                    if wh.alive and wh.profile is not None]

    def workers_alive(self) -> int:
        with self._lock:
            return sum(1 for wh in self._handles.values() if wh.alive)

    def stats(self) -> Dict[str, Any]:
        with self._lock:
            tasks = len(self._tasks)
            done = sum(1 for t in self._tasks.values() if t.finished)
        out = {
            "workers": self.workers_alive(),
            "tasks": tasks,
            "completed": done,
            "replays": self.replays,
            "lineage_replays": self.replays,
            "resubmits": self.resubmits,
            "worker_deaths": self.worker_deaths,
            "pfor_runs": self.pfor_runs,
            "chunks_dispatched": self.chunks_dispatched,
            "bytes_shipped": self.bytes_shipped,
            "gpu_chunks": self.gpu_chunks,
            "cpu_chunks": self.cpu_chunks,
            "pallas_chunks": self.pallas_chunks,
            "pallas_fallbacks": self.pallas_fallbacks,
            "unit_backend": {k: dict(v)
                             for k, v in self.unit_backend.items()},
            "chunks_executed": dict(self.chunks_executed),
            "sliced_args": self.sliced_args,
            "bytes_saved_sliced": self.bytes_saved_sliced,
            "blob_hits": self.blob_hits,
            "blob_misses": self.blob_misses,
            "cells_shipped": self.cells_shipped,
            "cells_skipped": self.cells_skipped,
            "rows_skipped": self.rows_skipped,
            "bytes_saved_rows": self.bytes_saved_rows,
            "jit_hits": self.jit_hits,
            "jit_recompiles": self.jit_recompiles,
            "jit_fallbacks": self.jit_fallbacks,
            "jit_compile_s": self.jit_compile_s,
            "resident_hits": self.resident_hits,
            "resident_stages": self.resident_stages,
            "resident_cells": self.resident_cells,
            "pallas_calls": self.pallas_calls,
            "pallas_interpret_calls": self.pallas_interpret_calls,
            "pipeline_depth": self.pipeline_depth,
            "cached_blobs": len(self._blob_cache),
            "chunks_executed_by_worker":
                dict(self.chunks_executed_by_worker),
            "faults": self._faults.snapshot(),
            "fault_events": len(self.fault_events),
            "transport": self.transport,
            "plane": self.plane.stats(),
        }
        if self.chaos is not None:
            out["chaos"] = self.chaos.stats()
        return out

    def phase_breakdown(self) -> Dict[str, float]:
        """Measured per-phase seconds for this runtime's pfor rounds
        (``plan/split/ship/dispatch/gather/merge/round``, plus
        ``overlap``/``wait`` for pipelined rounds — ``wait`` is head
        time blocked on in-flight results, i.e. wall overlapped with
        worker compute — and ``compute``/``idle`` when tracing is on),
        straight from the ``cluster#N.phase`` scope of the unified
        metrics registry."""
        return self._phase.snapshot()

    def telemetry(self) -> Dict[str, Any]:
        out = self.stats()
        out["profiles"] = [p.as_dict() for p in self.profiles()]
        out["local_gflops"] = self.local_profile.gflops
        out["phases"] = self.phase_breakdown()
        if self.variant_cache is not None:
            out["cache"] = self.variant_cache.telemetry()
        return out

    def shutdown(self) -> None:
        self._shutdown = True
        if self.listener is not None:
            self.listener.close()
        with self._lock:
            handles = list(self._handles.values())
        for wh in handles:
            try:
                wh.send(("shutdown",))
            except OSError:
                pass
        deadline = time.monotonic() + 2.0
        for wh in handles:
            if wh.proc is None:
                continue   # external worker: the shutdown message (or
                           # its closed socket) is all we owe it
            wh.proc.join(max(0.05, deadline - time.monotonic()))
            if wh.proc.is_alive():
                wh.proc.terminate()
                wh.proc.join(1.0)
        for wh in handles:
            wh.close_conn()
        if self._trace_path and self.trace:
            try:
                obs.export_chrome_trace(self._trace_path)
            except OSError:
                pass

    def __enter__(self) -> "ClusterRuntime":
        return self

    def __exit__(self, *exc) -> None:
        self.shutdown()
