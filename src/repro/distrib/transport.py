"""Fault-tolerant multi-host transport for the cluster runtime.

The wire protocol (framed pickled tuples, see :mod:`.worker`) is
transport-agnostic; this module supplies the two link flavors the head
and workers ride on:

  * **pipe** — the original single-host ``multiprocessing.Pipe``
    transport, wrapped in :class:`PipeLink` so concurrent senders (the
    worker's main loop + its heartbeat thread) serialize on one lock;
  * **tcp** — a :class:`HeadListener` accepts socket connections from
    workers on *any* host, authenticating each with the
    ``multiprocessing.connection`` HMAC challenge protocol. The authkey
    is held by this module (not baked into the listener), so it can be
    **rotated** mid-flight: connected workers learn the new key via a
    ``("rekey", key)`` message and use it on their next reconnect, while
    a stale client fails the challenge and is counted, not served.

Workers connect through :class:`ReconnectingClient`: a transient socket
failure triggers reconnect with exponential backoff (bounded tries)
before the link is declared dead, and non-droppable outbound messages
("done"/"err"/"obj" results) are buffered in an outbox and flushed after
the rejoin handshake — so a blip mid-serving-loop loses no results.
Heartbeats are sent ``droppable=True`` and simply skip a dead window.

Handshake (first message on every authenticated connection):

  worker → head: ("attach", wid, reconnect_attempts)   # known worker
               | ("join", sim_gpu)                     # new external worker
  head → worker: ("welcome", wid) | ("denied", reason)

A ``denied`` reply fences the worker permanently: the head has already
declared it dead (its objects were marked LOST and replayed), so letting
it resume under its old wid would corrupt ownership bookkeeping. The
fenced worker exits instead.
"""

from __future__ import annotations

import secrets
import threading
import time
from collections import deque
from multiprocessing.connection import (AuthenticationError, Client,
                                        Listener, answer_challenge,
                                        deliver_challenge)
from typing import Any, Optional, Tuple

__all__ = ["AuthenticationError", "HeadListener", "PipeLink",
           "ReconnectingClient", "WorkerFencedError", "authed_connect",
           "new_authkey"]


def new_authkey() -> bytes:
    return secrets.token_bytes(24)


def _as_key(authkey) -> bytes:
    if authkey is None:
        return new_authkey()
    if isinstance(authkey, str):
        return authkey.encode("utf-8")
    return bytes(authkey)


class WorkerFencedError(ConnectionError):
    """The head refused this worker's (re)join — it was already declared
    dead (or chaos told the head to refuse). The worker must exit."""


class HeadListener:
    """Accept-side of the TCP transport, with a rotatable authkey.

    ``multiprocessing.connection.Listener`` bakes its authkey in at
    construction; we bind the listener *without* one and run the same
    mutual HMAC challenge manually per accept against ``self.authkey``,
    which :meth:`rotate` can swap at any time. A client holding a stale
    key fails the challenge — counted in ``auth_failures``, never
    served."""

    def __init__(self, address: Tuple[str, int] = ("127.0.0.1", 0),
                 authkey: Optional[bytes] = None, backlog: int = 16):
        self._listener = Listener(tuple(address), backlog=backlog)
        self.authkey = _as_key(authkey)
        self.address: Tuple[str, int] = self._listener.address
        self.auth_failures = 0
        self.rotations = 0

    def accept(self):
        """Accept + mutually authenticate one connection. Raises
        :class:`AuthenticationError` (counted) on a bad key, ``OSError``
        when the listener is closed."""
        conn = self._listener.accept()
        key = self.authkey   # snapshot: a rotation racing the handshake
        try:                 # judges this client by one consistent key
            deliver_challenge(conn, key)
            answer_challenge(conn, key)
        except (AuthenticationError, EOFError, OSError) as exc:
            self.auth_failures += 1
            try:
                conn.close()
            except OSError:
                pass
            raise AuthenticationError(f"client failed auth: {exc}")
        return conn

    def rotate(self, new: Optional[bytes] = None) -> bytes:
        """Swap the authkey (callers broadcast ``("rekey", key)`` to
        connected workers so their reconnects keep working)."""
        self.authkey = _as_key(new)
        self.rotations += 1
        return self.authkey

    def close(self) -> None:
        try:
            self._listener.close()
        except OSError:
            pass


def authed_connect(address: Tuple[str, int], authkey: bytes):
    """Client-side connect + mutual HMAC challenge (the inverse order of
    :meth:`HeadListener.accept`)."""
    conn = Client(tuple(address))
    try:
        answer_challenge(conn, authkey)
        deliver_challenge(conn, authkey)
    except (AuthenticationError, EOFError, OSError):
        try:
            conn.close()
        except OSError:
            pass
        raise
    return conn


class PipeLink:
    """Single-host link over an inherited ``multiprocessing``
    connection. The lock serializes the worker's concurrent senders
    (main loop + heartbeat thread); a pipe cannot reconnect, so any
    failure is terminal for the link."""

    def __init__(self, conn):
        self._conn = conn
        self._lock = threading.Lock()
        self.reconnect_attempts = 0

    def send(self, msg, droppable: bool = False) -> None:
        with self._lock:
            try:
                self._conn.send(msg)
            except (OSError, BrokenPipeError, ValueError, TypeError):
                if not droppable:
                    raise

    def recv(self):
        return self._conn.recv()

    def drop(self) -> None:
        """Sever the link (chaos drill). Pipes cannot reconnect, so this
        is equivalent to the head losing the worker."""
        self.close()

    def set_authkey(self, key: bytes) -> None:   # protocol parity
        pass

    def close(self) -> None:
        try:
            self._conn.close()
        except OSError:
            pass


class ReconnectingClient:
    """Worker-side TCP link: authed connect, attach/join handshake,
    reconnect-with-exponential-backoff on transient failure, and an
    outbox so results produced while disconnected are delivered after
    the rejoin instead of lost.

    Thread contract: ``recv`` is called from exactly one thread (the
    worker main loop) and drives reconnection; ``send`` may be called
    from any thread and never blocks on a reconnect — on a dead link a
    non-droppable message parks in the outbox (flushed post-rejoin) and
    a droppable one (heartbeats) is discarded."""

    def __init__(self, address: Tuple[str, int], authkey: bytes,
                 wid: Optional[int] = None, sim_gpu: bool = False,
                 max_tries: int = 8, base_delay_s: float = 0.05,
                 max_delay_s: float = 2.0,
                 welcome_timeout_s: float = 10.0):
        self.address = tuple(address)
        self.authkey = _as_key(authkey)
        self.wid = wid
        self.sim_gpu = sim_gpu
        self.max_tries = max_tries
        self.base_delay_s = base_delay_s
        self.max_delay_s = max_delay_s
        self.welcome_timeout_s = welcome_timeout_s
        self._conn = None
        self._lock = threading.RLock()
        self._outbox: deque = deque()
        self._connected_once = False
        self.reconnect_attempts = 0   # failed connect attempts, total
        self.reconnects = 0           # successful re-attaches
        self.fenced = False

    # -- connection management -------------------------------------------
    def connect(self) -> None:
        """Initial connect + handshake; raises if the head is
        unreachable within the retry budget or the join is denied."""
        if not self._reconnect():
            raise WorkerFencedError(
                f"could not attach to head at {self.address}")

    def _handshake(self, conn) -> None:
        if self.wid is None:
            conn.send(("join", self.sim_gpu))
        else:
            conn.send(("attach", self.wid, self.reconnect_attempts))
        if not conn.poll(self.welcome_timeout_s):
            raise OSError("no handshake reply from head")
        reply = conn.recv()
        if reply[0] == "denied":
            raise WorkerFencedError(str(reply[1:]))
        self.wid = reply[1]

    def _reconnect(self) -> bool:
        """(Re)establish the link. Returns False once fenced — by a
        denial or by exhausting the retry budget."""
        with self._lock:
            if self.fenced:
                return False
            if self._conn is not None:
                try:
                    self._conn.close()
                except OSError:
                    pass
                self._conn = None
            first = not self._connected_once
            delay = self.base_delay_s
            for _ in range(self.max_tries):
                try:
                    conn = authed_connect(self.address, self.authkey)
                except (AuthenticationError, OSError, EOFError):
                    self.reconnect_attempts += 1
                    time.sleep(delay)
                    delay = min(self.max_delay_s, delay * 2)
                    continue
                try:
                    self._handshake(conn)
                except WorkerFencedError:
                    self.fenced = True
                    try:
                        conn.close()
                    except OSError:
                        pass
                    return False
                except (OSError, EOFError):
                    self.reconnect_attempts += 1
                    try:
                        conn.close()
                    except OSError:
                        pass
                    time.sleep(delay)
                    delay = min(self.max_delay_s, delay * 2)
                    continue
                self._conn = conn
                self._connected_once = True
                if not first:
                    self.reconnects += 1
                self._flush_locked()
                return True
            self.fenced = True
            return False

    def _flush_locked(self) -> None:
        while self._outbox and self._conn is not None:
            try:
                self._conn.send(self._outbox[0])
                self._outbox.popleft()
            except (OSError, BrokenPipeError, ValueError, TypeError):
                self._mark_broken(self._conn)
                break

    def _mark_broken(self, conn) -> None:
        if self._conn is conn:
            try:
                conn.close()
            except OSError:
                pass
            self._conn = None

    # -- link protocol ----------------------------------------------------
    def send(self, msg, droppable: bool = False) -> None:
        with self._lock:
            if self._conn is not None:
                try:
                    self._conn.send(msg)
                    return
                except (OSError, BrokenPipeError, ValueError, TypeError):
                    self._mark_broken(self._conn)
            if not droppable:
                self._outbox.append(msg)
            # the recv thread (which notices the same dead socket
            # promptly — the peer closed it) drives the reconnect and
            # flushes the outbox after the rejoin handshake

    def recv(self):
        """Blocking receive; transparently reconnects on transient
        failure. Raises ``EOFError`` once the link is fenced or the
        retry budget is spent — the worker's signal to exit."""
        while True:
            with self._lock:
                conn = self._conn
            if conn is None:
                if not self._reconnect():
                    raise EOFError("transport fenced / retries exhausted")
                continue
            try:
                return conn.recv()
            except (EOFError, OSError):
                with self._lock:
                    self._mark_broken(conn)

    def drop(self) -> None:
        """Sever the current socket (chaos drill: transient failure).
        The next ``recv`` reconnects with backoff."""
        with self._lock:
            if self._conn is not None:
                self._mark_broken(self._conn)

    def set_authkey(self, key: bytes) -> None:
        """Adopt a rotated authkey for future reconnects."""
        with self._lock:
            self.authkey = _as_key(key)

    def close(self) -> None:
        with self._lock:
            self.fenced = True
            if self._conn is not None:
                try:
                    self._conn.close()
                except OSError:
                    pass
                self._conn = None
