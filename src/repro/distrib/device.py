"""Per-worker device profiles: measured capability, not configured.

Every worker process measures its own hardware at startup — a small
matmul for FLOP rate, a copy sweep for memory bandwidth, ``os`` probes
for core count and memory — and reports the profile in its hello
message. The head adds a measured transport bandwidth (payload ping over
the worker's pipe). The placement scheduler and the local-vs-distributed
profitability test in :mod:`repro.core.cost` consume these numbers; on a
heterogeneous fleet the pfor sharder sizes chunks proportional to
``gflops``.

GPU probing is gated behind ``REPRO_DISTRIB_PROBE_GPU=1`` because a jax
import costs seconds per worker process; the offline container is
CPU-only anyway.

For laptops/CI, ``REPRO_DISTRIB_SIM_GPU`` makes jax-CPU workers *pose*
as GPU workers so heterogeneous routing is exercisable anywhere:
``all``/``*`` marks every worker, a comma-separated wid list (e.g.
``1`` or ``0,2``) marks just those. A simulated GPU reports
``has_gpu=True``, ``gpu_kind="sim"`` and ``gpu_gflops = gflops ×
REPRO_DISTRIB_SIM_GPU_FACTOR`` (default 4) — routing and chunk sizing
behave exactly as with real hardware, the jnp bodies just execute on
the jax CPU backend.
"""

from __future__ import annotations

import os
import socket
import time
from dataclasses import asdict, dataclass
from typing import Any, Dict

import numpy as np


@dataclass
class DeviceProfile:
    wid: int
    host: str = ""
    pid: int = 0
    cpus: int = 1
    mem_bytes: int = 0
    gflops: float = 1.0            # measured matmul rate
    membw_gbs: float = 1.0         # measured copy bandwidth
    has_gpu: bool = False
    gpu_kind: str = ""             # "cuda" / "tpu" / "sim" / ""
    gpu_gflops: float = 0.0        # measured (or simulated) device rate
    transport_mbs: float = 0.0     # filled by the head's payload ping
    h2d_gbs: float = 0.0           # measured host→device staging bandwidth
    d2h_gbs: float = 0.0           # measured device→host gather bandwidth
    gpu_probe_error: str = ""      # why the GPU probe failed (if it did)

    def as_dict(self) -> Dict[str, Any]:
        return asdict(self)

    @staticmethod
    def from_dict(d: Dict[str, Any]) -> "DeviceProfile":
        return DeviceProfile(**d)


def _probe_mem_bytes() -> int:
    try:
        return (os.sysconf("SC_PAGE_SIZE")
                * os.sysconf("SC_PHYS_PAGES"))
    except (ValueError, OSError, AttributeError):
        return 0


def _probe_gpu() -> tuple:
    """(has_gpu, kind, gpu_gflops, h2d_gbs, d2h_gbs, error) — measured
    on the real device.

    x64 is enabled *before* the timing matmul: the jnp twin workloads
    this rate prices are float64 (PolyBench semantics), and an f32 probe
    reads ~2x optimistic against them. Probe failures are returned as a
    reason string — the head records it on the profile and counts it in
    the faults scope instead of silently reporting a bare CPU."""
    if os.environ.get("REPRO_DISTRIB_PROBE_GPU") != "1":
        return False, "", 0.0, 0.0, 0.0, ""
    try:
        import jax

        # must precede any traced op, and matches the twins' f64 math
        jax.config.update("jax_enable_x64", True)
        import jax.numpy as jnp
        devs = [d for d in jax.devices()
                if d.platform not in ("cpu",)]
        if not devs:
            return False, "", 0.0, 0.0, 0.0, "no non-cpu jax devices"
        n = 512
        a = jnp.ones((n, n), dtype=jnp.float64)
        (a @ a).block_until_ready()   # compile + warm
        best = float("inf")
        for _ in range(3):
            t0 = time.perf_counter()
            (a @ a).block_until_ready()
            best = min(best, time.perf_counter() - t0)
        gflops = 2.0 * n ** 3 / max(1e-9, best) / 1e9

        # staging bandwidth, both directions — what the chunk pricing in
        # core.cost actually spends per chunk (8 MB, the blob-cache
        # sweep size, so the number reflects bulk transfers)
        host = np.ones(1 << 20, dtype=np.float64)  # 8 MB
        dev = jax.device_put(host)
        dev.block_until_ready()
        h2d = d2h = float("inf")
        for _ in range(3):
            t0 = time.perf_counter()
            jax.device_put(host).block_until_ready()
            h2d = min(h2d, time.perf_counter() - t0)
            t0 = time.perf_counter()
            np.asarray(dev)
            d2h = min(d2h, time.perf_counter() - t0)
        h2d_gbs = host.nbytes / max(1e-9, h2d) / 1e9
        d2h_gbs = host.nbytes / max(1e-9, d2h) / 1e9
        return (True, devs[0].platform, round(gflops, 3),
                round(h2d_gbs, 3), round(d2h_gbs, 3), "")
    except Exception as exc:
        return False, "", 0.0, 0.0, 0.0, f"{type(exc).__name__}: {exc}"


def sim_gpu_for(wid: int) -> bool:
    """Does ``REPRO_DISTRIB_SIM_GPU`` mark this wid as a posing GPU?"""
    env = os.environ.get("REPRO_DISTRIB_SIM_GPU", "").strip()
    if not env:
        return False
    if env in ("all", "*"):
        return wid >= 0
    try:
        return wid in {int(x) for x in env.split(",") if x.strip()}
    except ValueError:
        return False


def measure_profile(wid: int, n: int = 128,
                    sim_gpu: bool = None) -> DeviceProfile:
    """Micro-benchmark this process. ``n`` keeps the probe ~milliseconds.
    ``sim_gpu`` forces the simulated-GPU pose (None = consult the
    ``REPRO_DISTRIB_SIM_GPU`` env var)."""
    rng = np.random.default_rng(wid + 1)
    a = rng.normal(size=(n, n))
    b = rng.normal(size=(n, n))
    a @ b  # warm the BLAS path
    reps = 5
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        a @ b
        best = min(best, time.perf_counter() - t0)
    # best-of-N: scheduler noise only ever *slows* a rep, so the fastest
    # one is the honest capability number on a shared host
    gflops = 2.0 * n ** 3 / max(1e-9, best) / 1e9

    buf = rng.normal(size=1 << 20)          # 8 MB
    buf.copy()
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        buf.copy()
        best = min(best, time.perf_counter() - t0)
    membw_gbs = 2.0 * buf.nbytes / max(1e-9, best) / 1e9  # read + write

    (has_gpu, gpu_kind, gpu_gflops,
     h2d_gbs, d2h_gbs, gpu_probe_error) = _probe_gpu()
    if sim_gpu is None:
        sim_gpu = sim_gpu_for(wid)
    if sim_gpu and not has_gpu:
        # jax-CPU posing as a GPU (laptops/CI): capability tags and the
        # pricing table see a device ``factor``× faster than the host np
        # rate; execution stays on the jax CPU backend
        factor = float(os.environ.get("REPRO_DISTRIB_SIM_GPU_FACTOR",
                                      "4"))
        has_gpu, gpu_kind = True, "sim"
        gpu_gflops = round(gflops * max(0.1, factor), 3)
    return DeviceProfile(
        wid=wid,
        host=socket.gethostname(),
        pid=os.getpid(),
        cpus=os.cpu_count() or 1,
        mem_bytes=_probe_mem_bytes(),
        gflops=round(gflops, 3),
        membw_gbs=round(membw_gbs, 3),
        has_gpu=has_gpu,
        gpu_kind=gpu_kind,
        gpu_gflops=gpu_gflops,
        h2d_gbs=h2d_gbs,
        d2h_gbs=d2h_gbs,
        gpu_probe_error=gpu_probe_error,
    )
