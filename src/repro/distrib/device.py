"""Per-worker device profiles: measured capability, not configured.

Every worker process measures its own hardware at startup — a small
matmul for FLOP rate, a copy sweep for memory bandwidth, ``os`` probes
for core count and memory — and reports the profile in its hello
message. The head adds a measured transport bandwidth (payload ping over
the worker's pipe). The placement scheduler and the local-vs-distributed
profitability test in :mod:`repro.core.cost` consume these numbers; on a
heterogeneous fleet the pfor sharder sizes chunks proportional to
``gflops``.

GPU probing is gated behind ``REPRO_DISTRIB_PROBE_GPU=1`` because a jax
import costs seconds per worker process; the offline container is
CPU-only anyway.
"""

from __future__ import annotations

import os
import socket
import time
from dataclasses import asdict, dataclass
from typing import Any, Dict

import numpy as np


@dataclass
class DeviceProfile:
    wid: int
    host: str = ""
    pid: int = 0
    cpus: int = 1
    mem_bytes: int = 0
    gflops: float = 1.0            # measured matmul rate
    membw_gbs: float = 1.0         # measured copy bandwidth
    has_gpu: bool = False
    gpu_kind: str = ""
    transport_mbs: float = 0.0     # filled by the head's payload ping

    def as_dict(self) -> Dict[str, Any]:
        return asdict(self)

    @staticmethod
    def from_dict(d: Dict[str, Any]) -> "DeviceProfile":
        return DeviceProfile(**d)


def _probe_mem_bytes() -> int:
    try:
        return (os.sysconf("SC_PAGE_SIZE")
                * os.sysconf("SC_PHYS_PAGES"))
    except (ValueError, OSError, AttributeError):
        return 0


def _probe_gpu() -> tuple:
    if os.environ.get("REPRO_DISTRIB_PROBE_GPU") != "1":
        return False, ""
    try:
        import jax
        devs = [d for d in jax.devices()
                if d.platform not in ("cpu",)]
        if devs:
            return True, devs[0].platform
    except Exception:
        pass
    return False, ""


def measure_profile(wid: int, n: int = 128) -> DeviceProfile:
    """Micro-benchmark this process. ``n`` keeps the probe ~milliseconds."""
    rng = np.random.default_rng(wid + 1)
    a = rng.normal(size=(n, n))
    b = rng.normal(size=(n, n))
    a @ b  # warm the BLAS path
    reps = 5
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        a @ b
        best = min(best, time.perf_counter() - t0)
    # best-of-N: scheduler noise only ever *slows* a rep, so the fastest
    # one is the honest capability number on a shared host
    gflops = 2.0 * n ** 3 / max(1e-9, best) / 1e9

    buf = rng.normal(size=1 << 20)          # 8 MB
    buf.copy()
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        buf.copy()
        best = min(best, time.perf_counter() - t0)
    membw_gbs = 2.0 * buf.nbytes / max(1e-9, best) / 1e9  # read + write

    has_gpu, gpu_kind = _probe_gpu()
    return DeviceProfile(
        wid=wid,
        host=socket.gethostname(),
        pid=os.getpid(),
        cpus=os.cpu_count() or 1,
        mem_bytes=_probe_mem_bytes(),
        gflops=round(gflops, 3),
        membw_gbs=round(membw_gbs, 3),
        has_gpu=has_gpu,
        gpu_kind=gpu_kind,
    )
