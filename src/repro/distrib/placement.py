"""Placement-aware scheduling over measured device profiles.

Score = capability + data locality − load, the three signals the paper's
Ray deployment gets from Ray's scheduler and we compute explicitly:

  * **capability** — the worker's measured GFLOP/s normalized across the
    fleet, plus a bonus when the task prefers a GPU and the worker has
    one, minus a penalty when the task prefers a CPU and the worker's
    GPU would sit idle under it (heterogeneous placement: jnp-body pfor
    chunks carry ``device_pref="gpu"``, their np twins ``"cpu"``, so a
    mixed fleet runs each body where it prices cheapest);
  * **locality** — the fraction of the task's input bytes already
    resident in the worker's object cache (results live where they were
    produced, so chained tasks gravitate to their producers);
  * **load** — outstanding tasks on the worker (queue-depth pressure).

The scheduler is deliberately stateless over ``WorkerView`` snapshots so
it unit-tests without any processes.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

from .device import DeviceProfile
from .objects import TaskSpec


@dataclass
class WorkerView:
    """Scheduler-visible snapshot of one worker."""

    wid: int
    profile: DeviceProfile
    outstanding: int = 0
    resident: Dict[int, int] = field(default_factory=dict)  # oid → bytes


@dataclass(frozen=True)
class PlacementWeights:
    capability: float = 1.0
    locality: float = 2.0       # moving bytes beats moving flops
    load: float = 0.5
    gpu_bonus: float = 4.0
    # keep np-body chunks off GPU-capable workers (whose cycles the
    # hetero sharder already budgeted for jnp chunks); soft, so a
    # CPU-less fleet still runs everything
    cpu_pref_penalty: float = 2.0
    # stick a chunk to the worker its range was *sized for*
    # (proportional_chunks paired range i with view i's throughput);
    # soft — load pressure or a death still moves it elsewhere
    affinity: float = 2.0


class PlacementScheduler:
    def __init__(self, weights: PlacementWeights = PlacementWeights()):
        self.weights = weights

    def score(self, task: TaskSpec, view: WorkerView,
              max_gflops: float, arg_bytes: Dict[int, int]) -> float:
        w = self.weights
        cap = (view.profile.gflops / max_gflops) if max_gflops > 0 else 0.0
        s = w.capability * cap
        if task.device_pref == "gpu" and view.profile.has_gpu:
            s += w.gpu_bonus
        elif task.device_pref == "cpu" and view.profile.has_gpu:
            s -= w.cpu_pref_penalty
        if getattr(task, "pref_wid", None) == view.wid:
            s += w.affinity
        total = sum(arg_bytes.values())
        if total > 0:
            local = sum(nb for oid, nb in arg_bytes.items()
                        if oid in view.resident)
            s += w.locality * (local / total)
        s -= w.load * view.outstanding
        return s

    def place(self, task: TaskSpec, views: Sequence[WorkerView],
              arg_bytes: Optional[Dict[int, int]] = None) -> int:
        """Pick a worker id for ``task``; ties break to the lowest wid so
        placement is deterministic for tests."""
        if not views:
            raise RuntimeError("no live workers to place on")
        arg_bytes = arg_bytes or {}
        max_gflops = max(v.profile.gflops for v in views)
        best_wid, best_score = None, None
        for v in sorted(views, key=lambda v: v.wid):
            sc = self.score(task, v, max_gflops, arg_bytes)
            if best_score is None or sc > best_score:
                best_wid, best_score = v.wid, sc
        return best_wid

    @staticmethod
    def proportional_chunks(lo: int, hi: int,
                            weights: Sequence[float],
                            drop_empty: bool = True) -> List[range]:
        """Split [lo, hi) into one contiguous chunk per weight, sized
        proportional to the weights — the heterogeneous answer to equal
        tiling (a 2× faster worker gets a 2× larger chunk).

        ``drop_empty=False`` keeps zero-length ranges so the result
        stays index-aligned with ``weights`` — callers pairing chunks
        with per-worker metadata (e.g. the hetero sharder's
        backend-per-view table) need the alignment; a worker whose
        share rounds to zero must not shift every later chunk onto the
        wrong worker's backend."""
        n = hi - lo
        if n <= 0 or not weights:
            return []
        total = sum(max(1e-9, w) for w in weights)
        cuts, acc = [lo], 0.0
        for w in weights[:-1]:
            acc += max(1e-9, w)
            cuts.append(lo + int(round(n * acc / total)))
        cuts.append(hi)
        # enforce monotone non-overlapping cuts
        for i in range(1, len(cuts)):
            cuts[i] = min(hi, max(cuts[i], cuts[i - 1]))
        ranges = [range(cuts[i], cuts[i + 1])
                  for i in range(len(cuts) - 1)]
        if drop_empty:
            return [r for r in ranges if len(r) > 0]
        return ranges
