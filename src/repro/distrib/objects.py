"""The serialized object plane: ownership directory + lineage records.

Objects produced by cluster tasks live **where they were produced** (the
owning worker's in-process cache); the head keeps only a directory entry
(owner, size) unless the value was small enough to inline. ``get`` pulls
on demand; a dead owner turns the entry LOST and the lineage record —
the serialized task spec — is replayed on a surviving worker, exactly
the recovery contract :mod:`repro.runtime.lineage` implements inside one
process.
"""

from __future__ import annotations

import itertools
import threading
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

# object states
HEAD = "head"          # value held by the head (inlined / put())
REMOTE = "remote"      # value held by the owning worker
PENDING = "pending"    # producing task not finished yet
LOST = "lost"          # owner died before the value reached the head


@dataclass(frozen=True)
class ClusterRef:
    """Future-like handle to an object in the cluster plane."""

    oid: int
    task_id: Optional[int] = None   # producing task (lineage edge)

    def __repr__(self) -> str:
        return f"ClusterRef(oid={self.oid}, task={self.task_id})"


@dataclass
class ObjectMeta:
    oid: int
    state: str = PENDING
    owner: Optional[int] = None     # wid when state == REMOTE
    nbytes: int = 0
    value: Any = None               # when state == HEAD


@dataclass
class TaskSpec:
    """Serialized, replayable description of one cluster task.

    ``fn_blob`` is a :func:`repro.distrib.serial.dumps_fn` payload;
    ``args`` holds plain values and :class:`ClusterRef` placeholders.
    Chunk tasks reference a broadcast body blob instead and carry the
    iteration range. Both forms are self-contained enough to re-dispatch
    to any worker — that property *is* the lineage guarantee."""

    task_id: int
    kind: str                       # 'fn' | 'chunk'
    fn_blob: Optional[bytes]
    args: Tuple[Any, ...]
    out: ClusterRef
    blob_id: Optional[int] = None   # chunk: broadcast body
    lo: int = 0
    hi: int = 0
    written: Tuple[str, ...] = ()
    # chunk: arrays shipped as row slices [lo, hi) instead of riding in
    # the broadcast blob; their gathered updates arrive in chunk-local
    # coordinates and are re-based by the head
    sliced: Tuple[str, ...] = ()
    # chunk: the head-side ClosureParts this spec slices from — a live
    # reference (never pickled; the wire form is built in _wire_spec),
    # which is exactly what makes a mid-run replay self-contained
    parts: Any = None
    gather: bool = False            # force the result inline to the head
    device_pref: str = ""           # '' | 'cpu' | 'gpu'
    est_flops: float = 0.0
    attempts: int = 0
    # chunk: which body variant this spec executes (a registered
    # backend name — "np" | "jnp" | "pallas" | …); the hetero sharder
    # prices the choice per worker profile
    backend: str = "np"
    # chunk: the degradation chain — a tuple of (backend, blob_id,
    # parts) steps ordered by the registry (pallas → jnp → np). A chunk
    # that *errors* on a worker (jax missing there, a pallas lowering
    # failing at run time) pops one step on resubmit instead of burning
    # all its attempts. A bare (backend, blob_id, parts) triple (the
    # pre-registry single-step form) is still accepted.
    alt: Optional[Tuple[Any, ...]] = None
    # chunk: the worker whose measured throughput this range was sized
    # for — a soft placement affinity, so proportional chunking stays
    # meaningful (without it, small pipelined sub-chunks all drain to
    # whichever worker finishes fastest and the sizing is moot)
    pref_wid: Optional[int] = None


class ObjectPlane:
    """Head-side directory of every cluster object. Thread-safe."""

    def __init__(self):
        self._lock = threading.Lock()
        self._meta: Dict[int, ObjectMeta] = {}
        self._ids = itertools.count(1)
        self._events: Dict[int, threading.Event] = {}
        self.inlined = 0
        self.lost_marks = 0

    def new_ref(self, task_id: Optional[int] = None) -> ClusterRef:
        with self._lock:
            oid = next(self._ids)
            self._meta[oid] = ObjectMeta(oid)
            self._events[oid] = threading.Event()
        return ClusterRef(oid, task_id)

    def put_local(self, value: Any) -> ClusterRef:
        ref = self.new_ref()
        self.fulfill_inline(ref.oid, value)
        return ref

    # -- state transitions ------------------------------------------------
    def fulfill_inline(self, oid: int, value: Any) -> None:
        with self._lock:
            m = self._meta[oid]
            # value before state: readers access ObjectMeta fields
            # without the lock, and a HEAD state must imply the value
            # is already there
            m.value = value
            m.nbytes = int(getattr(value, "nbytes", 0) or 0)
            m.state = HEAD
            self.inlined += 1
            ev = self._events[oid]
        ev.set()

    def fulfill_remote(self, oid: int, owner: int, nbytes: int) -> None:
        with self._lock:
            m = self._meta[oid]
            # an inlined value never downgrades to a remote pointer
            if m.state != HEAD:
                m.state = REMOTE
                m.owner = owner
                m.nbytes = nbytes
            ev = self._events[oid]
        ev.set()

    def promote(self, oid: int, value: Any) -> None:
        """A remote value just arrived at the head: cache it."""
        with self._lock:
            m = self._meta[oid]
            m.value = value     # value before state (unlocked readers)
            m.state = HEAD

    def mark_worker_lost(self, wid: int) -> List[int]:
        """Owner died: every object it held becomes LOST (and un-ready
        so waiters fall through to lineage replay). Returns the oids."""
        lost = []
        with self._lock:
            for m in self._meta.values():
                if m.state == REMOTE and m.owner == wid:
                    m.state = LOST
                    m.owner = None
                    self._events[m.oid] = threading.Event()
                    lost.append(m.oid)
                    self.lost_marks += 1
        return lost

    def reset_pending(self, oid: int) -> None:
        """Replay is about to re-produce this object."""
        with self._lock:
            m = self._meta[oid]
            m.state = PENDING
            m.value = None
            self._events[oid] = threading.Event()

    def try_reset_lost(self, oid: int) -> bool:
        """Atomically claim a LOST object for replay. Exactly one of any
        number of concurrent getters wins; the rest keep waiting."""
        with self._lock:
            m = self._meta[oid]
            if m.state != LOST:
                return False
            m.state = PENDING
            m.value = None
            self._events[oid] = threading.Event()
            return True

    def release(self, oid: int) -> None:
        """Forget an object entirely (directory entry + value + event).
        For consumed intermediates — pfor chunk updates — whose lineage
        window closed with the run that gathered them."""
        with self._lock:
            self._meta.pop(oid, None)
            self._events.pop(oid, None)

    # -- queries ----------------------------------------------------------
    def contains(self, oid: int) -> bool:
        """Whether the directory still tracks ``oid`` (False once
        :meth:`release` consumed it — e.g. a duplicate/late "done" for a
        chunk whose pfor round already gathered and dropped it)."""
        with self._lock:
            return oid in self._meta

    def meta(self, oid: int) -> ObjectMeta:
        with self._lock:
            return self._meta[oid]

    def wait_ready(self, oid: int, timeout: Optional[float]) -> bool:
        with self._lock:
            ev = self._events.get(oid)
        if ev is None:
            return True  # released (consumed): nothing left to wait on
        return ev.wait(timeout)

    def resident_on(self, wid: int) -> Dict[int, int]:
        """oid → nbytes of every object currently owned by ``wid``."""
        with self._lock:
            return {m.oid: m.nbytes for m in self._meta.values()
                    if m.state == REMOTE and m.owner == wid}

    def stats(self) -> Dict[str, int]:
        with self._lock:
            states: Dict[str, int] = {}
            for m in self._meta.values():
                states[m.state] = states.get(m.state, 0) + 1
        return {"objects": sum(states.values()), **states,
                "inlined": self.inlined, "lost_marks": self.lost_marks}
