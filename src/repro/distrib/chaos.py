"""Deterministic fault-injection harness for the cluster runtime.

Every recovery path the runtime claims — worker death, hang, slowdown,
transient socket loss, message delay/duplication/drop, refused rejoin —
is exercisable on demand and **seeded**, so a chaos drill that fails in
CI replays bit-identically on a laptop.

Two injection planes:

  * **process/behavior faults** — helpers that tell a live worker to
    misbehave via a ``("chaos", op, arg)`` control message:
    :func:`hang` (stop making progress, optionally silencing heartbeats
    so liveness monitoring fires), :func:`slow` (fixed latency before
    every task), :func:`drop_conn` (sever the socket → reconnect/backoff
    drill), :func:`babble` (emit a malformed protocol message),
    :func:`exit` (clean self-termination). :func:`kill` SIGKILLs from
    the head side (the pre-existing drill). :func:`refuse_reconnect`
    fences a wid so its next rejoin is denied.

  * **message faults** — :class:`ChaosPlan` + :class:`ChaosWire`: the
    head wraps each worker connection's *send* side; messages may be
    dropped, duplicated, or delayed by a seeded RNG. Delay preserves
    FIFO order (one sender thread drains a due-time queue), because the
    wire protocol's blob-before-task ordering must hold even under
    chaos — chaos models a slow/lossy network, not a reordering one.
    ``drop_kinds``/``delay_kinds``/``dup_kinds`` narrow injection to
    specific message kinds and ``max_drops``/``max_dups`` bound the
    blast radius so drills terminate.

Pass a plan to the runtime: ``ClusterRuntime(chaos=ChaosPlan(seed=7,
delay_s=0.005))``. Counters on the plan (``dropped``/``duplicated``/
``delayed``) plus the runtime's ``faults`` metrics scope tell the drill
what actually fired.
"""

from __future__ import annotations

import random
import threading
import time
from dataclasses import dataclass, field
from typing import Optional, Sequence, Set, Tuple

__all__ = ["ChaosPlan", "ChaosWire", "kill", "hang", "slow",
           "drop_conn", "babble", "exit_worker", "refuse_reconnect"]


@dataclass
class ChaosPlan:
    """Seeded message-fault schedule, shared by every wire the runtime
    wraps with it (each wire derives its own RNG from ``(seed, wid)``,
    so per-worker decisions stay deterministic regardless of thread
    interleaving)."""

    seed: int = 0
    drop_p: float = 0.0
    dup_p: float = 0.0
    delay_s: float = 0.0
    # restrict injection to these message kinds (first tuple element);
    # empty = all kinds
    drop_kinds: Tuple[str, ...] = ()
    dup_kinds: Tuple[str, ...] = ()
    delay_kinds: Tuple[str, ...] = ()
    # hard budgets so a drill with p=1.0 still terminates/recovers
    max_drops: Optional[int] = None
    max_dups: Optional[int] = None
    # wids whose rejoin the head must deny (exercises the fenced path)
    refuse_rejoin: Set[int] = field(default_factory=set)
    # observed injections
    dropped: int = 0
    duplicated: int = 0
    delayed: int = 0
    _lock: threading.Lock = field(default_factory=threading.Lock,
                                  repr=False)

    def _kind(self, msg) -> str:
        try:
            return str(msg[0])
        except (TypeError, IndexError):
            return "?"

    def _may(self, kinds: Tuple[str, ...], kind: str) -> bool:
        return not kinds or kind in kinds

    def take_drop(self, rng: random.Random, msg) -> bool:
        kind = self._kind(msg)
        if not self._may(self.drop_kinds, kind) or self.drop_p <= 0:
            return False
        if rng.random() >= self.drop_p:
            return False
        with self._lock:
            if self.max_drops is not None and \
                    self.dropped >= self.max_drops:
                return False
            self.dropped += 1
        return True

    def take_dup(self, rng: random.Random, msg) -> bool:
        kind = self._kind(msg)
        if not self._may(self.dup_kinds, kind) or self.dup_p <= 0:
            return False
        if rng.random() >= self.dup_p:
            return False
        with self._lock:
            if self.max_dups is not None and \
                    self.duplicated >= self.max_dups:
                return False
            self.duplicated += 1
        return True

    def take_delay(self, rng: random.Random, msg) -> float:
        kind = self._kind(msg)
        if not self._may(self.delay_kinds, kind) or self.delay_s <= 0:
            return 0.0
        with self._lock:
            self.delayed += 1
        return self.delay_s

    def stats(self) -> dict:
        return {"seed": self.seed, "dropped": self.dropped,
                "duplicated": self.duplicated, "delayed": self.delayed}


class ChaosWire:
    """Connection wrapper injecting the plan's message faults on the
    **send** path (receive passes through untouched). Delayed sends are
    drained FIFO by one background thread, so relative order — the
    protocol's only ordering requirement — is preserved; drops and
    duplicates happen at enqueue time.

    Failure semantics shift under delay: a send that would have raised
    synchronously (dead peer) now fails on the drain thread and the
    loss surfaces via the receiver's connection-lost path instead —
    exactly how a real buffered network behaves."""

    def __init__(self, conn, plan: ChaosPlan, peer: int = 0):
        self._conn = conn
        self.plan = plan
        self.peer = peer
        # str seeding hashes via sha512 — deterministic across processes
        self._rng = random.Random(f"{plan.seed}:{peer}")
        self._cv = threading.Condition()
        self._queue = []          # [(due, seq, msg)] FIFO by seq
        self._seq = 0
        self._closed = False
        self._sender: Optional[threading.Thread] = None

    # -- sender thread (lazy: only when a delay is actually injected) ----
    def _ensure_sender(self) -> None:
        if self._sender is None:
            self._sender = threading.Thread(
                target=self._drain, name=f"chaos-wire-{self.peer}",
                daemon=True)
            self._sender.start()

    def _drain(self) -> None:
        while True:
            with self._cv:
                while not self._queue and not self._closed:
                    self._cv.wait(0.5)
                if self._closed and not self._queue:
                    return
                due, _, msg = self._queue[0]
                now = time.monotonic()
                if due > now:
                    self._cv.wait(due - now)
                    continue
                self._queue.pop(0)
            try:
                self._conn.send(msg)
            except (OSError, BrokenPipeError, ValueError, TypeError):
                with self._cv:
                    self._queue.clear()
                    self._closed = True
                return

    def send(self, msg) -> None:
        if self.plan.take_drop(self._rng, msg):
            return
        copies = 2 if self.plan.take_dup(self._rng, msg) else 1
        delay = self.plan.take_delay(self._rng, msg)
        with self._cv:
            queued = bool(self._queue)
        if delay <= 0 and not queued:
            for _ in range(copies):
                self._conn.send(msg)
            return
        # FIFO through the drain thread (even zero-delay messages must
        # queue behind an in-flight delayed one to keep order)
        self._ensure_sender()
        with self._cv:
            if self._closed:
                raise OSError("chaos wire closed")
            due = time.monotonic() + delay
            for _ in range(copies):
                self._queue.append((due, self._seq, msg))
                self._seq += 1
            self._cv.notify_all()

    def recv(self):
        return self._conn.recv()

    def poll(self, timeout=0.0):
        return self._conn.poll(timeout)

    def fileno(self):
        return self._conn.fileno()

    def close(self) -> None:
        with self._cv:
            self._closed = True
            self._cv.notify_all()
        try:
            self._conn.close()
        except OSError:
            pass


# -- behavior-fault helpers (head-side API) -------------------------------

def _send_op(rt, wid: Optional[int], op: str, arg=None) -> Optional[int]:
    """Deliver one chaos control message; returns the targeted wid or
    None when no live worker matched."""
    with rt._lock:
        live = [wh for wh in rt._handles.values() if wh.alive]
        if wid is not None:
            live = [wh for wh in live if wh.wid == wid]
    if not live:
        return None
    wh = live[0]
    try:
        wh.send(("chaos", op, arg))
    except OSError:
        return None
    return wh.wid


def kill(rt, wid: Optional[int] = None) -> Optional[int]:
    """SIGKILL a worker process (hard crash)."""
    return rt.kill_worker(wid)


def hang(rt, wid: Optional[int] = None,
         seconds: Optional[float] = None,
         silence_heartbeat: bool = True) -> Optional[int]:
    """Make a worker stop making progress for ``seconds`` (forever when
    None). With ``silence_heartbeat`` the hang looks like a dead process
    to the liveness monitor; without it, heartbeats keep flowing and
    only per-task deadlines can catch the wedge."""
    return _send_op(rt, wid, "hang",
                    {"seconds": seconds, "silence_hb": silence_heartbeat})


def slow(rt, wid: Optional[int] = None,
         per_task_s: float = 0.1) -> Optional[int]:
    """Inject fixed latency before every subsequent task on a worker."""
    return _send_op(rt, wid, "slow", per_task_s)


def drop_conn(rt, wid: Optional[int] = None) -> Optional[int]:
    """Sever a worker's socket (transient network failure). TCP workers
    reconnect with exponential backoff; pipe workers die."""
    return _send_op(rt, wid, "drop_conn")


def babble(rt, wid: Optional[int] = None) -> Optional[int]:
    """Make a worker emit one malformed protocol message (exercises the
    head's malformed-message accounting)."""
    return _send_op(rt, wid, "babble")


def exit_worker(rt, wid: Optional[int] = None) -> Optional[int]:
    """Clean self-termination (vs :func:`kill`'s SIGKILL)."""
    return _send_op(rt, wid, "exit")


def refuse_reconnect(rt, wid: int, plan: Optional[ChaosPlan] = None
                     ) -> None:
    """Deny this wid's next rejoin attempt — the worker is fenced and
    must exit; the head declares it dead when the reconnect grace
    expires."""
    plan = plan if plan is not None else getattr(rt, "chaos", None)
    if plan is None:
        plan = rt.chaos = ChaosPlan()
    plan.refuse_rejoin.add(wid)
