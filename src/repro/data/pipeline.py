"""Data pipeline: deterministic synthetic token streams, host-sharded,
with background prefetch.

Every host materializes only its shard of the global batch (shape
(global_batch/dp_shards, seq)); the loader is seeded per (host, step) so
restarts resume deterministically from the checkpointed step — the data
side of checkpoint/restart fault tolerance.
"""

from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np


@dataclass
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    num_hosts: int = 1
    host_id: int = 0
    seed: int = 1234
    embeds_dim: int = 0       # >0 → produce 'embeds' instead of tokens
    src_len: int = 0          # >0 → enc-dec: produce 'src_embeds'
    d_model: int = 0


class SyntheticTokens:
    """Zipf-ish synthetic language with local structure (so losses move)."""

    def __init__(self, cfg: DataConfig):
        assert cfg.global_batch % cfg.num_hosts == 0
        self.cfg = cfg
        self.local_batch = cfg.global_batch // cfg.num_hosts

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng(
            (cfg.seed * 1_000_003 + step) * 31 + cfg.host_id)
        b, s = self.local_batch, cfg.seq_len
        # markov-ish: next token = prev + small step (mod vocab) — low
        # entropy (≤ ln 3) so smoke-training measurably learns it
        start = rng.integers(0, cfg.vocab, size=(b, 1))
        steps = rng.integers(1, 4, size=(b, s - 1))
        toks = np.concatenate([start, steps], axis=1).cumsum(axis=1) \
            % cfg.vocab
        tokens = toks.astype(np.int32)
        labels = np.roll(tokens, -1, axis=1).astype(np.int32)
        labels[:, -1] = -100 if False else 0  # last position: predict 0
        out: Dict[str, np.ndarray] = {"labels": labels}
        if cfg.embeds_dim > 0:
            emb = rng.normal(size=(b, s, cfg.embeds_dim)) * 0.02
            out["embeds"] = emb.astype(np.float32)
        else:
            out["tokens"] = tokens
        if cfg.src_len > 0:
            src = rng.normal(size=(b, cfg.src_len, cfg.d_model)) * 0.02
            out["src_embeds"] = src.astype(np.float32)
        return out

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class Prefetcher:
    """Background-thread prefetch (depth-N pipeline ahead of the step)."""

    def __init__(self, source: SyntheticTokens, depth: int = 2,
                 start_step: int = 0):
        self.source = source
        self.q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._step = start_step
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._loop, daemon=True)
        self._thread.start()

    def _loop(self):
        while not self._stop.is_set():
            batch = self.source.batch_at(self._step)
            self._step += 1
            while not self._stop.is_set():
                try:
                    self.q.put(batch, timeout=0.1)
                    break
                except queue.Full:
                    continue

    def next(self, timeout: float = 10.0) -> Dict[str, np.ndarray]:
        return self.q.get(timeout=timeout)

    def stop(self):
        self._stop.set()


def make_pipeline(cfg: DataConfig, start_step: int = 0,
                  prefetch: int = 2) -> Prefetcher:
    return Prefetcher(SyntheticTokens(cfg), depth=prefetch,
                      start_step=start_step)
