"""Persistent specialization cache: compiled variants that survive restarts.

Keyed by ``(source hash, type signature, backend)``. Each entry holds the
generated variant sources plus the schedule metadata ``core/codegen.py``
produced, so a warm process rebuilds the multi-version dispatcher by
``exec``-ing stored source — skipping parse → SCoP → dependence →
schedule → codegen entirely. This is what turns the per-script compiler
into a serving-grade system: cold compile once, warm-start everywhere.

Everything stored is either generated Python source (text) or plain
dataclasses (``Schedule``/TIR/``TypeInfo`` — no callables), so pickle is
safe and stable. Writes are atomic (tempfile + ``os.replace``) so
concurrent processes sharing one cache directory never observe torn
entries; last-writer-wins is fine because entries are deterministic
functions of their key.
"""

from __future__ import annotations

import hashlib
import inspect
import json
import os
import pickle
import tempfile
import textwrap
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

_PICKLE_PROTO = 4
_FORMAT_VERSION = 1


def source_hash(fn_or_src) -> str:
    """Stable digest of a kernel's (dedented) source text."""
    if callable(fn_or_src):
        src = textwrap.dedent(inspect.getsource(fn_or_src))
    else:
        src = textwrap.dedent(str(fn_or_src))
    return hashlib.sha256(src.encode()).hexdigest()[:16]


def cache_key(src_hash: str, type_sig: str, backend: str) -> str:
    raw = f"v{_FORMAT_VERSION}|{src_hash}|{type_sig}|{backend}"
    return hashlib.sha256(raw.encode()).hexdigest()[:24]


@dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    puts: int = 0
    errors: int = 0
    # compiles that were skipped entirely thanks to a hit
    codegen_skipped: int = 0

    def as_dict(self) -> Dict[str, int]:
        return {"hits": self.hits, "misses": self.misses,
                "puts": self.puts, "errors": self.errors,
                "codegen_skipped": self.codegen_skipped}


@dataclass
class CacheEntry:
    """One compiled kernel: schedule + generated variant sources."""

    fn_name: str
    src_hash: str
    type_sig: str
    backend: str
    params: List[Tuple[str, Any]]       # (name, TypeInfo)
    sched: Any                          # core.schedule.Schedule
    generated: Dict[str, Any]           # variant name → GeneratedVariant
    compile_s: float = 0.0              # cold compile wall time
    created_at: float = field(default_factory=time.time)


class VariantCache:
    """On-disk store of :class:`CacheEntry` objects.

    A fresh ``VariantCache(same_dir)`` in a new process sees every entry
    the old process put — that is the whole point.
    """

    def __init__(self, cache_dir: str):
        self.cache_dir = os.path.abspath(os.path.expanduser(cache_dir))
        os.makedirs(self.cache_dir, exist_ok=True)
        self.stats = CacheStats()

    # -- paths ----------------------------------------------------------
    def _path(self, key: str) -> str:
        return os.path.join(self.cache_dir, f"{key}.pkl")

    # -- core API -------------------------------------------------------
    def get(self, src_hash: str, type_sig: str,
            backend: str) -> Optional[CacheEntry]:
        key = cache_key(src_hash, type_sig, backend)
        path = self._path(key)
        if not os.path.exists(path):
            self.stats.misses += 1
            return None
        try:
            with open(path, "rb") as f:
                entry = pickle.load(f)
        except Exception:
            # corrupt/stale entry: treat as miss, drop it
            self.stats.errors += 1
            self.stats.misses += 1
            try:
                os.unlink(path)
            except OSError:
                pass
            return None
        self.stats.hits += 1
        return entry

    def put(self, entry: CacheEntry) -> str:
        key = cache_key(entry.src_hash, entry.type_sig, entry.backend)
        path = self._path(key)
        fd, tmp = tempfile.mkstemp(dir=self.cache_dir, suffix=".tmp")
        try:
            with os.fdopen(fd, "wb") as f:
                pickle.dump(entry, f, protocol=_PICKLE_PROTO)
            os.replace(tmp, path)
        except Exception:
            self.stats.errors += 1
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise
        self.stats.puts += 1
        return key

    # -- maintenance ----------------------------------------------------
    def entries(self) -> List[str]:
        return sorted(k[:-4] for k in os.listdir(self.cache_dir)
                      if k.endswith(".pkl"))

    def clear(self) -> int:
        n = 0
        for name in os.listdir(self.cache_dir):
            if name.endswith(".pkl"):
                os.unlink(os.path.join(self.cache_dir, name))
                n += 1
        return n

    def telemetry(self) -> Dict[str, Any]:
        return {"dir": self.cache_dir,
                "entries": len(self.entries()),
                **self.stats.as_dict()}

    def dump_index(self) -> str:
        """Write a human-readable index.json next to the entries."""
        idx = []
        for key in self.entries():
            try:
                with open(self._path(key), "rb") as f:
                    e = pickle.load(f)
                idx.append({"key": key, "fn": e.fn_name,
                            "type_sig": e.type_sig, "backend": e.backend,
                            "compile_s": round(e.compile_s, 4),
                            "created_at": e.created_at})
            except Exception:
                continue
        path = os.path.join(self.cache_dir, "index.json")
        with open(path, "w") as f:
            json.dump(idx, f, indent=2)
        return path
