"""Persistent specialization cache: compiled variants that survive restarts.

Keyed by ``(source hash, type signature, backend)``. Each entry holds the
generated variant sources plus the schedule metadata ``core/codegen.py``
produced, so a warm process rebuilds the multi-version dispatcher by
``exec``-ing stored source — skipping parse → SCoP → dependence →
schedule → codegen entirely. This is what turns the per-script compiler
into a serving-grade system: cold compile once, warm-start everywhere.

Everything stored is either generated Python source (text) or plain
dataclasses (``Schedule``/TIR/``TypeInfo`` — no callables), so pickle is
safe and stable. Writes are atomic (tempfile + ``os.replace``) so
concurrent processes sharing one cache directory never observe torn
entries; last-writer-wins is fine because entries are deterministic
functions of their key.
"""

from __future__ import annotations

import hashlib
import inspect
import json
import os
import pickle
import tempfile
import textwrap
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

_PICKLE_PROTO = 4
_FORMAT_VERSION = 1


def source_hash(fn_or_src) -> str:
    """Stable digest of a kernel's (dedented) source text."""
    if callable(fn_or_src):
        src = textwrap.dedent(inspect.getsource(fn_or_src))
    else:
        src = textwrap.dedent(str(fn_or_src))
    return hashlib.sha256(src.encode()).hexdigest()[:16]


def cache_key(src_hash: str, type_sig: str, backend: str) -> str:
    raw = f"v{_FORMAT_VERSION}|{src_hash}|{type_sig}|{backend}"
    return hashlib.sha256(raw.encode()).hexdigest()[:24]


@dataclass
class CacheStats:
    hits: int = 0
    misses: int = 0
    puts: int = 0
    errors: int = 0
    # compiles that were skipped entirely thanks to a hit
    codegen_skipped: int = 0
    # entries evicted by prune()/auto-prune
    pruned: int = 0
    # hits satisfied from / entries published to the shared store
    shared_hits: int = 0
    shared_puts: int = 0

    def as_dict(self) -> Dict[str, int]:
        return {"hits": self.hits, "misses": self.misses,
                "puts": self.puts, "errors": self.errors,
                "codegen_skipped": self.codegen_skipped,
                "pruned": self.pruned,
                "shared_hits": self.shared_hits,
                "shared_puts": self.shared_puts}


@dataclass
class CacheEntry:
    """One compiled kernel: schedule + generated variant sources."""

    fn_name: str
    src_hash: str
    type_sig: str
    backend: str
    params: List[Tuple[str, Any]]       # (name, TypeInfo)
    sched: Any                          # core.schedule.Schedule
    generated: Dict[str, Any]           # variant name → GeneratedVariant
    compile_s: float = 0.0              # cold compile wall time
    created_at: float = field(default_factory=time.time)


class VariantCache:
    """On-disk store of :class:`CacheEntry` objects.

    A fresh ``VariantCache(same_dir)`` in a new process sees every entry
    the old process put — that is the whole point.
    """

    def __init__(self, cache_dir: str, max_entries: Optional[int] = None,
                 max_bytes: Optional[int] = None,
                 shared_dir: Optional[str] = None):
        self.cache_dir = os.path.abspath(os.path.expanduser(cache_dir))
        os.makedirs(self.cache_dir, exist_ok=True)
        self.stats = CacheStats()
        # size caps enforced on put (LRU eviction); None = unbounded
        self.max_entries = max_entries
        self.max_bytes = max_bytes
        self._puts_since_sweep = 0
        # two-tier shared store (ROADMAP "cross-node cache sharing"):
        # ``shared_dir`` names a fleet-wide directory (NFS mount, synced
        # volume, container-image bake). Local misses fall through to it
        # (fetched entries are copied local), local puts publish to it —
        # so one cold compile anywhere warm-starts every node.
        self.shared_dir = None
        if shared_dir is not None:
            self.shared_dir = os.path.abspath(
                os.path.expanduser(shared_dir))
            os.makedirs(self.shared_dir, exist_ok=True)

    # -- paths ----------------------------------------------------------
    def _path(self, key: str) -> str:
        return os.path.join(self.cache_dir, f"{key}.pkl")

    # -- core API -------------------------------------------------------
    def get(self, src_hash: str, type_sig: str,
            backend: str) -> Optional[CacheEntry]:
        key = cache_key(src_hash, type_sig, backend)
        path = self._path(key)
        if not os.path.exists(path):
            if not self._fetch_shared(key):
                self.stats.misses += 1
                return None
        try:
            with open(path, "rb") as f:
                entry = pickle.load(f)
        except Exception:
            # corrupt/stale entry: treat as miss, drop it
            self.stats.errors += 1
            self.stats.misses += 1
            try:
                os.unlink(path)
            except OSError:
                pass
            return None
        self.stats.hits += 1
        try:
            os.utime(path, None)  # LRU touch: mtime = last use
        except OSError:
            pass
        return entry

    def put(self, entry: CacheEntry) -> str:
        key = cache_key(entry.src_hash, entry.type_sig, entry.backend)
        path = self._path(key)
        fd, tmp = tempfile.mkstemp(dir=self.cache_dir, suffix=".tmp")
        try:
            with os.fdopen(fd, "wb") as f:
                pickle.dump(entry, f, protocol=_PICKLE_PROTO)
            os.replace(tmp, path)
        except Exception:
            self.stats.errors += 1
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise
        self.stats.puts += 1
        self._publish_shared(key)
        self._auto_prune()
        return key

    # -- shared-store backend -------------------------------------------
    def _shared_path(self, key: str) -> Optional[str]:
        if self.shared_dir is None:
            return None
        return os.path.join(self.shared_dir, f"{key}.pkl")

    def _fetch_shared(self, key: str) -> bool:
        """Local miss → pull the entry from the shared store (atomic
        copy into the local tier). Returns True when the local file now
        exists."""
        spath = self._shared_path(key)
        if spath is None or not os.path.exists(spath):
            return False
        try:
            with open(spath, "rb") as f:
                data = f.read()
            fd, tmp = tempfile.mkstemp(dir=self.cache_dir, suffix=".tmp")
            with os.fdopen(fd, "wb") as f:
                f.write(data)
            os.replace(tmp, self._path(key))
            self.stats.shared_hits += 1
            return True
        except OSError:
            self.stats.errors += 1
            return False

    def _publish_shared(self, key: str) -> None:
        spath = self._shared_path(key)
        if spath is None or os.path.exists(spath):
            return
        try:
            with open(self._path(key), "rb") as f:
                data = f.read()
            fd, tmp = tempfile.mkstemp(dir=self.shared_dir,
                                       suffix=".tmp")
            with os.fdopen(fd, "wb") as f:
                f.write(data)
            os.replace(tmp, spath)
            self.stats.shared_puts += 1
        except OSError:
            self.stats.errors += 1

    def _auto_prune(self) -> None:
        """Enforce the constructor caps. Eviction goes 10% below the cap
        so the stat() sweep amortizes over many puts instead of running
        on every insertion once the store sits at capacity; a byte-only
        cap (whose check itself needs the sweep) is polled every 16th
        put rather than on each one."""
        if self.max_entries is None and self.max_bytes is None:
            return
        self._puts_since_sweep += 1
        if self.max_entries is not None:
            over = len(self.entries()) > self.max_entries
        else:
            over = self._puts_since_sweep >= 16
        if over:
            self._puts_since_sweep = 0
            self.prune(
                max_entries=None if self.max_entries is None
                else max(1, int(self.max_entries * 0.9)),
                max_bytes=None if self.max_bytes is None
                else max(1, int(self.max_bytes * 0.9)))

    # -- maintenance ----------------------------------------------------
    def entries(self) -> List[str]:
        return sorted(k[:-4] for k in os.listdir(self.cache_dir)
                      if k.endswith(".pkl"))

    def clear(self) -> int:
        n = 0
        for name in os.listdir(self.cache_dir):
            if name.endswith(".pkl"):
                os.unlink(os.path.join(self.cache_dir, name))
                n += 1
        return n

    def prune(self, max_entries: Optional[int] = None,
              max_bytes: Optional[int] = None,
              max_age_s: Optional[float] = None) -> int:
        """LRU/size-cap eviction; returns the number of entries removed.

        Ordering comes from the same timestamps ``index.json`` reports:
        each entry's last-used time (file mtime, bumped on every hit by
        :meth:`get`, falling back to ``created_at``). ``max_age_s`` drops
        entries idle longer than the given age; ``max_entries`` /
        ``max_bytes`` then evict least-recently-used entries until the
        store fits. The sweep is stat()-based — entries are never
        deserialized — and an existing ``index.json`` has the evicted
        keys filtered out in place (a full metadata rebuild is
        :meth:`dump_index`); auto-prune additionally evicts 10% below
        the cap so this sweep amortizes across puts."""
        infos = []
        for key in self.entries():
            path = self._path(key)
            try:
                st = os.stat(path)
                infos.append((st.st_mtime, st.st_size, key, path))
            except OSError:
                continue
        infos.sort()  # oldest last-use first
        now = time.time()
        drop = []
        if max_age_s is not None:
            drop.extend(i for i in infos if now - i[0] > max_age_s)
        dropped = {i[2] for i in drop}
        kept = [i for i in infos if i[2] not in dropped]
        if max_entries is not None:
            while len(kept) > max_entries:
                drop.append(kept.pop(0))
        if max_bytes is not None:
            total = sum(i[1] for i in kept)
            while kept and total > max_bytes:
                victim = kept.pop(0)
                total -= victim[1]
                drop.append(victim)
        removed = 0
        dropped_keys = set()
        for _, _, key, path in drop:
            try:
                os.unlink(path)
                removed += 1
                dropped_keys.add(key)
            except OSError:
                pass
        if removed:
            self.stats.pruned += removed
            self._drop_from_index(dropped_keys)
        return removed

    def _drop_from_index(self, keys: set) -> None:
        """Filter evicted keys out of an existing index.json (cheap; a
        full rebuild with fresh metadata is :meth:`dump_index`)."""
        path = os.path.join(self.cache_dir, "index.json")
        if not os.path.exists(path):
            return
        try:
            with open(path) as f:
                idx = json.load(f)
            idx = [e for e in idx if e.get("key") not in keys]
            with open(path, "w") as f:
                json.dump(idx, f, indent=2)
        except Exception:
            pass  # index is advisory; never break eviction over it

    def telemetry(self) -> Dict[str, Any]:
        return {"dir": self.cache_dir,
                "entries": len(self.entries()),
                **self.stats.as_dict()}

    def dump_index(self) -> str:
        """Write a human-readable index.json next to the entries."""
        idx = []
        for key in self.entries():
            try:
                path = self._path(key)
                with open(path, "rb") as f:
                    e = pickle.load(f)
                idx.append({"key": key, "fn": e.fn_name,
                            "type_sig": e.type_sig, "backend": e.backend,
                            "compile_s": round(e.compile_s, 4),
                            "created_at": e.created_at,
                            "last_used": os.stat(path).st_mtime})
            except Exception:
                continue
        path = os.path.join(self.cache_dir, "index.json")
        with open(path, "w") as f:
            json.dump(idx, f, indent=2)
        return path
