"""Dynamic tracer: observe call-site signatures of unhinted kernels.

The paper's pipeline needs per-parameter (dtype, rank) facts before it can
compile anything; when the programmer has not written hints, this module
harvests them from live calls. Each traced call records the runtime
:class:`~repro.core.types.TypeInfo` of every argument plus its concrete
shape, and per-call wall latency — enough for hint synthesis
(:mod:`repro.profiler.hints`) and for the specializer's hot-call-site
promotion.

Overhead discipline: after ``full_sample`` calls with an already-seen
signature, per-call recording degrades to a counter bump (signature key
lookup only, no new allocation), so tracing a hot loop stays cheap.
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.core.types import (TypeInfo, nested_list_shape,
                              runtime_typeinfo)


@dataclass(frozen=True)
class ArgObservation:
    """One argument position as observed at runtime."""

    name: str
    kind: str                      # 'scalar' | 'array' | 'list' | 'unknown'
    dtype: Optional[str]
    rank: int
    shape: Tuple[int, ...]         # () for scalars
    # concrete value of integer scalars (structure parameters like N drive
    # the cost model, so distinct values are distinct signatures and the
    # profitability calibrator can recover per-call problem sizes)
    ivalue: Optional[int] = None

    @staticmethod
    def of(name: str, value: Any) -> "ArgObservation":
        ti = runtime_typeinfo(value)
        shape: Tuple[int, ...] = ()
        ivalue: Optional[int] = None
        if isinstance(value, np.ndarray):
            shape = tuple(int(s) for s in value.shape)
        elif hasattr(value, "shape") and not isinstance(value, (int, float)):
            try:
                shape = tuple(int(s) for s in value.shape)
            except Exception:
                shape = ()
        elif isinstance(value, list):
            shape = nested_list_shape(value)
        elif isinstance(value, (int, np.integer)) and not isinstance(
                value, bool):
            ivalue = int(value)
        return ArgObservation(name, ti.kind, ti.dtype, ti.rank, shape,
                              ivalue)

    def signature(self) -> Tuple:
        return (self.name, self.kind, self.dtype, self.rank, self.shape,
                self.ivalue)


@dataclass
class CallRecord:
    """Aggregate stats for one distinct call signature."""

    args: Tuple[ArgObservation, ...]
    calls: int = 0
    total_s: float = 0.0
    min_s: float = float("inf")
    max_s: float = 0.0

    @property
    def mean_s(self) -> float:
        return self.total_s / self.calls if self.calls else 0.0

    def observe(self, dt: float) -> None:
        self.calls += 1
        self.total_s += dt
        if dt < self.min_s:
            self.min_s = dt
        if dt > self.max_s:
            self.max_s = dt


@dataclass
class FunctionTrace:
    """Everything the tracer learned about one function."""

    fn_name: str
    param_names: List[str]
    records: Dict[Tuple, CallRecord] = field(default_factory=dict)
    calls: int = 0
    total_s: float = 0.0

    @property
    def signatures(self) -> List[CallRecord]:
        """Records ordered hottest-first (by call count, then total time)."""
        return sorted(self.records.values(),
                      key=lambda r: (-r.calls, -r.total_s))

    @property
    def dominant(self) -> Optional[CallRecord]:
        sigs = self.signatures
        return sigs[0] if sigs else None

    def observations_by_param(self) -> Dict[str, List[ArgObservation]]:
        out: Dict[str, List[ArgObservation]] = {n: [] for n in
                                                self.param_names}
        for rec in self.records.values():
            for ob in rec.args:
                out.setdefault(ob.name, []).append(ob)
        return out


class Tracer:
    """Records call signatures for any number of functions.

    Use as a decorator factory::

        tr = Tracer()

        @tr.wrap
        def kernel(a, b, n): ...

    or as a context manager that forces recording on for the block and
    restores the previous recording state on exit (traces persist — the
    context form just scopes *recording*)::

        tr.pause()
        with tr:                 # recording on inside the block
            kernel(x, y, 8)
        # recording paused again here

    """

    def __init__(self, full_sample: int = 32):
        self.full_sample = full_sample
        self.traces: Dict[str, FunctionTrace] = {}
        self._owners: Dict[str, Callable] = {}   # key → underlying fn
        self._lock = threading.Lock()
        self._recording = True
        self._recording_stack: List[bool] = []

    # -- recording control ----------------------------------------------
    def pause(self) -> None:
        self._recording = False

    def resume(self) -> None:
        self._recording = True

    def __enter__(self) -> "Tracer":
        self._recording_stack.append(self._recording)
        self._recording = True
        return self

    def __exit__(self, *exc) -> None:
        self._recording = self._recording_stack.pop() \
            if self._recording_stack else True

    # -- wrapping -------------------------------------------------------
    @staticmethod
    def _key(fn: Callable) -> str:
        """Registry key: module-qualified so two same-named functions in
        different modules/classes never share a trace."""
        mod = getattr(fn, "__module__", None) or "?"
        qual = getattr(fn, "__qualname__", None) \
            or getattr(fn, "__name__", repr(fn))
        return f"{mod}.{qual}"

    def wrap(self, fn: Callable) -> Callable:
        import functools
        import inspect

        name = getattr(fn, "__name__", repr(fn))
        try:
            param_names = [p for p in inspect.signature(fn).parameters]
        except (TypeError, ValueError):
            param_names = []
        with self._lock:
            key = self._key(fn)
            owner = self._owners.get(key)
            if owner is not None and owner is not fn:
                # distinct function object under the same qualname (e.g.
                # closures minted in a loop): never share a trace
                key = f"{key}#{id(fn):x}"
            self._owners[key] = fn
            tr = self.traces.setdefault(key, FunctionTrace(name,
                                                           param_names))

        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            if not self._recording:
                return fn(*args, **kwargs)
            t0 = time.perf_counter()
            out = fn(*args, **kwargs)
            dt = time.perf_counter() - t0
            self._record(tr, args, kwargs, dt)
            return out

        wrapper.__trace__ = tr  # type: ignore[attr-defined]
        wrapper.__wrapped_fn__ = fn  # type: ignore[attr-defined]
        return wrapper

    __call__ = wrap

    def _record(self, tr: FunctionTrace, args, kwargs, dt: float) -> None:
        obs = []
        names = tr.param_names or [f"arg{i}" for i in range(len(args))]
        for n, v in zip(names, args):
            obs.append(ArgObservation.of(n, v))
        for k, v in kwargs.items():
            obs.append(ArgObservation.of(k, v))
        key = tuple(o.signature() for o in obs)
        with self._lock:
            rec = tr.records.get(key)
            if rec is None:
                rec = CallRecord(args=tuple(obs))
                tr.records[key] = rec
            rec.observe(dt)
            tr.calls += 1
            tr.total_s += dt

    # -- queries --------------------------------------------------------
    def trace_of(self, fn_or_name) -> FunctionTrace:
        if callable(fn_or_name):
            tr = getattr(fn_or_name, "__trace__", None)
            if tr is not None:
                return tr
            for key, owner in self._owners.items():   # identity first
                if owner is fn_or_name:
                    return self.traces[key]
            fn_or_name = self._key(fn_or_name)
        if fn_or_name in self.traces:
            return self.traces[fn_or_name]
        # bare-name lookup: accept iff unambiguous
        matches = [t for k, t in self.traces.items()
                   if k == fn_or_name or k.endswith("." + fn_or_name)]
        if len(matches) == 1:
            return matches[0]
        if not matches:
            raise KeyError(fn_or_name)
        raise KeyError(f"{fn_or_name!r} is ambiguous: "
                       f"{len(matches)} traced functions share the name")

    def report(self) -> str:
        lines = ["Tracer report:"]
        for name, tr in self.traces.items():
            lines.append(f"  {name}: {tr.calls} calls, "
                         f"{len(tr.records)} distinct signatures, "
                         f"{tr.total_s:.4f}s total")
            for rec in tr.signatures[:5]:
                sig = ", ".join(
                    f"{o.name}:{o.kind}[{o.dtype},{o.rank}]{list(o.shape)}"
                    for o in rec.args)
                lines.append(f"    {rec.calls}× mean={rec.mean_s:.6f}s  "
                             f"({sig})")
        return "\n".join(lines)


# Module-level convenience tracer (what ``optimize(profile=True)`` uses
# when the caller does not pass its own).
_default_tracer = Tracer()


def trace(fn: Optional[Callable] = None, *, tracer: Optional[Tracer] = None):
    """``@trace`` decorator using the module default tracer."""
    t = tracer or _default_tracer
    if fn is not None:
        return t.wrap(fn)
    return t.wrap


def default_tracer() -> Tracer:
    return _default_tracer
