"""Background specializer: promote hot call sites to pinned fast paths.

Watches the dispatch statistics every :class:`~repro.core.multiversion.
CompiledKernel` already collects (per shape-signature call counts and the
decision the tree made for each), and when a signature crosses the hot
threshold, installs a :class:`Specialization` — the fully resolved
dispatch decision (variant + precomputed FLOP estimate) — into the
kernel's decision tree. Subsequent calls with that exact signature skip
legality matching and FLOP estimation entirely.

Correctness guarantee (paper §4.1) is preserved by construction: a
specialization only fires on an *exact* signature match, the decision it
replays was produced by the full legality→profitability tree for that
same signature, and every non-matching call — including the first call of
any new shape — still walks the original tree with the user's function as
the terminal fallback.

The thread is optional: ``scan_once()`` gives deterministic, test-friendly
promotion; ``start()`` runs the same scan on an interval.
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple


@dataclass
class Specialization:
    """A pinned dispatch decision for one exact call signature."""

    sig: Tuple
    variant_name: str
    flops: float
    legality_ok: bool
    tier: str = "exact"
    promoted_at: float = field(default_factory=time.monotonic)
    hits: int = 0
    latency_ema: Optional[float] = None   # maintained by CompiledKernel


class Specializer:
    """Registry + promotion loop over compiled kernels.

    ``hot_threshold`` is the call count at which a signature is considered
    hot. Kernels are registered by name; the same registry doubles as the
    serving engine's kernel telemetry source.
    """

    def __init__(self, hot_threshold: int = 16,
                 interval_s: float = 0.05,
                 max_specializations_per_kernel: int = 64,
                 demote_cold_scans: int = 3,
                 cold_after_s: float = 10.0,
                 regress_factor: float = 1.5,
                 min_hits_for_regress: int = 8):
        self.hot_threshold = hot_threshold
        self.interval_s = interval_s
        self.max_per_kernel = max_specializations_per_kernel
        # demotion policy: a pin is dropped when its signature goes cold
        # (no new hits across ``demote_cold_scans`` consecutive scans
        # AND at least ``cold_after_s`` of wall time — the time guard
        # keeps a fast background scan interval from thrashing pins of
        # slow-but-steady callers) or when its per-call latency EMA
        # regresses ``regress_factor``× against the full decision tree's
        # EMA for the same signature
        self.demote_cold_scans = demote_cold_scans
        self.cold_after_s = cold_after_s
        self.regress_factor = regress_factor
        self.min_hits_for_regress = min_hits_for_regress
        self.kernels: Dict[str, Any] = {}
        self.promotions: List[Tuple[str, Specialization]] = []
        self.demotions: List[Tuple[str, Tuple, str]] = []
        # (kernel, sig) → (hits at last scan, consecutive stale scans,
        #                  time the hit count last changed)
        self._hit_marks: Dict[Tuple[str, Tuple],
                              Tuple[int, int, float]] = {}
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()
        self._lock = threading.Lock()

    # -- registry -------------------------------------------------------
    def register(self, kernel, name: Optional[str] = None) -> None:
        with self._lock:
            self.kernels[name or kernel.__name__] = kernel

    def unregister(self, name: str) -> None:
        with self._lock:
            self.kernels.pop(name, None)

    # -- promotion ------------------------------------------------------
    def scan_once(self) -> List[Specialization]:
        """One promotion sweep; returns newly installed specializations."""
        promoted: List[Specialization] = []
        with self._lock:
            kernels = list(self.kernels.items())
        for kname, ck in kernels:
            counts = getattr(ck, "shape_counts", None)
            decisions = getattr(ck, "last_decisions", None)
            installed = getattr(ck, "specializations", None)
            if counts is None or decisions is None or installed is None:
                continue
            # demote first: it can free pin slots for hotter signatures
            self._demote_sweep(kname, ck)
            if len(installed) >= self.max_per_kernel:
                continue
            # snapshot to tolerate concurrent dispatch
            for sig, n in list(counts.items()):
                if n < self.hot_threshold or sig in installed:
                    continue
                dec = decisions.get(sig)
                if dec is None:
                    continue
                variant_name, flops, legality_ok = dec
                spec = Specialization(sig, variant_name, flops,
                                      legality_ok)
                ck.install_specialization(spec)
                self._hit_marks[(kname, sig)] = (0, 0, time.monotonic())
                promoted.append(spec)
                self.promotions.append((kname, spec))
                if len(installed) >= self.max_per_kernel:
                    break
        return promoted

    def _demote_sweep(self, kname: str, ck) -> None:
        """Drop pins that went cold or regressed (ROADMAP demotion item).

        Demoted signatures get their hot-counter reset, so a workload
        that comes back later re-earns its pin through the normal
        promotion path — demotion is a reversible cooldown, not a ban."""
        installed = getattr(ck, "specializations", None)
        if installed is None:
            return
        now = time.monotonic()
        for sig, spec in list(installed.items()):
            reason = None
            key = (kname, sig)
            last_hits, stale, changed_t = self._hit_marks.get(
                key, (0, 0, now))
            if spec.hits == last_hits:
                stale += 1
            else:
                stale, changed_t = 0, now
            self._hit_marks[key] = (spec.hits, stale, changed_t)
            if (stale >= self.demote_cold_scans
                    and now - changed_t >= self.cold_after_s):
                reason = "cold"
            else:
                tree = getattr(ck, "tree_latency", {}).get(sig)
                ema = getattr(spec, "latency_ema", None)
                if (tree is not None and ema is not None
                        and spec.hits >= self.min_hits_for_regress
                        and ema > self.regress_factor * tree):
                    reason = "latency_regression"
            if reason is None:
                continue
            ck.drop_specialization(sig)
            counts = getattr(ck, "shape_counts", None)
            if counts is not None and sig in counts:
                counts[sig] = 0
            self._hit_marks.pop(key, None)
            self.demotions.append((kname, sig, reason))

    # -- background thread ----------------------------------------------
    def start(self) -> None:
        if self._thread is not None:
            return
        self._stop.clear()

        def loop():
            while not self._stop.wait(self.interval_s):
                try:
                    self.scan_once()
                except Exception:
                    # promotion is best-effort; never kill the app thread
                    pass

        self._thread = threading.Thread(target=loop, daemon=True,
                                        name="automphc-specializer")
        self._thread.start()

    def stop(self) -> None:
        if self._thread is None:
            return
        self._stop.set()
        self._thread.join(timeout=2.0)
        self._thread = None

    def __enter__(self) -> "Specializer":
        self.start()
        return self

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- telemetry ------------------------------------------------------
    def telemetry(self) -> Dict[str, Any]:
        with self._lock:
            kernels = list(self.kernels.items())
        out: Dict[str, Any] = {
            "hot_threshold": self.hot_threshold,
            "promotions": len(self.promotions),
            "demoted": len(self.demotions),
            "running": self._thread is not None,
            "kernels": {},
        }
        for name, ck in kernels:
            stats = ck.stats() if hasattr(ck, "stats") else {}
            out["kernels"][name] = stats
        return out
