"""Hint synthesis: observed runtime signatures → front-end hint strings.

Folds a :class:`~repro.profiler.tracer.FunctionTrace` into the
``'ndarray[f64,2]'`` hint strings that ``core/parser.py`` +
``core/types.py`` already consume, widening observed shapes into guarded
buckets. One trace yields a *legality-ordered* hint set:

  tier 0 ``exact``   — dtype+rank hints, guarded on the exact shapes of
                       the dominant signature (tightest specialization);
  tier 1 ``bucket``  — same hints, shapes widened to enclosing
                       power-of-two buckets (stable under mild shape
                       drift, e.g. batch 60 ↔ 64);
  tier 2 ``rank``    — dtype+rank only, no shape guard (exactly what a
                       hand-written paper hint expresses).

All three tiers share the same hint strings — the paper's legality check
is dtype+rank — so a single compile serves every tier. The shape guards
are the tier-membership predicates exposed to tooling (``HintTier.admits``
answers "would the dominant-signature specialization still apply to this
shape?"); runtime pinning itself keys on exact dispatch signatures in
``core/multiversion.py``, and bucket-guard dispatch is a ROADMAP open
item.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

# pow2_bucket is re-exported: callers grew up importing it from here, but
# the implementation lives in core (the dispatcher's bucket guards must
# not depend on the profiler package)
from repro.core.cost import pow2_bucket  # noqa: F401  (re-export)
from repro.core.types import promote_dtype

from .tracer import ArgObservation, FunctionTrace

# Reverse of core.types._DTYPE_ALIASES — emit paper-style short names.
_SHORT_DTYPE = {
    "float64": "f64",
    "float32": "f32",
    "bfloat16": "bf16",
    "int64": "i64",
    "int32": "i32",
    "bool": "bool",
    "complex64": "c64",
    "complex128": "c128",
}


def _short(dtype: Optional[str]) -> str:
    if dtype is None:
        return "f64"
    return _SHORT_DTYPE.get(dtype, dtype)




@dataclass(frozen=True)
class ShapeGuard:
    """Per-dimension admission ranges for one array parameter.

    ``dims[i] = (lo, hi)`` admits sizes with ``lo < s <= hi`` (the
    exact tier uses ``(s-1, s)``)."""

    dims: Tuple[Tuple[int, int], ...]

    def admits(self, shape: Sequence[int]) -> bool:
        if len(shape) != len(self.dims):
            return False
        return all(lo < s <= hi for s, (lo, hi) in zip(shape, self.dims))

    @staticmethod
    def exact(shape: Sequence[int]) -> "ShapeGuard":
        return ShapeGuard(tuple((s - 1, s) for s in shape))

    @staticmethod
    def bucketed(shape: Sequence[int]) -> "ShapeGuard":
        return ShapeGuard(tuple(pow2_bucket(s) for s in shape))


@dataclass
class HintTier:
    """One legality tier: hint strings plus optional shape guards."""

    name: str                           # 'exact' | 'bucket' | 'rank'
    hints: Dict[str, str]               # param name → hint string
    guards: Dict[str, ShapeGuard] = field(default_factory=dict)

    def admits(self, shapes: Dict[str, Sequence[int]]) -> bool:
        """Do the given runtime shapes fall inside this tier's guards?

        Params without a guard are unconstrained (legality still checks
        dtype/rank downstream)."""
        for name, guard in self.guards.items():
            if name not in shapes or not guard.admits(shapes[name]):
                return False
        return True


def _fold_param(obs: List[ArgObservation]) -> Tuple[str, Optional[Tuple[int, ...]]]:
    """Fold all observations of one parameter into (hint, dominant shape).

    Mixed ranks widen to rank-less ``ndarray``; mixed dtypes promote."""
    if not obs:
        return "", None
    kinds = {o.kind for o in obs}
    if kinds == {"scalar"}:
        dtype = None
        for o in obs:
            dtype = promote_dtype(dtype, o.dtype)
        if dtype in ("int64", "int32"):
            return "int", None
        if dtype == "bool":
            return "bool", None
        if dtype in ("complex64", "complex128"):
            return "complex", None
        return "float", None
    if kinds <= {"array", "list"}:
        dtype = None
        for o in obs:
            dtype = promote_dtype(dtype, o.dtype)
        ranks = {o.rank for o in obs}
        base = "list" if kinds == {"list"} else "ndarray"
        if len(ranks) != 1:
            return ("ndarray", None)  # rank varies: legality guard decides
        rank = ranks.pop()
        shape = obs[0].shape if len({o.shape for o in obs}) == 1 else None
        return (f"{base}[{_short(dtype)},{rank}]", shape)
    return "", None  # unknown / mixed kind: leave unhinted


def synthesize_hints(trace: FunctionTrace) -> Dict[str, str]:
    """The widest-legal hints (tier ``rank``) — what a programmer would
    have written by hand after watching the same calls."""
    by_param = trace.observations_by_param()
    out: Dict[str, str] = {}
    for name in trace.param_names:
        hint, _ = _fold_param(by_param.get(name, []))
        if hint:
            out[name] = hint
    return out


def synthesize_hint_tiers(trace: FunctionTrace) -> List[HintTier]:
    """Legality-ordered tiers (most-specific first) from one trace."""
    hints = synthesize_hints(trace)
    dom = trace.dominant
    tiers: List[HintTier] = []
    if dom is not None:
        arr_shapes = {o.name: o.shape for o in dom.args
                      if o.kind in ("array", "list") and o.shape
                      and o.name in hints and "[" in hints[o.name]}
        if arr_shapes:
            tiers.append(HintTier(
                "exact", dict(hints),
                {n: ShapeGuard.exact(s) for n, s in arr_shapes.items()}))
            tiers.append(HintTier(
                "bucket", dict(hints),
                {n: ShapeGuard.bucketed(s) for n, s in arr_shapes.items()}))
    tiers.append(HintTier("rank", dict(hints)))
    return tiers


def type_signature(hints: Dict[str, object],
                   param_names: Sequence[str]) -> str:
    """Canonical signature string for cache keying.

    This is THE encoding the variant cache keys on (the compiler calls it
    too). Hints are canonicalized through the front-end's own annotation
    parser, so alias spellings (``'ndarray[f64,2]'`` vs
    ``'ndarray[float64,2]'``) produce identical keys. Order follows the
    function's own parameter order."""
    from repro.core.types import parse_annotation

    parts = []
    for n in param_names:
        ti = parse_annotation(hints.get(n))
        parts.append(f"{n}:{ti.kind}[{ti.dtype},{ti.rank}]")
    return ";".join(parts)
