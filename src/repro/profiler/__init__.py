"""Dynamic profiler subsystem: close the hint→compile→dispatch loop.

The paper's front-end is driven by type hints that "can be supplied by the
programmer or obtained by dynamic profiler tools" (§1, §4.1). This package
is the second half of that sentence:

  * :mod:`tracer` — low-overhead call-site recorder for *unhinted*
    functions (dtype, rank, shape buckets, call counts, latency);
  * :mod:`hints` — folds observed signatures into the ``'ndarray[f64,2]'``
    hint strings the front-end already consumes, widening shapes into a
    legality-ordered set of guarded tiers (exact → power-of-two bucket →
    rank-only);
  * :mod:`cache` — persistent on-disk variant store keyed by
    ``(source hash, type signature, backend)``; a warm process skips
    parse → SCoP → schedule → codegen entirely;
  * :mod:`specializer` — background thread that watches dispatch stats,
    promotes hot call sites to shape-specialized fast paths, and hot-swaps
    them into the decision tree (original-function fallback preserved).

Entry points live on :func:`repro.core.compiler.optimize`
(``optimize(profile=True)`` / ``optimize.from_trace``).
"""

from .tracer import ArgObservation, CallRecord, FunctionTrace, Tracer, trace
from .hints import HintTier, synthesize_hints, synthesize_hint_tiers
from .cache import VariantCache, CacheStats, cache_key, source_hash
from .specializer import Specialization, Specializer

__all__ = [
    "ArgObservation", "CallRecord", "FunctionTrace", "Tracer", "trace",
    "HintTier", "synthesize_hints", "synthesize_hint_tiers",
    "VariantCache", "CacheStats", "cache_key", "source_hash",
    "Specialization", "Specializer",
]
