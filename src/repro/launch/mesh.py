"""Production mesh construction.

Single-pod: (data=16, model=16) = 256 chips (one v5e pod).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the `pod` axis
composes with `data` for hierarchical data parallelism (reduce-scatter
intra-pod over ICI, all-reduce inter-pod over DCN).

Defined as FUNCTIONS so importing this module never touches jax device
state — the dry-run entry point must set XLA_FLAGS before any jax init.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Tiny mesh over the actually-present devices (tests, examples)."""
    n = len(jax.devices())
    assert n % model == 0
    return jax.make_mesh((n // model, model), ("data", "model"))
