"""Serving driver: batched continuous-batching engine over a model.

    PYTHONPATH=src python -m repro.launch.serve --arch stablelm_3b \
        --smoke --requests 8 --max-tokens 12

Loads (or initializes) a model, spins up the ServeEngine (fixed-slot KV
cache, per-slot positions, greedy decode), feeds a synthetic request
stream with staggered arrivals, and reports latency/throughput stats.
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.models import transformer as T
from repro.serve.engine import Request, ServeEngine


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm_3b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=96)
    ap.add_argument("--max-tokens", type=int, default=12)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke \
        else get_config(args.arch)
    params, _ = T.init_params(cfg, jax.random.key(0))
    eng = ServeEngine(params, cfg, n_slots=args.slots,
                      max_seq=args.max_seq)

    rng = np.random.default_rng(0)
    t0 = time.perf_counter()
    for i in range(args.requests):
        plen = int(rng.integers(4, 16))
        eng.add_request(Request(
            f"req-{i}", rng.integers(0, cfg.vocab, plen),
            max_tokens=args.max_tokens))
        eng.step()  # staggered arrivals exercise continuous batching
    done = eng.run_until_done()
    wall = time.perf_counter() - t0

    gen_tokens = sum(len(r.generated) for r in done)
    ttfts = [r.first_token_s - r.submitted_s for r in done]
    lats = [r.finished_s - r.submitted_s for r in done]
    stats = {
        "requests": len(done),
        "tokens_generated": gen_tokens,
        "throughput_tok_s": gen_tokens / wall,
        "ttft_p50_s": float(np.median(ttfts)),
        "latency_p50_s": float(np.median(lats)),
    }
    print(f"[serve] {cfg.name}: {stats}")
    return stats


if __name__ == "__main__":
    main()
