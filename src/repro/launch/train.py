"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch stablelm_3b \
        --smoke --steps 50 --batch 8 --seq 64 --ckpt-dir /tmp/ckpt

Wires every substrate together on the local device(s): config → planner
(auto-sharding on the host mesh) → data pipeline (host-sharded, prefetch)
→ jit'd train step (grad accumulation, remat, optional int8 grad
compression, AdamW w/ optional 8-bit moments) → async checkpointing with
resume-on-restart. The production path is the same code under the
(16, 16)/(2, 16, 16) meshes exercised by dryrun.py.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import ckpt as C
from repro.configs import get_config, get_smoke_config
from repro.core import planner as planner_mod
from repro.data.pipeline import DataConfig, make_pipeline
from repro.launch.mesh import make_host_mesh
from repro.models import transformer as T
from repro.train import AdamWConfig, init_opt_state, make_train_step


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm_3b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-friendly)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--compress", default=None, choices=[None, "int8"])
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke \
        else get_config(args.arch)
    cfg.microbatch = min(cfg.microbatch, max(1, args.batch // 2)) or 1
    mesh = make_host_mesh()

    # --- planner: auto-sharding on whatever mesh we actually have -------
    p_shapes = jax.eval_shape(
        lambda: T.init_params(cfg, jax.random.key(0))[0])
    holder = {}

    def cap():
        params, specs = T.init_params(cfg, jax.random.key(0))
        holder["specs"] = specs
        return params

    jax.eval_shape(cap)
    plan = planner_mod.plan(cfg, holder["specs"], p_shapes, mesh,
                            seq=args.seq, batch=args.batch, kind="train")
    print(f"[train] {cfg.name}: {plan.describe()}", flush=True)

    opt_cfg = AdamWConfig(lr=args.lr, quantize_moments=cfg.opt_8bit)
    step_fn = jax.jit(make_train_step(cfg, opt_cfg,
                                      compress=args.compress))

    # --- init or resume ---------------------------------------------------
    start_step = 0
    with mesh:
        params, _ = T.init_params(cfg, jax.random.key(0))
        opt = init_opt_state(params, opt_cfg)
        if args.ckpt_dir:
            last = C.latest_step(args.ckpt_dir)
            if last is not None:
                got, extra = C.restore(args.ckpt_dir, last,
                                       {"params": params, "opt": opt})
                params, opt = got["params"], got["opt"]
                start_step = int(extra.get("data_step", last))
                print(f"[train] resumed from step {last}", flush=True)

    data = make_pipeline(DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                                    global_batch=args.batch,
                                    embeds_dim=cfg.d_model
                                    if cfg.embeds_input else 0,
                                    src_len=args.seq
                                    if cfg.is_encdec else 0,
                                    d_model=cfg.d_model),
                         start_step=start_step)
    ckpt = C.AsyncCheckpointer(args.ckpt_dir) if args.ckpt_dir else None

    losses = []
    t0 = time.perf_counter()
    try:
        with mesh:
            for i in range(start_step, start_step + args.steps):
                batch = {k: jnp.asarray(v)
                         for k, v in data.next().items()}
                params, opt, m = step_fn(params, opt, batch)
                losses.append(float(m["loss"]))
                if (i + 1) % args.log_every == 0:
                    tput = (i + 1 - start_step) * args.batch * args.seq \
                        / (time.perf_counter() - t0)
                    print(f"[train] step {i + 1} loss {losses[-1]:.4f} "
                          f"({tput:.0f} tok/s)", flush=True)
                if ckpt and (i + 1) % args.ckpt_every == 0:
                    ckpt.save_async(i + 1, {"params": params, "opt": opt},
                                    extra={"data_step": i + 1})
    finally:
        data.stop()
        if ckpt:
            ckpt.wait()
    print(f"[train] done: loss {losses[0]:.4f} → {losses[-1]:.4f}")
    return {"losses": losses, "params": params}


if __name__ == "__main__":
    main()
