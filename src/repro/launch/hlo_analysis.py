"""Trip-count-corrected analysis of optimized HLO modules.

XLA's ``cost_analysis()`` counts a ``while`` body ONCE, so scanned-layer
models (every model here: layer scan × grad-accumulation scan × xent
chunk scan) under-report FLOPs/bytes/collective traffic by the product of
trip counts. The optimized HLO text carries the exact trip count in each
while's ``backend_config`` (``"known_trip_count":{"n":"12"}``), so this
module walks the module from ENTRY, multiplying every instruction's
contribution by the enclosing loops' trip counts:

  * flops            — dot ops: 2 × result_elems × contracted_extent
  * memory bytes     — Σ (result + operand bytes) of every materialized
                       instruction (post-opt HLO: fusion boundaries are
                       real HBM traffic)
  * collective bytes — result bytes of all-gather / all-reduce /
                       reduce-scatter / all-to-all / collective-permute
                       (output-size convention, applied consistently)
"""

from __future__ import annotations

import json
import math
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2,
                "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3b11fnuz": 1,
                "s64": 8, "s32": 4, "s16": 2, "s8": 1,
                "u64": 8, "u32": 4, "u16": 2, "u8": 1, "pred": 1,
                "c64": 8, "c128": 16}

_SHAPE_RE = re.compile(
    r"(f64|f32|f16|bf16|f8e4m3fn|f8e5m2|f8e4m3b11fnuz|s64|s32|s16|s8|u64|"
    r"u32|u16|u8|pred|c64|c128)\[([\d,]*)\]")

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_FREE_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
             "bitcast", "after-all", "add-dependency", "domain"}


def shape_bytes(text: str, normalize_f32: bool = False) -> int:
    """Bytes of all shapes in ``text``. With ``normalize_f32``, f32 counts
    at bf16 width: the TPU target runs the model in bf16, and every f32
    buffer the CPU backend materializes around dots is a legalization
    artifact (CPU has no native bf16 dot). Genuinely-f32 buffers (softmax
    stats, fp32 grad accumulators) are under-weighted ≤2× — documented in
    EXPERIMENTS.md §Roofline conventions."""
    total = 0
    for m in _SHAPE_RE.finditer(text):
        dt, dims = m.group(1), m.group(2)
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        w = _DTYPE_BYTES.get(dt, 4)
        if normalize_f32 and dt == "f32":
            w = 2
        total += n * w
    return total


def shape_elems_first(text: str) -> Tuple[Optional[str], int]:
    m = _SHAPE_RE.search(text)
    if not m:
        return None, 0
    dims = m.group(2)
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return m.group(1), n


@dataclass
class Instr:
    name: str
    opcode: str
    result_text: str          # type portion before opcode
    operands: List[str]
    attrs: str                # text after the operand list
    line: str


@dataclass
class Computation:
    name: str
    instrs: List[Instr] = field(default_factory=list)
    shapes: Dict[str, str] = field(default_factory=dict)  # %name → type txt
    root_opcode: str = ""
    params: List[str] = field(default_factory=list)  # signature order


_COMP_HEAD = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\((.*)\)\s*->")
_INSTR = re.compile(r"^(ROOT\s+)?%([\w.\-]+)\s*=\s*(.*)$")
_OPCODE = re.compile(r"^((?:\([^)]*\)|[^ (]+)\s+)?([\w\-]+)\(")


def parse_module(text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry: Optional[str] = None
    cur: Optional[Computation] = None
    for raw in text.splitlines():
        line = raw.strip()
        if not line or line.startswith("//"):
            continue
        if line.endswith("{"):
            m = _COMP_HEAD.match(line)
            if m:
                cur = Computation(m.group(2))
                comps[cur.name] = cur
                if m.group(1):
                    entry = cur.name
                # parameter shapes from the signature (in order)
                for pm in re.finditer(r"([\w.\-]+)\s*:\s*([^,)]+)",
                                      m.group(3)):
                    cur.shapes[pm.group(1)] = pm.group(2)
                    cur.params.append(pm.group(1))
                continue
        if line == "}":
            cur = None
            continue
        if cur is None:
            continue
        im = _INSTR.match(line)
        if not im:
            continue
        name, rhs = im.group(2), im.group(3)
        om = _OPCODE.match(rhs)
        if not om:
            continue
        result_text = om.group(1) or ""
        opcode = om.group(2)
        # operands: %names inside the first (...) group after opcode
        paren = rhs[om.end() - 1:]
        depth, i, end = 0, 0, len(paren)
        for i, ch in enumerate(paren):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        oper_text = paren[1:end]
        attrs = paren[end + 1:]
        operands = re.findall(r"%([\w.\-]+)", oper_text)
        instr = Instr(name, opcode, result_text, operands, attrs, line)
        cur.instrs.append(instr)
        cur.shapes[name] = result_text if result_text else ""
        if im.group(1):  # ROOT
            cur.root_opcode = opcode
    return comps, entry


def _trip_count(instr: Instr) -> int:
    m = re.search(r'"known_trip_count":\{"n":"(\d+)"\}', instr.line)
    if m:
        return int(m.group(1))
    return 1


def _called(instr: Instr, key: str) -> Optional[str]:
    m = re.search(key + r"=%([\w.\-]+)", instr.line)
    return m.group(1) if m else None


@dataclass
class Totals:
    flops: float = 0.0
    memory_bytes: float = 0.0
    collective_bytes: float = 0.0
    per_kind: Dict[str, float] = field(
        default_factory=lambda: {k: 0.0 for k in _COLLECTIVES})
    counts: Dict[str, float] = field(
        default_factory=lambda: {k: 0 for k in _COLLECTIVES})
    max_trip_product: float = 1.0
    by_opcode: Dict[str, float] = field(default_factory=dict)
    top_instrs: List[Tuple[float, str]] = field(default_factory=list)


# Operands smaller than this are assumed VMEM-resident across loop
# iterations (counted once, not × trip count) — the standard roofline
# perfect-cache assumption for small reused tiles (v5e VMEM = 128 MiB).
VMEM_RESIDENT_BYTES = 16 * 2**20


def _dot_flops(instr: Instr, comp: Computation) -> float:
    _, out_elems = shape_elems_first(instr.result_text)
    if not out_elems:
        return 0.0
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", instr.attrs)
    cdims = [int(x) for x in m.group(1).split(",")] if m and m.group(1) \
        else []
    if not instr.operands:
        return 0.0
    lhs_shape_text = comp.shapes.get(instr.operands[0], "")
    sm = _SHAPE_RE.search(lhs_shape_text)
    contract = 1
    if sm and sm.group(2):
        dims = [int(x) for x in sm.group(2).split(",")]
        for cd in cdims:
            if cd < len(dims):
                contract *= dims[cd]
    return 2.0 * out_elems * contract


def analyze(text: str) -> Totals:
    comps, entry = parse_module(text)
    tot = Totals()
    if entry is None:
        return tot

    def walk(comp_name: str, mult: float, depth: int = 0):
        comp = comps.get(comp_name)
        if comp is None or depth > 64:
            return
        tot.max_trip_product = max(tot.max_trip_product, mult)
        for ins in comp.instrs:
            op = ins.opcode
            if op == "while":
                trip = _trip_count(ins)
                body = _called(ins, "body")
                cond = _called(ins, "condition")
                if body:
                    walk(body, mult * trip, depth + 1)
                if cond:
                    walk(cond, mult * trip, depth + 1)
                continue
            if op == "conditional":
                for branch in re.findall(r"(?:branch_computations=\{([^}]*)\}"
                                         r"|true_computation=%([\w.\-]+)"
                                         r"|false_computation=%([\w.\-]+))",
                                         ins.line):
                    for g in branch:
                        if g:
                            for nm in re.findall(r"%?([\w.\-]+)", g):
                                walk(nm, mult, depth + 1)
                continue
            if op in _FREE_OPS:
                continue
            base = op
            started = False
            for kind in _COLLECTIVES:
                if base.startswith(kind):
                    if base.endswith("-done"):
                        started = True
                        break
                    b = shape_bytes(ins.result_text, normalize_f32=True)
                    tot.collective_bytes += mult * b
                    tot.per_kind[kind] += mult * b
                    tot.counts[kind] += mult
                    started = True
                    break
            if started:
                pass
            if op in ("dot", "dot_general", "convolution"):
                tot.flops += mult * _dot_flops(ins, comp)
            # memory traffic at post-opt boundaries, with in-place /
            # slice-op semantics (matching HloCostAnalysis conventions):
            #  * dynamic-slice / gather: only the slice moves;
            #  * dynamic-update-slice / scatter (incl. fusions rooted at
            #    them): read+write of the update region, the aliased big
            #    operand does not stream through HBM.
            eff_op = op
            fusion_comp: Optional[Computation] = None
            if op == "fusion":
                called = _called(ins, "calls")
                if called and called in comps:
                    fusion_comp = comps[called]
                    root = fusion_comp.root_opcode
                    if root in ("dynamic-update-slice", "scatter",
                                "dynamic-slice", "gather"):
                        eff_op = root
            # CPU-backend artifact: bf16 dots are legalized by upcasting
            # operands to f32, materializing identity converts that do not
            # exist on the TPU target (native bf16 MXU) — elide them.
            if op in ("convert",) or (
                    fusion_comp is not None and
                    fusion_comp.root_opcode == "convert"
                    and len(ins.operands) == 1):
                _, res_e = shape_elems_first(ins.result_text)
                _, op_e = shape_elems_first(
                    comp.shapes.get(ins.operands[0], "")) \
                    if ins.operands else (None, 0)
                if res_e == op_e and res_e > 0:
                    continue
            opnd_bytes = [shape_bytes(comp.shapes.get(o, ""),
                                      normalize_f32=True)
                          for o in ins.operands]
            if fusion_comp is not None and eff_op == op:
                # operand consumed only via dynamic-slice inside the
                # fusion: only the slices stream from HBM
                for oi, pname in enumerate(fusion_comp.params):
                    if oi >= len(opnd_bytes):
                        break
                    consumers = [fi for fi in fusion_comp.instrs
                                 if pname in fi.operands]
                    if consumers and all(fi.opcode == "dynamic-slice"
                                         for fi in consumers):
                        opnd_bytes[oi] = sum(
                            shape_bytes(fi.result_text, normalize_f32=True)
                            for fi in consumers)
            res_bytes = shape_bytes(ins.result_text, normalize_f32=True)
            if eff_op in ("dynamic-slice", "gather"):
                b = mult * 2 * res_bytes
            elif eff_op in ("dynamic-update-slice", "scatter"):
                small = sum(opnd_bytes) - (max(opnd_bytes)
                                           if opnd_bytes else 0)
                b = mult * 2 * small
            else:
                # buffers < VMEM_RESIDENT_BYTES inside loops do not
                # round-trip HBM each iteration (perfect-cache roofline
                # convention); DS/DUS slices of big buffers (above) do.
                def _amt(nb: int) -> float:
                    if mult > 1 and nb < VMEM_RESIDENT_BYTES:
                        return float(nb)
                    return mult * float(nb)

                b = _amt(res_bytes)
                for ob in opnd_bytes:
                    b += _amt(ob)
            tot.memory_bytes += b
            tot.by_opcode[eff_op] = tot.by_opcode.get(eff_op, 0.0) + b
            if b > 1e8:
                tot.top_instrs.append((b, ins.line[:140]))

    walk(entry, 1.0)
    return tot


def analyze_compiled(compiled) -> Dict[str, object]:
    text = compiled.as_text()
    t = analyze(text)
    top = sorted(t.by_opcode.items(), key=lambda kv: -kv[1])[:10]
    return {
        "flops_corrected": t.flops,
        "memory_bytes_corrected": t.memory_bytes,
        "collective_bytes_corrected": t.collective_bytes,
        "collective_per_kind": t.per_kind,
        "collective_counts": t.counts,
        "max_trip_product": t.max_trip_product,
        "top_memory_opcodes": {k: v for k, v in top},
        "hlo_bytes": len(text),
    }
