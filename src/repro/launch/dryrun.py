import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell:
  * build the step function (train_step / prefill / serve_step),
  * auto-shard with the planner (legality → profitability),
  * ``jax.jit(fn, in_shardings=…).lower(**ShapeDtypeStructs).compile()``
    on the production mesh — 512 placeholder host devices stand in for
    the chips; XLA runs the full GSPMD partitioner so sharding mismatches,
    compile-time OOMs and unsupported collectives surface as real errors,
  * record memory_analysis / cost_analysis / per-collective bytes (parsed
    from the compiled HLO) into artifacts/dryrun/results.json — the
    roofline analysis (§Roofline in EXPERIMENTS.md) reads from there.

Usage:
  python -m repro.launch.dryrun --arch stablelm_3b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--force]
"""

import argparse
import json
import re
import sys
import time
import traceback
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCHS, SHAPES, get_config
from repro.configs.registry import ShapeSpec, cell_is_skipped
from repro.core import planner as planner_mod
from repro.core.cost import TPU_V5E, roofline
from repro.launch.mesh import make_production_mesh
from repro.models import transformer as T
from repro.models.common import ArchConfig
from repro.train import AdamWConfig, init_opt_state, make_train_step
from repro.train.optimizer import MomentState

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "artifacts", "dryrun")


# ---------------------------------------------------------------------------
# input_specs: ShapeDtypeStruct stand-ins for every model input
# ---------------------------------------------------------------------------

def input_specs(cfg: ArchConfig, shape: ShapeSpec) -> Dict[str, Any]:
    """Abstract input batch for one cell (no allocation)."""
    B, S = shape.global_batch, shape.seq_len
    sds = jax.ShapeDtypeStruct
    if shape.kind in ("train", "prefill"):
        batch: Dict[str, Any] = {}
        if cfg.embeds_input:
            batch["embeds"] = sds((B, S, cfg.d_model), jnp.float32)
        else:
            batch["tokens"] = sds((B, S), jnp.int32)
        if shape.kind == "train":
            batch["labels"] = sds((B, S), jnp.int32)
        if cfg.is_encdec:
            batch["src_embeds"] = sds((B, S, cfg.d_model), jnp.float32)
        return batch
    # decode: one new token against a full cache
    return {"tokens": sds((B, 1), jnp.int32)}


def _static_specs(cfg: ArchConfig):
    """Build the specs tree without materializing params."""
    closure: Dict[str, Any] = {}

    def capture():
        params, specs = T.init_params(cfg, jax.random.key(0))
        closure["specs"] = specs
        return params

    jax.eval_shape(capture)
    return closure["specs"]


# ---------------------------------------------------------------------------
# Cell builders
# ---------------------------------------------------------------------------

def build_train_cell(cfg: ArchConfig, shape: ShapeSpec, mesh, plan):
    opt_cfg = AdamWConfig(quantize_moments=cfg.opt_8bit)
    step = make_train_step(cfg, opt_cfg)
    p_shapes = jax.eval_shape(lambda: T.init_params(
        cfg, jax.random.key(0))[0])
    o_shapes = jax.eval_shape(lambda: init_opt_state(
        jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), p_shapes),
        opt_cfg))
    batch = input_specs(cfg, shape)

    p_sh = plan.param_shardings
    repl = NamedSharding(mesh, P())

    def moment_sh(param_sh):
        return MomentState(param_sh, repl)

    o_sh = type(o_shapes)(
        step=repl,
        m=jax.tree.map(lambda s: moment_sh(s), p_sh,
                       is_leaf=lambda x: isinstance(x, NamedSharding)),
        v=jax.tree.map(lambda s: moment_sh(s), p_sh,
                       is_leaf=lambda x: isinstance(x, NamedSharding)),
    )
    b_sh = jax.tree.map(
        lambda s: planner_mod.batch_sharding(
            mesh, plan.strategy, shape.global_batch,
            extra_dims=len(s.shape) - 1),
        batch)
    metrics_sh = {"loss": repl, "grad_norm": repl, "step": repl}
    jitted = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh),
                     out_shardings=(p_sh, o_sh, metrics_sh))
    return jitted, (p_shapes, o_shapes, batch)


def build_prefill_cell(cfg: ArchConfig, shape: ShapeSpec, mesh, plan):
    batch = input_specs(cfg, shape)

    def prefill_fn(params, batch):
        return T.prefill(params, batch, cfg, max_seq=shape.seq_len)

    p_shapes = jax.eval_shape(lambda: T.init_params(
        cfg, jax.random.key(0))[0])
    b_sh = jax.tree.map(
        lambda s: planner_mod.batch_sharding(
            mesh, plan.strategy, shape.global_batch,
            extra_dims=len(s.shape) - 1),
        batch)
    jitted = jax.jit(prefill_fn, in_shardings=(plan.param_shardings, b_sh))
    return jitted, (p_shapes, batch)


def build_decode_cell(cfg: ArchConfig, shape: ShapeSpec, mesh, plan):
    B, S = shape.global_batch, shape.seq_len
    cross = S if cfg.is_encdec else 0

    def serve_step(params, tokens, caches):
        return T.decode_step(params, tokens, caches, cfg)

    p_shapes = jax.eval_shape(lambda: T.init_params(
        cfg, jax.random.key(0))[0])
    cache_shapes = jax.eval_shape(
        lambda: T.init_caches(cfg, B, S, cross_len=cross,
                              uniform_index=True))
    tok = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    c_sh = jax.tree.map(
        lambda s: planner_mod.cache_sharding(mesh, plan.strategy, cfg, B,
                                             tuple(s.shape)),
        cache_shapes)
    t_sh = planner_mod.batch_sharding(mesh, plan.strategy, B, extra_dims=1)
    logits_sh = planner_mod.batch_sharding(mesh, plan.strategy, B,
                                           extra_dims=1)
    jitted = jax.jit(serve_step,
                     in_shardings=(plan.param_shardings, t_sh, c_sh),
                     out_shardings=(logits_sh, c_sh))
    return jitted, (p_shapes, tok, cache_shapes)


# ---------------------------------------------------------------------------
# HLO collective accounting
# ---------------------------------------------------------------------------

_SHAPE_RE = re.compile(r"(f64|f32|f16|bf16|f8\w*|s64|s32|s16|s8|u64|u32|"
                       r"u16|u8|pred|c64|c128)\[([\d,]*)\]")
_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8,
                "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4,
                "u16": 2, "u8": 1, "pred": 1, "c64": 8, "c128": 16}
_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shape_bytes(text: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(text):
        dt, dims = m.group(1), m.group(2)
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


def collective_bytes(hlo_text: str) -> Dict[str, Any]:
    per_kind: Dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    counts: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        ls = line.strip()
        if "=" not in ls:
            continue
        lhs, rhs = ls.split("=", 1)
        rhs = rhs.strip()
        for kind in _COLLECTIVES:
            # match op name at the start of the rhs expression:
            #   bf16[...]{...} all-gather(...)
            m = re.match(r"^(\([^)]*\)|[\w\[\],{}:#*\s]*?)\s*"
                         + kind + r"(-start|-done)?\(", rhs)
            if m:
                if m.group(2) == "-done":
                    break  # counted at -start
                # result shape(s) of the collective (output-size convention)
                header = rhs.split(kind)[0]
                per_kind[kind] += _shape_bytes(header)
                counts[kind] += 1
                break
    total = sum(per_kind.values())
    return {"total_bytes": total, "per_kind_bytes": per_kind,
            "counts": counts}


# ---------------------------------------------------------------------------
# Cell runner
# ---------------------------------------------------------------------------

def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             cfg_override: Optional[ArchConfig] = None,
             verbose: bool = True) -> Dict[str, Any]:
    cfg = cfg_override or get_config(arch)
    shape = SHAPES[shape_name]
    skip = cell_is_skipped(cfg, shape_name)
    if skip:
        return {"arch": arch, "shape": shape_name,
                "mesh": "multi" if multi_pod else "single",
                "status": "skipped", "reason": skip}
    mesh = make_production_mesh(multi_pod=multi_pod)
    t0 = time.perf_counter()
    p_shapes = jax.eval_shape(lambda: T.init_params(
        cfg, jax.random.key(0))[0])
    specs = _static_specs(cfg)
    plan = planner_mod.plan(cfg, specs, p_shapes, mesh,
                            seq=shape.seq_len, batch=shape.global_batch,
                            kind=shape.kind)
    import dataclasses as _dc

    rows = shape.global_batch
    if shape.kind == "train":
        rows = shape.global_batch // max(1, plan.estimate.microbatch)
    # anchor activations on the planner's effective DP axes
    ax: tuple = ()
    for i in range(len(plan.strategy.batch_axes), 0, -1):
        cand = plan.strategy.batch_axes[:i]
        if rows % planner_mod._mesh_size(mesh, cand) == 0:
            ax = cand
            break
    moe_ax = cap_ax = None
    if cfg.n_experts:
        from repro.models.moe import padded_experts

        e_pad = padded_experts(cfg, 16)
        f = cfg.expert_d_ff or cfg.d_ff
        spec = planner_mod.resolve_leaf_spec(
            (e_pad, cfg.d_model, f), ("experts", "embed", "mlp"),
            plan.strategy, mesh)
        if spec[0] is not None:
            moe_ax = (spec[0],) if isinstance(spec[0], str) \
                else tuple(spec[0])
            # capacity dim covers the mesh axes experts cannot
            cap_ax = tuple(a for a in mesh.axis_names
                           if a not in moe_ax) or None
    cfg = _dc.replace(cfg, microbatch=plan.estimate.microbatch
                      if shape.kind == "train" else cfg.microbatch,
                      act_batch_axes=ax or None,
                      moe_expert_axes=moe_ax,
                      moe_capacity_axes=cap_ax)
    if verbose:
        print(f"[{arch} × {shape_name} × "
              f"{'multi' if multi_pod else 'single'}] mb={cfg.microbatch} "
              f"{plan.describe()}", flush=True)

    if shape.kind == "train":
        jitted, args = build_train_cell(cfg, shape, mesh, plan)
    elif shape.kind == "prefill":
        jitted, args = build_prefill_cell(cfg, shape, mesh, plan)
    else:
        jitted, args = build_decode_cell(cfg, shape, mesh, plan)

    with mesh:
        lowered = jitted.lower(*args)
        t_lower = time.perf_counter() - t0
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0 - t_lower

    result: Dict[str, Any] = {
        "arch": arch, "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "chips": mesh.size,
        "status": "ok",
        "strategy": plan.strategy.name,
        "microbatch": cfg.microbatch,
        "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
        "planner_estimate": {
            "hbm_gib_per_chip": plan.estimate.hbm_bytes_per_chip / 2**30,
            "compute_s": plan.estimate.compute_s,
            "memory_s": plan.estimate.memory_s,
            "collective_s": plan.estimate.collective_s,
        },
    }

    try:
        ca = compiled.cost_analysis()
        if isinstance(ca, list):
            ca = ca[0]
        result["cost_analysis"] = {
            "flops": float(ca.get("flops", 0.0)),
            "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
            "transcendentals": float(ca.get("transcendentals", 0.0)),
        }
    except Exception as exc:  # pragma: no cover
        result["cost_analysis"] = {"error": str(exc)}

    try:
        ma = compiled.memory_analysis()
        mem = {}
        for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                     "temp_size_in_bytes", "generated_code_size_in_bytes",
                     "alias_size_in_bytes"):
            if hasattr(ma, attr):
                mem[attr] = int(getattr(ma, attr))
        result["memory_analysis"] = mem
    except Exception as exc:  # pragma: no cover
        result["memory_analysis"] = {"error": str(exc)}

    try:
        from repro.launch import hlo_analysis

        corrected = hlo_analysis.analyze_compiled(compiled)
        result["hlo_corrected"] = corrected
    except Exception as exc:  # pragma: no cover
        corrected = {}
        result["hlo_corrected"] = {"error": str(exc)}

    # roofline terms (per §Roofline; single-pod is the reported table).
    # FLOPs/bytes/collective are trip-count-corrected from the optimized
    # HLO (launch/hlo_analysis.py) — raw cost_analysis() counts each
    # while body once and is kept only for reference.
    n_active = cfg.active_param_count()
    tokens = (shape.global_batch * shape.seq_len
              if shape.kind != "decode" else shape.global_batch)
    model_flops = (6.0 if shape.kind == "train" else 2.0) * n_active \
        * tokens
    # the optimized module is the per-device SPMD program → corrected
    # numbers are PER-CHIP; roofline terms divide by 1 chip.
    hlo_flops = corrected.get("flops_corrected", 0.0) or 0.0
    hlo_bytes = corrected.get("memory_bytes_corrected", 0.0) or 0.0
    coll_bytes = corrected.get("collective_bytes_corrected", 0.0) or 0.0
    rt = roofline(hlo_flops, hlo_bytes, coll_bytes, 1, TPU_V5E)
    global_hlo_flops = hlo_flops * mesh.size
    result["roofline"] = {
        "compute_s": rt.compute_s,
        "memory_s": rt.memory_s,
        "collective_s": rt.collective_s,
        "dominant": rt.dominant,
        "model_flops": model_flops,
        "hlo_flops_global": global_hlo_flops,
        "useful_flops_ratio": (model_flops / global_hlo_flops
                               if global_hlo_flops else None),
    }
    if verbose:
        print(f"  ok: compile={t_compile:.1f}s flops={hlo_flops:.3e} "
              f"bytes={hlo_bytes:.3e} coll={coll_bytes:.3e} "
              f"dominant={rt.dominant}", flush=True)
    return result


# ---------------------------------------------------------------------------
# Sweep + cache
# ---------------------------------------------------------------------------

def _results_path() -> str:
    os.makedirs(ART_DIR, exist_ok=True)
    return os.path.join(ART_DIR, "results.json")


def load_results() -> Dict[str, Any]:
    path = _results_path()
    if os.path.exists(path):
        with open(path) as f:
            return json.load(f)
    return {}


def save_results(res: Dict[str, Any]) -> None:
    with open(_results_path(), "w") as f:
        json.dump(res, f, indent=1)


def cell_key(arch: str, shape: str, multi_pod: bool) -> str:
    return f"{arch}|{shape}|{'multi' if multi_pod else 'single'}"


def sweep(archs, shapes, meshes, force=False) -> None:
    results = load_results()
    for multi_pod in meshes:
        for arch in archs:
            for shape in shapes:
                key = cell_key(arch, shape, multi_pod)
                prev = results.get(key)
                if prev and not force and prev.get("status") in (
                        "ok", "skipped"):
                    continue
                try:
                    res = run_cell(arch, shape, multi_pod=multi_pod)
                except Exception as exc:
                    res = {"arch": arch, "shape": shape,
                           "mesh": "multi" if multi_pod else "single",
                           "status": "error", "error": str(exc)[:2000],
                           "traceback":
                               traceback.format_exc()[-4000:]}
                    print(f"[{key}] ERROR: {exc}", flush=True)
                results[key] = res
                save_results(results)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--set", dest="overrides", action="append",
                    default=[], metavar="KEY=VALUE",
                    help="config override for hillclimb iterations "
                         "(e.g. --set microbatch=8)")
    ap.add_argument("--tag", default=None,
                    help="store result under <cell>#<tag> (keeps the "
                         "baseline row)")
    args = ap.parse_args()

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    if args.all:
        sweep(ARCHS, list(SHAPES), meshes, force=args.force)
        return
    assert args.arch and args.shape, "--arch and --shape (or --all)"
    cfg_override = None
    if args.overrides:
        import dataclasses as _dc

        cfg_override = get_config(args.arch)
        kv = {}
        for ov in args.overrides:
            k, v = ov.split("=", 1)
            cur = getattr(cfg_override, k)
            if isinstance(cur, bool):
                v = v.lower() in ("1", "true", "yes")
            elif isinstance(cur, int):
                v = int(v)
            elif isinstance(cur, float):
                v = float(v)
            kv[k] = v
        cfg_override = _dc.replace(cfg_override, **kv)
    res = run_cell(args.arch, args.shape, multi_pod=args.multi_pod,
                   cfg_override=cfg_override)
    if args.overrides:
        res["overrides"] = args.overrides
    key = cell_key(args.arch, args.shape, args.multi_pod)
    if args.tag:
        key = f"{key}#{args.tag}"
    results = load_results()
    results[key] = res
    save_results(results)
    print(json.dumps({k: res.get(k) for k in
                      ("strategy", "microbatch", "roofline")}, indent=1))


if __name__ == "__main__":
    main()
