"""Distributed AdamW with optional int8-quantized moments.

Moments are stored per-parameter either in fp32 or as (int8 payload,
per-tensor fp32 absmax scale). The 8-bit path is a *legality* requirement
for the ≥100B assigned archs: fp32 Adam for nemotron-4-340b needs ~5.4 TB
of state — more than a 256-chip v5e pod holds — so the multi-versioner's
memory-legality branch selects the quantized variant (DESIGN.md §5).

States inherit the parameters' shardings (the planner shards both), giving
ZeRO-style partitioning for free: FSDP-sharded params ⇒ FSDP-sharded
moments.
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    quantize_moments: bool = False  # int8 m/v


# ---------------------------------------------------------------------------
# int8 moment codec
# ---------------------------------------------------------------------------

def _q8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def _dq8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


class MomentState(NamedTuple):
    payload: jnp.ndarray            # fp32 or int8
    scale: jnp.ndarray              # () fp32; unused when fp32


def _encode(x: jnp.ndarray, quantize: bool) -> MomentState:
    if quantize:
        q, s = _q8(x)
        return MomentState(q, s)
    return MomentState(x.astype(jnp.float32), jnp.float32(1.0))


def _decode(st: MomentState) -> jnp.ndarray:
    if st.payload.dtype == jnp.int8:
        return _dq8(st.payload, st.scale)
    return st.payload


class OptState(NamedTuple):
    step: jnp.ndarray
    m: Any   # pytree of MomentState
    v: Any


def init_opt_state(params, cfg: AdamWConfig) -> OptState:
    def mk(p):
        z = jnp.zeros(p.shape, jnp.float32)
        return _encode(z, cfg.quantize_moments)

    return OptState(
        step=jnp.zeros((), jnp.int32),
        m=jax.tree.map(mk, params),
        v=jax.tree.map(mk, params),
    )


def global_norm(grads) -> jnp.ndarray:
    sq = sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
             for g in jax.tree.leaves(grads))
    return jnp.sqrt(sq)


def adamw_update(params, grads, state: OptState,
                 cfg: AdamWConfig):
    """One AdamW step; returns (new_params, new_state)."""
    step = state.step + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12)) \
        if cfg.grad_clip > 0 else jnp.float32(1.0)

    bc1 = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, m_st, v_st):
        g32 = g.astype(jnp.float32) * clip
        m = cfg.b1 * _decode(m_st) + (1 - cfg.b1) * g32
        v = cfg.b2 * _decode(v_st) + (1 - cfg.b2) * jnp.square(g32)
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        p32 = p.astype(jnp.float32)
        if cfg.weight_decay > 0 and p.ndim >= 2:
            delta = delta + cfg.weight_decay * p32
        p_new = (p32 - cfg.lr * delta).astype(p.dtype)
        return p_new, _encode(m, cfg.quantize_moments), \
            _encode(v, cfg.quantize_moments)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = jax.tree.flatten(
        state.m, is_leaf=lambda x: isinstance(x, MomentState))[0]
    flat_v = jax.tree.flatten(
        state.v, is_leaf=lambda x: isinstance(x, MomentState))[0]
    outs = [upd(p, g, m, v)
            for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in outs])
    new_m = treedef.unflatten([o[1] for o in outs])
    new_v = treedef.unflatten([o[2] for o in outs])
    return new_p, OptState(step, new_m, new_v)


def opt_state_bytes(state: OptState) -> int:
    total = 0
    for leaf in jax.tree.leaves(state):
        total += leaf.size * leaf.dtype.itemsize
    return total
