"""Training step factory: grad accumulation, remat, compression, optimizer.

``make_train_step(cfg)`` returns a pure function

    train_step(params, opt_state, batch) -> (params, opt_state, metrics)

with the global batch split into ``cfg.microbatch`` accumulation steps
scanned sequentially (the memory roofline term decides the count), loss
rematerialized per microbatch, optional int8 gradient compression at the
accumulate boundary (the DP all-reduce surrogate point under GSPMD), and
AdamW (optionally 8-bit states) applied once.
"""

from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models import transformer as T
from repro.models.common import ArchConfig

from . import grad_compress
from .optimizer import AdamWConfig, OptState, adamw_update, init_opt_state


def _split_microbatches(batch: Dict, n: int) -> Dict:
    """(B, …) → (n, B/n, …) for every leaf."""
    def split(x):
        b = x.shape[0]
        assert b % n == 0, f"global batch {b} not divisible by {n}"
        return x.reshape(n, b // n, *x.shape[1:])

    return jax.tree.map(split, batch)


def make_loss_fn(cfg: ArchConfig):
    def loss_fn(params, microbatch):
        return T.loss_fn(params, microbatch, cfg)

    return loss_fn


def make_train_step(cfg: ArchConfig, opt_cfg: Optional[AdamWConfig] = None,
                    compress: Optional[str] = None):
    opt_cfg = opt_cfg or AdamWConfig(quantize_moments=cfg.opt_8bit)
    loss_fn = make_loss_fn(cfg)
    grad_fn = jax.value_and_grad(loss_fn)

    def train_step(params, opt_state: OptState, batch):
        n = max(1, cfg.microbatch)
        mbs = _split_microbatches(batch, n)

        def accum(carry, mb):
            gsum, lsum = carry
            loss, grads = grad_fn(params, mb)
            gsum = jax.tree.map(
                lambda a, g: a + g.astype(jnp.float32), gsum, grads)
            return (gsum, lsum + loss), None

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                          params)
        (gsum, lsum), _ = jax.lax.scan(accum, (g0, jnp.float32(0.0)), mbs)
        grads = jax.tree.map(lambda g: g / n, gsum)
        if compress == "int8":
            grads = grad_compress.roundtrip_int8(grads)
        new_params, new_opt = adamw_update(params, grads, opt_state,
                                           opt_cfg)
        metrics = {
            "loss": lsum / n,
            "grad_norm": jnp.sqrt(sum(
                jnp.sum(jnp.square(g)) for g in jax.tree.leaves(grads))),
            "step": new_opt.step,
        }
        return new_params, new_opt, metrics

    return train_step


def make_init(cfg: ArchConfig, opt_cfg: Optional[AdamWConfig] = None):
    opt_cfg = opt_cfg or AdamWConfig(quantize_moments=cfg.opt_8bit)

    def init(key):
        params, specs = T.init_params(cfg, key)
        opt_state = init_opt_state(params, opt_cfg)
        return params, opt_state, specs

    return init
