from .optimizer import AdamWConfig, OptState, adamw_update, init_opt_state
from .train_loop import make_init, make_loss_fn, make_train_step

__all__ = ["AdamWConfig", "OptState", "adamw_update", "init_opt_state",
           "make_init", "make_loss_fn", "make_train_step"]
