"""Gradient compression for the data-parallel all-reduce.

Two schemes, selectable per config (a profitability decision on the
collective roofline term):

  * ``int8``  — stochastic-rounding int8 with per-leaf absmax scale:
    4× less DP all-reduce traffic than fp32, 2× less than bf16. The
    all-reduce runs over the *decoded* values (psum of int8 is lossy
    across shards), so the win is realized by casting before the
    cross-replica reduce and decoding after — here expressed as
    compress → psum(fp32 of int8) → decode.
  * ``topk``  — magnitude top-k sparsification with error feedback; the
    residual is carried to the next step (classic deep-gradient-
    compression). Used by the hillclimb when the collective term
    dominates and the topology makes all-gather-of-sparse cheaper.
"""

from __future__ import annotations

from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class CompressedGrad(NamedTuple):
    payload: jnp.ndarray
    scale: jnp.ndarray


def compress_int8(g: jnp.ndarray, key=None) -> CompressedGrad:
    g32 = g.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-12) / 127.0
    x = g32 / scale
    if key is not None:  # stochastic rounding
        x = jnp.floor(x + jax.random.uniform(key, x.shape))
    else:
        x = jnp.round(x)
    q = jnp.clip(x, -127, 127).astype(jnp.int8)
    return CompressedGrad(q, scale.astype(jnp.float32))


def decompress_int8(c: CompressedGrad) -> jnp.ndarray:
    return c.payload.astype(jnp.float32) * c.scale


def compress_tree_int8(grads, key=None):
    leaves, treedef = jax.tree.flatten(grads)
    keys = (jax.random.split(key, len(leaves)) if key is not None
            else [None] * len(leaves))
    out = [compress_int8(g, k) for g, k in zip(leaves, keys)]
    return treedef.unflatten(out)


def decompress_tree_int8(ctree):
    return jax.tree.map(decompress_int8, ctree,
                        is_leaf=lambda x: isinstance(x, CompressedGrad))


def roundtrip_int8(grads, key=None):
    """compress→decompress (what each DP replica sends/receives)."""
    return decompress_tree_int8(compress_tree_int8(grads, key))


# ---------------------------------------------------------------------------
# top-k with error feedback
# ---------------------------------------------------------------------------

class TopKState(NamedTuple):
    residual: Any  # pytree of fp32 residuals


def init_topk_state(grads) -> TopKState:
    return TopKState(jax.tree.map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads))


def topk_sparsify(g: jnp.ndarray, res: jnp.ndarray,
                  frac: float) -> Tuple[jnp.ndarray, jnp.ndarray]:
    g32 = g.astype(jnp.float32) + res
    flat = g32.reshape(-1)
    k = max(1, int(flat.size * frac))
    thresh = jax.lax.top_k(jnp.abs(flat), k)[0][-1]
    mask = jnp.abs(g32) >= thresh
    sent = jnp.where(mask, g32, 0.0)
    new_res = g32 - sent
    return sent, new_res


def topk_roundtrip(grads, state: TopKState,
                   frac: float = 0.05) -> Tuple[Any, TopKState]:
    outs = jax.tree.map(
        lambda g, r: topk_sparsify(g, r, frac), grads, state.residual)
    sent = jax.tree.map(lambda o: o[0], outs,
                        is_leaf=lambda x: isinstance(x, tuple))
    res = jax.tree.map(lambda o: o[1], outs,
                       is_leaf=lambda x: isinstance(x, tuple))
    return sent, TopKState(res)
