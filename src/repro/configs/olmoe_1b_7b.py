"""olmoe-1b-7b [moe]: 16L d_model=2048 16H (kv=16) d_ff=1024/expert,
vocab=50304, MoE 64 experts top-8, every layer [arXiv:2409.02060; hf]."""

from repro.models.common import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="olmoe_1b_7b", family="moe",
        layers=16, d_model=2048, n_heads=16, kv_heads=16,
        d_ff=1024, vocab=50304,
        n_experts=64, experts_topk=8, expert_d_ff=1024,
        moe_every=1, moe_offset=0,
        mlp_act="silu", tie_embeddings=False,
        microbatch=2, remat="full", fused_xent=True,
        skip_shapes={"long_500k": "full quadratic attention"},
    )


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="olmoe_1b_7b_smoke", family="moe",
        layers=2, d_model=64, n_heads=4, kv_heads=4, d_ff=32,
        vocab=512, n_experts=8, experts_topk=2, expert_d_ff=32,
        moe_every=1, tie_embeddings=False,
        microbatch=1, remat="none", attn_chunk=64,
    )
