"""qwen2-moe-a2.7b [moe]: 24L d_model=2048 16H (kv=16) d_ff=1408/expert,
vocab=151936, 60 routed experts top-4 + 4 shared
[hf:Qwen/Qwen1.5-MoE-A2.7B; hf].

60 % 16 ≠ 0 → routed experts padded 60 → 64 with router logits masked
(legality branch, DESIGN.md §4)."""

from repro.models.common import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="qwen2_moe_a2_7b", family="moe",
        layers=24, d_model=2048, n_heads=16, kv_heads=16,
        d_ff=1408, vocab=151936,
        n_experts=60, experts_topk=4, n_shared_experts=4,
        expert_d_ff=1408, moe_every=1, moe_offset=0,
        qkv_bias=True, mlp_act="silu", tie_embeddings=False,
        microbatch=2, remat="full", fused_xent=True,
        skip_shapes={"long_500k": "full quadratic attention"},
    )


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="qwen2_moe_a2_7b_smoke", family="moe",
        layers=2, d_model=64, n_heads=4, kv_heads=4, d_ff=32,
        vocab=512, n_experts=6, experts_topk=2, n_shared_experts=1,
        expert_d_ff=32, qkv_bias=True, tie_embeddings=False,
        microbatch=1, remat="none", attn_chunk=64,
    )
