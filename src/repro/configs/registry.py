"""Architecture and shape registry: the 10 assigned (arch × shape) grids.

Shapes (LM family):
  train_4k     seq 4,096   global_batch 256   → train_step
  prefill_32k  seq 32,768  global_batch 32    → prefill
  decode_32k   seq 32,768  global_batch 128   → serve_step (1 new token)
  long_500k    seq 524,288 global_batch 1     → serve_step; sub-quadratic
                                                archs only (see DESIGN.md)
"""

from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass
from typing import Dict, Optional

from repro.models.common import ArchConfig


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

ARCHS = [
    "seamless_m4t_medium",
    "olmoe_1b_7b",
    "qwen2_moe_a2_7b",
    "qwen1_5_110b",
    "nemotron_4_340b",
    "gemma2_2b",
    "stablelm_3b",
    "llava_next_mistral_7b",
    "jamba_1_5_large_398b",
    "xlstm_125m",
]

# accept dashed ids from the assignment table too
_ALIASES = {a.replace("_", "-"): a for a in ARCHS}
_ALIASES.update({
    "seamless-m4t-medium": "seamless_m4t_medium",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "qwen1.5-110b": "qwen1_5_110b",
    "nemotron-4-340b": "nemotron_4_340b",
    "gemma2-2b": "gemma2_2b",
    "stablelm-3b": "stablelm_3b",
    "llava-next-mistral-7b": "llava_next_mistral_7b",
    "jamba-1.5-large-398b": "jamba_1_5_large_398b",
    "xlstm-125m": "xlstm_125m",
})


def canon(arch: str) -> str:
    return _ALIASES.get(arch, arch)


def get_config(arch: str) -> ArchConfig:
    mod = importlib.import_module(f"repro.configs.{canon(arch)}")
    return mod.config()


def get_smoke_config(arch: str) -> ArchConfig:
    mod = importlib.import_module(f"repro.configs.{canon(arch)}")
    return mod.smoke_config()


def shape_spec(name: str) -> ShapeSpec:
    return SHAPES[name]


def cell_is_skipped(cfg: ArchConfig, shape: str) -> Optional[str]:
    """Reason string if this (arch, shape) cell is skipped, else None."""
    return cfg.skip_shapes.get(shape)
