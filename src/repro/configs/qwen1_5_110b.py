"""qwen1.5-110b [dense]: 80L d_model=8192 64H (GQA kv=8) d_ff=49152,
vocab=152064, QKV bias [hf:Qwen/Qwen1.5-0.5B family; hf]."""

from repro.models.common import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="qwen1_5_110b", family="dense",
        layers=80, d_model=8192, n_heads=64, kv_heads=8,
        d_ff=49152, vocab=152064,
        qkv_bias=True, mlp_act="silu", tie_embeddings=False,
        microbatch=16, remat="full", fused_xent=True, opt_8bit=True,
        seq_shard=True,
        skip_shapes={"long_500k": "full quadratic attention"},
    )


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="qwen1_5_110b_smoke", family="dense",
        layers=2, d_model=64, n_heads=8, kv_heads=2, d_ff=128,
        vocab=512, qkv_bias=True, tie_embeddings=False,
        microbatch=1, remat="none", attn_chunk=64,
    )
