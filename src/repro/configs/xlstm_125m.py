"""xlstm-125m [ssm]: 12L d_model=768 4H d_ff=0 vocab=50304 — alternating
mLSTM (matrix-memory) and sLSTM (scalar-memory) blocks, no separate FFN
[arXiv:2405.04517; unverified].

Runs long_500k: recurrent state decode is O(1) per token. Tiny model: the
planner's profitability tree keeps it pure-DP (model axis unused) — the
paper's "not worth distributing" branch."""

from repro.models.common import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="xlstm_125m", family="ssm",
        layers=12, d_model=768, n_heads=4, kv_heads=4,
        d_ff=0, vocab=50304,
        xlstm_pattern=("mlstm", "slstm"),
        tie_embeddings=True,
        microbatch=1, remat="full", fused_xent=True,
    )


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="xlstm_125m_smoke", family="ssm",
        layers=2, d_model=64, n_heads=2, kv_heads=2, d_ff=0,
        vocab=512, xlstm_pattern=("mlstm", "slstm"),
        microbatch=1, remat="none", attn_chunk=64,
    )
