"""jamba-1.5-large-398b [hybrid]: 72L d_model=8192 64H (GQA kv=8)
d_ff=24576, vocab=65536, MoE 16e top-2, Mamba:attn 7:1 interleave
[arXiv:2403.19887; hf].

Period-8 superblock: attention at index 4, Mamba elsewhere; MoE FFN on odd
indices. Runs long_500k (hybrid → sub-quadratic: Mamba state + 9 attention
layers with KV cache)."""

from repro.models.common import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="jamba_1_5_large_398b", family="hybrid",
        layers=72, d_model=8192, n_heads=64, kv_heads=8,
        d_ff=24576, vocab=65536,
        period=8, attn_idx=4,
        n_experts=16, experts_topk=2, expert_d_ff=24576,
        moe_every=2, moe_offset=1,
        ssm_state=16, ssm_expand=2,
        mlp_act="silu", tie_embeddings=False,
        microbatch=16, remat="full", fused_xent=True, opt_8bit=True,
        seq_shard=True,
    )


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="jamba_1_5_large_398b_smoke", family="hybrid",
        layers=8, d_model=64, n_heads=4, kv_heads=2, d_ff=128,
        vocab=512, period=4, attn_idx=2,
        n_experts=4, experts_topk=2, expert_d_ff=64,
        moe_every=2, moe_offset=1, ssm_state=4, ssm_expand=2,
        tie_embeddings=False,
        microbatch=1, remat="none", attn_chunk=64,
    )
