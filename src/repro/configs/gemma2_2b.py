"""gemma2-2b [dense]: 26L d_model=2304 8H (GQA kv=4) d_ff=9216,
vocab=256000 — alternating local(4096)/global attention, attn softcap 50,
final-logit softcap 30 [arXiv:2408.00118; hf].

8 q-heads < tp=16 → head-axis TP fails the divisibility legality check;
the planner falls back to mlp/row-parallel sharding for this arch
(DESIGN.md §4). long_500k skipped: global layers are quadratic."""

from repro.models.common import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="gemma2_2b", family="dense",
        layers=26, d_model=2304, n_heads=8, kv_heads=4,
        d_ff=9216, vocab=256000,
        alt_local_global=True, sliding_window=4096,
        attn_softcap=50.0, logit_softcap=30.0,
        mlp_act="gelu", tie_embeddings=True,
        microbatch=2, remat="full", fused_xent=True,
        # §Perf hillclimb winner: q-sequence sharding removes the
        # per-layer activation all-reduces of head_dim-TP attention
        # (prefill_32k roofline bound 18.3 s → 0.95 s, EXPERIMENTS.md)
        seq_shard=True, attn_chunk=4096,
        skip_shapes={"long_500k": "global-attention layers are quadratic"},
    )


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="gemma2_2b_smoke", family="dense",
        layers=2, d_model=64, n_heads=4, kv_heads=2, d_ff=128,
        vocab=512, alt_local_global=True, sliding_window=32,
        attn_softcap=50.0, logit_softcap=30.0, mlp_act="gelu",
        microbatch=1, remat="none", attn_chunk=64,
    )
