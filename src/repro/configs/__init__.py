"""Assigned-architecture configs (public-literature presets).

Each module exposes ``config()`` → ArchConfig (the exact assigned
dimensions) and ``smoke_config()`` → a reduced same-family config for CPU
smoke tests. ``repro.configs.registry`` maps ``--arch <id>`` to them.
"""

from .registry import ARCHS, SHAPES, get_config, get_smoke_config, shape_spec

__all__ = ["ARCHS", "SHAPES", "get_config", "get_smoke_config",
           "shape_spec"]
