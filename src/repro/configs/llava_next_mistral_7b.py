"""llava-next-mistral-7b [vlm]: Mistral-7B backbone — 32L d_model=4096
32H (GQA kv=8) d_ff=14336 vocab=32000, sliding-window 4096
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified].

The anyres vision frontend is a STUB: input_specs() provides pre-projected
patch+text embeddings (B, S, d_model); the backbone transformer is what is
built/sharded/lowered here."""

from repro.models.common import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="llava_next_mistral_7b", family="vlm",
        layers=32, d_model=4096, n_heads=32, kv_heads=8,
        d_ff=14336, vocab=32000,
        sliding_window=4096, embeds_input=True,
        mlp_act="silu", tie_embeddings=False,
        microbatch=4, remat="full", fused_xent=True,
        skip_shapes={"long_500k": "assigned long-context shapes run on "
                                  "ssm/hybrid archs only"},
    )


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="llava_next_mistral_7b_smoke", family="vlm",
        layers=2, d_model=64, n_heads=4, kv_heads=2, d_ff=128,
        vocab=512, sliding_window=32, embeds_input=True,
        tie_embeddings=False,
        microbatch=1, remat="none", attn_chunk=64,
    )
