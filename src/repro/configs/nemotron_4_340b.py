"""nemotron-4-340b [dense]: 96L d_model=18432 96H (GQA kv=8) d_ff=73728,
vocab=256000, squared-ReLU MLP [arXiv:2402.16819; unverified].

Largest assigned cell. fp32 Adam moments would need ~5.4 TB (> the 4 TB
single-pod HBM) → the multi-versioner's legality branch selects the 8-bit
optimizer-state variant (train/optimizer.py)."""

from repro.models.common import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="nemotron_4_340b", family="dense",
        layers=96, d_model=18432, n_heads=96, kv_heads=8,
        d_ff=73728, vocab=256000,
        mlp_act="sqrelu", tie_embeddings=False,
        # §Perf hillclimb winners: dots-remat removes the recompute
        # all-gather wave (useful flops 0.48 → 0.96); plain attention at
        # 4k (chunked only beyond 2×attn_chunk) trims memory 6%
        microbatch=16, remat="dots", fused_xent=True, opt_8bit=True,
        seq_shard=True, attn_chunk=2048,
        skip_shapes={"long_500k": "full quadratic attention"},
    )


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="nemotron_4_340b_smoke", family="dense",
        layers=2, d_model=96, n_heads=8, kv_heads=2, d_ff=192,
        vocab=512, mlp_act="sqrelu", tie_embeddings=False,
        microbatch=1, remat="none", attn_chunk=64,
    )
