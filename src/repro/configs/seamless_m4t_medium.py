"""seamless-m4t-medium [audio]: enc-dec multimodal backbone
[arXiv:2308.11596; hf]. 12L dec + 12L enc, d_model=1024, 16H (kv=16),
d_ff=4096, vocab=256206. The audio frontend is a STUB: input_specs()
provides precomputed frame embeddings (B, S_src, d_model).

Vocab 256206 is indivisible by tp=16 → planner pads to a multiple of
tp×128 (legality branch, DESIGN.md §4)."""

from repro.models.common import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="seamless_m4t_medium", family="audio",
        layers=12, d_model=1024, n_heads=16, kv_heads=16,
        d_ff=4096, vocab=256206,
        is_encdec=True, enc_layers=12, embeds_input=False,
        mlp_act="gelu", tie_embeddings=True,
        microbatch=1, remat="full", fused_xent=True,
        skip_shapes={"long_500k": "full quadratic attention (enc-dec); "
                                  "sub-quadratic variants only"},
    )


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="seamless_m4t_medium_smoke", family="audio",
        layers=2, d_model=64, n_heads=4, kv_heads=4, d_ff=128,
        vocab=503,  # deliberately indivisible → exercises padding
        is_encdec=True, enc_layers=2, mlp_act="gelu",
        microbatch=1, remat="none", attn_chunk=64,
    )
