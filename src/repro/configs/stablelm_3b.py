"""stablelm-3b [dense]: 32L d_model=2560 32H (kv=32, MHA) d_ff=6912,
vocab=50304 [hf:stabilityai/stablelm family; unverified]."""

from repro.models.common import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="stablelm_3b", family="dense",
        layers=32, d_model=2560, n_heads=32, kv_heads=32,
        d_ff=6912, vocab=50304,
        mlp_act="silu", tie_embeddings=False,
        microbatch=2, remat="full", fused_xent=True,
        skip_shapes={"long_500k": "full quadratic attention"},
    )


def smoke_config() -> ArchConfig:
    return ArchConfig(
        name="stablelm_3b_smoke", family="dense",
        layers=2, d_model=64, n_heads=4, kv_heads=4, d_ff=128,
        vocab=512, tie_embeddings=False,
        microbatch=1, remat="none", attn_chunk=64,
    )
