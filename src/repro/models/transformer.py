"""Model assembly: init, forward (scan-over-periods + remat), prefill,
decode — for every family in the pool (dense / MoE / hybrid / ssm /
enc-dec / frontend-stub multimodal).

Each layer = mixer (attn | mamba | mlstm | slstm) + optional FFN
(dense MLP | MoE). Layer parameters are stacked over period instances and
scanned, so a 96-layer model lowers to one compact while-loop in HLO.
"""

from __future__ import annotations

import math
from functools import partial
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from . import moe as moe_mod
from . import ssm as ssm_mod
from .common import (ArchConfig, KeyGen, _init, apply_attn, apply_mlp,
                     cross_kv_from_encoder, init_attn, init_mlp,
                     lm_head_loss, rmsnorm)

TP_DEFAULT = 16  # production mesh model-axis size (vocab/expert padding)


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _init_layer(key, cfg: ArchConfig, idx_in_period: int,
                with_cross: bool) -> Tuple[Dict, Dict]:
    kg = KeyGen(key)
    kind = cfg.layer_kind(idx_in_period)
    p: Dict = {}
    s: Dict = {}
    if kind == "attn":
        p["attn"], s["attn"] = init_attn(kg, cfg)
    elif kind == "mamba":
        p["mamba"], s["mamba"] = ssm_mod.init_mamba(kg, cfg)
    elif kind == "mlstm":
        p["mlstm"], s["mlstm"] = ssm_mod.init_mlstm(kg, cfg)
    elif kind == "slstm":
        p["slstm"], s["slstm"] = ssm_mod.init_slstm(kg, cfg)
    if with_cross:
        p["cross"], s["cross"] = init_attn(kg, cfg)
    if cfg.d_ff > 0 or cfg.layer_is_moe(idx_in_period):
        if cfg.layer_is_moe(idx_in_period):
            p["moe"], s["moe"] = moe_mod.init_moe(kg, cfg, TP_DEFAULT)
        else:
            p["mlp"], s["mlp"] = init_mlp(kg, cfg)
    return p, s


def _stack_specs(s: Dict) -> Dict:
    return jax.tree.map(lambda spec: ("layers",) + spec, s,
                        is_leaf=lambda x: isinstance(x, tuple)
                        and all(isinstance(e, str) for e in x))


def init_params(cfg: ArchConfig, key) -> Tuple[Dict, Dict]:
    """Returns (params, specs). specs mirrors params with logical-axis
    tuples on every leaf — the planner's input."""
    kg = KeyGen(key)
    vpad = cfg.padded_vocab(TP_DEFAULT)
    params: Dict = {
        # N(0, 1/d): unit-variance inputs after the sqrt(d) embed scaling
        # and modest logits when tied as the unembedding.
        "embed": _init(kg(), (vpad, cfg.d_model), cfg.dtype,
                       scale=1.0 / math.sqrt(cfg.d_model)),
        "final_ln": jnp.zeros((cfg.d_model,), cfg.dtype),
    }
    specs: Dict = {
        "embed": ("vocab", "embed"),
        "final_ln": ("embed",),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = _init(kg(), (vpad, cfg.d_model), cfg.dtype,
                                  scale=1.0 / math.sqrt(cfg.d_model))
        specs["unembed"] = ("vocab", "embed")

    def stack_layers(idx: int, with_cross: bool):
        keys = jax.random.split(kg(), cfg.n_periods)
        p0, s0 = _init_layer(keys[0], cfg, idx, with_cross)
        stacked = jax.vmap(
            lambda k: _init_layer(k, cfg, idx, with_cross)[0])(keys)
        return stacked, _stack_specs(s0)

    blocks, bspecs = [], []
    for i in range(cfg.period):
        p, s = stack_layers(i, with_cross=cfg.is_encdec)
        blocks.append(p)
        bspecs.append(s)
    params["blocks"] = blocks
    specs["blocks"] = bspecs

    if cfg.is_encdec:
        enc_cfg = cfg
        n_enc_periods = cfg.enc_layers
        keys = jax.random.split(kg(), n_enc_periods)
        p0, s0 = _init_layer(keys[0], cfg, 0, with_cross=False)
        params["encoder"] = jax.vmap(
            lambda k: _init_layer(k, cfg, 0, False)[0])(keys)
        specs["encoder"] = _stack_specs(s0)
        params["enc_final_ln"] = jnp.zeros((cfg.d_model,), cfg.dtype)
        specs["enc_final_ln"] = ("embed",)
    return params, specs


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------

def _apply_layer(p, x, cfg: ArchConfig, idx_in_period: int, *, positions,
                 cache=None, enc_out=None):
    kind = cfg.layer_kind(idx_in_period)
    new_cache = None
    if kind == "attn":
        window = cfg.layer_window(idx_in_period)
        x, new_cache = apply_attn(p["attn"], x, cfg, positions=positions,
                                  window=window,
                                  cache=None if cache is None
                                  else cache.get("kv"))
        new_cache = None if new_cache is None else {"kv": new_cache}
    elif kind == "mamba":
        x, st = ssm_mod.apply_mamba(p["mamba"], x, cfg,
                                    None if cache is None
                                    else cache.get("ssm"))
        new_cache = None if st is None else {"ssm": st}
    elif kind == "mlstm":
        x, st = ssm_mod.apply_mlstm(p["mlstm"], x, cfg,
                                    None if cache is None
                                    else cache.get("ssm"))
        new_cache = None if st is None else {"ssm": st}
    elif kind == "slstm":
        x, st = ssm_mod.apply_slstm(p["slstm"], x, cfg,
                                    None if cache is None
                                    else cache.get("ssm"))
        new_cache = None if st is None else {"ssm": st}
    if "cross" in p:
        if enc_out is not None:
            ckv = cross_kv_from_encoder(p["cross"], enc_out, cfg)
        elif cache is not None and "cross_kv" in cache:
            ckv = cache["cross_kv"]
        else:
            ckv = None
        if ckv is not None:
            x, _ = apply_attn(p["cross"], x, cfg, positions=positions,
                              cross_kv=ckv)
            if new_cache is not None:
                new_cache["cross_kv"] = ckv
    if "moe" in p:
        x = moe_mod.apply_moe(p["moe"], x, cfg)
    elif "mlp" in p:
        x = apply_mlp(p["mlp"], x, cfg)
    return x, new_cache


def forward(params, x, cfg: ArchConfig, *, positions, caches=None,
            enc_out=None):
    """x: (B, S, D) embeddings. caches: list per idx_in_period of stacked
    cache pytrees (leading dim n_periods) or None. Returns (x, caches)."""
    blocks = params["blocks"]

    def seq_constraint(x):
        """Activation anchoring between layers: batch dim pinned to the
        planner's choice (GSPMD propagation can drift to replication
        inside scanned+remat'd bodies — a silent 16× compute waste), and
        optionally seq→model (Megatron-SP analogue) so remat checkpoints
        shard over the TP degree."""
        want_seq = cfg.seq_shard and x.shape[1] % 16 == 0
        if not want_seq and not cfg.act_batch_axes:
            return x
        try:
            from jax.sharding import PartitionSpec as P_

            b_spec = (tuple(cfg.act_batch_axes) if cfg.act_batch_axes
                      else P_.UNCONSTRAINED)
            s_spec = "model" if want_seq else P_.UNCONSTRAINED
            if want_seq and cfg.act_batch_axes \
                    and "model" in cfg.act_batch_axes:
                s_spec = P_.UNCONSTRAINED
            return jax.lax.with_sharding_constraint(
                x, P_(b_spec, s_spec, P_.UNCONSTRAINED))
        except Exception:
            return x  # no mesh / axis missing: constraint is a no-op

    def body(carry, xs):
        x = carry
        x = seq_constraint(x)
        bp = xs[0]
        cc = xs[1] if caches is not None else [None] * cfg.period
        new_cc = []
        for i in range(cfg.period):
            x, nc = _apply_layer(bp[i], x, cfg, i, positions=positions,
                                 cache=cc[i], enc_out=enc_out)
            new_cc.append(nc)
        x = seq_constraint(x)
        if caches is not None:
            return x, new_cc
        return x, None

    if cfg.remat == "full":
        body = jax.checkpoint(body)
    elif cfg.remat == "dots":
        # save matmul outputs: backward skips recompute (≈1 fewer
        # all-gather wave of FSDP params) at ~2-3× checkpoint memory
        body = jax.checkpoint(
            body,
            policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
    xs = (blocks,) if caches is None else (blocks, caches)
    x, new_caches = jax.lax.scan(body, x, xs)
    return x, new_caches


def encode(params, src_embeds, cfg: ArchConfig):
    """Encoder stack (non-causal attention) for enc-dec archs."""
    enc = params["encoder"]
    positions = jnp.arange(src_embeds.shape[1])

    def body(x, bp):
        h = rmsnorm(x, bp["attn"]["ln"], cfg.norm_eps)
        q = jnp.einsum("bsd,dhk->bshk", h, bp["attn"]["wq"])
        k = jnp.einsum("bsd,dhk->bshk", h, bp["attn"]["wk"])
        v = jnp.einsum("bsd,dhk->bshk", h, bp["attn"]["wv"])
        if cfg.qkv_bias:
            q, k, v = q + bp["attn"]["bq"], k + bp["attn"]["bk"], \
                v + bp["attn"]["bv"]
        from .common import plain_attention, rope
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
        out = plain_attention(q, k, v, causal=False)
        x = x + jnp.einsum("bshk,hkd->bsd", out,
                           bp["attn"]["wo"]).astype(x.dtype)
        x = apply_mlp(bp["mlp"], x, cfg)
        return x, None

    if cfg.remat == "full":
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, src_embeds, enc)
    return rmsnorm(x, params["enc_final_ln"], cfg.norm_eps)


def embed_tokens(params, tokens, cfg: ArchConfig):
    return params["embed"][tokens] * jnp.asarray(
        math.sqrt(cfg.d_model), cfg.dtype)


# ---------------------------------------------------------------------------
# Top-level steps (loss / prefill / decode)
# ---------------------------------------------------------------------------

def loss_fn(params, batch, cfg: ArchConfig):
    """batch: {'tokens' or 'embeds', 'labels', optional 'src_embeds'}."""
    if cfg.embeds_input and "embeds" in batch:
        x = batch["embeds"].astype(cfg.dtype)
    else:
        x = embed_tokens(params, batch["tokens"], cfg)
    enc_out = None
    if cfg.is_encdec:
        enc_out = encode(params, batch["src_embeds"].astype(cfg.dtype),
                         cfg)
    positions = jnp.arange(x.shape[1])
    x, _ = forward(params, x, cfg, positions=positions, enc_out=enc_out)
    return lm_head_loss(params, x, batch["labels"], cfg,
                        cfg.padded_vocab(TP_DEFAULT))


def init_caches(cfg: ArchConfig, batch: int, max_seq: int,
                cross_len: int = 0, uniform_index: bool = False):
    """Stacked decode caches: list per idx_in_period. ``cross_len`` > 0
    adds encoder cross-KV slots (enc-dec decode entry point).
    ``uniform_index`` → scalar per-layer position (steady-state decode;
    cheap DUS updates) instead of per-slot positions (continuous
    batching)."""
    caches = []
    for i in range(cfg.period):
        kind = cfg.layer_kind(i)
        if kind == "attn":
            shape = (cfg.n_periods, batch, max_seq, cfg.kv_heads,
                     cfg.head_dim)
            idx_shape = (cfg.n_periods,) if uniform_index \
                else (cfg.n_periods, batch)
            c = {"kv": {"k": jnp.zeros(shape, cfg.dtype),
                        "v": jnp.zeros(shape, cfg.dtype),
                        "index": jnp.zeros(idx_shape, jnp.int32)}}
        else:
            if kind == "mamba":
                st = ssm_mod.init_mamba_state(cfg, batch)
            else:
                st = ssm_mod.init_xlstm_state(cfg, kind, batch)
            c = {"ssm": jax.tree.map(
                lambda a: jnp.broadcast_to(
                    a[None], (cfg.n_periods,) + a.shape), st)}
        if cfg.is_encdec and cross_len > 0:
            c["cross_kv"] = (
                jnp.zeros((cfg.n_periods, batch, cross_len, cfg.kv_heads,
                           cfg.head_dim), cfg.dtype),
                jnp.zeros((cfg.n_periods, batch, cross_len, cfg.kv_heads,
                           cfg.head_dim), cfg.dtype))
        caches.append(c)
    return caches


def prefill(params, batch, cfg: ArchConfig, max_seq: int):
    """Run the prompt through the model, filling caches.
    Returns (caches, last_token_logits)."""
    if cfg.embeds_input and "embeds" in batch:
        x = batch["embeds"].astype(cfg.dtype)
    else:
        x = embed_tokens(params, batch["tokens"], cfg)
    b, s = x.shape[0], x.shape[1]
    enc_out = None
    if cfg.is_encdec:
        enc_out = encode(params, batch["src_embeds"].astype(cfg.dtype),
                         cfg)
    caches = init_caches(cfg, b, max_seq)
    positions = jnp.arange(s)
    x, caches = forward(params, x, cfg, positions=positions,
                        caches=caches, enc_out=enc_out)
    x = rmsnorm(x[:, -1:], params["final_ln"], cfg.norm_eps)
    w = params.get("unembed", params["embed"])
    from .common import mask_padded_vocab
    logits = mask_padded_vocab(jnp.einsum("btd,vd->btv", x, w),
                               cfg.vocab)[:, 0]
    return caches, logits


def decode_step(params, tokens, caches, cfg: ArchConfig, *, enc_out=None):
    """One decode step. tokens: (B, 1) int32. Returns (logits, caches)."""
    x = embed_tokens(params, tokens, cfg)
    # position from any attention cache index (all layers share it)
    pos0 = None
    for c in caches:
        if c is not None and "kv" in c:
            pos0 = c["kv"]["index"][0]
            break
    if pos0 is None:
        pos0 = jnp.zeros((x.shape[0],), jnp.int32)
    if pos0.ndim == 0:  # uniform decode position
        positions = pos0 + jnp.arange(x.shape[1])
    else:
        positions = pos0[:, None] + jnp.arange(x.shape[1])[None, :]
    x, caches = forward(params, x, cfg, positions=positions,
                        caches=caches, enc_out=enc_out)
    x = rmsnorm(x, params["final_ln"], cfg.norm_eps)
    w = params.get("unembed", params["embed"])
    from .common import mask_padded_vocab, softcap
    logits = jnp.einsum("btd,vd->btv", x, w)
    logits = mask_padded_vocab(softcap(logits, cfg.logit_softcap),
                               cfg.vocab)[:, 0]
    return logits, caches
