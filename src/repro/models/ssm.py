"""State-space / recurrent blocks: Mamba (jamba) and xLSTM (mLSTM/sLSTM).

The sequence recurrence is the one iteration dimension the planner must
never shard (knowledge-base entry 'ssm_scan': sequential on seq, parallel
on batch/feature). Training uses an associative scan (log-depth, lowers to
compact HLO); decode keeps an explicit recurrent state — which is why these
families run the long_500k shape that quadratic attention cannot.
"""

from __future__ import annotations

import math
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .common import ArchConfig, KeyGen, _init, rmsnorm


# ---------------------------------------------------------------------------
# Mamba (selective SSM, Mamba-1 style simplified)
# ---------------------------------------------------------------------------

def init_mamba(kg: KeyGen, cfg: ArchConfig) -> Tuple[Dict, Dict]:
    d = cfg.d_model
    inner = cfg.ssm_expand * d
    n = cfg.ssm_state
    p = {
        "in_proj": _init(kg(), (d, 2 * inner), cfg.dtype),
        "x_proj": _init(kg(), (inner, 2 * n + 1), cfg.dtype),
        "dt_bias": jnp.zeros((inner,), jnp.float32),
        "a_log": jnp.log(jnp.broadcast_to(
            jnp.arange(1, n + 1, dtype=jnp.float32), (inner, n))),
        "d_skip": jnp.ones((inner,), jnp.float32),
        "out_proj": _init(kg(), (inner, d), cfg.dtype),
        "ln": jnp.zeros((d,), cfg.dtype),
    }
    s = {
        "in_proj": ("embed", "inner"),
        "x_proj": ("inner", "ssm"),
        "dt_bias": ("inner",),
        "a_log": ("inner", "ssm"),
        "d_skip": ("inner",),
        "out_proj": ("inner", "embed"),
        "ln": ("embed",),
    }
    return p, s


def _mamba_scan_train(xz, dt, B, C, a, d_skip, use_pallas=False):
    """Associative scan over seq. xz:(B,L,I) dt:(B,L,I) B/C:(B,L,N)."""
    if use_pallas:
        from repro.kernels.mamba_scan import ops as scan_ops

        return scan_ops.mamba_scan(xz, dt, B, C, a, d_skip)
    # h_t = A_t * h_{t-1} + B_t x_t ; associative over (A, Bx)
    a_bar = jnp.exp(dt[..., None] * (-jnp.exp(a))[None, None])  # (B,L,I,N)
    bx = (dt * xz)[..., None] * B[..., None, :]                 # (B,L,I,N)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    a_cum, h = jax.lax.associative_scan(combine, (a_bar, bx), axis=1)
    y = (h * C[..., None, :]).sum(-1)                           # (B,L,I)
    return y + d_skip[None, None] * xz


def apply_mamba(p, x, cfg: ArchConfig, state: Optional[Dict] = None):
    """x: (B, S, D). state (decode): {'h': (B, I, N)}."""
    b, s, d = x.shape
    inner = cfg.ssm_expand * d
    n = cfg.ssm_state
    h0 = rmsnorm(x, p["ln"], cfg.norm_eps)
    proj = jnp.einsum("bsd,di->bsi", h0, p["in_proj"])
    xz, gate = jnp.split(proj, 2, axis=-1)
    xz = jax.nn.silu(xz)
    dbc = jnp.einsum("bsi,ik->bsk", xz, p["x_proj"]).astype(jnp.float32)
    dt = jax.nn.softplus(dbc[..., 0:1] + p["dt_bias"][None, None])
    Bm, Cm = dbc[..., 1:1 + n], dbc[..., 1 + n:]
    a = p["a_log"]

    if state is None:
        y = _mamba_scan_train(xz.astype(jnp.float32), dt, Bm, Cm, a,
                              p["d_skip"], cfg.use_pallas)
        new_state = None
    else:
        # single-token decode: s == 1
        a_bar = jnp.exp(dt[:, 0, :, None] * (-jnp.exp(a))[None])
        bx = (dt[:, 0] * xz[:, 0].astype(jnp.float32))[..., None] \
            * Bm[:, 0, None, :]
        h = a_bar * state["h"] + bx                       # (B, I, N)
        y = (h * Cm[:, 0, None, :]).sum(-1)[:, None]      # (B,1,I)
        y = y + p["d_skip"][None, None] * xz.astype(jnp.float32)
        new_state = {"h": h}

    y = (y * jax.nn.silu(gate.astype(jnp.float32))).astype(x.dtype)
    out = jnp.einsum("bsi,id->bsd", y, p["out_proj"])
    return x + out, new_state


def init_mamba_state(cfg: ArchConfig, batch: int) -> Dict:
    inner = cfg.ssm_expand * cfg.d_model
    return {"h": jnp.zeros((batch, inner, cfg.ssm_state), jnp.float32)}


# ---------------------------------------------------------------------------
# xLSTM blocks
# ---------------------------------------------------------------------------

def init_mlstm(kg: KeyGen, cfg: ArchConfig) -> Tuple[Dict, Dict]:
    d = cfg.d_model
    h, hd = cfg.n_heads, cfg.d_model // cfg.n_heads
    p = {
        "wq": _init(kg(), (d, h, hd), cfg.dtype),
        "wk": _init(kg(), (d, h, hd), cfg.dtype),
        "wv": _init(kg(), (d, h, hd), cfg.dtype),
        "wif": _init(kg(), (d, 2 * h), cfg.dtype),  # input+forget gates
        "wo": _init(kg(), (h, hd, d), cfg.dtype),
        "ln": jnp.zeros((d,), cfg.dtype),
    }
    s = {
        "wq": ("embed", "heads", "head_dim"),
        "wk": ("embed", "heads", "head_dim"),
        "wv": ("embed", "heads", "head_dim"),
        "wif": ("embed", "heads"),
        "wo": ("heads", "head_dim", "embed"),
        "ln": ("embed",),
    }
    return p, s


def apply_mlstm(p, x, cfg: ArchConfig, state: Optional[Dict] = None):
    """Matrix-memory LSTM: per head a (hd × hd) outer-product memory with
    scalar input/forget gates; parallel (attention-like) form in training,
    recurrent form in decode."""
    b, s, d = x.shape
    h = cfg.n_heads
    hd = d // h
    xin = rmsnorm(x, p["ln"], cfg.norm_eps)
    q = jnp.einsum("bsd,dhk->bshk", xin, p["wq"]) / math.sqrt(hd)
    k = jnp.einsum("bsd,dhk->bshk", xin, p["wk"]) / math.sqrt(hd)
    v = jnp.einsum("bsd,dhk->bshk", xin, p["wv"])
    gates = jnp.einsum("bsd,dg->bsg", xin, p["wif"]).astype(jnp.float32)
    i_gate = jnp.exp(jnp.minimum(gates[..., :h], 8.0))       # stabilized
    f_gate = jax.nn.sigmoid(gates[..., h:])

    if state is None:
        # parallel form: D[t,τ] = (∏_{j=τ+1..t} f_j) · i_τ  (τ ≤ t)
        logf = jnp.log(f_gate + 1e-8)                        # (B,S,H)
        cum = jnp.cumsum(logf, axis=1)
        decay = cum[:, :, None, :] - cum[:, None, :, :]      # (B,t,τ,H)
        causal = jnp.tril(jnp.ones((s, s), bool))
        dmat = jnp.where(causal[None, :, :, None],
                         jnp.exp(decay) * i_gate[:, None], 0.0)
        scores = jnp.einsum("bthk,bshk->bths", q, k).astype(jnp.float32)
        scores = scores * jnp.moveaxis(dmat, 3, 2)           # (B,t,H,τ)
        norm = jnp.maximum(jnp.abs(scores.sum(-1)), 1.0)
        out = jnp.einsum("bths,bshk->bthk",
                         (scores / norm[..., None]).astype(x.dtype), v)
        new_state = None
    else:
        # recurrent: C_t = f C_{t-1} + i (v ⊗ k); y = C_t q / max(|n·q|,1)
        C, nvec = state["C"], state["n"]
        f1 = f_gate[:, 0, :, None, None]
        i1 = i_gate[:, 0, :, None, None]
        C = f1 * C + i1 * jnp.einsum("bhk,bhl->bhkl",
                                     v[:, 0].astype(jnp.float32),
                                     k[:, 0].astype(jnp.float32))
        nvec = f_gate[:, 0, :, None] * nvec \
            + i_gate[:, 0, :, None] * k[:, 0].astype(jnp.float32)
        num = jnp.einsum("bhkl,bhl->bhk", C, q[:, 0].astype(jnp.float32))
        den = jnp.maximum(
            jnp.abs(jnp.einsum("bhl,bhl->bh", nvec,
                               q[:, 0].astype(jnp.float32))), 1.0)
        out = (num / den[..., None])[:, None].astype(x.dtype)
        new_state = {"C": C, "n": nvec}

    # head-wise normalization (xLSTM applies GroupNorm before out-proj)
    out32 = out.astype(jnp.float32)
    var = jnp.mean(jnp.square(out32), axis=-1, keepdims=True)
    out = (out32 * jax.lax.rsqrt(var + 1e-6)).astype(x.dtype)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return x + y.astype(x.dtype), new_state


def init_slstm(kg: KeyGen, cfg: ArchConfig) -> Tuple[Dict, Dict]:
    d = cfg.d_model
    p = {
        "wx": _init(kg(), (d, 4 * d), cfg.dtype),   # i, f, z, o pre-acts
        "wh": _init(kg(), (d, 4 * d), cfg.dtype),
        "ln": jnp.zeros((d,), cfg.dtype),
    }
    s = {"wx": ("embed", "inner"), "wh": ("embed", "inner"),
         "ln": ("embed",)}
    return p, s


def apply_slstm(p, x, cfg: ArchConfig, state: Optional[Dict] = None):
    """Scalar-memory LSTM with exponential gating; lax.scan over seq."""
    b, s, d = x.shape
    xin = rmsnorm(x, p["ln"], cfg.norm_eps)
    pre_x = jnp.einsum("bsd,dg->bsg", xin, p["wx"]).astype(jnp.float32)

    def step(carry, xt):
        h_prev, c_prev, n_prev = carry
        pre = xt + h_prev @ p["wh"].astype(jnp.float32)
        i, f, z, o = jnp.split(pre, 4, axis=-1)
        i = jnp.exp(jnp.minimum(i, 8.0))
        f = jax.nn.sigmoid(f)
        c = f * c_prev + i * jnp.tanh(z)
        n = f * n_prev + i
        h = jax.nn.sigmoid(o) * c / jnp.maximum(n, 1.0)
        return (h, c, n), h

    if state is None:
        h0 = jnp.zeros((b, d), jnp.float32)
        init = (h0, h0, jnp.ones((b, d), jnp.float32))
        (_, _, _), hs = jax.lax.scan(step, init,
                                     jnp.moveaxis(pre_x, 1, 0))
        y = jnp.moveaxis(hs, 0, 1)
        new_state = None
    else:
        carry = (state["h"], state["c"], state["n"])
        carry, h = step(carry, pre_x[:, 0])
        y = h[:, None]
        new_state = {"h": carry[0], "c": carry[1], "n": carry[2]}

    # feature-wise normalization (GroupNorm analogue)
    var = jnp.mean(jnp.square(y), axis=-1, keepdims=True)
    out = (y * jax.lax.rsqrt(var + 1e-6)).astype(x.dtype)
    return x + out, new_state


def init_xlstm_state(cfg: ArchConfig, kind: str, batch: int) -> Dict:
    d = cfg.d_model
    h, hd = cfg.n_heads, cfg.d_model // cfg.n_heads
    if kind == "mlstm":
        return {"C": jnp.zeros((batch, h, hd, hd), jnp.float32),
                "n": jnp.zeros((batch, h, hd), jnp.float32)}
    return {"h": jnp.zeros((batch, d), jnp.float32),
            "c": jnp.zeros((batch, d), jnp.float32),
            "n": jnp.ones((batch, d), jnp.float32)}
