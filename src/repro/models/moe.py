"""Mixture-of-Experts FFN with token-choice top-k routing.

Dispatch is the sort-free capacity scheme: per-token expert assignments are
flattened, positions within each expert computed by a cumulative sum over
the (tokens·topk, experts) one-hot, tokens beyond capacity dropped, and
activations scattered into an (experts·capacity, d) buffer that is
batch-matmul'd against stacked expert weights. This keeps every shape
static (compile-friendly at 512 devices) without materializing the
(tokens, experts, capacity) dispatch tensor.

Expert weights carry the 'experts' logical axis → the planner shards them
over the `model` mesh axis (expert parallelism); the scatter/gather across
the (data-sharded) token axis and (model-sharded) expert axis is where
GSPMD inserts the all-to-all — the MoE collective the roofline analysis
tracks. Experts are padded up to a multiple of the mesh axis when the
config's count is indivisible (qwen2-moe: 60 → 64), with router logits of
padded experts masked to -inf (a legality-branch resolution, DESIGN.md §4).
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .common import ArchConfig, KeyGen, _act, _init, rmsnorm


def padded_experts(cfg: ArchConfig, tp: int = 16) -> int:
    e = cfg.n_experts
    return ((e + tp - 1) // tp) * tp


def init_moe(kg: KeyGen, cfg: ArchConfig, tp: int = 16
             ) -> Tuple[Dict, Dict]:
    d = cfg.d_model
    f = cfg.expert_d_ff or cfg.d_ff
    e_pad = padded_experts(cfg, tp)
    gated = cfg.mlp_act in ("silu", "gelu")
    p = {
        "router": _init(kg(), (d, e_pad), jnp.float32),
        "wi": _init(kg(), (e_pad, d, f), cfg.dtype),
        "wo": _init(kg(), (e_pad, f, d), cfg.dtype),
        "ln": jnp.zeros((d,), cfg.dtype),
    }
    s = {
        "router": ("embed", "experts"),
        "wi": ("experts", "embed", "mlp"),
        "wo": ("experts", "mlp", "embed"),
        "ln": ("embed",),
    }
    if gated:
        p["wg"] = _init(kg(), (e_pad, d, f), cfg.dtype)
        s["wg"] = ("experts", "embed", "mlp")
    if cfg.n_shared_experts:
        p["shared_wi"] = _init(kg(), (d, f * cfg.n_shared_experts),
                               cfg.dtype)
        p["shared_wo"] = _init(kg(), (f * cfg.n_shared_experts, d),
                               cfg.dtype)
        s["shared_wi"] = ("embed", "mlp")
        s["shared_wo"] = ("mlp", "embed")
        if gated:
            p["shared_wg"] = _init(kg(), (d, f * cfg.n_shared_experts),
                                   cfg.dtype)
            s["shared_wg"] = ("embed", "mlp")
    return p, s


def apply_moe(p, x, cfg: ArchConfig):
    """x: (B, S, D) → (B, S, D) residual-added."""
    b, s, d = x.shape
    h = rmsnorm(x, p["ln"], cfg.norm_eps)
    t = b * s
    ht = h.reshape(t, d)
    e_pad = p["router"].shape[1]
    k = cfg.experts_topk
    act = _act(cfg.mlp_act)

    logits = ht.astype(jnp.float32) @ p["router"]  # (T, E)
    if e_pad != cfg.n_experts:
        pad_mask = jnp.arange(e_pad) >= cfg.n_experts
        logits = jnp.where(pad_mask[None, :], -1e30, logits)
    weights, expert_ids = jax.lax.top_k(logits, k)        # (T, K)
    weights = jax.nn.softmax(weights, axis=-1).astype(x.dtype)

    # --- capacity + position within expert -----------------------------
    cap = int(max(1, (t * k // e_pad) * cfg.capacity_factor))
    flat_e = expert_ids.reshape(-1)                         # (T*K,)
    onehot = jax.nn.one_hot(flat_e, e_pad, dtype=jnp.int32)  # (T*K, E)
    pos_in_e = (jnp.cumsum(onehot, axis=0) - 1)             # running count
    pos = jnp.take_along_axis(pos_in_e, flat_e[:, None],
                              axis=1)[:, 0]                 # (T*K,)
    keep = pos < cap
    slot = jnp.where(keep, flat_e * cap + pos, e_pad * cap)  # overflow slot

    # --- dispatch -------------------------------------------------------
    xe = jnp.repeat(ht, k, axis=0)                          # (T*K, D)
    buf = jnp.zeros((e_pad * cap + 1, d), x.dtype).at[slot].add(xe)
    buf = buf[:-1].reshape(e_pad, cap, d)

    def _anchor(t):
        """Pin (expert, capacity) dims to the planner's axes: without
        this, GSPMD propagation can leave the expert einsums replicated
        over idle mesh axes (a silent 16× compute waste). The capacity
        dim takes the axes the expert count cannot cover."""
        if not cfg.moe_expert_axes:
            return t
        try:
            from jax.sharding import PartitionSpec as P_

            ax = tuple(cfg.moe_expert_axes)
            e_spec = ax if len(ax) > 1 else ax[0]
            c_ax = tuple(cfg.moe_capacity_axes or ())
            c_spec = (c_ax if len(c_ax) > 1 else c_ax[0]) if c_ax \
                else P_.UNCONSTRAINED
            spec = [e_spec, c_spec] + [P_.UNCONSTRAINED] * (t.ndim - 2)
            return jax.lax.with_sharding_constraint(t, P_(*spec))
        except Exception:
            return t

    buf = _anchor(buf)

    # --- expert computation (batched over experts) -----------------------
    up = jnp.einsum("ecd,edf->ecf", buf, p["wi"])
    if "wg" in p:
        gate = jnp.einsum("ecd,edf->ecf", buf, p["wg"])
        up = act(gate) * up
    else:
        up = act(up)
    out = _anchor(jnp.einsum("ecf,efd->ecd", up, p["wo"]))  # (E, C, D)

    # --- combine ----------------------------------------------------------
    out_flat = out.reshape(e_pad * cap, d)
    out_flat = jnp.concatenate(
        [out_flat, jnp.zeros((1, d), out_flat.dtype)], axis=0)
    gathered = out_flat[slot]                               # (T*K, D)
    gathered = gathered * weights.reshape(-1)[:, None]
    y = gathered.reshape(t, k, d).sum(axis=1)

    # --- shared experts (dense) -------------------------------------------
    if "shared_wi" in p:
        up_s = jnp.einsum("td,df->tf", ht, p["shared_wi"])
        if "shared_wg" in p:
            up_s = act(jnp.einsum("td,df->tf", ht, p["shared_wg"])) * up_s
        else:
            up_s = act(up_s)
        y = y + jnp.einsum("tf,fd->td", up_s, p["shared_wo"])

    return x + y.reshape(b, s, d).astype(x.dtype)
