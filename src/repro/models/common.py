"""Common model substrate: config, layers, attention, losses.

Models are pure-function JAX: parameters are nested dicts of arrays, every
leaf carries *logical axis names* (in a parallel `specs` tree) consumed by
the auto-sharding planner — the LM-scale incarnation of the paper's
``pfor(output=…, input=…, transfer=…)`` dataflow clauses.

Layer stacks are built as scan-over-periods: the repeating block pattern
(1 for homogeneous transformers, 2 for gemma2 local/global and xlstm
mLSTM/sLSTM, 8 for jamba attn/mamba interleave) is unrolled inside the scan
body while the scan runs over period instances — keeping HLO compact enough
to compile 96-layer models on the CPU dry-run host.
"""

from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from functools import partial
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# Config
# ---------------------------------------------------------------------------

@dataclass
class ArchConfig:
    name: str
    family: str                  # 'dense' | 'moe' | 'encdec' | 'hybrid' | 'ssm' | 'vlm' | 'audio'
    layers: int = 12
    d_model: int = 768
    n_heads: int = 12
    kv_heads: int = 12
    d_ff: int = 3072
    vocab: int = 50304
    head_dim: Optional[int] = None
    # MoE
    n_experts: int = 0
    experts_topk: int = 0
    n_shared_experts: int = 0
    expert_d_ff: int = 0
    moe_every: int = 1           # MoE on layers where (idx % moe_every)==moe_offset
    moe_offset: int = 0
    capacity_factor: float = 1.25
    # attention flavor
    mlp_act: str = "silu"        # 'silu' | 'gelu' | 'sqrelu' | 'relu'
    qkv_bias: bool = False
    sliding_window: int = 0      # gemma2 local layers
    alt_local_global: bool = False
    attn_softcap: float = 0.0
    logit_softcap: float = 0.0
    rope_theta: float = 10000.0
    # hybrid (jamba): period pattern of layer kinds
    period: int = 1
    attn_every: int = 1          # attention at idx % period == attn_idx
    attn_idx: int = 0
    ssm_state: int = 16          # mamba state size
    ssm_expand: int = 2
    # xlstm
    xlstm_pattern: Tuple[str, ...] = ()
    # encoder-decoder
    enc_layers: int = 0
    is_encdec: bool = False
    # frontend stub (audio frames / vision patches): inputs are embeddings
    embeds_input: bool = False
    # numerics / training
    dtype: Any = jnp.bfloat16
    norm_eps: float = 1e-6
    tie_embeddings: bool = True
    # parallelism knobs (filled by configs; tuned by hillclimb)
    microbatch: int = 1          # grad-accumulation steps
    remat: str = "full"          # 'full' | 'none'
    attn_chunk: int = 1024       # online-softmax KV chunk for long seq
    fused_xent: bool = True      # vocab-sharded cross-entropy
    opt_8bit: bool = False       # int8 Adam moments
    seq_shard: bool = False      # sequence-parallel residual stream: remat
                                 # checkpoints shard seq over `model`
    act_batch_axes: Optional[Tuple[str, ...]] = None
    # ^ planner-chosen mesh axes for the activation batch dim; anchored
    #   between layers so GSPMD's propagation never drifts to replication
    #   inside the scanned/remat'd body (runtime knob, set by launch)
    moe_expert_axes: Optional[Tuple[str, ...]] = None
    # ^ planner-chosen mesh axes for the expert dim of MoE dispatch
    #   buffers (same anchoring rationale, applied inside apply_moe)
    moe_capacity_axes: Optional[Tuple[str, ...]] = None
    # ^ mesh axes for the capacity dim (covers axes experts cannot)
    force_strategy: Optional[str] = None   # hillclimb: pin the planner
    use_pallas: bool = False     # TPU kernels (validated separately)
    # skip list: shapes this arch cannot run (with reason)
    skip_shapes: Dict[str, str] = field(default_factory=dict)

    def __post_init__(self):
        if self.head_dim is None:
            self.head_dim = self.d_model // self.n_heads
        if self.period == 1 and self.alt_local_global:
            self.period = 2
        if self.xlstm_pattern and self.period == 1:
            self.period = len(self.xlstm_pattern)

    @property
    def n_periods(self) -> int:
        assert self.layers % self.period == 0, (self.name, self.layers,
                                                self.period)
        return self.layers // self.period

    def layer_kind(self, idx_in_period: int) -> str:
        if self.xlstm_pattern:
            return self.xlstm_pattern[idx_in_period]
        if self.family == "hybrid":
            return "attn" if idx_in_period == self.attn_idx else "mamba"
        return "attn"

    def layer_is_moe(self, idx_in_period: int) -> bool:
        if self.n_experts == 0:
            return False
        return idx_in_period % self.moe_every == self.moe_offset

    def layer_window(self, idx_in_period: int) -> int:
        if self.alt_local_global:
            return self.sliding_window if idx_in_period % 2 == 0 else 0
        return self.sliding_window  # 0 = no window; mistral: all layers

    def padded_vocab(self, tp: int = 16, align: int = 128) -> int:
        q = tp * align
        return ((self.vocab + q - 1) // q) * q

    def param_count(self) -> int:
        """Approximate total parameters (for roofline MODEL_FLOPS)."""
        d, v = self.d_model, self.vocab
        total = v * d  # embedding
        if not self.tie_embeddings:
            total += v * d
        for p in range(self.period):
            kind = self.layer_kind(p)
            if kind == "attn":
                total_l = d * self.n_heads * self.head_dim \
                    + 2 * d * self.kv_heads * self.head_dim \
                    + self.n_heads * self.head_dim * d
            elif kind == "mamba":
                inner = self.ssm_expand * d
                total_l = d * inner * 2 + inner * d \
                    + inner * (2 * self.ssm_state + 2)
            elif kind in ("mlstm", "slstm"):
                inner = d * 2
                total_l = 4 * d * inner + inner * d
            else:
                total_l = 0
            if self.layer_is_moe(p):
                eff = self.expert_d_ff or self.d_ff
                total_l += self.n_experts * 3 * d * eff
                total_l += self.n_shared_experts * 3 * d * eff
                total_l += d * self.n_experts  # router
            elif kind == "attn" and self.d_ff > 0:
                mult = 3 if self.mlp_act in ("silu", "gelu") else 2
                total_l += mult * d * self.d_ff
            total += total_l * self.n_periods
        if self.is_encdec:
            total = int(total * 1.6)  # encoder stack + cross attention
        return int(total)

    def active_param_count(self) -> int:
        """Active params per token (MoE: only routed top-k experts)."""
        if self.n_experts == 0:
            return self.param_count()
        d = self.d_model
        eff = self.expert_d_ff or self.d_ff
        per_layer_all = self.n_experts * 3 * d * eff
        per_layer_act = (self.experts_topk + self.n_shared_experts) \
            * 3 * d * eff
        n_moe_layers = sum(1 for p in range(self.period)
                           if self.layer_is_moe(p)) * self.n_periods
        return self.param_count() - n_moe_layers * (per_layer_all -
                                                    per_layer_act)


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------

def _init(key, shape, dtype, scale=None):
    if scale is None:
        scale = 1.0 / math.sqrt(shape[0] if shape else 1)
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


class KeyGen:
    def __init__(self, key):
        self.key = key

    def __call__(self):
        self.key, sub = jax.random.split(self.key)
        return sub


# ---------------------------------------------------------------------------
# Primitive layers
# ---------------------------------------------------------------------------

def rmsnorm(x, scale, eps=1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1,
                   keepdims=True)
    out = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def rope(x, positions, theta=10000.0):
    """x: (..., S, H, D). positions: (..., S)."""
    d = x.shape[-1]
    half = d // 2
    freqs = jnp.exp(-jnp.arange(0, half, dtype=jnp.float32)
                    * (math.log(theta) / half))
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (...,S,half)
    cos = jnp.cos(angles)[..., :, None, :]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin,
                           x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def softcap(x, cap):
    if cap and cap > 0:
        return jnp.tanh(x / cap) * cap
    return x


def _act(name: str):
    return {
        "silu": jax.nn.silu,
        "gelu": jax.nn.gelu,
        "relu": jax.nn.relu,
        "sqrelu": lambda x: jnp.square(jax.nn.relu(x)),
    }[name]


# ---------------------------------------------------------------------------
# Attention (GQA, sliding window, softcap, chunked online softmax, KV cache)
# ---------------------------------------------------------------------------

def attention_scores_block(q, k, v, mask, cap):
    """Plain attention over one KV block. q:(B,Sq,H,D) k/v:(B,Skv,KVH,D)."""
    b, sq, h, d = q.shape
    kvh = k.shape[2]
    groups = h // kvh
    qg = q.reshape(b, sq, kvh, groups, d)
    scores = jnp.einsum("bqkgd,bskd->bqkgs", qg, k,
                        preferred_element_type=jnp.float32)
    scores = scores / math.sqrt(d)
    scores = softcap(scores, cap)
    if mask is not None:
        scores = jnp.where(mask[:, :, None, None, :], scores, -1e30)
    return scores, groups


def _query_positions(q_offset, sq):
    """q_offset: scalar or (B,). Returns q_pos of shape (sq,) or (B,sq)."""
    off = jnp.asarray(q_offset)
    if off.ndim == 0:
        return off + jnp.arange(sq)
    return off[:, None] + jnp.arange(sq)[None, :]


def plain_attention(q, k, v, *, causal: bool, window: int = 0,
                    cap: float = 0.0, q_offset=0):
    b, sq, h, d = q.shape
    skv = k.shape[1]
    kvh = k.shape[2]
    q_pos = _query_positions(q_offset, sq)       # (sq,) or (B,sq)
    k_pos = jnp.arange(skv)
    mask = jnp.ones(q_pos.shape + (skv,), bool)
    if causal:
        mask &= k_pos <= q_pos[..., None]
    if window and window > 0:
        mask &= k_pos > (q_pos[..., None] - window)
    mask = jnp.broadcast_to(mask if mask.ndim == 3 else mask[None],
                            (b, sq, skv))
    scores, groups = attention_scores_block(q, k, v, mask, cap)
    probs = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bqkgs,bskd->bqkgd", probs, v)
    return out.reshape(b, sq, h, d)


def chunked_attention(q, k, v, *, causal: bool, window: int = 0,
                      cap: float = 0.0, chunk: int = 1024, q_offset=0):
    """Online-softmax attention, O(Sq·chunk) memory — the jnp twin of the
    Pallas flash kernel (kernels/flash_attention). KV chunks are read with
    dynamic_slice inside the scan (no padded/transposed copy of the whole
    cache is ever materialized)."""
    b, sq, h, d = q.shape
    skv = k.shape[1]
    kvh = k.shape[2]
    groups = h // kvh
    # largest chunk ≤ requested that divides skv (avoids padding copies)
    c = min(chunk, skv)
    while skv % c:
        c -= 1
    chunk = c
    n_chunks = skv // chunk
    qg = q.reshape(b, sq, kvh, groups, d)
    q_pos = _query_positions(q_offset, sq)       # (sq,) or (B,sq)

    def body(carry, ci):
        m, l, acc = carry
        kb = jax.lax.dynamic_slice_in_dim(k, ci * chunk, chunk, axis=1)
        vb = jax.lax.dynamic_slice_in_dim(v, ci * chunk, chunk, axis=1)
        k_pos = ci * chunk + jnp.arange(chunk)
        # f32 accumulation on bf16 inputs: native MXU behaviour on TPU;
        # keeps the CPU-legalization convert on the chunk (inside the
        # loop) instead of a hoisted full-cache f32 copy
        s = jnp.einsum("bqkgd,bskd->bqkgs", qg, kb,
                       preferred_element_type=jnp.float32)
        s = softcap(s / math.sqrt(d), cap)
        msk = jnp.broadcast_to(k_pos < skv, q_pos.shape + (chunk,))
        if causal:
            msk = msk & (k_pos <= q_pos[..., None])
        if window and window > 0:
            msk = msk & (k_pos > (q_pos[..., None] - window))
        msk = jnp.broadcast_to(msk if msk.ndim == 3 else msk[None],
                               (b, sq, chunk))
        s = jnp.where(msk[:, :, None, None, :], s, -1e30)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] \
            + jnp.einsum("bqkgs,bskd->bqkgd", p.astype(q.dtype), vb,
                         preferred_element_type=jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, sq, kvh, groups), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, sq, kvh, groups), jnp.float32)
    a0 = jnp.zeros((b, sq, kvh, groups, d), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0), jnp.arange(n_chunks))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.reshape(b, sq, h, d).astype(q.dtype)


def attention(q, k, v, cfg: ArchConfig, *, causal=True, window=0,
              q_offset=0):
    skv = k.shape[1]
    if cfg.use_pallas:
        from repro.kernels.flash_attention import ops as flash_ops

        return flash_ops.flash_attention(
            q, k, v, causal=causal, window=window,
            softcap=cfg.attn_softcap)
    if skv > 2 * cfg.attn_chunk:
        return chunked_attention(q, k, v, causal=causal, window=window,
                                 cap=cfg.attn_softcap,
                                 chunk=cfg.attn_chunk, q_offset=q_offset)
    return plain_attention(q, k, v, causal=causal, window=window,
                           cap=cfg.attn_softcap, q_offset=q_offset)


# ---------------------------------------------------------------------------
# Attention block (params + apply, with optional KV cache)
# ---------------------------------------------------------------------------

def init_attn(kg: KeyGen, cfg: ArchConfig) -> Tuple[Dict, Dict]:
    d, h, kvh, hd = cfg.d_model, cfg.n_heads, cfg.kv_heads, cfg.head_dim
    p = {
        "wq": _init(kg(), (d, h, hd), cfg.dtype),
        "wk": _init(kg(), (d, kvh, hd), cfg.dtype),
        "wv": _init(kg(), (d, kvh, hd), cfg.dtype),
        "wo": _init(kg(), (h, hd, d), cfg.dtype),
        "ln": jnp.zeros((d,), cfg.dtype),
    }
    s = {
        "wq": ("embed", "heads", "head_dim"),
        "wk": ("embed", "kv_heads", "head_dim"),
        "wv": ("embed", "kv_heads", "head_dim"),
        "wo": ("heads", "head_dim", "embed"),
        "ln": ("embed",),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h, hd), cfg.dtype)
        p["bk"] = jnp.zeros((kvh, hd), cfg.dtype)
        p["bv"] = jnp.zeros((kvh, hd), cfg.dtype)
        s["bq"] = ("heads", "head_dim")
        s["bk"] = ("kv_heads", "head_dim")
        s["bv"] = ("kv_heads", "head_dim")
    return p, s


def apply_attn(p, x, cfg: ArchConfig, *, positions, window=0, cache=None,
               cross_kv=None):
    """x: (B, S, D). cache: dict(k, v, index) for decode. cross_kv: (k, v)
    for encoder-decoder cross attention (ignores cache/causal)."""
    h = rmsnorm(x, p["ln"], cfg.norm_eps)
    q = jnp.einsum("bsd,dhk->bshk", h, p["wq"])
    if cfg.qkv_bias:
        q = q + p["bq"]
    if cross_kv is not None:
        k, v = cross_kv
        q = rope(q, positions, cfg.rope_theta)
        out = plain_attention(q, k, v, causal=False, window=0,
                              cap=cfg.attn_softcap)
    else:
        k = jnp.einsum("bsd,dhk->bshk", h, p["wk"])
        v = jnp.einsum("bsd,dhk->bshk", h, p["wv"])
        if cfg.qkv_bias:
            k = k + p["bk"]
            v = v + p["bv"]
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
        if cache is not None:
            # index: scalar () = uniform decode position (steady-state
            # serving; tiny dynamic-update-slice writes), or (B,) =
            # per-slot positions (continuous batching; elementwise
            # one-hot select — a scatter here would force GSPMD into
            # involuntary full rematerialization)
            idx = cache["index"]
            bsz, s_new = k.shape[0], k.shape[1]
            s_max = cache["k"].shape[1]
            if idx.ndim == 0:
                ck = jax.lax.dynamic_update_slice_in_dim(
                    cache["k"], k.astype(cache["k"].dtype), idx, axis=1)
                cv = jax.lax.dynamic_update_slice_in_dim(
                    cache["v"], v.astype(cache["v"].dtype), idx, axis=1)
            elif s_new == 1:
                sel = (jnp.arange(s_max)[None, :]
                       == idx[:, None])[..., None, None]
                ck = jnp.where(sel, k.astype(cache["k"].dtype),
                               cache["k"])
                cv = jnp.where(sel, v.astype(cache["v"].dtype),
                               cache["v"])
            else:
                # per-slot prefill always fills from position 0
                ck = jax.lax.dynamic_update_slice_in_dim(
                    cache["k"], k.astype(cache["k"].dtype), 0, axis=1)
                cv = jax.lax.dynamic_update_slice_in_dim(
                    cache["v"], v.astype(cache["v"].dtype), 0, axis=1)
            cache = {"k": ck, "v": cv, "index": idx + s_new}
            out = attention(q, ck, cv, cfg, causal=True, window=window,
                            q_offset=idx)
        else:
            out = attention(q, k, v, cfg, causal=True, window=window)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return x + y.astype(x.dtype), cache


def cross_kv_from_encoder(p, enc_out, cfg: ArchConfig):
    k = jnp.einsum("bsd,dhk->bshk", enc_out, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", enc_out, p["wv"])
    return k, v


# ---------------------------------------------------------------------------
# MLP block
# ---------------------------------------------------------------------------

def init_mlp(kg: KeyGen, cfg: ArchConfig, d_ff: Optional[int] = None
             ) -> Tuple[Dict, Dict]:
    d = cfg.d_model
    f = d_ff or cfg.d_ff
    gated = cfg.mlp_act in ("silu", "gelu")
    p = {
        "wi": _init(kg(), (d, f), cfg.dtype),
        "wo": _init(kg(), (f, d), cfg.dtype),
        "ln": jnp.zeros((d,), cfg.dtype),
    }
    s = {"wi": ("embed", "mlp"), "wo": ("mlp", "embed"), "ln": ("embed",)}
    if gated:
        p["wg"] = _init(kg(), (d, f), cfg.dtype)
        s["wg"] = ("embed", "mlp")
    return p, s


def apply_mlp(p, x, cfg: ArchConfig):
    h = rmsnorm(x, p["ln"], cfg.norm_eps)
    up = jnp.einsum("bsd,df->bsf", h, p["wi"])
    act = _act(cfg.mlp_act)
    if "wg" in p:
        gate = jnp.einsum("bsd,df->bsf", h, p["wg"])
        up = act(gate) * up
    else:
        up = act(up)
    y = jnp.einsum("bsf,fd->bsd", up, p["wo"])
    return x + y.astype(x.dtype)


# ---------------------------------------------------------------------------
# Losses
# ---------------------------------------------------------------------------

def cross_entropy(logits, labels, vocab: int):
    """logits: (B,S,V) f32-able; labels: (B,S) int32; -100 → ignore.
    Entries ≥ vocab in the padded dimension are masked."""
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(
        logits.astype(jnp.float32),
        jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    valid = labels >= 0
    loss = jnp.where(valid, lse - gold, 0.0)
    return loss.sum() / jnp.maximum(valid.sum(), 1)


def mask_padded_vocab(logits, vocab: int):
    vpad = logits.shape[-1]
    if vpad == vocab:
        return logits
    mask = jnp.arange(vpad) < vocab
    return jnp.where(mask, logits, -1e30)


def lm_head_loss(params, x, labels, cfg: ArchConfig, padded_vocab: int):
    """Final norm + unembed + xent. With cfg.fused_xent the (B,S,V) logit
    tensor is consumed chunk-wise along S so only a chunk is ever live —
    the vocab axis itself is sharded by the planner."""
    x = rmsnorm(x, params["final_ln"], cfg.norm_eps)
    w = params.get("unembed", params["embed"])  # (Vpad, D)

    if cfg.logit_softcap:
        def logit_fn(chunk):
            return mask_padded_vocab(
                softcap(jnp.einsum("btd,vd->btv", chunk, w),
                        cfg.logit_softcap), cfg.vocab)
    else:
        def logit_fn(chunk):
            return mask_padded_vocab(
                jnp.einsum("btd,vd->btv", chunk, w), cfg.vocab)

    if not cfg.fused_xent:
        logits = logit_fn(x)
        return cross_entropy(logits, labels, cfg.vocab)

    # chunk along sequence to bound live logits
    b, s, d = x.shape
    n_chunks = min(8, s) if s >= 8 else 1
    while s % n_chunks:
        n_chunks -= 1
    xs = x.reshape(b, n_chunks, s // n_chunks, d)
    ls = labels.reshape(b, n_chunks, s // n_chunks)

    def body(carry, blk):
        tot, cnt = carry
        xc, lc = blk
        logits = logit_fn(xc)
        lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
        gold = jnp.take_along_axis(
            logits.astype(jnp.float32),
            jnp.maximum(lc, 0)[..., None], axis=-1)[..., 0]
        valid = lc >= 0
        tot = tot + jnp.where(valid, lse - gold, 0.0).sum()
        cnt = cnt + valid.sum().astype(jnp.float32)
        return (tot, cnt), None

    (tot, cnt), _ = jax.lax.scan(
        body, (jnp.float32(0.0), jnp.float32(0.0)),
        (jnp.moveaxis(xs, 1, 0), jnp.moveaxis(ls, 1, 0)))
    return tot / jnp.maximum(cnt, 1.0)
