"""Immutable object store with globally-addressable ObjectRefs.

Reproduces the Ray properties the paper relies on (§2.2):
  * objects are immutable — "elides the need for expensive consistency
    protocols, state coherence protocols, and other synchronization";
  * every object is addressable by an ObjectRef (the paper's ObjectID);
  * objects may be *evicted* (simulating node loss); the lineage module
    reconstructs them by replaying the producing sub-graph.
"""

from __future__ import annotations

import itertools
import threading
from dataclasses import dataclass, field
from typing import Any, Dict, Optional


@dataclass(frozen=True)
class ObjectRef:
    """Future-like handle to an object in the store (paper: ObjectID)."""

    id: int
    task_id: Optional[int] = None   # producing task (lineage edge)
    index: int = 0                  # position among the task's outputs

    def __repr__(self) -> str:
        return f"ObjectRef(id={self.id}, task={self.task_id})"


class ObjectLostError(RuntimeError):
    pass


class ObjectStore:
    """In-memory immutable store. Thread-safe."""

    def __init__(self):
        self._lock = threading.Lock()
        self._data: Dict[int, Any] = {}
        self._events: Dict[int, threading.Event] = {}
        self._ids = itertools.count(1)
        self.puts = 0
        self.evictions = 0

    def new_ref(self, task_id: Optional[int] = None,
                index: int = 0) -> ObjectRef:
        with self._lock:
            ref = ObjectRef(next(self._ids), task_id, index)
            self._events[ref.id] = threading.Event()
            return ref

    def put_value(self, value: Any) -> ObjectRef:
        """Directly place a value (no producing task → not recoverable)."""
        ref = self.new_ref()
        self.fulfill(ref, value)
        return ref

    def fulfill(self, ref: ObjectRef, value: Any) -> None:
        with self._lock:
            if ref.id in self._data:
                # immutability: double-fulfill must carry the same object;
                # replays after eviction are allowed to re-store.
                pass
            self._data[ref.id] = value
            ev = self._events.setdefault(ref.id, threading.Event())
            self.puts += 1
        ev.set()

    def available(self, ref: ObjectRef) -> bool:
        with self._lock:
            return ref.id in self._data

    def wait(self, ref: ObjectRef, timeout: Optional[float] = None) -> bool:
        ev = self._events.get(ref.id)
        if ev is None:
            return False
        return ev.wait(timeout)

    def get_local(self, ref: ObjectRef) -> Any:
        """Fetch without recovery; raises if evicted/never produced."""
        with self._lock:
            if ref.id not in self._data:
                raise ObjectLostError(f"{ref} not in store")
            return self._data[ref.id]

    def evict(self, ref: ObjectRef) -> None:
        """Simulate object loss (node failure)."""
        with self._lock:
            if ref.id in self._data:
                del self._data[ref.id]
                self._events[ref.id] = threading.Event()
                self.evictions += 1

    def size(self) -> int:
        with self._lock:
            return len(self._data)
