"""raylite TaskRuntime: the Ray-analogue DAG runtime (paper §2.2).

    rt = TaskRuntime(workers=4)
    ref = rt.submit(fn, a, other_ref)     # returns immediately (future)
    val = rt.get(ref)                     # blocks; recovers lost objects

Properties reproduced from the paper:
  * tasks spawn asynchronously; the DAG builds without waiting for
    intermediate results ("hide the latency of task instantiation",
    "extract pipeline parallelism");
  * immutable object store → no barriers, no coherence traffic;
  * lineage replay recovers evicted objects (node failures);
  * speculative duplicates mitigate stragglers (no MPI-style barrier to
    stall on);
  * elastic worker pool (scale_to) — tasks never bind to a fixed world
    size.
"""

from __future__ import annotations

import itertools
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

from .executor import WorkItem, WorkerPool
from .lineage import LineageGraph, TaskRecord
from .store import ObjectLostError, ObjectRef, ObjectStore


@dataclass
class TaskState:
    record: TaskRecord
    submitted_s: float
    started_s: Optional[float] = None
    finished_s: Optional[float] = None
    attempts: int = 0
    speculated: bool = False
    error: Optional[BaseException] = None


class TaskFailedError(RuntimeError):
    pass


class TaskRuntime:
    def __init__(self, workers: int = 4, max_attempts: int = 3,
                 speculation: bool = True,
                 straggler_factor: float = 4.0,
                 straggler_min_s: float = 0.05):
        self.store = ObjectStore()
        self.lineage = LineageGraph(self.store)
        self.pool = WorkerPool(workers)
        self.max_attempts = max_attempts
        self._tasks: Dict[int, TaskState] = {}
        self._task_ids = itertools.count(1)
        self._lock = threading.Lock()
        self._durations: List[float] = []
        self.speculation = speculation
        self.straggler_factor = straggler_factor
        self.straggler_min_s = straggler_min_s
        self._monitor: Optional[threading.Thread] = None
        self._shutdown = threading.Event()
        # test hook: {fn_qualname: fail_first_n_attempts}
        self.failure_injections: Dict[str, int] = {}
        if speculation:
            self._monitor = threading.Thread(
                target=self._speculate_loop, daemon=True,
                name="raylite-speculation")
            self._monitor.start()

    # -- submission -------------------------------------------------------
    def submit(self, fn: Callable, *args, num_returns: int = 1,
               **kwargs) -> Any:
        tid = next(self._task_ids)
        out_refs = tuple(self.store.new_ref(tid, i)
                         for i in range(num_returns))
        rec = TaskRecord(tid, fn, args, kwargs, out_refs)
        self.lineage.record(rec)
        st = TaskState(rec, time.perf_counter())
        with self._lock:
            self._tasks[tid] = st
        self._schedule(st)
        return out_refs[0] if num_returns == 1 else list(out_refs)

    def put(self, value: Any) -> ObjectRef:
        return self.store.put_value(value)

    def _schedule(self, st: TaskState) -> None:
        self.pool.dispatch(WorkItem(st.record.task_id,
                                    lambda: self._execute(st)))

    # -- execution -----------------------------------------------------------
    def _execute(self, st: TaskState) -> None:
        rec = st.record
        if all(self.store.available(r) for r in rec.out_refs):
            return  # speculative duplicate lost the race — discard
        st.started_s = time.perf_counter()
        st.attempts += 1
        try:
            args = [self._resolve(a) for a in rec.args]
            kwargs = {k: self._resolve(v) for k, v in rec.kwargs.items()}
            inject = self.failure_injections.get(
                getattr(rec.fn, "__qualname__", ""), 0)
            if st.attempts <= inject:
                raise RuntimeError(
                    f"injected failure (attempt {st.attempts})")
            result = rec.fn(*args, **kwargs)
        except BaseException as exc:  # noqa: BLE001 — worker must survive
            st.error = exc
            if st.attempts < self.max_attempts:
                self._schedule(st)
            else:
                for r in rec.out_refs:
                    self.store.fulfill(r, _TaskError(exc))
            return
        st.error = None
        st.finished_s = time.perf_counter()
        with self._lock:
            self._durations.append(st.finished_s - st.started_s)
        outs = result if len(rec.out_refs) > 1 else (result,)
        for r, v in zip(rec.out_refs, outs):
            self.store.fulfill(r, v)

    def _resolve(self, v: Any) -> Any:
        if isinstance(v, ObjectRef):
            return self.get(v)
        return v

    # -- retrieval -----------------------------------------------------------
    def get(self, ref_or_refs, timeout: Optional[float] = 60.0):
        if isinstance(ref_or_refs, list):
            return [self.get(r, timeout) for r in ref_or_refs]
        ref: ObjectRef = ref_or_refs
        deadline = None if timeout is None else time.monotonic() + timeout
        while True:
            if self.store.wait(ref, 0.05):
                break
            # Not fulfilled: if the producing task already completed once,
            # the object was evicted (node loss) → lineage replay.
            rec = self.lineage.producer_of(ref)
            if rec is not None:
                st = self._tasks.get(rec.task_id)
                if (st is not None and st.finished_s is not None
                        and not self.store.available(ref)):
                    break
            if deadline is not None and time.monotonic() > deadline:
                raise TimeoutError(f"timed out waiting for {ref}")
        try:
            val = self.store.get_local(ref)
        except ObjectLostError:
            val = self.lineage.reconstruct(ref)
        if isinstance(val, _TaskError):
            raise TaskFailedError(str(val.exc)) from val.exc
        return val

    def wait(self, refs: List[ObjectRef], num_returns: int = 1,
             timeout: Optional[float] = None):
        """ray.wait analogue: (ready, pending)."""
        deadline = None if timeout is None else time.monotonic() + timeout
        ready, pending = [], list(refs)
        while len(ready) < num_returns and pending:
            progressed = False
            for r in list(pending):
                if self.store.available(r):
                    ready.append(r)
                    pending.remove(r)
                    progressed = True
            if len(ready) >= num_returns:
                break
            if deadline is not None and time.monotonic() > deadline:
                break
            if not progressed:
                time.sleep(0.002)
        return ready, pending

    # -- fault injection / recovery ------------------------------------------
    def kill_worker_and_evict(self) -> int:
        """Simulate a node failure: stop one worker and evict everything it
        would have held locally (we evict the most recent objects)."""
        victim = self.pool.kill_worker()
        evicted = 0
        if victim is not None:
            self.pool.add_worker()  # replacement node joins
        return evicted

    def evict(self, ref: ObjectRef) -> None:
        self.store.evict(ref)

    # -- stragglers ------------------------------------------------------------
    def _speculate_loop(self) -> None:
        while not self._shutdown.wait(0.02):
            with self._lock:
                durs = sorted(self._durations[-64:])
                median = durs[len(durs) // 2] if durs else None
                running = [st for st in self._tasks.values()
                           if st.started_s is not None
                           and st.finished_s is None
                           and st.error is None
                           and not st.speculated]
            if median is None:
                continue
            limit = max(self.straggler_min_s,
                        self.straggler_factor * median)
            now = time.perf_counter()
            for st in running:
                if now - st.started_s > limit:
                    st.speculated = True
                    self._schedule(st)  # duplicate; first fulfill wins

    # -- elasticity ------------------------------------------------------------
    def scale_to(self, n: int) -> None:
        self.pool.scale_to(n)

    def stats(self) -> Dict[str, Any]:
        with self._lock:
            done = [st for st in self._tasks.values()
                    if st.finished_s is not None]
            spec = sum(1 for st in self._tasks.values() if st.speculated)
            retries = sum(max(0, st.attempts - 1)
                          for st in self._tasks.values())
        return {
            "tasks": len(self._tasks),
            "completed": len(done),
            "speculated": spec,
            "retries": retries,
            "lineage_replays": self.lineage.replays,
            "store_objects": self.store.size(),
            "workers": self.pool.size,
        }

    def shutdown(self) -> None:
        self._shutdown.set()
        self.pool.shutdown()


@dataclass
class _TaskError:
    exc: BaseException

    def __str__(self) -> str:
        return repr(self.exc)
