"""raylite — the Ray-analogue distributed runtime (paper §2.2).

Public API mirrors the Ray calls the paper's generated code uses:

    from repro.runtime import TaskRuntime
    rt = TaskRuntime(workers=8)
    ref = rt.submit(fn, *args)      # ray.remote(fn).remote(*args)
    rt.get(ref)                     # ray.get
    rt.wait(refs, num_returns=1)    # ray.wait
"""

from .elastic import ElasticController, ElasticPolicy
from .lineage import LineageGraph, LineagePoisonedError
from .store import ObjectLostError, ObjectRef, ObjectStore
from .tasks import TaskFailedError, TaskRuntime

__all__ = [
    "ElasticController", "ElasticPolicy", "LineageGraph",
    "LineagePoisonedError", "ObjectLostError", "ObjectRef", "ObjectStore",
    "TaskFailedError", "TaskRuntime",
]
