"""Lineage-based fault tolerance (paper §2.2, after Lineage Stash [22]).

"Data store immutability, combined with the deterministic nature of the
task graph, enable fault tolerance, as any missing object in the graph can
be recomputed by simply replaying the sub-graph leading up to and including
the object's parent vertex."

The lineage graph maps every ObjectRef to the (pure, deterministic) task
that produced it; ``reconstruct`` replays the minimal sub-graph for a lost
object, re-fetching transitively-lost inputs first.
"""

from __future__ import annotations

import threading
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Tuple

from .store import ObjectLostError, ObjectRef, ObjectStore


@dataclass
class TaskRecord:
    task_id: int
    fn: Callable
    args: Tuple[Any, ...]        # values or ObjectRefs
    kwargs: Dict[str, Any]
    out_refs: Tuple[ObjectRef, ...]


class LineageGraph:
    def __init__(self, store: ObjectStore):
        self.store = store
        self._by_task: Dict[int, TaskRecord] = {}
        self._producer: Dict[int, int] = {}  # object id → task id
        self._lock = threading.Lock()
        self.replays = 0

    def record(self, rec: TaskRecord) -> None:
        with self._lock:
            self._by_task[rec.task_id] = rec
            for ref in rec.out_refs:
                self._producer[ref.id] = rec.task_id

    def producer_of(self, ref: ObjectRef):
        with self._lock:
            tid = self._producer.get(ref.id)
            return self._by_task.get(tid) if tid is not None else None

    # -- recovery -----------------------------------------------------------
    def reconstruct(self, ref: ObjectRef) -> Any:
        """Return the object's value, replaying producers as needed.

        Idempotent under concurrent eviction: the replayed value is
        returned *directly* from the task's own output, never re-read
        through the store — so an eviction racing the replay (a worker
        killed mid-replay re-evicting what we just fulfilled) cannot
        turn a successful recomputation into an ObjectLostError. A
        racing second replay of the same object is harmless: tasks are
        pure and deterministic, both produce the same value."""
        if self.store.available(ref):
            try:
                return self.store.get_local(ref)
            except ObjectLostError:
                pass  # evicted between the check and the read: replay
        rec = self.producer_of(ref)
        if rec is None:
            raise ObjectLostError(
                f"{ref} lost and has no lineage (direct put?)")
        args = [self.reconstruct(a) if isinstance(a, ObjectRef) else a
                for a in rec.args]
        kwargs = {k: (self.reconstruct(v) if isinstance(v, ObjectRef)
                      else v)
                  for k, v in rec.kwargs.items()}
        with self._lock:
            self.replays += 1
        result = rec.fn(*args, **kwargs)
        outs = result if len(rec.out_refs) > 1 else (result,)
        value = None
        for r, v in zip(rec.out_refs, outs):
            self.store.fulfill(r, v)
            if r.id == ref.id:
                value = v
        return value
