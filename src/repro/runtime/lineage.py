"""Lineage-based fault tolerance (paper §2.2, after Lineage Stash [22]).

"Data store immutability, combined with the deterministic nature of the
task graph, enable fault tolerance, as any missing object in the graph can
be recomputed by simply replaying the sub-graph leading up to and including
the object's parent vertex."

The lineage graph maps every ObjectRef to the (pure, deterministic) task
that produced it; ``reconstruct`` replays the minimal sub-graph for a lost
object, re-fetching transitively-lost inputs first.

Robustness contract (shared with the cluster runtime's replay path):
replays are *budgeted* per object — an object whose producer keeps
failing (or whose storage keeps evaporating under it) is **poisoned**
with a named cause after ``max_replays`` attempts, and every dependent
that tries to reconstruct through it fails with that cause attached
instead of looping forever. Retry → replay lineage → poison dependents,
in that order.
"""

from __future__ import annotations

import threading
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Tuple

from .store import ObjectLostError, ObjectRef, ObjectStore


class LineagePoisonedError(ObjectLostError):
    """Reconstruction hit an object whose replay budget is exhausted
    (or that was explicitly poisoned); the message names the root
    cause so dependents fail forensically, not anonymously."""


@dataclass
class TaskRecord:
    task_id: int
    fn: Callable
    args: Tuple[Any, ...]        # values or ObjectRefs
    kwargs: Dict[str, Any]
    out_refs: Tuple[ObjectRef, ...]


class LineageGraph:
    def __init__(self, store: ObjectStore, max_replays: int = 8):
        self.store = store
        self.max_replays = max_replays   # per-object replay budget
        self._by_task: Dict[int, TaskRecord] = {}
        self._producer: Dict[int, int] = {}  # object id → task id
        self._replay_counts: Dict[int, int] = {}
        self._poisoned: Dict[int, str] = {}  # object id → named cause
        self._lock = threading.Lock()
        self.replays = 0
        self.poisons = 0

    def record(self, rec: TaskRecord) -> None:
        with self._lock:
            self._by_task[rec.task_id] = rec
            for ref in rec.out_refs:
                self._producer[ref.id] = rec.task_id

    def producer_of(self, ref: ObjectRef):
        with self._lock:
            tid = self._producer.get(ref.id)
            return self._by_task.get(tid) if tid is not None else None

    # -- poisoning ----------------------------------------------------------
    def poison(self, ref: ObjectRef, cause: str) -> None:
        """Mark an object unreconstructable with a named cause; every
        dependent reconstruction through it raises that cause."""
        with self._lock:
            if ref.id not in self._poisoned:
                self._poisoned[ref.id] = cause
                self.poisons += 1

    def poison_cause(self, ref: ObjectRef) -> Optional[str]:
        with self._lock:
            return self._poisoned.get(ref.id)

    def _charge_replay(self, ref: ObjectRef) -> None:
        """Spend one unit of the object's replay budget; poison it (and
        raise, naming the exhaustion) when the budget runs dry."""
        with self._lock:
            cause = self._poisoned.get(ref.id)
            if cause is None:
                n = self._replay_counts.get(ref.id, 0) + 1
                self._replay_counts[ref.id] = n
                if n <= self.max_replays:
                    self.replays += 1
                    return
                cause = (f"{ref} exceeded its replay budget "
                         f"({self.max_replays}) — storage or producer "
                         f"is failing repeatedly")
                self._poisoned[ref.id] = cause
                self.poisons += 1
        raise LineagePoisonedError(cause)

    # -- recovery -----------------------------------------------------------
    def reconstruct(self, ref: ObjectRef) -> Any:
        """Return the object's value, replaying producers as needed.

        Idempotent under concurrent eviction: the replayed value is
        returned *directly* from the task's own output, never re-read
        through the store — so an eviction racing the replay (a worker
        killed mid-replay re-evicting what we just fulfilled) cannot
        turn a successful recomputation into an ObjectLostError. A
        racing second replay of the same object is harmless: tasks are
        pure and deterministic, both produce the same value."""
        if self.store.available(ref):
            try:
                return self.store.get_local(ref)
            except ObjectLostError:
                pass  # evicted between the check and the read: replay
        cause = self.poison_cause(ref)
        if cause is not None:
            raise LineagePoisonedError(cause)
        rec = self.producer_of(ref)
        if rec is None:
            raise ObjectLostError(
                f"{ref} lost and has no lineage (direct put?)")
        args = [self.reconstruct(a) if isinstance(a, ObjectRef) else a
                for a in rec.args]
        kwargs = {k: (self.reconstruct(v) if isinstance(v, ObjectRef)
                      else v)
                  for k, v in rec.kwargs.items()}
        self._charge_replay(ref)
        result = rec.fn(*args, **kwargs)
        outs = result if len(rec.out_refs) > 1 else (result,)
        value = None
        for r, v in zip(rec.out_refs, outs):
            self.store.fulfill(r, v)
            if r.id == ref.id:
                value = v
        return value
