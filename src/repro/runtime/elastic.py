"""Elastic autoscaling controller for the task runtimes.

Watches queue depth and resizes the fleet within
[min_workers, max_workers]. The same control loop drives both runtime
flavors, duck-typed on ``scale_to`` plus a size/depth probe:

  * :class:`repro.runtime.tasks.TaskRuntime` — thread-backed pool
    (``rt.pool.size`` / ``rt.pool.queue_depth()``); scaling is instant.
  * :class:`repro.distrib.cluster.ClusterRuntime` — real worker
    processes (``rt.workers_alive()`` / ``rt.queue_depth()``); growth
    spawns + profiles + pre-warms a worker, shrink marks one draining
    (it finishes in-flight work, hands objects back, then exits).

On real clusters this is the autoscaler requesting/releasing nodes;
here it exercises the same control loop against live fleets so
elasticity is a tested property of the runtimes, not an aspiration.
"""

from __future__ import annotations

import threading
from dataclasses import dataclass
from typing import Callable, Optional


@dataclass
class ElasticPolicy:
    min_workers: int = 1
    max_workers: int = 16
    scale_up_queue_per_worker: float = 2.0   # queue/worker above → grow
    scale_down_idle_queue: int = 0           # queue at/below → shrink
    step: int = 2


class ElasticController:
    """Queue-depth autoscaler over any runtime exposing ``scale_to``.

    Size and depth are probed duck-typed: a thread-pool runtime exposes
    them on ``rt.pool``, the cluster runtime directly (a draining
    cluster worker no longer counts toward size, so the controller
    never double-shrinks a drain already in progress)."""

    def __init__(self, rt, policy: ElasticPolicy = None,
                 interval_s: float = 0.05,
                 depth_fn: Optional[Callable[[], int]] = None):
        self.rt = rt
        self.policy = policy or ElasticPolicy()
        self.interval_s = interval_s
        # external pressure signal (e.g. a serving engine's queue
        # depth) overriding the runtime's own task-queue probe — the
        # fleet scales with *request* backlog, not just tasks already
        # in flight
        self.depth_fn = depth_fn
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self.decisions: list = []

    def _size(self) -> int:
        pool = getattr(self.rt, "pool", None)
        if pool is not None:
            return int(pool.size)
        views = getattr(self.rt, "_views", None)
        if views is not None:
            # live, non-draining, attached workers — what placement
            # actually has to work with
            return len(views())
        return int(self.rt.workers_alive())

    def _depth(self) -> int:
        if self.depth_fn is not None:
            return int(self.depth_fn())
        pool = getattr(self.rt, "pool", None)
        if pool is not None:
            return int(pool.queue_depth())
        return int(self.rt.queue_depth())

    def tick(self) -> int:
        """One control-loop step; returns the new target size."""
        p = self.policy
        size = max(1, self._size())
        depth = self._depth()
        target = size
        if depth > p.scale_up_queue_per_worker * size:
            target = min(p.max_workers, size + p.step)
        elif depth <= p.scale_down_idle_queue and size > p.min_workers:
            target = max(p.min_workers, size - 1)
        if target != size:
            self.rt.scale_to(target)
            self.decisions.append((size, target, depth))
        return target

    def start(self) -> None:
        def loop():
            while not self._stop.wait(self.interval_s):
                self.tick()

        self._thread = threading.Thread(target=loop, daemon=True,
                                        name="raylite-elastic")
        self._thread.start()

    def stop(self) -> None:
        self._stop.set()
