"""Elastic autoscaling policy for the raylite worker pool.

Watches queue depth and completed-task latency and resizes the pool within
[min_workers, max_workers]. On real clusters this is the autoscaler
requesting/releasing nodes; here it exercises the same control loop against
the thread-backed pool so elasticity is a tested property of the runtime,
not an aspiration.
"""

from __future__ import annotations

import threading
from dataclasses import dataclass
from typing import Optional

from .tasks import TaskRuntime


@dataclass
class ElasticPolicy:
    min_workers: int = 1
    max_workers: int = 16
    scale_up_queue_per_worker: float = 2.0   # queue/worker above → grow
    scale_down_idle_queue: int = 0           # queue at/below → shrink
    step: int = 2


class ElasticController:
    def __init__(self, rt: TaskRuntime, policy: ElasticPolicy = None,
                 interval_s: float = 0.05):
        self.rt = rt
        self.policy = policy or ElasticPolicy()
        self.interval_s = interval_s
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self.decisions: list = []

    def tick(self) -> int:
        """One control-loop step; returns the new target size."""
        p = self.policy
        size = max(1, self.rt.pool.size)
        depth = self.rt.pool.queue_depth()
        target = size
        if depth > p.scale_up_queue_per_worker * size:
            target = min(p.max_workers, size + p.step)
        elif depth <= p.scale_down_idle_queue and size > p.min_workers:
            target = max(p.min_workers, size - 1)
        if target != size:
            self.rt.scale_to(target)
            self.decisions.append((size, target, depth))
        return target

    def start(self) -> None:
        def loop():
            while not self._stop.wait(self.interval_s):
                self.tick()

        self._thread = threading.Thread(target=loop, daemon=True,
                                        name="raylite-elastic")
        self._thread.start()

    def stop(self) -> None:
        self._stop.set()
