"""Worker pool: the cluster stand-in.

Each Worker is a thread modelling one node-process. The pool is elastic
(workers can be added/removed live) and failure-injectable (a worker can be
"killed", which both stops the thread and evicts the objects it produced —
the combination the lineage module must recover from).
"""

from __future__ import annotations

import queue
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional


@dataclass
class WorkItem:
    task_id: int
    run: Callable[[], None]     # executes + fulfills; owns error handling


_POISON = object()


class Worker(threading.Thread):
    def __init__(self, pool: "WorkerPool", wid: int):
        super().__init__(name=f"raylite-worker-{wid}", daemon=True)
        self.pool = pool
        self.wid = wid
        self.alive = True
        self.killed = False
        self.current_task: Optional[int] = None
        self.produced: List[int] = []  # object ids this worker fulfilled

    def run(self) -> None:
        while self.alive:
            try:
                item = self.pool._queue.get(timeout=0.1)
            except queue.Empty:
                continue
            if item is _POISON:
                self.alive = False
                break
            if self.killed:
                # dead node: requeue for someone else
                self.pool._queue.put(item)
                break
            self.current_task = item.task_id
            try:
                item.run()
            finally:
                self.current_task = None
        self.pool._on_worker_exit(self)


class WorkerPool:
    def __init__(self, workers: int = 4):
        self._queue: "queue.Queue" = queue.Queue()
        self._lock = threading.Lock()
        self._workers: List[Worker] = []
        self._next_wid = 0
        self.scale_to(workers)

    # -- elasticity ------------------------------------------------------
    def scale_to(self, n: int) -> None:
        with self._lock:
            live = [w for w in self._workers if w.alive and not w.killed]
            delta = n - len(live)
        if delta > 0:
            for _ in range(delta):
                self.add_worker()
        elif delta < 0:
            for _ in range(-delta):
                self._queue.put(_POISON)

    def add_worker(self) -> Worker:
        with self._lock:
            w = Worker(self, self._next_wid)
            self._next_wid += 1
            self._workers.append(w)
        w.start()
        return w

    def _on_worker_exit(self, w: Worker) -> None:
        with self._lock:
            if w in self._workers:
                self._workers.remove(w)

    @property
    def size(self) -> int:
        with self._lock:
            return len([w for w in self._workers
                        if w.alive and not w.killed])

    def queue_depth(self) -> int:
        return self._queue.qsize()

    # -- failure injection -------------------------------------------------
    def kill_worker(self, wid: Optional[int] = None) -> Optional[Worker]:
        """Simulate a node failure: stop the worker; caller evicts its
        produced objects."""
        with self._lock:
            candidates = [w for w in self._workers
                          if w.alive and not w.killed]
            if not candidates:
                return None
            victim = candidates[0]
            if wid is not None:
                for w in candidates:
                    if w.wid == wid:
                        victim = w
                        break
            victim.killed = True
            victim.alive = False
            return victim

    # -- scheduling -------------------------------------------------------
    def dispatch(self, item: WorkItem) -> None:
        self._queue.put(item)

    def shutdown(self) -> None:
        with self._lock:
            n = len(self._workers)
        for _ in range(n):
            self._queue.put(_POISON)
