"""Runtime surface of the pallas backend (bound as ``__plk`` in twins).

The pattern matcher (:mod:`repro.core.patterns`) rewrites recognized
pfor unit bodies onto these three entry points; generated pallas twins
call them with plain numpy blocks and store the numpy result back into
the captured (possibly chunk-sliced) arrays. Each wrapper adapts the
matched shape onto the corresponding seed Pallas kernel:

* :func:`matmul` — blocked matmul (``kernels/matmul``), ragged shapes
  padded by the kernel's own dispatcher.
* :func:`attention_rows` — unscaled-softmax row attention onto the
  flash kernel (``kernels/flash_attention``): the kernel bakes in a
  ``1/sqrt(d)`` score scale, so queries are pre-multiplied by
  ``sqrt(d)`` to cancel it; block sizes are clamped to divisors because
  the kernel refuses ragged tiles (zero-padding K would pollute the
  softmax).
* :func:`scan_rows` — first-order linear recurrence onto the selective
  scan kernel (``kernels/mamba_scan``) via the identity mapping
  ``dt=1, B=C=1 (N=1), a=log(-log(c))`` which requires ``0<c<1``; an
  out-of-range coefficient raises, which the cluster counts as a
  lowering failure and degrades down the ``TaskSpec.alt`` chain.

On CPU-only hosts the kernels run in Pallas *interpret* mode, so CI
exercises the full routing path; a real ``pallas_call`` lowering is
used when ``REPRO_DISTRIB_PROBE_GPU=1`` and jax actually sees an
accelerator. ``REPRO_PALLAS_CHAOS=fail`` makes every entry point raise
(deterministic fallback-path tests).

This module enables jax x64 itself: generated chunk bodies compute in
the caller's (usually float64) dtype, and the serializer's x64 forcing
only covers jax-prefixed module globals, which ``__plk`` is not.
"""

from __future__ import annotations

import math
import os
from typing import Dict

import numpy as np

import jax

jax.config.update("jax_enable_x64", True)

import jax.numpy as jnp  # noqa: E402  (after x64 so f64 survives)

from .flash_attention.flash_attention import flash_attention_bhsd  # noqa: E402
from .mamba_scan import ops as _mamba_ops  # noqa: E402
from .matmul import ops as _matmul_ops  # noqa: E402

_STATS: Dict[str, float] = {}


def _bump(key: str, val: float = 1) -> None:
    _STATS[key] = _STATS.get(key, 0) + val


def stats() -> Dict[str, float]:
    """Counters accumulated since the last :func:`take_stats`."""
    return dict(_STATS)


def take_stats() -> Dict[str, float]:
    """Drain the counters; the worker piggybacks them on chunk ``done``
    messages exactly like :func:`repro.distrib.accel.take_stats`."""
    out = dict(_STATS)
    _STATS.clear()
    return out


def reset() -> None:
    _STATS.clear()


def _use_interpret() -> bool:
    """Interpret mode unless a real accelerator was probed *and* jax
    actually sees one (mirrors the device layer's opt-in probe gate)."""
    if os.environ.get("REPRO_DISTRIB_PROBE_GPU") != "1":
        return True
    return jax.default_backend() not in ("gpu", "tpu")


def _chaos() -> None:
    if os.environ.get("REPRO_PALLAS_CHAOS") == "fail":
        raise RuntimeError("pallas-chaos")


def _count(interpret: bool) -> None:
    _bump("pallas_calls")
    if interpret:
        _bump("pallas_interpret_calls")


def _div_block(n: int, pref: int) -> int:
    """Largest block <= pref that divides n (kernels refuse ragged
    tiles)."""
    b = max(1, min(pref, n))
    while n % b:
        b -= 1
    return b


def matmul(a, b):
    """``a @ b`` through the blocked Pallas matmul kernel."""
    _chaos()
    interpret = _use_interpret()
    _count(interpret)
    out = _matmul_ops.matmul(jnp.asarray(a), jnp.asarray(b),
                             force_pallas=True, interpret=interpret)
    return np.asarray(out)


def attention_rows(q, k, v):
    """Unscaled-softmax attention for a block of query rows.

    ``out[r, j] = sum_t exp(q[r]·k[t]) v[t, j] / sum_t exp(q[r]·k[t])``
    with q ``(R, D)``, k ``(T, D)``, v ``(T, D)``.
    """
    _chaos()
    interpret = _use_interpret()
    _count(interpret)
    q = jnp.asarray(q)
    k = jnp.asarray(k)
    v = jnp.asarray(v)
    rows, d = q.shape
    skv = k.shape[0]
    # cancel the kernel's baked-in 1/sqrt(d) score scale
    qs = q * jnp.asarray(math.sqrt(d), q.dtype)
    out = flash_attention_bhsd(
        qs[None], k[None], v[None], causal=False, window=0, softcap=0.0,
        bq=_div_block(rows, 128), bk=_div_block(skv, 128),
        interpret=interpret)
    return np.asarray(out[0])


def scan_rows(x_rows, c):
    """First-order recurrence ``h_t = c*h_{t-1} + x[r, t]`` per row,
    ``h_{-1} = 0``, through the selective-scan kernel."""
    _chaos()
    c = float(c)
    if not 0.0 < c < 1.0:
        raise ValueError(
            f"pallas-lowering-infeasible: scan decay coefficient {c!r} "
            f"outside (0, 1) (a = log(-log(c)) undefined)")
    interpret = _use_interpret()
    _count(interpret)
    x_rows = jnp.asarray(x_rows)
    rows, length = x_rows.shape
    dtype = x_rows.dtype
    # identity mapping: B=1 batch, I=rows channels, N=1 state; with
    # dt=1 and B=C=1 the recurrence collapses to h = exp(-exp(a))*h + x
    # and a = log(-log(c)) makes exp(-exp(a)) == c exactly
    x = x_rows.T[None]                               # (1, L, R)
    dt = jnp.ones((1, length, rows), dtype)
    ones_n = jnp.ones((1, length, 1), dtype)
    a = jnp.full((rows, 1), math.log(-math.log(c)), dtype)
    d_skip = jnp.zeros((rows,), dtype)
    y = _mamba_ops.mamba_scan(x, dt, ones_n, ones_n, a, d_skip,
                              force_pallas=True, interpret=interpret)
    return np.asarray(y[0]).T                        # (R, L)
