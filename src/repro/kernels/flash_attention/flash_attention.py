"""Flash attention as a Pallas TPU kernel.

Online-softmax over KV blocks with fp32 running (max, sum, acc) carried in
VMEM scratch across the innermost (sequential) KV-block grid axis. Handles
GQA (q heads grouped over kv heads), causal masking, sliding windows, and
gemma-style score softcap. Block sizes are MXU/VPU aligned (multiples of
128 on the lane dim); VMEM footprint per step = bq·d + 2·bk·d + bq·bk fp32
≈ 1.3 MB at (bq=128, bk=128, d=128).

The memory-roofline win vs the naive path: scores (Sq × Skv) never
materialize in HBM — exactly the term the §Perf hillclimb targets for
prefill_32k cells.
"""

from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  kv_steps: int, bq: int, bk: int, scale: float,
                  causal: bool, window: int, softcap: float, acc_dtype):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]                       # (bq, d)
    k = k_ref[0]                       # (bk, d)
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=acc_dtype) * scale    # (bq, bk)
    if softcap and softcap > 0:
        s = jnp.tanh(s / softcap) * softcap

    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = jnp.ones((bq, bk), jnp.bool_)
    if causal:
        mask &= k_pos <= q_pos
    if window and window > 0:
        mask &= k_pos > (q_pos - window)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                # (bq, 1)
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=1, keepdims=True)
    m_ref[...] = m_new
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p.astype(v_ref.dtype), v_ref[0], (((1,), (0,)), ((), ())),
        preferred_element_type=acc_dtype)

    @pl.when(ki == kv_steps - 1)
    def _store():
        o_ref[0] = (acc_ref[...] /
                    jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "softcap",
                                             "bq", "bk", "interpret"))
def flash_attention_bhsd(q, k, v, *, causal: bool = True, window: int = 0,
                         softcap: float = 0.0, bq: int = 128,
                         bk: int = 128, interpret: bool = False):
    """Single-kv-head layout: q (BH, Sq, D), k/v (BH, Skv, D)."""
    bh, sq, d = q.shape
    skv = k.shape[1]
    bq = min(bq, sq)
    bk = min(bk, skv)
    assert sq % bq == 0 and skv % bk == 0, (sq, skv, bq, bk)
    kv_steps = skv // bk
    scale = 1.0 / math.sqrt(d)
    # running max/sum/acc in at least fp32; f64 inputs keep precision
    acc_dtype = jnp.promote_types(q.dtype, jnp.float32)
    kern = functools.partial(
        _flash_kernel, kv_steps=kv_steps, bq=bq, bk=bk, scale=scale,
        causal=causal, window=window, softcap=softcap,
        acc_dtype=acc_dtype)
    return pl.pallas_call(
        kern,
        grid=(bh, sq // bq, kv_steps),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), acc_dtype),
            pltpu.VMEM((bq, 1), acc_dtype),
            pltpu.VMEM((bq, d), acc_dtype),
        ],
        interpret=interpret,
    )(q, k, v)
