"""Pure-jnp oracle for the flash-attention kernel (plain softmax path)."""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0,
                  softcap: float = 0.0):
    """q: (B, Sq, H, D); k/v: (B, Skv, KVH, D). GQA via head groups."""
    b, sq, h, d = q.shape
    skv, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    qg = q.reshape(b, sq, kvh, g, d)
    acc = jnp.promote_types(q.dtype, jnp.float32)
    s = jnp.einsum("bqkgd,bskd->bqkgs", qg, k,
                   preferred_element_type=acc) / math.sqrt(d)
    if softcap and softcap > 0:
        s = jnp.tanh(s / softcap) * softcap
    q_pos = jnp.arange(sq)
    k_pos = jnp.arange(skv)
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= k_pos[None, :] <= q_pos[:, None]
    if window and window > 0:
        mask &= k_pos[None, :] > (q_pos[:, None] - window)
    s = jnp.where(mask[None, :, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bqkgs,bskd->bqkgd", p.astype(q.dtype), v)
    return out.reshape(b, sq, h, d)
