"""Public flash-attention wrapper: GQA layout handling + dispatch.

(B, S, H, D) GQA tensors are regrouped to (B·KVH·G, S, D) with K/V
broadcast over the G query-head groups, run through the Pallas kernel,
and regrouped back. Dispatch: Pallas on TPU (or forced for tests);
otherwise the jnp oracle.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .flash_attention import flash_attention_bhsd
from .ref import attention_ref


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    softcap: float = 0.0, bq: int = 128, bk: int = 128,
                    force_pallas: bool = False, interpret: bool = False):
    on_tpu = jax.default_backend() == "tpu"
    if not (on_tpu or force_pallas):
        return attention_ref(q, k, v, causal=causal, window=window,
                             softcap=softcap)
    b, sq, h, d = q.shape
    skv, kvh = k.shape[1], k.shape[2]
    g = h // kvh
    # (B, S, H, D) → (B·H, S, D) with kv broadcast across groups
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, sq, d)
    kf = jnp.repeat(k.transpose(0, 2, 1, 3), g, axis=1).reshape(
        b * h, skv, d)
    vf = jnp.repeat(v.transpose(0, 2, 1, 3), g, axis=1).reshape(
        b * h, skv, d)
    out = flash_attention_bhsd(
        qf, kf, vf, causal=causal, window=window, softcap=softcap,
        bq=bq, bk=bk, interpret=interpret or not on_tpu)
    return out.reshape(b, h, sq, d).transpose(0, 2, 1, 3)
