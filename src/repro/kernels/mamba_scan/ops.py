"""Public selective-scan wrapper with backend dispatch."""

from __future__ import annotations

import jax

from .mamba_scan import mamba_scan as _kernel
from .ref import mamba_scan_ref


def mamba_scan(x, dt, Bm, Cm, a, d_skip, *, chunk: int = 128,
               force_pallas: bool = False, interpret: bool = False):
    on_tpu = jax.default_backend() == "tpu"
    if not (on_tpu or force_pallas):
        return mamba_scan_ref(x, dt, Bm, Cm, a, d_skip)
    l = x.shape[1]
    c = min(chunk, l)
    while l % c:
        c -= 1
    return _kernel(x, dt, Bm, Cm, a, d_skip, chunk=c,
                   interpret=interpret or not on_tpu)
