"""Pure-jnp oracle for the selective-scan kernel: sequential recurrence.

h_t = exp(dt_t ⊙ A) ⊙ h_{t-1} + (dt_t ⊙ x_t) ⊗ B_t ;  y_t = h_t · C_t
x/dt: (B, L, I);  Bm/Cm: (B, L, N);  a: (I, N) log-decay;  d: (I,) skip.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def mamba_scan_ref(x, dt, Bm, Cm, a, d_skip):
    b, l, inner = x.shape
    n = Bm.shape[-1]
    decay = -jnp.exp(a)                              # (I, N)

    def step(h, inputs):
        xt, dtt, bt, ct = inputs
        a_bar = jnp.exp(dtt[..., None] * decay[None])      # (B, I, N)
        h = a_bar * h + (dtt * xt)[..., None] * bt[:, None, :]
        y = (h * ct[:, None, :]).sum(-1)                   # (B, I)
        return h, y

    h0 = jnp.zeros((b, inner, n), jnp.promote_types(x.dtype,
                                                    jnp.float32))
    xs = (jnp.moveaxis(x, 1, 0), jnp.moveaxis(dt, 1, 0),
          jnp.moveaxis(Bm, 1, 0), jnp.moveaxis(Cm, 1, 0))
    _, ys = jax.lax.scan(step, h0, xs)
    y = jnp.moveaxis(ys, 0, 1)                             # (B, L, I)
    return y + d_skip[None, None] * x
