"""Selective-scan (Mamba) as a chunked Pallas TPU kernel.

The GPU reference implementation is a warp-parallel prefix scan; the TPU
adaptation (DESIGN.md §2) is a CHUNKED recurrence: the sequence axis is
tiled into VMEM-resident chunks scanned by the sequential grid axis, with
the (I, N) state carried in fp32 scratch. Inside a chunk the recurrence
runs as an unrolled-on-VPU fori_loop over timesteps — each step is a fully
vectorized (I, N) elementwise update, which is what the 8×128 VPU wants;
cross-chunk parallelism comes from the batch grid axis.

VMEM per step = chunk·I (x, dt) + chunk·N (B, C) + I·N state fp32 —
~1.2 MB at (chunk=128, I=1024, N=16).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _scan_kernel(x_ref, dt_ref, b_ref, c_ref, a_ref, dskip_ref, y_ref,
                 h_ref, *, chunk: int, acc_dtype):
    ci = pl.program_id(1)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    decay = -jnp.exp(a_ref[...].astype(acc_dtype))  # (I, N)
    x = x_ref[0].astype(acc_dtype)                 # (chunk, I)
    dt = dt_ref[0].astype(acc_dtype)
    bm = b_ref[0].astype(acc_dtype)                # (chunk, N)
    cm = c_ref[0].astype(acc_dtype)
    dskip = dskip_ref[...].astype(acc_dtype)       # (1, I)

    def step(t, carry):
        h, y = carry
        a_bar = jnp.exp(dt[t][:, None] * decay)    # (I, N)
        h = a_bar * h + (dt[t] * x[t])[:, None] * bm[t][None, :]
        yt = (h * cm[t][None, :]).sum(axis=1)      # (I,)
        y = jax.lax.dynamic_update_slice_in_dim(y, yt[None], t, axis=0)
        return h, y

    y0 = jnp.zeros((chunk, x.shape[1]), acc_dtype)
    h, y = jax.lax.fori_loop(0, chunk, step, (h_ref[...], y0))
    h_ref[...] = h
    y_ref[0] = (y + dskip * x).astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def mamba_scan(x, dt, Bm, Cm, a, d_skip, *, chunk: int = 128,
               interpret: bool = False):
    """x/dt: (B, L, I); Bm/Cm: (B, L, N); a: (I, N); d_skip: (I,)."""
    b, l, inner = x.shape
    n = Bm.shape[-1]
    chunk = min(chunk, l)
    assert l % chunk == 0, (l, chunk)
    # state carried in at least fp32; f64 inputs keep full precision
    acc_dtype = jnp.promote_types(x.dtype, jnp.float32)
    kern = functools.partial(_scan_kernel, chunk=chunk,
                             acc_dtype=acc_dtype)
    return pl.pallas_call(
        kern,
        grid=(b, l // chunk),
        in_specs=[
            pl.BlockSpec((1, chunk, inner), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, chunk, inner), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, chunk, n), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, chunk, n), lambda i, j: (i, j, 0)),
            pl.BlockSpec((inner, n), lambda i, j: (0, 0)),
            pl.BlockSpec((1, inner), lambda i, j: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, inner), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct((b, l, inner), x.dtype),
        scratch_shapes=[pltpu.VMEM((inner, n), acc_dtype)],
        interpret=interpret,
    )(x, dt, Bm, Cm, a, d_skip.reshape(1, -1))
