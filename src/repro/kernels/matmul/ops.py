"""jit'd public wrapper for the blocked matmul kernel.

Dispatch is a profitability condition (paper §4.1): the Pallas kernel is
selected on TPU backends for MXU-aligned shapes; otherwise the jnp oracle
(which XLA lowers natively) runs. Padding handles ragged shapes.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .matmul import matmul as _matmul_kernel
from .ref import matmul_ref


def _pad_to(x, mult0, mult1):
    p0 = (-x.shape[0]) % mult0
    p1 = (-x.shape[1]) % mult1
    if p0 or p1:
        x = jnp.pad(x, ((0, p0), (0, p1)))
    return x


def matmul(x, y, *, bm: int = 128, bn: int = 128, bk: int = 512,
           force_pallas: bool = False, interpret: bool = False):
    """Matmul with kernel dispatch. On non-TPU backends the reference
    path runs unless ``force_pallas`` (tests use interpret=True)."""
    on_tpu = jax.default_backend() == "tpu"
    if not (on_tpu or force_pallas):
        return matmul_ref(x, y)
    m, k = x.shape
    _, n = y.shape
    bm_, bn_, bk_ = min(bm, m), min(bn, n), min(bk, k)
    xp = _pad_to(x, bm_, bk_)
    yp = _pad_to(y, bk_, bn_)
    out = _matmul_kernel(xp, yp, bm=bm_, bn=bn_, bk=bk_,
                         interpret=interpret or not on_tpu)
    return out[:m, :n]
