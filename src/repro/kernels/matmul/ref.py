"""Pure-jnp oracle for the blocked matmul kernel."""

from __future__ import annotations

import jax.numpy as jnp


def matmul_ref(x, y, out_dtype=None):
    out_dtype = out_dtype or x.dtype
    acc = jnp.promote_types(x.dtype, jnp.float32)
    return jnp.dot(x, y, preferred_element_type=acc).astype(out_dtype)
