"""Blocked MXU matmul: the accelerator variant of the compiler's raised
``np.dot`` (the paper's NumPy→CuPy conversion, re-targeted at TPU).

Grid (M/bm, N/bn, K/bk); K is the innermost (sequential) axis so the fp32
VMEM accumulator carries across K steps. Block sizes default to 128×128
tiles (MXU-aligned: the systolic array is 128×128) with bk=512 to amortize
HBM→VMEM transfers; VMEM footprint = bm·bk + bk·bn + 2·bm·bn fp32 ≤ ~1.6MB
at defaults, well under the 128 MiB v5e VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _matmul_kernel(x_ref, y_ref, o_ref, acc_ref, *, k_steps: int,
                   acc_dtype):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...], y_ref[...],
                            preferred_element_type=acc_dtype)

    @pl.when(pl.program_id(2) == k_steps - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk",
                                             "interpret"))
def matmul(x, y, *, bm: int = 128, bn: int = 128, bk: int = 512,
           interpret: bool = False):
    """x: (M, K), y: (K, N) → (M, N). Shapes must tile evenly (ops.py
    pads otherwise)."""
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, (x.shape, y.shape)
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, \
        (m, n, k, bm, bn, bk)
    k_steps = k // bk
    # accumulate in at least fp32; f64 inputs keep full precision
    acc_dtype = jnp.promote_types(x.dtype, jnp.float32)
    return pl.pallas_call(
        functools.partial(_matmul_kernel, k_steps=k_steps,
                          acc_dtype=acc_dtype),
        grid=(m // bm, n // bn, k_steps),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), acc_dtype)],
        interpret=interpret,
    )(x, y)
