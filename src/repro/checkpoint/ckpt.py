"""Sharded checkpointing with async save and reshard-on-restore.

Layout:  <dir>/step_<N>/
           manifest.json     — tree structure, shapes, dtypes, step
           <leaf_key>.npy    — one file per pytree leaf (full array; each
                               host writes only leaves it owns in a real
                               multi-host run — single-host here)

Properties delivered for the fault-tolerance story (DESIGN.md §5):
  * atomic publish: data written to step_<N>.tmp, renamed on completion —
    a crash mid-save never corrupts the latest checkpoint;
  * async save: the host thread snapshots device arrays then writes in the
    background, keeping the train loop running;
  * reshard-on-restore: restore() takes target shardings and device_puts
    each leaf accordingly — elastic re-scaling (e.g. 256→512 chips)
    restores the same checkpoint under a new mesh/plan.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _flatten_with_paths(tree) -> List[Tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(_path_str(p) for p in path)
        out.append((key, leaf))
    return out


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return str(p.idx)
    if hasattr(p, "name"):
        return str(p.name)
    return str(p)


def save(ckpt_dir: str, step: int, tree, extra: Optional[Dict] = None
         ) -> str:
    """Synchronous sharded save with atomic publish."""
    tmp = os.path.join(ckpt_dir, f"step_{step}.tmp")
    final = os.path.join(ckpt_dir, f"step_{step}")
    os.makedirs(tmp, exist_ok=True)
    leaves = _flatten_with_paths(tree)
    manifest = {"step": step, "extra": extra or {}, "leaves": {}}
    for key, leaf in leaves:
        arr = np.asarray(jax.device_get(leaf))
        fname = key.replace("/", "__") + ".npy"
        logical_dtype = str(arr.dtype)
        if logical_dtype not in ("float64", "float32", "float16", "int64",
                                 "int32", "int16", "int8", "uint8", "bool",
                                 "complex64", "complex128"):
            # ml_dtypes (bfloat16 …): store raw bits, record logical dtype
            arr = arr.view(np.uint8).reshape(arr.shape + (-1,)) \
                if arr.dtype.itemsize != 2 else arr.view(np.uint16)
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"][key] = {
            "file": fname,
            "shape": list(arr.shape),
            "dtype": logical_dtype,
        }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


class AsyncCheckpointer:
    """Snapshot on the caller thread, write on a background thread."""

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: Optional[threading.Thread] = None
        self.last_path: Optional[str] = None

    def save_async(self, step: int, tree, extra: Optional[Dict] = None):
        self.wait()  # one in flight at a time
        snapshot = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                tree)

        def work():
            self.last_path = save(self.ckpt_dir, step, snapshot, extra)
            self._gc()

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(available_steps(self.ckpt_dir))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.ckpt_dir, f"step_{s}"),
                          ignore_errors=True)


def available_steps(ckpt_dir: str) -> List[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith(".tmp"):
            try:
                out.append(int(name[len("step_"):]))
            except ValueError:
                continue
    return sorted(out)


def latest_step(ckpt_dir: str) -> Optional[int]:
    steps = available_steps(ckpt_dir)
    return steps[-1] if steps else None


def restore(ckpt_dir: str, step: int, like_tree,
            shardings=None) -> Tuple[Any, Dict]:
    """Restore into the structure of ``like_tree``. ``shardings`` (same
    structure or a single sharding) reshard leaves onto the current mesh —
    restoring a 256-chip checkpoint onto 512 chips just works."""
    path = os.path.join(ckpt_dir, f"step_{step}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    flat_like = _flatten_with_paths(like_tree)
    treedef = jax.tree_util.tree_structure(like_tree)
    shard_list: List[Any]
    if shardings is None:
        shard_list = [None] * len(flat_like)
    elif isinstance(shardings, (list, tuple)) or hasattr(
            shardings, "keys") or jax.tree_util.tree_structure(
            shardings) == treedef:
        shard_list = [s for _, s in _flatten_with_paths(shardings)]
    else:
        shard_list = [shardings] * len(flat_like)
    leaves = []
    for (key, like_leaf), shd in zip(flat_like, shard_list):
        info = manifest["leaves"][key]
        arr = np.load(os.path.join(path, info["file"]))
        if str(arr.dtype) != info["dtype"]:
            # raw-bit storage of an ml_dtypes array: view back
            import ml_dtypes  # noqa: F401

            arr = arr.view(np.dtype(info["dtype"]))
        want_dtype = like_leaf.dtype if hasattr(like_leaf, "dtype") else \
            arr.dtype
        if str(arr.dtype) != str(want_dtype):
            arr = jnp.asarray(arr).astype(want_dtype)
        if shd is not None:
            leaves.append(jax.device_put(arr, shd))
        else:
            leaves.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, leaves), manifest["extra"]
