"""Low-overhead span recorder: the tracing half of the obs plane.

Design constraints (ISSUE 6):

  * tracing is OFF by default — every entry point is a cheap flag check
    and the :func:`span` context manager degrades to a shared no-op, so
    serving loops pay nothing when dark;
  * a **bounded ring buffer** holds the events (a serving loop tracing
    forever must not grow head memory);
  * spans can cross threads: :func:`begin` returns a token that any
    thread may :func:`end` (the cluster head begins a chunk's in-flight
    span on the dispatch thread and ends it on the receive thread);
  * events from *other processes* (workers) enter via
    :meth:`SpanRecorder.record_external` with a clock offset — the head
    aligns per-worker monotonic clocks onto its own timeline.

Timestamps are ``time.perf_counter()`` seconds (monotonic, per
process). The Chrome-trace exporter re-bases them to microseconds from
the earliest recorded event.
"""

from __future__ import annotations

import os
import threading
import time
from collections import deque
from typing import Any, Dict, Iterable, List, Optional, Tuple

__all__ = ["SpanEvent", "SpanRecorder", "SpanToken"]

# Perfetto/chrome groups rows by (pid, tid). Head threads get small
# tids in registration order (main thread first); worker processes are
# offset so they sort below the head's threads on the same node row.
WORKER_TID_BASE = 100


class SpanEvent:
    """One completed span. Plain slots object — these are created on
    hot paths and held by the thousand in the ring."""

    __slots__ = ("name", "cat", "t0", "t1", "pid", "tid", "args")

    def __init__(self, name: str, cat: str, t0: float, t1: float,
                 pid: int, tid: int, args: Optional[Dict[str, Any]]):
        self.name = name
        self.cat = cat
        self.t0 = t0
        self.t1 = t1
        self.pid = pid
        self.tid = tid
        self.args = args

    @property
    def dur(self) -> float:
        return max(0.0, self.t1 - self.t0)

    def as_dict(self) -> Dict[str, Any]:
        return {"name": self.name, "cat": self.cat, "t0": self.t0,
                "t1": self.t1, "pid": self.pid, "tid": self.tid,
                "args": dict(self.args or {})}


class SpanToken:
    """Handle for a cross-thread span: created by ``begin`` on one
    thread, finished by ``end`` (possibly elsewhere). ``end`` is
    idempotent — a resubmitted task racing its own completion records
    the span once."""

    __slots__ = ("name", "cat", "t0", "tid", "args", "_done")

    def __init__(self, name: str, cat: str, t0: float, tid: int,
                 args: Optional[Dict[str, Any]]):
        self.name = name
        self.cat = cat
        self.t0 = t0
        self.tid = tid
        self.args = args
        self._done = False


class SpanRecorder:
    """Ring-buffered span store shared by one process."""

    def __init__(self, capacity: Optional[int] = None):
        if capacity is None:
            capacity = int(os.environ.get("REPRO_TRACE_CAPACITY",
                                          "65536"))
        self.capacity = max(16, capacity)
        self._ring: deque = deque(maxlen=self.capacity)
        self._lock = threading.Lock()
        self.dropped = 0          # events evicted by the ring bound
        self._tids: Dict[int, int] = {}       # thread ident → small tid
        self._tid_names: Dict[Tuple[int, int], str] = {}  # (pid,tid)→name
        self._pid_names: Dict[int, str] = {0: "node0"}

    # -- thread/track naming ------------------------------------------------
    def tid_for_current_thread(self) -> int:
        th = threading.current_thread()
        ident = th.ident or 0
        tid = self._tids.get(ident)
        if tid is None:
            with self._lock:
                tid = self._tids.setdefault(ident, len(self._tids))
                self._tid_names.setdefault((0, tid), f"head:{th.name}")
        return tid

    def name_track(self, pid: int, tid: int, name: str) -> None:
        with self._lock:
            self._tid_names[(pid, tid)] = name

    def name_node(self, pid: int, name: str) -> None:
        with self._lock:
            self._pid_names[pid] = name

    def track_names(self) -> Dict[Tuple[int, int], str]:
        with self._lock:
            return dict(self._tid_names)

    def node_names(self) -> Dict[int, str]:
        with self._lock:
            return dict(self._pid_names)

    # -- recording ----------------------------------------------------------
    def record(self, name: str, cat: str, t0: float, t1: float,
               pid: int = 0, tid: Optional[int] = None,
               args: Optional[Dict[str, Any]] = None) -> None:
        if tid is None:
            tid = self.tid_for_current_thread()
        ev = SpanEvent(name, cat, t0, t1, pid, tid, args)
        with self._lock:
            if len(self._ring) == self.capacity:
                self.dropped += 1
            self._ring.append(ev)

    def begin(self, name: str, cat: str,
              args: Optional[Dict[str, Any]] = None,
              tid: Optional[int] = None) -> SpanToken:
        if tid is None:
            tid = self.tid_for_current_thread()
        return SpanToken(name, cat, time.perf_counter(), tid, args)

    def end(self, token: Optional[SpanToken],
            extra: Optional[Dict[str, Any]] = None) -> None:
        if token is None or token._done:
            return
        token._done = True
        args = token.args
        if extra:
            args = dict(args or {})
            args.update(extra)
        self.record(token.name, token.cat, token.t0,
                    time.perf_counter(), tid=token.tid, args=args)

    def record_external(self, spans: Iterable[tuple], *, offset: float,
                        pid: int, tid: int,
                        base_args: Optional[Dict[str, Any]] = None
                        ) -> float:
        """Ingest spans measured on another process's monotonic clock.

        ``spans`` are ``(name, t0, t1[, args])`` tuples in the remote
        clock; ``offset`` maps remote → local time (``local = remote +
        offset``). Returns the total busy seconds ingested (consumers
        accumulate per-worker utilization from it)."""
        busy = 0.0
        for entry in spans:
            name, t0, t1 = entry[0], entry[1], entry[2]
            args = dict(entry[3]) if len(entry) > 3 and entry[3] else {}
            if base_args:
                args.update(base_args)
            self.record(name, "worker", t0 + offset, t1 + offset,
                        pid=pid, tid=tid, args=args or None)
            busy += max(0.0, t1 - t0)
        return busy

    # -- access -------------------------------------------------------------
    def events(self) -> List[SpanEvent]:
        with self._lock:
            return list(self._ring)

    def __len__(self) -> int:
        with self._lock:
            return len(self._ring)

    def clear(self) -> None:
        with self._lock:
            self._ring.clear()
            self.dropped = 0
