"""Trace analyzer: ``python -m repro.obs.summarize trace.json``.

Reads a Chrome-trace JSON produced by :func:`repro.obs.
export_chrome_trace` and renders the text instrument panel:

  * compile-pipeline stage totals per kernel (parse → SCoP →
    dependence → schedule → fusion → codegen → cache-store);
  * per-phase head totals across all pfor rounds (plan / split /
    dispatch / ship / gather / merge) with their share of round wall;
  * per-worker utilization — busy vs idle % over the traced rounds,
    split by span kind (run / restore / diff / deserialize);
  * the **critical path of each pfor round**: the head phase chain,
    descending into the last-finishing chunk (the one that gated the
    gather) and its worker-side breakdown;
  * a direct dominant-phase statement, e.g.
    ``gather on head = 61% of round wall``.

``--json`` emits the same summary machine-readable (CI asserts on it).
Exit status: 0 on success, 2 on a malformed/unreadable trace.
"""

from __future__ import annotations

import argparse
import json
import sys
from collections import defaultdict
from typing import Any, Dict, List, Optional

HEAD_PHASES = ("plan", "split", "dispatch", "ship", "gather", "merge")
WORKER_KINDS = ("deserialize", "restore", "run", "diff")


def _dur_s(ev: Dict[str, Any]) -> float:
    return float(ev.get("dur", 0.0)) / 1e6


def _args(ev: Dict[str, Any]) -> Dict[str, Any]:
    return ev.get("args") or {}


def load_events(path: str) -> List[Dict[str, Any]]:
    with open(path) as f:
        doc = json.load(f)
    events = doc["traceEvents"] if isinstance(doc, dict) else doc
    if not isinstance(events, list):
        raise ValueError("traceEvents is not a list")
    return [ev for ev in events if ev.get("ph") == "X"]


def summarize(events: List[Dict[str, Any]]) -> Dict[str, Any]:
    rounds = [ev for ev in events if ev["name"] == "pfor_round"]
    phase_evs = [ev for ev in events if ev.get("cat") == "pfor"
                 and ev["name"] in HEAD_PHASES]
    chunk_evs = [ev for ev in events if ev["name"] == "chunk_inflight"]
    worker_evs = [ev for ev in events if ev.get("cat") == "worker"]
    compile_evs = [ev for ev in events if ev.get("cat") == "compile"]

    out: Dict[str, Any] = {}

    # -- compile pipeline ---------------------------------------------------
    compile_stages: Dict[str, Dict[str, float]] = defaultdict(
        lambda: defaultdict(float))
    for ev in compile_evs:
        kernel = _args(ev).get("kernel", "?")
        compile_stages[kernel][ev["name"]] += _dur_s(ev)
    out["compile"] = {k: dict(v) for k, v in compile_stages.items()}

    # -- head phase totals --------------------------------------------------
    round_wall = sum(_dur_s(ev) for ev in rounds)
    phases: Dict[str, float] = defaultdict(float)
    for ev in phase_evs:
        phases[ev["name"]] += _dur_s(ev)
    out["rounds_traced"] = len(rounds)
    out["round_wall_s"] = round(round_wall, 6)
    out["phases"] = {
        name: {"total_s": round(total, 6),
               "share_of_round_wall": (round(total / round_wall, 4)
                                       if round_wall > 0 else None)}
        for name, total in sorted(phases.items(),
                                  key=lambda kv: -kv[1])}

    # -- per-worker utilization --------------------------------------------
    workers: Dict[str, Dict[str, Any]] = {}
    for ev in worker_evs:
        wid = _args(ev).get("wid")
        key = f"w{wid}" if wid is not None else \
            f"pid{ev['pid']}.tid{ev['tid']}"
        w = workers.setdefault(key, {"busy_s": 0.0, "spans": 0,
                                     **{f"{k}_s": 0.0
                                        for k in WORKER_KINDS},
                                     "run_spans": 0})
        d = _dur_s(ev)
        w["busy_s"] += d
        w["spans"] += 1
        if ev["name"] in WORKER_KINDS:
            w[f"{ev['name']}_s"] += d
        if ev["name"] == "run":
            w["run_spans"] += 1
    for w in workers.values():
        for k in list(w):
            if k.endswith("_s"):
                w[k] = round(w[k], 6)
        if round_wall > 0:
            w["busy_pct"] = round(100.0 * w["busy_s"] / round_wall, 1)
            w["idle_pct"] = round(100.0 - min(100.0, w["busy_pct"]), 1)
    out["workers"] = dict(sorted(workers.items()))

    # -- critical path per round -------------------------------------------
    crits: List[Dict[str, Any]] = []
    for ev in sorted(rounds, key=lambda e: _args(e).get("round", 0)):
        rid = _args(ev).get("round")
        wall = _dur_s(ev)
        if wall <= 0:
            continue
        rp = {p["name"]: _dur_s(p) for p in phase_evs
              if _args(p).get("round") == rid}
        chunks = [c for c in chunk_evs if _args(c).get("round") == rid]
        crit: Dict[str, Any] = {
            "round": rid, "unit": _args(ev).get("unit"),
            "wall_s": round(wall, 6),
            "phases_pct": {n: round(100.0 * d / wall, 1)
                           for n, d in sorted(rp.items(),
                                              key=lambda kv: -kv[1])},
        }
        if chunks:
            # the chunk that finished last gated the gather: descend
            # into its worker spans for the path below the head
            last = max(chunks, key=lambda c: c["ts"] + c["dur"])
            la = _args(last)
            wspans = [w for w in worker_evs
                      if _args(w).get("task") == la.get("task")]
            on_worker = sum(_dur_s(w) for w in wspans)
            crit["gating_chunk"] = {
                "task": la.get("task"), "lo": la.get("lo"),
                "hi": la.get("hi"), "wid": la.get("wid"),
                "backend": la.get("backend"),
                "inflight_s": round(_dur_s(last), 6),
                "inflight_pct_of_wall": round(
                    100.0 * _dur_s(last) / wall, 1),
                "on_worker": {w["name"]: round(_dur_s(w), 6)
                              for w in wspans},
                "queue_ship_wait_s": round(
                    max(0.0, _dur_s(last) - on_worker), 6),
            }
        crits.append(crit)
    out["critical_paths"] = crits

    # -- dominant phase -----------------------------------------------------
    if phases and round_wall > 0:
        name, total = max(phases.items(), key=lambda kv: kv[1])
        out["dominant"] = {
            "phase": name, "total_s": round(total, 6),
            "pct_of_round_wall": round(100.0 * total / round_wall, 1),
            "statement": (f"{name} on head = "
                          f"{100.0 * total / round_wall:.0f}% of round "
                          f"wall ({len(rounds)} round(s) traced)"),
        }
    return out


def render(s: Dict[str, Any]) -> str:
    lines: List[str] = []
    if s["compile"]:
        lines.append("== compile pipeline ==")
        for kernel, stages in s["compile"].items():
            stage_txt = " | ".join(
                f"{n} {d * 1e3:.1f}ms"
                for n, d in sorted(stages.items(), key=lambda kv: -kv[1]))
            lines.append(f"  {kernel}: {stage_txt}")
    lines.append(f"== head phases ({s['rounds_traced']} pfor round(s), "
                 f"wall {s['round_wall_s'] * 1e3:.1f}ms) ==")
    for name, row in s["phases"].items():
        share = row["share_of_round_wall"]
        pct = f"{share * 100:.1f}%" if share is not None else "n/a"
        lines.append(f"  {name:<9} {row['total_s'] * 1e3:9.2f}ms  "
                     f"{pct:>6} of round wall")
    lines.append("== workers ==")
    for key, w in s["workers"].items():
        util = (f"busy {w.get('busy_pct', 0.0):.1f}% / "
                f"idle {w.get('idle_pct', 0.0):.1f}%"
                if "busy_pct" in w else f"busy {w['busy_s'] * 1e3:.1f}ms")
        lines.append(
            f"  {key:<6} {util}  "
            f"(run {w['run_spans']}x {w['run_s'] * 1e3:.1f}ms, "
            f"restore {w['restore_s'] * 1e3:.1f}ms, "
            f"diff {w['diff_s'] * 1e3:.1f}ms)")
    if s["critical_paths"]:
        lines.append("== critical path per round ==")
        for c in s["critical_paths"]:
            phase_txt = " -> ".join(f"{n} {p:.0f}%"
                                    for n, p in c["phases_pct"].items())
            lines.append(f"  round {c['round']} "
                         f"({c['wall_s'] * 1e3:.1f}ms): {phase_txt}")
            g = c.get("gating_chunk")
            if g:
                on_w = ", ".join(f"{n} {d * 1e3:.1f}ms"
                                 for n, d in g["on_worker"].items())
                lines.append(
                    f"    gated by chunk [{g['lo']},{g['hi']}) on "
                    f"w{g['wid']} ({g['backend']}): in-flight "
                    f"{g['inflight_pct_of_wall']:.0f}% of wall — "
                    f"{on_w or 'no worker spans'}; queue/ship wait "
                    f"{g['queue_ship_wait_s'] * 1e3:.1f}ms")
    if "dominant" in s:
        lines.append(f"== diagnosis ==")
        lines.append(f"  {s['dominant']['statement']}")
    return "\n".join(lines)


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.summarize",
        description="Summarize a repro.obs Chrome trace")
    ap.add_argument("trace", help="trace JSON path")
    ap.add_argument("--json", action="store_true",
                    help="emit the summary as JSON")
    args = ap.parse_args(argv)
    try:
        events = load_events(args.trace)
        s = summarize(events)
    except (OSError, ValueError, KeyError, TypeError) as exc:
        print(f"summarize: bad trace {args.trace!r}: {exc}",
              file=sys.stderr)
        return 2
    if args.json:
        print(json.dumps(s, indent=1))
    else:
        print(render(s))
    return 0


if __name__ == "__main__":
    sys.exit(main())
