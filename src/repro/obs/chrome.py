"""Chrome-trace / Perfetto JSON export.

Emits the classic ``{"traceEvents": [...]}`` array format (loadable in
``chrome://tracing`` and https://ui.perfetto.dev): one complete-``X``
event per recorded span plus ``M`` metadata events naming the tracks.
Convention (ISSUE 6): **pid = node**, **tid = worker / head thread** —
head threads occupy small tids, worker processes sit at
``100 + wid`` on the node that hosts them, so one aligned timeline
shows head phases above the per-chunk worker spans they dispatched.
"""

from __future__ import annotations

import json
from typing import Any, Dict, List

from .spans import SpanRecorder

__all__ = ["chrome_trace_events", "export_chrome_trace"]


def chrome_trace_events(rec: SpanRecorder) -> List[Dict[str, Any]]:
    events = rec.events()
    out: List[Dict[str, Any]] = []
    for pid, name in sorted(rec.node_names().items()):
        out.append({"ph": "M", "name": "process_name", "pid": pid,
                    "tid": 0, "args": {"name": name}})
    for (pid, tid), name in sorted(rec.track_names().items()):
        out.append({"ph": "M", "name": "thread_name", "pid": pid,
                    "tid": tid, "args": {"name": name}})
        # sort_index keeps head threads above workers within a node
        out.append({"ph": "M", "name": "thread_sort_index", "pid": pid,
                    "tid": tid, "args": {"sort_index": tid}})
    if not events:
        return out
    epoch = min(ev.t0 for ev in events)
    for ev in events:
        entry: Dict[str, Any] = {
            "ph": "X", "name": ev.name, "cat": ev.cat,
            "ts": round((ev.t0 - epoch) * 1e6, 3),
            "dur": round(ev.dur * 1e6, 3),
            "pid": ev.pid, "tid": ev.tid,
        }
        if ev.args:
            entry["args"] = ev.args
        out.append(entry)
    return out


def export_chrome_trace(rec: SpanRecorder, path: str,
                        extra_meta: Dict[str, Any] = None) -> str:
    doc: Dict[str, Any] = {
        "traceEvents": chrome_trace_events(rec),
        "displayTimeUnit": "ms",
    }
    meta = {"recorder_capacity": rec.capacity, "dropped": rec.dropped}
    if extra_meta:
        meta.update(extra_meta)
    doc["otherData"] = meta
    with open(path, "w") as f:
        json.dump(doc, f)
    return path
