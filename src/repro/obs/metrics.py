"""Unified metrics registry: one process-wide store behind every
telemetry surface.

Before this module each subsystem kept its own ad-hoc counter dict —
``ClusterRuntime.stats()/telemetry()``, ``CompiledKernel.stats()``,
``ServeEngine.telemetry()`` — with no way to ask "everything, now" or
to alias a counter into a bench row. The registry is their **single
backing store**: the legacy attributes/keys still read and write the
same names (via registry-backed descriptors), so existing callers and
tests see identical values, while :func:`MetricsRegistry.snapshot`
exposes the union under stable dotted names
(``cluster0.phase.gather_s``, ``kernel.stap#1.spec_hits``, …).

Metric kinds:
  * :class:`Counter` — monotonically-ish increasing number (``inc``;
    ``set`` exists because legacy code assigns zeros / test fixtures
    reset counters);
  * :class:`Gauge` — last-write-wins value;
  * :class:`Histogram` — count/total/min/max plus a bounded reservoir
    for percentiles;
  * :class:`DictMetric` — a ``dict`` subclass registered under a name,
    for structured legacy telemetry (``unit_backend``,
    ``chunks_executed``) that must keep full mapping semantics.

All mutation goes through a single registry lock; these are telemetry
paths, not inner loops.
"""

from __future__ import annotations

import threading
from collections import deque
from typing import Any, Callable, Dict, Optional

__all__ = ["Counter", "Gauge", "Histogram", "DictMetric", "Scope",
           "MetricsRegistry", "registry"]

_LOCK = threading.Lock()


class Counter:
    __slots__ = ("value",)

    kind = "counter"

    def __init__(self, value: float = 0):
        self.value = value

    def inc(self, n: float = 1) -> None:
        with _LOCK:
            self.value += n

    def set(self, v: float) -> None:
        self.value = v

    def snapshot(self):
        return self.value


class Gauge:
    __slots__ = ("value",)

    kind = "gauge"

    def __init__(self, value: float = 0):
        self.value = value

    def set(self, v: float) -> None:
        self.value = v

    def snapshot(self):
        return self.value


class Histogram:
    """Bounded-reservoir histogram: exact count/total/min/max, recent
    window for percentiles."""

    kind = "histogram"

    def __init__(self, window: int = 512):
        self.count = 0
        self.total = 0.0
        self.min: Optional[float] = None
        self.max: Optional[float] = None
        self._window: deque = deque(maxlen=window)

    def observe(self, v: float) -> None:
        with _LOCK:
            self.count += 1
            self.total += v
            self.min = v if self.min is None else min(self.min, v)
            self.max = v if self.max is None else max(self.max, v)
            self._window.append(v)

    def percentile(self, q: float) -> Optional[float]:
        with _LOCK:
            window = sorted(self._window)
        if not window:
            return None
        idx = min(len(window) - 1, int(q / 100.0 * len(window)))
        return window[idx]

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def snapshot(self):
        return {"count": self.count, "total": round(self.total, 9),
                "mean": round(self.mean, 9), "min": self.min,
                "max": self.max, "p50": self.percentile(50),
                "p95": self.percentile(95), "p99": self.percentile(99)}


class DictMetric(dict):
    """A dict that *is* the registry entry — structured legacy
    telemetry keeps its mapping API while living in the store."""

    kind = "dict"

    def snapshot(self):
        return {k: (dict(v) if isinstance(v, dict) else v)
                for k, v in self.items()}


class Scope:
    """Namespace view over the registry (``prefix.name`` keys)."""

    def __init__(self, reg: "MetricsRegistry", prefix: str):
        self._reg = reg
        self.prefix = prefix

    def _full(self, name: str) -> str:
        return f"{self.prefix}.{name}" if self.prefix else name

    def counter(self, name: str) -> Counter:
        return self._reg._get_or_create(self._full(name), Counter)

    def gauge(self, name: str) -> Gauge:
        return self._reg._get_or_create(self._full(name), Gauge)

    def histogram(self, name: str) -> Histogram:
        return self._reg._get_or_create(self._full(name), Histogram)

    def dictmetric(self, name: str) -> DictMetric:
        return self._reg._get_or_create(self._full(name), DictMetric)

    def __getitem__(self, name: str) -> Counter:
        return self.counter(name)

    def inc(self, name: str, n: float = 1) -> None:
        self.counter(name).inc(n)

    def add_time(self, name: str, seconds: float) -> None:
        """Accumulate a duration counter (``*_s`` convention)."""
        self.counter(name).inc(seconds)

    def sub(self, name: str) -> "Scope":
        return Scope(self._reg, self._full(name))

    def snapshot(self) -> Dict[str, Any]:
        return self._reg.snapshot(self.prefix)

    def reset(self) -> None:
        self._reg.reset(self.prefix)


class MetricsRegistry:
    def __init__(self):
        self._metrics: Dict[str, Any] = {}
        self._seq: Dict[str, int] = {}

    def _get_or_create(self, full: str, cls: Callable):
        m = self._metrics.get(full)
        if m is None:
            with _LOCK:
                m = self._metrics.get(full)
                if m is None:
                    m = cls()
                    self._metrics[full] = m
        if not isinstance(m, cls):
            raise TypeError(
                f"metric {full!r} already registered as "
                f"{type(m).__name__}, not {cls.__name__}")
        return m

    def scope(self, prefix: str) -> Scope:
        return Scope(self, prefix)

    def unique_scope(self, kind: str) -> Scope:
        """``kind#N`` scope with a process-unique suffix — one per
        runtime/kernel/engine instance."""
        with _LOCK:
            n = self._seq.get(kind, 0)
            self._seq[kind] = n + 1
        return Scope(self, f"{kind}#{n}")

    def get(self, full: str):
        return self._metrics.get(full)

    def names(self, prefix: str = "") -> list:
        return sorted(k for k in self._metrics
                      if not prefix or k == prefix
                      or k.startswith(prefix + "."))

    def snapshot(self, prefix: str = "") -> Dict[str, Any]:
        """Flat ``{name: value}`` view. With ``prefix``, keys are
        relative to it (``cluster0.phase`` → ``{"gather_s": ...}``)."""
        out: Dict[str, Any] = {}
        for name in self.names(prefix):
            key = name[len(prefix) + 1:] if prefix else name
            out[key or name] = self._metrics[name].snapshot()
        return out

    def reset(self, prefix: str = "") -> None:
        """Zero counters/gauges and clear dicts under ``prefix``
        (metric objects stay registered — live references held by
        subsystems keep working)."""
        for name in self.names(prefix):
            m = self._metrics[name]
            if isinstance(m, (Counter, Gauge)):
                m.set(0)
            elif isinstance(m, DictMetric):
                m.clear()
            elif isinstance(m, Histogram):
                m.__init__(window=m._window.maxlen or 512)


registry = MetricsRegistry()


class MetricAttr:
    """Class descriptor exposing a scoped registry counter as a plain
    numeric attribute, so legacy ``self.blob_hits += 1`` call sites and
    ``rt.blob_hits`` readers keep working verbatim while the value
    lives in the registry. Instances normally set their ``_mscope`` in
    ``__init__``; an instance without one (e.g. built via ``__new__``
    in tests) gets a unique scope lazily on first access."""

    def __init__(self, name: str):
        self.name = name

    @staticmethod
    def _scope_of(obj) -> Scope:
        sc = getattr(obj, "_mscope", None)
        if sc is None:
            sc = registry.unique_scope(type(obj).__name__.lower())
            obj._mscope = sc
        return sc

    def __get__(self, obj, objtype=None):
        if obj is None:
            return self
        return self._scope_of(obj).counter(self.name).value

    def __set__(self, obj, value):
        self._scope_of(obj).counter(self.name).set(value)
