"""repro.obs — distributed tracing + unified metrics plane.

One import point for the whole instrument panel:

    from repro import obs

    obs.enable()                        # or REPRO_TRACE=1 / optimize(trace=True)
    with obs.span("gather", cat="pfor", round=3):
        ...
    tok = obs.begin("chunk_inflight", cat="pfor", tid=...)  # cross-thread
    obs.end(tok)                        # any thread, idempotent

    obs.metrics.scope("cluster0").inc("blob_hits")
    obs.export_chrome_trace("trace.json")     # Perfetto-loadable
    # python -m repro.obs.summarize trace.json  → text breakdown

Tracing is **off by default**: ``span``/``begin``/``end`` cost one flag
check when dark (a shared no-op context manager, no allocation). The
metrics registry is always live — it is the single backing store behind
``ClusterRuntime.stats()``, ``CompiledKernel.stats()`` and
``ServeEngine.telemetry()`` — because counters are how those surfaces
already work; only the *timeline* recording is gated.
"""

from __future__ import annotations

import os
import time
from typing import Any, Dict, Optional

from . import chrome as _chrome
from .metrics import MetricAttr, registry as metrics  # noqa: F401
from .spans import WORKER_TID_BASE, SpanRecorder, SpanToken  # noqa: F401

__all__ = ["enabled", "enable", "disable", "span", "begin", "end",
           "recorder", "export_chrome_trace", "metrics", "MetricAttr",
           "worker_tid", "WORKER_TID_BASE"]

_enabled = os.environ.get("REPRO_TRACE", "") not in ("", "0", "false")
_recorder = SpanRecorder()


def enabled() -> bool:
    return _enabled


def enable(capacity: Optional[int] = None) -> None:
    """Turn span recording on (idempotent). ``capacity`` resizes the
    ring buffer (only when it changes — enabling mid-run never drops
    what was already recorded)."""
    global _enabled, _recorder
    if capacity is not None and capacity != _recorder.capacity:
        _recorder = SpanRecorder(capacity)
    _enabled = True


def disable() -> None:
    global _enabled
    _enabled = False


def recorder() -> SpanRecorder:
    return _recorder


class _NullSpan:
    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL = _NullSpan()


class _Span:
    __slots__ = ("name", "cat", "args", "t0")

    def __init__(self, name: str, cat: str, args: Optional[Dict]):
        self.name = name
        self.cat = cat
        self.args = args
        self.t0 = 0.0

    def __enter__(self):
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc):
        _recorder.record(self.name, self.cat, self.t0,
                         time.perf_counter(), args=self.args)
        return False


def span(name: str, cat: str = "app", **args: Any):
    """Context manager recording one span on the current thread.
    A no-op singleton when tracing is off."""
    if not _enabled:
        return _NULL
    return _Span(name, cat, args or None)


def begin(name: str, cat: str = "app",
          **args: Any) -> Optional[SpanToken]:
    """Start a cross-thread span; returns a token (or None when
    tracing is off) that any thread passes to :func:`end`."""
    if not _enabled:
        return None
    return _recorder.begin(name, cat, args or None)


def end(token: Optional[SpanToken],
        **extra: Any) -> None:
    if token is None:
        return
    _recorder.end(token, extra or None)


def worker_tid(wid: int) -> int:
    """Track id for worker ``wid`` on its node (head threads keep the
    small tids)."""
    return WORKER_TID_BASE + wid


def export_chrome_trace(path: str,
                        extra_meta: Optional[Dict[str, Any]] = None
                        ) -> str:
    """Write the recorded spans as Perfetto/chrome://tracing JSON."""
    return _chrome.export_chrome_trace(_recorder, path, extra_meta)
