import os
import sys

# Tests see the real single CPU device (the 512-device override is local
# to launch/dryrun.py, per the multi-pod dry-run contract).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
