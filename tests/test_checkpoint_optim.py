"""Checkpoint roundtrip/reshard/async + optimizer + compression tests."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt as C
from repro.train.grad_compress import (TopKState, compress_int8,
                                       decompress_int8, init_topk_state,
                                       roundtrip_int8, topk_roundtrip)
from repro.train.optimizer import (AdamWConfig, adamw_update,
                                   init_opt_state, opt_state_bytes)


def _tree():
    return {"a": jnp.arange(12.0).reshape(3, 4),
            "b": {"c": jnp.ones((5,), jnp.bfloat16),
                  "d": jnp.zeros((2, 2), jnp.int32)}}


def test_checkpoint_roundtrip():
    tree = _tree()
    with tempfile.TemporaryDirectory() as d:
        C.save(d, 7, tree, extra={"step": 7})
        like = jax.tree.map(jnp.zeros_like, tree)
        got, extra = C.restore(d, 7, like)
        assert extra == {"step": 7}
        for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(tree)):
            assert a.dtype == b.dtype
            np.testing.assert_array_equal(np.asarray(a, np.float32),
                                          np.asarray(b, np.float32))


def test_checkpoint_atomic_and_gc():
    tree = _tree()
    with tempfile.TemporaryDirectory() as d:
        ac = C.AsyncCheckpointer(d, keep=2)
        for s in (1, 2, 3, 4):
            ac.save_async(s, tree)
        ac.wait()
        assert C.available_steps(d) == [3, 4]
        assert C.latest_step(d) == 4
        assert not any(n.endswith(".tmp") for n in os.listdir(d))


def test_checkpoint_reshard_on_restore():
    """Restore with explicit shardings (device_put path)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    mesh = jax.make_mesh((1,), ("data",))
    tree = {"w": jnp.arange(8.0)}
    with tempfile.TemporaryDirectory() as d:
        C.save(d, 0, tree)
        sh = {"w": NamedSharding(mesh, P("data"))}
        got, _ = C.restore(d, 0, jax.tree.map(jnp.zeros_like, tree),
                           shardings=sh)
        assert got["w"].sharding == sh["w"]
        np.testing.assert_array_equal(np.asarray(got["w"]),
                                      np.arange(8.0))


def test_adamw_converges_quadratic():
    p = {"w": jnp.full((4, 4), 5.0)}
    cfg = AdamWConfig(lr=0.3, weight_decay=0.0, grad_clip=0)
    st = init_opt_state(p, cfg)
    for _ in range(50):
        g = jax.tree.map(lambda w: 2 * w, p)
        p, st = adamw_update(p, g, st, cfg)
    assert float(jnp.abs(p["w"]).max()) < 0.5


def test_adamw_8bit_tracks_fp32():
    p32 = {"w": jnp.full((16, 16), 3.0)}
    p8 = {"w": jnp.full((16, 16), 3.0)}
    c32 = AdamWConfig(lr=0.1, weight_decay=0.0, quantize_moments=False)
    c8 = AdamWConfig(lr=0.1, weight_decay=0.0, quantize_moments=True)
    s32, s8 = init_opt_state(p32, c32), init_opt_state(p8, c8)
    for _ in range(20):
        g32 = jax.tree.map(lambda w: 2 * w, p32)
        g8 = jax.tree.map(lambda w: 2 * w, p8)
        p32, s32 = adamw_update(p32, g32, s32, c32)
        p8, s8 = adamw_update(p8, g8, s8, c8)
    # same direction of travel, bounded divergence
    assert float(jnp.abs(p8["w"] - p32["w"]).max()) < 0.5
    # and the memory claim: int8 moments ≈ 4× smaller
    assert opt_state_bytes(s8) < 0.45 * opt_state_bytes(s32)


def test_int8_compression_error_bounded():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(size=(64, 64)), jnp.float32)
    c = compress_int8(g)
    back = decompress_int8(c)
    scale = float(jnp.max(jnp.abs(g))) / 127.0
    assert float(jnp.abs(back - g).max()) <= scale * 0.51 + 1e-6


def test_int8_tree_roundtrip_shapes():
    tree = {"a": jnp.ones((3, 3)), "b": jnp.zeros((7,))}
    back = roundtrip_int8(tree)
    assert jax.tree.structure(back) == jax.tree.structure(tree)


def test_topk_error_feedback_accumulates():
    """With error feedback, repeated compression transmits everything
    eventually (residual → 0 for a constant gradient)."""
    g = {"w": jnp.asarray(np.linspace(-1, 1, 100).reshape(10, 10),
                          jnp.float32)}
    st = init_topk_state(g)
    sent_total = jax.tree.map(jnp.zeros_like, g)
    for _ in range(30):
        sent, st = topk_roundtrip(g, st, frac=0.1)
        sent_total = jax.tree.map(lambda a, b: a + b, sent_total, sent)
    # total transmitted ≈ 30 × g for the large entries; residual bounded
    assert float(jnp.abs(st.residual["w"]).max()) <= \
        float(jnp.abs(g["w"]).max()) * 10


def test_data_pipeline_determinism_and_sharding():
    from repro.data.pipeline import DataConfig, SyntheticTokens

    cfg0 = DataConfig(vocab=100, seq_len=8, global_batch=8, num_hosts=2,
                      host_id=0)
    cfg1 = DataConfig(vocab=100, seq_len=8, global_batch=8, num_hosts=2,
                      host_id=1)
    a = SyntheticTokens(cfg0).batch_at(3)
    b = SyntheticTokens(cfg0).batch_at(3)
    c = SyntheticTokens(cfg1).batch_at(3)
    np.testing.assert_array_equal(a["tokens"], b["tokens"])  # determinism
    assert not np.array_equal(a["tokens"], c["tokens"])      # host shards
    assert a["tokens"].shape == (4, 8)                        # B/hosts


def test_prefetcher_resumes_from_step():
    from repro.data.pipeline import DataConfig, SyntheticTokens, \
        make_pipeline

    cfg = DataConfig(vocab=64, seq_len=4, global_batch=2)
    src = SyntheticTokens(cfg)
    pf = make_pipeline(cfg, start_step=5)
    try:
        got = pf.next()
        np.testing.assert_array_equal(got["tokens"],
                                      src.batch_at(5)["tokens"])
    finally:
        pf.stop()
