"""Unit + property tests for the integer-affine core (isl_lite).

The property tests need hypothesis; when it is absent (tier-1 containers
ship without it) they are skipped and the deterministic smoke tests below
still run.
"""

import pytest

from repro.core.isl_lite import (Affine, Domain, LoopDim,
                                 affine_eq_may_hold, banerjee_test,
                                 gcd_test)

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False


if HAVE_HYPOTHESIS:
    names = st.sampled_from(["i", "j", "k", "M", "N"])
    coeffs = st.integers(-5, 5)

    @st.composite
    def affines(draw):
        n = draw(st.integers(0, 3))
        a = Affine.constant(draw(st.integers(-10, 10)))
        for _ in range(n):
            a = a + Affine.var(draw(names), draw(coeffs))
        return a

    @given(affines(), affines())
    @settings(max_examples=200, deadline=None)
    def test_add_commutes(a, b):
        assert (a + b).equals(b + a)

    @given(affines(), affines(), affines())
    @settings(max_examples=100, deadline=None)
    def test_add_associates(a, b, c):
        assert ((a + b) + c).equals(a + (b + c))

    @given(affines())
    @settings(max_examples=100, deadline=None)
    def test_sub_self_zero(a):
        assert (a - a).is_zero()

    @given(affines(), st.integers(-4, 4))
    @settings(max_examples=100, deadline=None)
    def test_scale_distributes(a, c):
        assert (a * c + a * (-c)).is_zero()

    @given(affines(), st.dictionaries(names, st.integers(-20, 20),
                                      min_size=5, max_size=5))
    @settings(max_examples=200, deadline=None)
    def test_evaluate_homomorphic(a, env):
        b = a + Affine.var("i", 2)
        assert b.evaluate(env) == a.evaluate(env) + 2 * env["i"]
else:
    def test_hypothesis_property_suite_skipped():
        pytest.importorskip("hypothesis")


def test_affine_algebra_smoke():
    """Deterministic slice of the property suite (no hypothesis needed)."""
    a = Affine.var("i", 2) + Affine.constant(3)
    b = Affine.var("j", -1) + Affine.var("i")
    assert (a + b).equals(b + a)
    assert ((a + b) + a).equals(a + (b + a))
    assert (a - a).is_zero()
    assert (a * 3 + a * (-3)).is_zero()
    env = {"i": 4, "j": -2}
    assert (a + Affine.var("i", 2)).evaluate(env) == \
        a.evaluate(env) + 2 * env["i"]


def test_gcd_test():
    # 2x + 4y = 3 has no integer solution
    assert not gcd_test([2, 4], 3)
    assert gcd_test([2, 4], 6)
    assert gcd_test([], 0)
    assert not gcd_test([], 1)


def test_banerjee_interval():
    # x - y = 100 with x,y in [0, 9]: impossible
    assert not banerjee_test([1, -1], -100, [(0, 9), (0, 9)])
    assert banerjee_test([1, -1], -5, [(0, 9), (0, 9)])


def test_affine_eq_may_hold_disjoint():
    i, j = Affine.var("i"), Affine.var("j")
    # i == j + 100 with both in [0, 9]: never
    assert not affine_eq_may_hold(i, j + 100,
                                  {"i": (0, 9), "j": (0, 9)})
    assert affine_eq_may_hold(i, j, {"i": (0, 9), "j": (0, 9)})


def test_domain_cardinality_triangular():
    M = 7
    dom = Domain((
        LoopDim("i", Affine.constant(0), Affine.constant(M)),
        LoopDim("j", Affine.var("i") + 1, Affine.constant(M)),
    ))
    # sum_{i<M} (M - i - 1) = M(M-1)/2
    assert dom.cardinality({}) == M * (M - 1) // 2


def test_domain_rectangular_flag():
    d1 = Domain((LoopDim("i", Affine.constant(0), Affine.var("M")),))
    assert d1.is_rectangular()
    d2 = Domain((
        LoopDim("i", Affine.constant(0), Affine.var("M")),
        LoopDim("j", Affine.var("i"), Affine.var("M")),
    ))
    assert not d2.is_rectangular()
    assert d2.triangular_pairs() == [("i", "j", 0)]
