"""Fusion pass tests: legality (illegal fusions rejected), numerical
equivalence of fused vs unfused schedules on randomized inputs, array
contraction, the backend cost-gate profiles, and the satellite features
that ride with the pass (bucket dispatch, threshold calibration, cache
pruning)."""

import os
import time

import numpy as np
import pytest

from benchmarks.fusion_chains import CHAINS
from benchmarks.polybench_kernels import KERNELS, clone_args, to_lists
from repro.core import codegen, cost, parser, schedule, scop
from repro.core.compiler import compile_kernel, optimize
from repro.core.isl_lite import Affine, LoopDim
from repro.core.schedule import RaisedUnit, SeqLoopUnit
from repro.profiler.cache import CacheEntry, VariantCache


# ---------------------------------------------------------------------------
# helpers
# ---------------------------------------------------------------------------

def _units(fn, fuse=True, profile="functional"):
    tir_fn = parser.parse_function(fn)
    return schedule.schedule(scop.extract(tir_fn), fuse=fuse,
                             fusion_profile=profile)


def _assert_variants_identical(fn, make_args, out_idx, n=17, seeds=(0, 1, 2),
                               backends=("np",)):
    """Fused and unfused compilations must agree bit-for-bit."""
    ck_f = compile_kernel(fn, fuse=True)
    ck_u = compile_kernel(fn, fuse=False)
    for seed in seeds:
        rng = np.random.default_rng(seed)
        args, _ = make_args(n, rng)
        for backend in backends:
            if backend not in ck_f.variants or backend not in ck_u.variants:
                continue
            a_f, a_u = clone_args(args), clone_args(args)
            ck_f.call_variant(backend, *a_f)
            ck_u.call_variant(backend, *a_u)
            for oi in out_idx:
                np.testing.assert_array_equal(
                    np.asarray(a_f[oi]), np.asarray(a_u[oi]),
                    err_msg=f"{fn.__name__} backend={backend} seed={seed}")


# ---------------------------------------------------------------------------
# same-array flow fusion
# ---------------------------------------------------------------------------

def test_gemm_list_fuses_to_single_statement():
    sched = _units(KERNELS["gemm"]["list"])
    raised = [u for u in sched.units if isinstance(u, RaisedUnit)]
    assert len(raised) == 1
    assert sched.fusion.fused_units == 1
    # the fused statement is exactly the hand-written NumPy form
    src = codegen.generate(sched, "np").source
    assert "*=" not in src and "+=" not in src


def test_inplace_profile_keeps_aug_statements():
    # on the np profile `C *= beta; C += …` stays distributed (in-place
    # library calls beat an expression + slice store)…
    sched = _units(KERNELS["gemm"]["list"], profile="inplace")
    raised = [u for u in sched.units if isinstance(u, RaisedUnit)]
    assert len(raised) == 2
    # …but a constant fill still folds: tmp = 0; tmp += dot → tmp = dot
    sched2 = _units(KERNELS["2mm"]["list"], profile="inplace")
    assert sched2.fusion.fused_units == 1


def test_fusion_polybench_chains_bit_identical():
    for name in ("gemm", "2mm", "3mm", "atax", "bicg", "gesummv"):
        k = KERNELS[name]
        _assert_variants_identical(k["list"], k["make_args"], k_out(name),
                                   backends=("np", "jnp"))


def k_out(name):
    rng = np.random.default_rng(0)
    _, meta = KERNELS[name]["make_args"](4, rng)
    return meta["out"]


def test_fusion_chain_kernels_bit_identical():
    for name, k in CHAINS.items():
        rng = np.random.default_rng(0)
        _, meta = k["make_args"](8, rng)
        _assert_variants_identical(k["np"], k["make_args"], meta["out"],
                                   backends=("np", "jnp"))


def test_fused_matches_reference():
    for name, k in CHAINS.items():
        rng = np.random.default_rng(42)
        args, meta = k["make_args"](12, rng)
        ref_args = clone_args(args)
        k["ref"](*ref_args)
        ck = compile_kernel(k["np"], fuse=True)
        got = clone_args(args)
        ck.call_variant("np", *got)
        for oi in meta["out"]:
            np.testing.assert_allclose(np.asarray(got[oi]),
                                       np.asarray(ref_args[oi]),
                                       atol=1e-10, err_msg=name)


# ---------------------------------------------------------------------------
# array contraction
# ---------------------------------------------------------------------------

def test_contraction_eliminates_local_temp():
    k = CHAINS["smooth"]
    ck = compile_kernel(k["np"], fuse=True)
    src = ck.source("np")
    assert "T" not in [ln.split(" =")[0].strip()
                       for ln in src.splitlines()]
    meta = ck.variants["np"].generated.meta
    assert "T" in meta.contracted_arrays


def test_contraction_inside_loop_body():
    k = CHAINS["doitgen_local"]
    ck = compile_kernel(k["np"], fuse=True)
    assert "w" in ck.variants["np"].generated.meta.contracted_arrays
    assert "w = " not in ck.source("np")


def test_contraction_rejected_for_nested_reduction():
    def keeps_library_calls(A: "ndarray[f64,2]", x: "ndarray[f64,1]",
                            out: "ndarray[f64,1]", N: int):
        T = np.dot(A[0:N, 0:N], A[0:N, 0:N])
        out[0:N] = np.dot(T[0:N, 0:N], x[0:N])

    ck = compile_kernel(keeps_library_calls, fuse=True)
    # substituting the dot into the second contraction would nest
    # reductions and break einsum raising: keep both library calls
    assert ck.sched.fusion.contracted_arrays == []
    assert ck.sched.fusion.rejected >= 1
    assert "T = " in ck.source("np")


def test_contraction_rejected_by_cost_gate_on_reuse():
    def expensive_twice(A: "ndarray[f64,2]", B: "ndarray[f64,2]",
                        out: "ndarray[f64,2]", N: int):
        T = np.dot(A[0:N, 0:N], B[0:N, 0:N])
        out[0:N, 0:N] = T[0:N, 0:N] * T[0:N, 0:N]

    ck = compile_kernel(expensive_twice, fuse=True)
    # two uses of an O(N³) producer: the roofline gate keeps the single
    # library call instead of computing the matmul twice
    assert ck.sched.fusion.contracted_arrays == []
    assert "T = " in ck.source("np")
    # a cheap elementwise producer IS duplicated (memory term dominates)
    assert cost.fusion_profitable(1e6, producer_flops_pp=1.0, uses=2)
    assert not cost.fusion_profitable(1e6, producer_flops_pp=512.0, uses=3)


# ---------------------------------------------------------------------------
# legality: illegal fusions must be rejected
# ---------------------------------------------------------------------------

def test_recurrence_not_vectorized():
    # reduction-carried dependence: vectorizing would read stale values
    def seq(a: "ndarray[f64,1]", N: int):
        for i in range(1, N):
            a[i] = a[i - 1] * 2.0

    sched = _units(seq)
    assert any(isinstance(u, SeqLoopUnit) for u in sched.units)
    ck = compile_kernel(seq, fuse=True)
    a = np.ones(9)
    want = a.copy()
    for i in range(1, 9):
        want[i] = want[i - 1] * 2.0
    ck.call_variant("np", a, 9)
    np.testing.assert_array_equal(a, want)


def test_forward_self_read_still_vectorizes():
    # forward reads observe original values either way → absorb is legal
    def fwd(a: "ndarray[f64,1]", N: int):
        for i in range(0, N - 1):
            a[i] = a[i + 1] * 2.0

    sched = _units(fwd)
    assert not any(isinstance(u, SeqLoopUnit) for u in sched.units)
    ck = compile_kernel(fwd, fuse=True)
    a = np.arange(8.0)
    want = a.copy()
    for i in range(0, 7):
        want[i] = want[i + 1] * 2.0
    ck.call_variant("np", a, 8)
    np.testing.assert_array_equal(a, want)


def test_anti_dependence_blocks_flow_fusion():
    # the consumer reads W at a *different* element than it writes: the
    # producer's store must stay visible, so no fusion
    def antidep(w: "ndarray[f64,1]", x: "ndarray[f64,1]", N: int):
        w[0:N] = x[0:N] * 2.0
        w[0:N] += w[N - 1] * np.ones(N)[0:N]

    sched = _units(antidep)
    raised = [u for u in sched.units if isinstance(u, RaisedUnit)]
    assert len(raised) >= 2 or sched.fusion.fused_units == 0


def test_aug_consumer_self_read_gets_producer_value():
    # `out = a+1; out += out*2` — the consumer's *explicit* read of out
    # must see the producer's value, not the pre-producer array
    def self_read(a: "ndarray[f64,1]", out: "ndarray[f64,1]", N: int):
        out[0:N] = a[0:N] + 1.0
        out[0:N] += out[0:N] * 2.0

    sched = _units(self_read, profile="functional")
    assert sched.fusion.fused_units == 1
    ck_f = compile_kernel(self_read, fuse=True)
    a = np.arange(4.0)
    for backend in [b for b in ("np", "jnp") if b in ck_f.variants]:
        out = np.zeros(4)
        ck_f.call_variant(backend, a, out, 4)
        np.testing.assert_allclose(out, (a + 1.0) * 3.0)


def test_interleaved_writer_blocks_fusion():
    # a unit between producer and consumer writes the producer's input:
    # folding the producer past it would read the wrong values
    def interleaved(a: "ndarray[f64,1]", b: "ndarray[f64,1]", N: int):
        b[0:N] = a[0:N] * 2.0
        a[0:N] = a[0:N] + 1.0
        b[0:N] += a[0:N]

    ck_f = compile_kernel(interleaved, fuse=True)
    ck_u = compile_kernel(interleaved, fuse=False)
    rng = np.random.default_rng(5)
    a = rng.normal(size=6)
    b = np.zeros(6)
    af, bf = a.copy(), b.copy()
    au, bu = a.copy(), b.copy()
    ck_f.call_variant("np", af, bf, 6)
    ck_u.call_variant("np", au, bu, 6)
    np.testing.assert_array_equal(bf, bu)
    np.testing.assert_array_equal(af, au)


# ---------------------------------------------------------------------------
# loop fusion
# ---------------------------------------------------------------------------

def test_adjacent_recurrence_loops_fuse():
    def two_loops(a: "ndarray[f64,1]", b: "ndarray[f64,1]", N: int):
        for i in range(1, N):
            a[i] = a[i - 1] + 1.0
        for i in range(1, N):
            b[i] = b[i - 1] * 2.0

    sched = _units(two_loops)
    loops = [u for u in sched.units if isinstance(u, SeqLoopUnit)]
    assert len(loops) == 1
    assert sched.fusion.loops_fused == 1
    ck = compile_kernel(two_loops, fuse=True)
    a, b = np.zeros(7), np.ones(7)
    wa, wb = a.copy(), b.copy()
    for i in range(1, 7):
        wa[i] = wa[i - 1] + 1.0
    for i in range(1, 7):
        wb[i] = wb[i - 1] * 2.0
    ck.call_variant("np", a, b, 7)
    np.testing.assert_array_equal(a, wa)
    np.testing.assert_array_equal(b, wb)


def test_loop_fusion_rejected_on_cross_iteration_dependence():
    # the second loop reads a[] at a different iteration: merging would
    # observe partially-updated values
    def cross(a: "ndarray[f64,1]", b: "ndarray[f64,1]", N: int):
        for i in range(1, N):
            a[i] = a[i - 1] + 1.0
        for i in range(1, N):
            b[i] = b[i - 1] + a[N - i]

    sched = _units(cross)
    loops = [u for u in sched.units if isinstance(u, SeqLoopUnit)]
    assert len(loops) == 2
    ck_f = compile_kernel(cross, fuse=True)
    ck_u = compile_kernel(cross, fuse=False)
    a0 = np.zeros(9)
    b0 = np.zeros(9)
    af, bf, au, bu = a0.copy(), b0.copy(), a0.copy(), b0.copy()
    ck_f.call_variant("np", af, bf, 9)
    ck_u.call_variant("np", au, bu, 9)
    np.testing.assert_array_equal(bf, bu)


# ---------------------------------------------------------------------------
# loop-fallback atomicity (codegen snapshot)
# ---------------------------------------------------------------------------

def test_loop_fallback_snapshots_self_reads():
    from repro.core.scop import CanonStmt, VAccess

    n = 8
    i = LoopDim("i", Affine.constant(0), Affine.constant(n))
    # a[i] = a[N-1-i]: the reversed (coeff -1) access defeats slice
    # raising → loop fallback, which must read a pre-statement snapshot
    stmt = CanonStmt(
        write_array="a", write_idx=(Affine.var("i"),),
        domain=scop.Domain((i,)),
        rhs=VAccess("a", (Affine.constant(n - 1) - Affine.var("i"),)))
    em = codegen.Emitter(None, "np")  # schedule unused by emit_raised
    em.emit_raised(codegen.RaisedUnit(stmt))
    assert "loop-fallback" in em.meta.raised_ops
    src = "def f(a):\n" + "\n".join(em.lines) + "\n"
    ns = {"xp": np}
    exec(compile(src, "<test>", "exec"), ns)
    a = np.arange(float(n))
    ns["f"](a)
    np.testing.assert_array_equal(a, np.arange(float(n))[::-1])


# ---------------------------------------------------------------------------
# telemetry + cache keying
# ---------------------------------------------------------------------------

def test_stats_expose_fusion_counters():
    ck = compile_kernel(CHAINS["smooth"]["np"], fuse=True)
    st = ck.stats()
    assert st["contracted_arrays"] == 1
    assert st["fused_units"] >= 1
    assert "bucket_hits" in st and "bucket_specs" in st


def test_cache_key_distinguishes_fusion(tmp_path):
    cache = VariantCache(str(tmp_path))
    fn = CHAINS["smooth"]["np"]
    compile_kernel(fn, fuse=True, cache=cache)
    compile_kernel(fn, fuse=False, cache=cache)
    assert len(cache.entries()) == 2  # distinct keys, no collision
    ck = compile_kernel(fn, fuse=True, cache=cache)
    assert ck.from_cache


# ---------------------------------------------------------------------------
# profile-guided threshold calibration
# ---------------------------------------------------------------------------

def test_calibrate_accel_threshold():
    default = cost.ACCEL_FLOP_THRESHOLD
    assert cost.calibrate_accel_threshold([]) == default
    # 1e9 flops in 10ms → 1e11 flop/s → threshold = 2ms × 1e11 = 2e8
    thr = cost.calibrate_accel_threshold([(1e9, 1e-2)])
    assert thr == pytest.approx(2e8)
    # a slow *original* must never lower the threshold below the static
    # default (its rate underestimates the np variant the threshold
    # actually arbitrates against)
    lo = cost.calibrate_accel_threshold([(1.0, 1e6)])
    assert lo == default
    hi = cost.calibrate_accel_threshold([(1e15, 1e-9)])
    assert hi == pytest.approx(default * 64)


def test_profiled_function_calibrates_threshold():
    def addmul(a: "ndarray[f64,1]", b: "ndarray[f64,1]", N: int):
        a[0:N] = a[0:N] + b[0:N] * 2.0

    pf = optimize(profile=True, warmup=3, enable_jax=False)(addmul)
    rng = np.random.default_rng(0)
    a, b = rng.normal(size=64), rng.normal(size=64)
    for _ in range(4):
        pf(a.copy(), b, 64)
    assert pf.compiled is not None
    d = cost.ACCEL_FLOP_THRESHOLD
    assert d <= pf.compiled.accel_threshold <= d * 64

    # explicit threshold wins over calibration
    pf2 = optimize(profile=True, warmup=2, enable_jax=False,
                   accel_threshold=123.0)(addmul)
    for _ in range(3):
        pf2(a.copy(), b, 64)
    assert pf2.compiled.accel_threshold == 123.0


# ---------------------------------------------------------------------------
# variant-cache pruning
# ---------------------------------------------------------------------------

def _entry(tag):
    return CacheEntry(fn_name=f"k{tag}", src_hash=f"h{tag}",
                      type_sig="a:int[None,None]", backend="np",
                      params=[], sched=None, generated={})


def test_cache_prune_lru(tmp_path):
    cache = VariantCache(str(tmp_path))
    for tag in range(5):
        cache.put(_entry(tag))
        time.sleep(0.01)
    cache.dump_index()
    # touch entry 0 so it becomes most-recently-used
    assert cache.get("h0", "a:int[None,None]", "np") is not None
    removed = cache.prune(max_entries=2)
    assert removed == 3
    assert cache.stats.pruned == 3
    assert len(cache.entries()) == 2
    # the touched entry survived LRU eviction
    assert cache.get("h0", "a:int[None,None]", "np") is not None
    assert cache.get("h1", "a:int[None,None]", "np") is None
    # evicted keys were filtered out of index.json (no rebuild needed)
    import json
    idx = json.load(open(os.path.join(str(tmp_path), "index.json")))
    assert len(idx) == 2
    assert {e["fn"] for e in idx} == {"k0", "k4"}
    assert all("last_used" in e for e in idx)


def test_cache_prune_age_and_autocap(tmp_path):
    cache = VariantCache(str(tmp_path))
    for tag in range(3):
        cache.put(_entry(tag))
    old = os.path.join(str(tmp_path), cache.entries()[0] + ".pkl")
    past = time.time() - 3600
    os.utime(old, (past, past))
    assert cache.prune(max_age_s=600) == 1
    assert len(cache.entries()) == 2
    # auto-prune on put keeps the store within max_entries
    capped = VariantCache(str(tmp_path / "capped"), max_entries=2)
    for tag in range(4):
        capped.put(_entry(tag))
        time.sleep(0.01)
    assert len(capped.entries()) == 2


# ---------------------------------------------------------------------------
# allocator-cost term in the fusion gate
# ---------------------------------------------------------------------------

def test_alloc_cost_per_backend():
    from repro.core import cost

    nbytes = 1 << 20
    # np temps pay malloc + first-touch faults; jnp's arena is cheaper
    assert cost.alloc_cost_s("np", nbytes) > cost.alloc_cost_s(
        "jnp", nbytes)
    assert cost.alloc_cost_s("np", 0) == cost.ALLOC_BASE_S["np"]


def test_fusion_gate_alloc_term_flips_np_decision():
    from repro.core import cost

    # pick (points, flops_pp, uses) near the old break-even: the memory
    # term alone says "don't fuse", the eliminated np allocation says
    # "fuse" — the elem_chain anomaly's regime
    pts, uses = 4096.0, 3
    bw_only_saved = (1 + uses) * pts * 8 / cost.HOST_CPU.hbm_bw
    alloc_np = cost.alloc_cost_s("np", pts * 8)
    # flops_pp sized between the two thresholds
    flops_pp = (bw_only_saved + 0.5 * alloc_np) * cost.HOST_CPU.peak_flops \
        / ((uses - 1) * pts)
    assert cost.fusion_profitable(pts, flops_pp, uses, backend="np")
    assert not cost.fusion_profitable(pts, flops_pp, uses, backend="jnp")


def test_single_use_contraction_always_fuses():
    from repro.core import cost

    assert cost.fusion_profitable(1e9, 1e6, 1, backend="np")
    assert cost.fusion_profitable(1e9, 1e6, 1, backend="jnp")
