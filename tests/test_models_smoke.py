"""Per-architecture smoke tests: reduced same-family config, one
forward/train step on CPU, asserting output shapes and no NaNs."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCHS, get_config, get_smoke_config
from repro.models import transformer as T
from repro.train import AdamWConfig, init_opt_state, make_train_step

B, S = 2, 16

# Big-config smokes dominate suite wall time; the small trio keeps every
# code path (dense / MoE / recurrent) in the fast tier-1 run and the rest
# runs under `pytest -m slow`.
_FAST_ARCHS = {"stablelm_3b", "xlstm_125m", "olmoe_1b_7b"}


def _arch_params(archs):
    return [a if a in _FAST_ARCHS
            else pytest.param(a, marks=pytest.mark.slow)
            for a in archs]


def _batch(cfg):
    batch = {"labels": jnp.ones((B, S), jnp.int32)}
    if cfg.embeds_input:
        batch["embeds"] = jnp.ones((B, S, cfg.d_model), jnp.float32) * 0.01
    else:
        batch["tokens"] = jnp.zeros((B, S), jnp.int32)
    if cfg.is_encdec:
        batch["src_embeds"] = jnp.ones((B, S, cfg.d_model),
                                       jnp.float32) * 0.01
    return batch


@pytest.mark.parametrize("arch", _arch_params(ARCHS))
def test_smoke_forward_loss(arch):
    cfg = get_smoke_config(arch)
    params, specs = T.init_params(cfg, jax.random.key(0))
    loss = T.loss_fn(params, _batch(cfg), cfg)
    assert loss.shape == ()
    assert not bool(jnp.isnan(loss)), f"{arch}: NaN loss"
    assert float(loss) > 0


@pytest.mark.parametrize("arch", _arch_params(ARCHS))
def test_smoke_train_step(arch):
    cfg = get_smoke_config(arch)
    params, _ = T.init_params(cfg, jax.random.key(1))
    opt = init_opt_state(params, AdamWConfig())
    step = make_train_step(cfg)
    p2, o2, metrics = step(params, opt, _batch(cfg))
    assert not bool(jnp.isnan(metrics["loss"]))
    assert int(o2.step) == 1
    # at least one parameter changed
    moved = any(
        bool(jnp.any(a != b))
        for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)))
    assert moved, f"{arch}: no parameter update"


@pytest.mark.parametrize("arch", _arch_params(
    ["stablelm_3b", "gemma2_2b", "jamba_1_5_large_398b", "xlstm_125m",
     "seamless_m4t_medium"]))
def test_smoke_prefill_decode(arch):
    cfg = get_smoke_config(arch)
    params, _ = T.init_params(cfg, jax.random.key(2))
    batch = {"tokens": jnp.arange(8, dtype=jnp.int32)[None] % cfg.vocab}
    if cfg.is_encdec:
        batch["src_embeds"] = jnp.ones((1, 12, cfg.d_model),
                                       jnp.float32) * 0.01
    caches, logits = T.prefill(params, batch, cfg, max_seq=24)
    assert logits.shape[-1] == cfg.padded_vocab(16)
    tok = logits.argmax(-1)[:, None].astype(jnp.int32)
    logits2, caches = T.decode_step(params, tok, caches, cfg)
    assert not bool(jnp.isnan(logits2).any()), f"{arch}: NaN decode"


def test_decode_matches_forward_stablelm():
    """Incremental decode == full forward at each position."""
    cfg = get_smoke_config("stablelm_3b")
    params, _ = T.init_params(cfg, jax.random.key(3))
    toks = jnp.asarray([[5, 9, 2, 7, 1, 3]], jnp.int32)
    caches, logits = T.prefill(params, {"tokens": toks[:, :3]}, cfg,
                               max_seq=12)
    # decode the 4th token and compare against a fresh prefill of 4
    l_dec, caches = T.decode_step(params, toks[:, 3:4], caches, cfg)
    _, l_full = T.prefill(params, {"tokens": toks[:, :4]}, cfg,
                          max_seq=12)
    assert bool(jnp.allclose(l_dec, l_full, atol=2e-2)), \
        float(jnp.abs(l_dec - l_full).max())


def test_full_configs_match_assignment():
    """The exact assigned dimensions (no reduction) per the public table."""
    expect = {
        "seamless_m4t_medium": (12, 1024, 16, 16, 4096, 256206),
        "olmoe_1b_7b": (16, 2048, 16, 16, 1024, 50304),
        "qwen2_moe_a2_7b": (24, 2048, 16, 16, 1408, 151936),
        "qwen1_5_110b": (80, 8192, 64, 8, 49152, 152064),
        "nemotron_4_340b": (96, 18432, 96, 8, 73728, 256000),
        "gemma2_2b": (26, 2304, 8, 4, 9216, 256000),
        "stablelm_3b": (32, 2560, 32, 32, 6912, 50304),
        "llava_next_mistral_7b": (32, 4096, 32, 8, 14336, 32000),
        "jamba_1_5_large_398b": (72, 8192, 64, 8, 24576, 65536),
        "xlstm_125m": (12, 768, 4, 4, 0, 50304),
    }
    for arch, (L, d, h, kv, ff, v) in expect.items():
        cfg = get_config(arch)
        assert (cfg.layers, cfg.d_model, cfg.n_heads, cfg.kv_heads,
                cfg.d_ff, cfg.vocab) == (L, d, h, kv, ff, v), arch


def test_moe_expert_padding():
    cfg = get_config("qwen2_moe_a2_7b")
    from repro.models.moe import padded_experts

    assert cfg.n_experts == 60
    assert padded_experts(cfg, 16) == 64  # legality branch: 60 → 64


def test_vocab_padding():
    cfg = get_config("seamless_m4t_medium")
    assert cfg.vocab == 256206
    vp = cfg.padded_vocab(16)
    assert vp % (16 * 128) == 0 and vp >= cfg.vocab
