"""Observability plane tests: span recorder (nesting, threads,
cross-thread tokens, ring bound), clock-offset alignment, Chrome-trace
export schema, the summarize analyzer, and the unified metrics registry
backing the legacy stats()/telemetry() surfaces."""

import json
import threading
import time

import numpy as np
import pytest

from repro import obs
from repro.core.compiler import compile_kernel
from repro.distrib import ClusterRuntime
from repro.distrib.cluster import _WorkerHandle
from repro.obs import summarize
from repro.obs.metrics import Counter, DictMetric, MetricsRegistry
from repro.obs.spans import SpanRecorder


@pytest.fixture
def traced():
    """Tracing on with a clean ring; restores the dark default after."""
    was = obs.enabled()
    obs.enable()
    obs.recorder().clear()
    yield obs.recorder()
    obs.recorder().clear()
    if not was:
        obs.disable()


# ---------------------------------------------------------------------------
# span recorder
# ---------------------------------------------------------------------------

def test_span_dark_by_default_costs_nothing():
    assert not obs.enabled()
    rec = obs.recorder()
    n = len(rec)
    # the no-op context manager is a shared singleton and records nothing
    s1 = obs.span("x", cat="t")
    s2 = obs.span("y", cat="t")
    assert s1 is s2
    with s1:
        pass
    assert obs.begin("x") is None
    obs.end(None)          # safe on the dark-path token
    assert len(rec) == n


def test_span_nesting_and_args(traced):
    with obs.span("outer", cat="t", round=1):
        with obs.span("inner", cat="t", task=7):
            time.sleep(0.001)
    evs = {e.name: e for e in traced.events()}
    assert set(evs) == {"outer", "inner"}
    # inner closed first and nests strictly inside outer
    assert evs["outer"].t0 <= evs["inner"].t0
    assert evs["inner"].t1 <= evs["outer"].t1
    assert evs["inner"].dur > 0
    assert evs["outer"].args == {"round": 1}
    assert evs["inner"].args == {"task": 7}
    # both ran on the same (main) thread → same track
    assert evs["outer"].tid == evs["inner"].tid


def test_spans_from_threads_get_distinct_tracks(traced):
    def work():
        with obs.span("worker_side", cat="t"):
            time.sleep(0.001)

    with obs.span("main_side", cat="t"):
        th = threading.Thread(target=work, name="helper")
        th.start()
        th.join()
    evs = {e.name: e for e in traced.events()}
    assert evs["main_side"].tid != evs["worker_side"].tid
    names = traced.track_names()
    assert any(v.startswith("head:") for v in names.values())


def test_cross_thread_token_and_idempotent_end(traced):
    tok = obs.begin("inflight", cat="t", task=3)
    done = threading.Event()

    def finisher():
        obs.end(tok, wid=1)
        done.set()

    threading.Thread(target=finisher).start()
    assert done.wait(5.0)
    obs.end(tok, wid=9)      # second end: a no-op, not a second event
    evs = [e for e in traced.events() if e.name == "inflight"]
    assert len(evs) == 1
    assert evs[0].args == {"task": 3, "wid": 1}


def test_ring_buffer_bounds_memory():
    rec = SpanRecorder(capacity=16)
    assert rec.capacity == 16
    for i in range(40):
        rec.record(f"e{i}", "t", 0.0, 1.0)
    assert len(rec) == 16
    assert rec.dropped == 24
    # oldest events were the ones evicted
    assert [e.name for e in rec.events()][0] == "e24"
    rec.clear()
    assert len(rec) == 0 and rec.dropped == 0


def test_enable_resizes_only_on_change(traced):
    rec = obs.recorder()
    rec.record("keep", "t", 0.0, 1.0)
    obs.enable()                     # same capacity: ring untouched
    assert [e.name for e in obs.recorder().events()] == ["keep"]


# ---------------------------------------------------------------------------
# clock alignment
# ---------------------------------------------------------------------------

def test_worker_clock_offset_takes_min_over_samples():
    wh = _WorkerHandle(0, None, None)
    assert wh.clock_offset is None
    wh.note_clock(time.perf_counter() - 0.010)   # slow handshake
    first = wh.clock_offset
    assert first == pytest.approx(0.010, abs=0.005)
    wh.note_clock(time.perf_counter() - 0.001)   # tighter sample wins
    assert wh.clock_offset < first
    wh.note_clock(time.perf_counter() - 0.020)   # looser sample ignored
    assert wh.clock_offset < first


def test_record_external_aligns_remote_clock(traced):
    # worker clock with a wildly different epoch (fresh process)
    skew = 123.456
    wh = _WorkerHandle(1, None, None)
    wh.note_clock(time.perf_counter() - skew)
    r0 = time.perf_counter() - skew          # remote span start = "now"
    busy = traced.record_external(
        [("run", r0, r0 + 0.002, {"note": "remote"})],
        offset=wh.clock_offset, pid=0, tid=obs.worker_tid(1),
        base_args={"wid": 1, "task": 5})
    assert busy == pytest.approx(0.002, abs=1e-9)
    ev = traced.events()[-1]
    assert ev.cat == "worker" and ev.tid == obs.worker_tid(1)
    # landed on the head timeline within the handshake latency
    assert abs(ev.t0 - time.perf_counter()) < 0.1
    assert ev.args == {"note": "remote", "wid": 1, "task": 5}


# ---------------------------------------------------------------------------
# Chrome-trace export + summarize
# ---------------------------------------------------------------------------

def _synthetic_round(rec):
    """A hand-built pfor round: head phases + one chunk on worker 1."""
    t = 100.0
    rec.name_node(0, "head-node")
    rec.name_track(0, obs.worker_tid(1), "worker1")
    rec.record("plan", "pfor", t, t + 0.01, args={"round": 0})
    rec.record("dispatch", "pfor", t + 0.01, t + 0.02, args={"round": 0})
    rec.record("run", "worker", t + 0.02, t + 0.08,
               tid=obs.worker_tid(1),
               args={"task": 1, "wid": 1, "round": 0, "lo": 0, "hi": 8,
                     "backend": "np"})
    rec.record("chunk_inflight", "pfor", t + 0.015, t + 0.085,
               tid=obs.worker_tid(1),
               args={"round": 0, "task": 1, "lo": 0, "hi": 8,
                     "backend": "np", "wid": 1, "ran": "np"})
    rec.record("gather", "pfor", t + 0.08, t + 0.095, args={"round": 0})
    rec.record("pfor_round", "pfor", t, t + 0.1,
               args={"round": 0, "name": "body", "unit": 0, "chunks": 1,
                     "workers": 1})
    rec.record("parse", "compile", t - 1.0, t - 0.99,
               args={"kernel": "k"})


def test_chrome_trace_schema_roundtrip(tmp_path, traced):
    _synthetic_round(traced)
    path = str(tmp_path / "trace.json")
    obs.export_chrome_trace(path, extra_meta={"suite": "test"})
    doc = json.load(open(path))
    assert doc["displayTimeUnit"] == "ms"
    assert doc["otherData"]["suite"] == "test"
    assert doc["otherData"]["dropped"] == 0
    evs = doc["traceEvents"]
    meta = [e for e in evs if e["ph"] == "M"]
    xs = [e for e in evs if e["ph"] == "X"]
    assert {m["name"] for m in meta} >= {"process_name", "thread_name"}
    assert any(m["args"].get("name") == "worker1" for m in meta)
    for e in xs:
        assert e["ts"] >= 0 and e["dur"] >= 0      # µs from min-t0 epoch
        assert {"pid", "tid", "cat", "name"} <= set(e)
    # timestamps re-based: earliest X event sits at the epoch
    assert min(e["ts"] for e in xs) == 0
    inflight = next(e for e in xs if e["name"] == "chunk_inflight")
    assert inflight["tid"] == obs.worker_tid(1)
    assert inflight["args"]["lo"] == 0


def test_summarize_reads_exported_trace(tmp_path, traced, capsys):
    _synthetic_round(traced)
    path = str(tmp_path / "trace.json")
    obs.export_chrome_trace(path)
    s = summarize.summarize(summarize.load_events(path))
    assert s["rounds_traced"] == 1
    assert s["workers"]["w1"]["run_spans"] == 1
    assert s["workers"]["w1"]["busy_s"] == pytest.approx(0.06, abs=1e-6)
    assert s["compile"]["k"]["parse"] == pytest.approx(0.01, abs=1e-6)
    [cp] = s["critical_paths"]
    assert cp["gating_chunk"]["wid"] == 1
    assert "% of round wall" in s["dominant"]["statement"]
    # the CLI contract the CI smoke relies on: exit 0, valid --json
    assert summarize.main([path, "--json"]) == 0
    out = json.loads(capsys.readouterr().out)
    assert out["dominant"]["phase"] in ("plan", "dispatch", "gather",
                                        "split", "ship", "merge")


def test_summarize_rejects_garbage(tmp_path):
    bad = tmp_path / "bad.json"
    bad.write_text("{not json")
    assert summarize.main([str(bad)]) == 2
    assert summarize.main([str(tmp_path / "missing.json")]) == 2


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------

def test_registry_scopes_and_snapshot():
    reg = MetricsRegistry()
    sc = reg.unique_scope("thing")
    sc2 = reg.unique_scope("thing")
    assert sc.prefix == "thing#0" and sc2.prefix == "thing#1"
    sc.inc("hits")
    sc.inc("hits", 2)
    sc.add_time("busy_s", 0.25)
    sc.dictmetric("routes")["np"] = 3
    assert sc.snapshot() == {"hits": 3, "busy_s": 0.25,
                             "routes": {"np": 3}}
    # prefix isolation: the sibling scope saw nothing
    assert sc2.snapshot() == {}
    # full-registry view keeps dotted names
    assert reg.snapshot()["thing#0.hits"] == 3


def test_registry_reset_keeps_live_references():
    reg = MetricsRegistry()
    sc = reg.scope("rt")
    c = sc.counter("n")
    d = sc.dictmetric("m")
    c.inc(5)
    d["k"] = 1
    reg.reset("rt")
    assert c.value == 0 and dict(d) == {}
    # the *same* objects are still registered — live holders keep working
    assert sc.counter("n") is c and sc.dictmetric("m") is d
    c.inc()
    assert reg.snapshot("rt")["n"] == 1


def test_registry_kind_conflict_raises():
    reg = MetricsRegistry()
    reg.scope("a").counter("x")
    with pytest.raises(TypeError):
        reg.scope("a").gauge("x")


def test_counter_threaded_increments():
    c = Counter()

    def bump():
        for _ in range(500):
            c.inc()

    threads = [threading.Thread(target=bump) for _ in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert c.value == 2000


def test_serve_engine_counters_alias_registry():
    from repro.serve.engine import ServeEngine

    eng = ServeEngine.__new__(ServeEngine)   # no model build needed
    eng.ticks = 0
    eng.ticks += 3
    eng.prefills = 2
    prefix = eng._mscope.prefix
    assert prefix.startswith("serveengine")
    assert eng.ticks == 3
    assert obs.metrics.get(f"{prefix}.ticks").value == 3
    assert obs.metrics.get(f"{prefix}.prefills").value == 2


def _twice(x: "ndarray[f64,1]", out: "ndarray[f64,1]", n: int):
    for i in range(0, n):
        out[i] = x[i] * 2.0


def test_compiled_kernel_stats_backed_by_registry():
    ck = compile_kernel(_twice, distribute=False)
    x = np.arange(4.0)
    out = np.zeros(4)
    ck(x, out, 4)
    assert np.allclose(out, x * 2)
    st = ck.stats()
    called = next(name for name, row in st["variants"].items()
                  if row["calls"] == 1)
    assert st["variants"][called]["total_s"] > 0
    reg_view = obs.metrics.snapshot(ck._mscope.prefix)
    assert reg_view[f"variants.{called}.calls"] == 1
    # legacy attribute writes land in the registry too
    ck.spec_hits += 4
    assert obs.metrics.snapshot(ck._mscope.prefix)["spec_hits"] == 4
    assert ck.stats()["spec_hits"] == 4


# ---------------------------------------------------------------------------
# live cluster: spans + registry end to end
# ---------------------------------------------------------------------------

def _obs_stap(A: "ndarray[f64,2]", s: "ndarray[f64,1]",
              out: "ndarray[f64,1]", N: int, M: int, iters: int):
    for i in range(0, N):
        w = 0.1 * s[0:M]
        for it in range(0, iters):
            w = w + 0.1 * (s[0:M] - A[i, 0:M] * w[0:M])
        out[i] = np.dot(w[0:M], A[i, 0:M])


def test_live_cluster_trace_covers_every_chunk(tmp_path, traced):
    rng = np.random.default_rng(11)
    N, M, iters = 32, 16, 8
    A = rng.normal(size=(N, M)) * 0.1
    s = rng.normal(size=M)
    out_ref = np.zeros(N)
    _obs_stap(A, s, out_ref, N, M, iters)

    path = str(tmp_path / "cluster_trace.json")
    rt = ClusterRuntime(workers=2, trace=path)
    try:
        ck = compile_kernel(_obs_stap, runtime=rt)
        assert ck.sched.has_pfor
        ck.pfor_config.distribute_threshold = 0
        out = np.zeros(N)
        ck.call_variant("np", A, s, out, N, M, iters)
        assert np.allclose(out, out_ref, atol=1e-12)

        st = rt.stats()
        assert st["chunks_dispatched"] > 0
        # legacy stats keys alias the runtime's registry scope
        prefix = rt._mscope.prefix
        reg = obs.metrics.snapshot(prefix)
        assert reg["chunks_dispatched"] == st["chunks_dispatched"]
        assert reg["bytes_shipped"] == st["bytes_shipped"]
        assert rt.chunks_dispatched == st["chunks_dispatched"]

        evs = traced.events()
        inflight = [e for e in evs if e.name == "chunk_inflight"]
        runs = [e for e in evs if e.cat == "worker" and e.name == "run"]
        assert len(inflight) == st["chunks_dispatched"]
        # every dispatched chunk produced a worker-side run span, keyed
        # by the same (task, lo, hi)
        run_keys = {(e.args["task"], e.args["lo"], e.args["hi"])
                    for e in runs}
        for e in inflight:
            key = (e.args["task"], e.args["lo"], e.args["hi"])
            assert key in run_keys, f"chunk {key} has no worker span"
            assert e.args["wid"] in (0, 1)
            # aligned onto the head clock: worker span nests inside its
            # in-flight envelope (offset ≤ one handshake latency)
            rspan = next(r for r in runs
                         if (r.args["task"], r.args["lo"],
                             r.args["hi"]) == key)
            assert rspan.t0 >= e.t0 - 0.05
            assert rspan.t1 <= e.t1 + 0.05
        # round accounting made it into the phase counters
        ph = rt.phase_breakdown()
        assert ph["round_s"] > 0 and ph["compute_s"] > 0
        assert ph["gather_s"] > 0
        assert rt.telemetry()["phases"] == ph
    finally:
        rt.shutdown()

    # shutdown exported the Perfetto trace; the analyzer accepts it and
    # sees every worker compute
    assert summarize.main([path, "--json"]) == 0
    s_doc = summarize.summarize(summarize.load_events(path))
    assert s_doc["rounds_traced"] >= 1
    for w, row in s_doc["workers"].items():
        assert row["run_spans"] > 0, f"{w} has no compute spans"
